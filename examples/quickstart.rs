//! Quickstart: replicate an in-memory KV store with Tempo across 3
//! simulated EC2 sites, submit a handful of commands, and print the
//! linearized results.
//!
//! Run with: `cargo run --release --example quickstart`

use tempo::check::assert_psmr;
use tempo::core::Config;
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::store::KvStore;
use tempo::workload::ConflictWorkload;

fn main() {
    // 3 replicas (Ireland, N. California, Singapore), f = 1.
    let config = Config::new(3, 1);
    let mut opts = SimOpts::new(Topology::ec2_three());
    opts.clients_per_site = 4;
    opts.warmup_us = 0;
    opts.duration_us = 2_000_000; // 2 s of simulated time
    opts.drain_us = 2_000_000;
    opts.seed = 7;
    opts.record_execution = true;

    // 10% of commands hit the same key and therefore conflict.
    let result = run::<Tempo, _>(config.clone(), opts, ConflictWorkload::new(0.1, 100));

    println!("Tempo quickstart — 3 sites, f=1, 2s simulated");
    println!(
        "  completed ops: {}  mean latency: {:.1} ms  p99: {:.1} ms",
        result.metrics.ops,
        result.metrics.latency.mean() / 1e3,
        result.metrics.latency.quantile(0.99) as f64 / 1e3
    );
    println!(
        "  fast path: {} slow path: {}",
        result.metrics.counters.fast_path, result.metrics.counters.slow_path
    );

    // Replay each replica's execution log into a KV store: all replicas
    // must converge to the same state (that's what SMR is for).
    let submitted: std::collections::HashMap<_, _> =
        result.submitted.iter().map(|(d, c)| (*d, c.clone())).collect();
    let digests: Vec<u64> = result
        .execution_logs
        .iter()
        .map(|log| {
            let mut store = KvStore::new();
            for (dot, _) in log {
                store.execute(&submitted[dot]);
            }
            store.digest()
        })
        .collect();
    println!("  replica state digests: {digests:x?}");
    assert!(digests.windows(2).all(|w| w[0] == w[1]), "replicas diverged!");

    // And the full PSMR specification holds.
    assert_psmr(&config, &result, true);
    println!("  PSMR check: OK (validity, per-key order, real-time, liveness)");
}
