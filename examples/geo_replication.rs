//! Geo-replication scenario (the paper's Fig. 5 setting, abridged):
//! compare the per-site latency of leaderless Tempo against leader-based
//! FPaxos over the 5 EC2 regions of Table 2.
//!
//! Run with: `cargo run --release --example geo_replication`

use tempo::bench_util::{latency_opts, ms};
use tempo::core::Config;
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, topology::EC2_SITES, Topology};
use tempo::workload::ConflictWorkload;

fn main() {
    let clients = 32;
    let conflicts = 0.02;

    let tempo_res = run::<Tempo, _>(
        Config::new(5, 1),
        latency_opts(Topology::ec2(), clients, 1),
        ConflictWorkload::new(conflicts, 100),
    );
    let fpaxos_res = run::<FPaxos, _>(
        Config::new(5, 1),
        latency_opts(Topology::ec2(), clients, 1),
        ConflictWorkload::new(conflicts, 100),
    );

    println!("Per-site mean latency (ms), f=1, 2% conflicts, 5 EC2 sites:");
    println!("{:<14} {:>10} {:>10}", "site", "tempo", "fpaxos");
    for (site, name) in EC2_SITES.iter().enumerate() {
        let t = tempo_res.metrics.site_latency.get(&site).map(|h| h.mean() as u64).unwrap_or(0);
        let f = fpaxos_res.metrics.site_latency.get(&site).map(|h| h.mean() as u64).unwrap_or(0);
        println!("{name:<14} {:>10} {:>10}", ms(t), ms(f));
    }
    let t_mean = tempo_res.metrics.latency.mean();
    let f_mean = fpaxos_res.metrics.latency.mean();
    println!("\naverage: tempo {:.1} ms, fpaxos {:.1} ms", t_mean / 1e3, f_mean / 1e3);
    println!(
        "fpaxos leader-site vs worst-site spread: {:.1}x (tempo is uniform — the fairness\n\
         argument of the paper's Fig. 5)",
        fpaxos_res.metrics.site_latency.values().map(|h| h.mean()).fold(0.0, f64::max)
            / fpaxos_res.metrics.site_latency.values().map(|h| h.mean()).fold(f64::MAX, f64::min)
    );
}
