//! End-to-end driver (DESIGN.md requirement): a REAL Tempo cluster —
//! three full nodes with real TCP sockets on localhost, each running the
//! production state machine, the wire codec, the tick loop and an
//! in-memory KV store — serving REAL request/response clients: every
//! client is a `TcpClient` session on its own socket, sending
//! `ClientSubmit` frames (docs/WIRE.md tag 17) and blocking for the
//! matching `ClientReply` (tag 18). We report throughput and the latency
//! distribution, verify the replicas' stores converged (Merkle-rooted
//! per-slot digests), check the steady-state frame pool actually hits,
//! and — the response-validity half — check a sequential client's
//! responses byte-for-byte against a local KvStore oracle.
//!
//! Run with: `cargo run --release --example e2e_cluster`
//!
//! **`--sweep-workers`**: the e2e TCP benchmark the deterministic
//! simulator cannot provide (it drives worker slots round-robin on one
//! thread): boot the same cluster at `--workers` 1/2/4 with per-slot
//! batching on, drive pipelined load over real sockets, and report
//! ops/s plus the byte-path counters — `frames_merged` verifying that
//! the per-peer outbound merger collapses the ≤ workers per-slot MBatch
//! flushes of a tick back into ~1 wire frame per (peer, tick),
//! regardless of `--workers`.
//!
//! **`--kill-node`**: client-failover mode — a pipelined `TcpClient`
//! drives RMW traffic at node 0, node 0 is shut down with a full window
//! of requests unacked, and the session fails over to node 1 re-issuing
//! the lot with their original rids. The replicas' per-client dedup
//! window (`Config::dedup_window`) must absorb every copy the dead
//! coordinator already pushed through the protocol: the mode proves
//! exactly-once end to end by checking that a private RMW counter key
//! advanced by exactly one step per acknowledged request — no lost and
//! no duplicate executions — and that every rid completed exactly once
//! at the client.
//!
//! **`--read-pct N`**: the stability-powered local-read mode — a
//! read-heavy zipf mix (`ZipfWorkload::with_read_ratio`) over real TCP
//! with 2 worker slots per node, asserting that every `Op::Read` is
//! served at its coordinator from the stability frontier: the summed
//! `local_reads` counter matches the reads the clients sent, nothing
//! degrades to the ordering path, and the read path puts zero bytes on
//! the wire.
//!
//! Results recorded in EXPERIMENTS.md §E2E.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use tempo::client::Session;
use tempo::core::{ClientId, Command, Config, Op, ProcessId, StorageMode};
use tempo::metrics::Histogram;
use tempo::net::{local_addrs, start_node, start_node_in, NodeHandle, TcpClient};
use tempo::store::KvStore;
use tempo::util::{Rng, Zipf};

fn boot_cluster(
    r: usize,
    config: &Config,
) -> tempo::util::error::Result<(Vec<NodeHandle>, Vec<String>)> {
    let addrs = local_addrs(r)?;
    // Nodes dial each other inside start_node, so they must boot in
    // parallel (like real processes would).
    let nodes: Vec<_> = (0..r as u32)
        .map(|i| {
            let config = config.clone();
            let addrs = addrs.clone();
            std::thread::spawn(move || {
                start_node(ProcessId(i), config, addrs)
                    .unwrap_or_else(|e| panic!("node {i}: {e:#}"))
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|t| t.join().unwrap())
        .collect();
    std::thread::sleep(Duration::from_millis(300)); // mesh up
    Ok((nodes, addrs))
}

/// Closed-loop zipfian load from `clients_per_node` TCP clients per node
/// for `duration`; returns total completed ops.
fn drive_load(
    addrs: &[String],
    clients_per_node: usize,
    duration: Duration,
    hist: Option<&Arc<std::sync::Mutex<Histogram>>>,
) -> u64 {
    let ops = Arc::new(AtomicU64::new(0));
    let deadline = Instant::now() + duration;
    std::thread::scope(|scope| {
        for (n, addr) in addrs.iter().enumerate() {
            for c in 0..clients_per_node {
                let ops = ops.clone();
                scope.spawn(move || {
                    let client = ClientId((n * 100 + c) as u64);
                    let mut tc = match TcpClient::connect(addr, client) {
                        Ok(tc) => tc,
                        Err(e) => panic!("client {client:?}: connect: {e:#}"),
                    };
                    tc.set_timeout(Some(Duration::from_secs(5))).expect("timeout");
                    let mut rng = Rng::new((n * 100 + c) as u64 + 1);
                    let zipf = Zipf::new(10_000, 0.7);
                    while Instant::now() < deadline {
                        let key = zipf.sample(&mut rng);
                        let op = if rng.gen_bool(0.5) { Op::Rmw } else { Op::Get };
                        let t0 = Instant::now();
                        match tc.submit_single(key, op, 100) {
                            Ok(_) => {
                                ops.fetch_add(1, Ordering::Relaxed);
                                if let Some(h) = hist {
                                    h.lock()
                                        .unwrap()
                                        .record(t0.elapsed().as_micros() as u64);
                                }
                            }
                            Err(e) => {
                                eprintln!("client {client:?}: {e:#}; stopping");
                                break;
                            }
                        }
                    }
                });
            }
        }
    });
    ops.load(Ordering::Relaxed)
}

/// `--sweep-workers`: real-thread scaling + frame-merging validation
/// over TCP, the measurement the single-threaded simulator cannot make.
fn sweep_workers() -> tempo::util::error::Result<()> {
    let r = 3usize;
    let duration = Duration::from_secs(3);
    let clients_per_node = 8;
    println!(
        "--- e2e --sweep-workers ({r} nodes, {} closed-loop TCP clients, \
         {}s per cell, per-slot batching on) ---",
        r * clients_per_node,
        duration.as_secs()
    );
    println!(
        "{:>7} {:>10} {:>12} {:>12} {:>14} {:>12}",
        "workers", "ops/s", "wire frames", "merged away", "members/frame", "pool hit%"
    );
    for workers in [1usize, 2, 4] {
        // Batching gives each worker slot one MBatch per (peer, tick);
        // the per-peer merger below the slots must then restore ~one
        // frame per (peer, tick) regardless of the worker count.
        let config = Config::new(r, 1)
            .with_tick_interval_us(1_000)
            .with_workers(workers)
            .with_batching(64);
        // Pool counters are process-wide and monotone; snapshot before
        // the cell so the hit rate below is this cell's alone.
        let hits0 = tempo::net::wire::pool_stats::hits();
        let misses0 = tempo::net::wire::pool_stats::misses();
        let (nodes, addrs) = boot_cluster(r, &config)?;
        let total = drive_load(&addrs, clients_per_node, duration, None);
        std::thread::sleep(Duration::from_millis(500)); // drain
        let mut wire_frames = 0u64;
        let mut merged = 0u64;
        for n in &nodes {
            wire_frames += n.wire_frames();
            merged += n.counters().frames_merged;
        }
        let pool_pct = {
            let hits = (tempo::net::wire::pool_stats::hits() - hits0) as f64;
            let misses = (tempo::net::wire::pool_stats::misses() - misses0) as f64;
            100.0 * hits / (hits + misses).max(1.0)
        };
        let members_per_frame = (wire_frames + merged) as f64 / wire_frames.max(1) as f64;
        println!(
            "{workers:>7} {:>10.0} {wire_frames:>12} {merged:>12} \
             {members_per_frame:>14.2} {pool_pct:>11.1}%",
            total as f64 / duration.as_secs_f64()
        );
        assert!(total > 0, "no ops at workers={workers}");
        if workers > 1 {
            // The acceptance claim: per-worker batchers emit up to
            // `workers` MBatch frames per (peer, tick); the per-peer
            // merger coalesces them, so merged frames must be observed
            // and carry >1 member on average once slots multiply.
            assert!(
                merged > 0,
                "workers={workers}: the per-peer merger never coalesced frames"
            );
        }
        for n in nodes {
            n.shutdown();
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    println!(
        "sweep OK: members/frame grows with --workers while wire frames per \
         (peer, tick) stay ~1 — the merger undoes the per-slot frame split."
    );
    Ok(())
}

/// `--read-pct N`: read-heavy mixes over real TCP with sharded worker
/// slots; every read must serve locally from the stability frontier.
fn read_mix(read_pct: u32) -> tempo::util::error::Result<()> {
    use tempo::workload::{Workload, ZipfWorkload};
    assert!(read_pct <= 100, "--read-pct takes 0..=100");
    let r = 3usize;
    let duration = Duration::from_secs(3);
    let clients_per_node = 8;
    // Two worker slots: a read must route to the slot owning its key and
    // still serve locally with the protocol state sharded across threads.
    let config = Config::new(r, 1).with_tick_interval_us(1_000).with_workers(2);
    println!(
        "--- e2e --read-pct {read_pct} ({r} nodes, 2 worker slots each, {} \
         closed-loop TCP clients, {}s) ---",
        r * clients_per_node,
        duration.as_secs()
    );
    let (nodes, addrs) = boot_cluster(r, &config)?;
    let ops = Arc::new(AtomicU64::new(0));
    let reads_sent = Arc::new(AtomicU64::new(0));
    let deadline = Instant::now() + duration;
    std::thread::scope(|scope| {
        for (n, addr) in addrs.iter().enumerate() {
            for c in 0..clients_per_node {
                let ops = ops.clone();
                let reads_sent = reads_sent.clone();
                scope.spawn(move || {
                    let client = ClientId((n * 100 + c) as u64);
                    let mut tc = match TcpClient::connect(addr, client) {
                        Ok(tc) => tc,
                        Err(e) => panic!("client {client:?}: connect: {e:#}"),
                    };
                    tc.set_timeout(Some(Duration::from_secs(5))).expect("timeout");
                    let mut rng = Rng::new((n * 100 + c) as u64 + 1);
                    let mut wl = ZipfWorkload::new(10_000, 0.7, 100)
                        .with_read_ratio(read_pct as f64 / 100.0);
                    while Instant::now() < deadline {
                        let spec = wl.next(client, &mut rng);
                        let is_read = spec.op == Op::Read;
                        match tc.submit_single(spec.keys[0], spec.op.clone(), spec.payload_len) {
                            Ok(_) => {
                                ops.fetch_add(1, Ordering::Relaxed);
                                if is_read {
                                    reads_sent.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(e) => {
                                eprintln!("client {client:?}: {e:#}; stopping");
                                break;
                            }
                        }
                    }
                });
            }
        }
    });
    let total = ops.load(Ordering::Relaxed);
    let reads = reads_sent.load(Ordering::Relaxed);
    std::thread::sleep(Duration::from_millis(500)); // drain parked reads
    let (mut local_reads, mut slow_reads, mut slack_served, mut read_bytes) =
        (0u64, 0u64, 0u64, 0u64);
    for n in &nodes {
        let c = n.counters();
        local_reads += c.local_reads;
        slow_reads += c.slow_reads;
        slack_served += c.read_slack_served;
        read_bytes += c.read_path_bytes;
    }
    println!(
        "  {:.0} ops/s; {reads} reads sent, {local_reads} served locally, \
         {slow_reads} degraded, {slack_served} via slack, {read_bytes} \
         read-path wire bytes",
        total as f64 / duration.as_secs_f64()
    );
    assert!(total > 0, "no operations completed");
    assert!(reads > 0, "the mix produced no reads");
    assert_eq!(
        local_reads, reads,
        "every single-shard single-key read must serve at its coordinator"
    );
    assert_eq!(slow_reads, 0, "no read should degrade to the ordering path");
    assert_eq!(read_bytes, 0, "a local read must not put a byte on the wire");
    println!(
        "read mix OK: {local_reads}/{reads} reads served from the stability \
         frontier with zero wire bytes, across 2 worker slots per node."
    );
    for n in nodes {
        n.shutdown();
    }
    Ok(())
}

/// `--kill-node`: kill the client's node and prove the failover path is
/// exactly-once over real TCP.
///
/// Two duplicate-risk paths are exercised:
/// - a request the cluster **already executed** is re-issued (the reply
///   was lost with the old connection) — the replicas' dedup window must
///   absorb the copy and replay the cached response;
/// - a window of requests that **died with the node** is re-issued — the
///   re-issues must each execute exactly once at the survivor.
///
/// The proof is a private RMW counter key only this client touches:
/// payload 0 keeps the KvStore RMW step at exactly +1, so the final
/// version counts executions — a lost one leaves it short, a duplicated
/// one overshoots.
///
/// The window is raced into the dying node *before* the kill, so some
/// proposals die mid-protocol — orphaned at the survivors, stalling the
/// stability frontier for their keys. No harness steps in: the TCP
/// runtime's own failure detector must notice the silence (heartbeats,
/// WIRE.md tag 26), suspect the dead coordinator after
/// `Config::suspect_delay_us`, evict it through the epoch vote, and let
/// recovery re-drive the orphaned dots — only then can the survivor
/// execute the client's re-issues. The client paces its failover
/// attempts with `TcpClient::backoff` while that plays out.
fn kill_node() -> tempo::util::error::Result<()> {
    let r = 3usize;
    let config = Config::new(r, 1)
        .with_tick_interval_us(1_000)
        .with_workers(2)
        .with_retry_interval_ticks(50)
        .with_heartbeat_interval_us(20_000)
        .with_suspect_delay_us(400_000);
    println!(
        "--- e2e --kill-node ({r} nodes, 2 worker slots each, \
         heartbeats every 20 ms, suspect after 400 ms) ---"
    );
    let (mut nodes, addrs) = boot_cluster(r, &config)?;

    let key = 1u64 << 42;
    let mut tc = TcpClient::connect(&addrs[0], ClientId(7_777))?;
    tc.set_timeout(Some(Duration::from_secs(5)))?;
    let mut submitted = std::collections::HashSet::new();
    let mut completed = std::collections::HashSet::new();

    // Warm phase: closed loop against node 0, all acked.
    for _ in 0..20 {
        let rid = tc.submit_async(vec![key], Op::Rmw, 0)?;
        submitted.insert(rid);
        let (done, _) = tc.recv_reply()?;
        assert!(completed.insert(done), "duplicate reply for {done}");
    }

    // Dedup phase: submit one more, give the cluster time to order and
    // execute it everywhere, then fail over to node 1 *without reading
    // the reply* — the rid is unacked from the session's point of view,
    // so it is re-issued even though every replica already applied it.
    // The dedup window must absorb the copy (the counter advances once)
    // and node 1 must replay the cached response.
    let dup_rid = tc.submit_async(vec![key], Op::Rmw, 0)?;
    submitted.insert(dup_rid);
    std::thread::sleep(Duration::from_millis(600));
    let reissued = tc.failover(&addrs[1])?;
    assert_eq!(reissued, 1, "exactly the unread rid must be re-issued");
    let (done, _) = tc.recv_reply()?;
    assert_eq!(done, dup_rid, "the re-issue must complete under its rid");
    completed.insert(done);
    println!("  executed-but-unacked rid re-issued at node 1 and absorbed");

    // Kill phase: race a window of submissions into node 1 while it is
    // still alive, *then* stop it. Some of the window executes before
    // the shutdown (the re-issues below are absorbed by the dedup
    // window); whatever was mid-protocol is orphaned at the survivors
    // and stalls its key until the failure detector fires — the
    // re-issues' only path to execution is suspicion -> eviction ->
    // recovery, all driven by the runtime itself.
    for _ in 0..19 {
        match tc.submit_async(vec![key], Op::Rmw, 0) {
            Ok(rid) => {
                submitted.insert(rid);
            }
            Err(_) => break, // connection already reset; re-issue the rest below
        }
    }
    let victim = nodes.remove(1);
    victim.shutdown();
    println!(
        "  node 1 killed with {} requests unacked",
        submitted.len() - completed.len()
    );

    let mut failovers = 0u32;
    while completed.len() < submitted.len() {
        match tc.recv_reply() {
            Ok((rid, _)) => {
                assert!(completed.insert(rid), "duplicate reply for {rid}");
            }
            Err(e) => {
                failovers += 1;
                assert!(failovers <= 5, "failover loop not converging: {e:#}");
                // Jittered exponential pacing between attempts: gives the
                // detector/eviction/recovery pipeline time to unstall the
                // orphaned dots instead of hammering the survivor.
                std::thread::sleep(tc.backoff(
                    failovers - 1,
                    Duration::from_millis(50),
                    Duration::from_millis(800),
                ));
                let n = tc.failover(&addrs[2])?;
                println!("  failover #{failovers}: re-issued {n} rids at node 2");
            }
        }
    }
    assert_eq!(completed, submitted, "every rid must complete exactly once");
    assert!(failovers > 0, "node death never surfaced to the client");

    // The detector itself must have done the work: both survivors
    // heartbeated, noticed the silence, and voted the victim out. The
    // client side can finish before the suspect delay elapses (re-issues
    // absorbed by dedup), so give the detector its window.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let done = nodes
            .iter()
            .all(|n| {
                let c = n.counters();
                c.suspicions >= 1 && c.evictions >= 1
            });
        if done {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "survivors never suspected+evicted the dead node: {:?}",
            nodes.iter().map(|n| n.counters()).collect::<Vec<_>>()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    for (i, n) in nodes.iter().enumerate() {
        let c = n.counters();
        assert!(
            c.heartbeats_sent > 0 && c.heartbeats_seen > 0,
            "survivor {i}: no heartbeat traffic ({c:?})"
        );
    }
    println!("  both survivors suspected and evicted node 1 on their own");

    // Exactly-once proof at the state machine.
    let expected = submitted.len() as u64;
    let mut check = TcpClient::connect(&addrs[2], ClientId(7_778))?;
    check.set_timeout(Some(Duration::from_secs(5)))?;
    let (_, response) = check.submit_single(key, Op::Get, 0)?;
    assert_eq!(
        response.versions,
        vec![(key, expected)],
        "counter key must show exactly {expected} executions"
    );
    let mut dedup_hits = 0u64;
    for n in &nodes {
        dedup_hits += n.counters().dedup_hits;
    }
    assert!(
        dedup_hits > 0,
        "the surviving replicas absorbed no duplicate delivery"
    );
    println!(
        "  all {expected} rids completed exactly once; counter key at \
         version {expected}; {dedup_hits} duplicate deliveries absorbed \
         by the dedup window"
    );
    for n in nodes {
        n.shutdown();
    }
    Ok(())
}

/// `--kill-restart`: the durability acceptance run — a REAL crash-recovery
/// cycle over TCP. Three nodes journal executions under
/// `StorageMode::Disk` (per-slot WAL + content-addressed snapshots,
/// `store::storage`); node 0 is stopped mid-session with an
/// executed-but-unacked request outstanding, restarted from its data
/// directory, and must:
///
/// - recover snapshot + WAL tail locally and fetch whatever pages it is
///   missing from a survivor over the transfer plane (tags 22–24);
/// - absorb the client's re-issue of the unacked rid via the dedup
///   window recovered **from disk** (exactly-once across restart);
/// - keep serving ordered traffic afterwards (the survivors redial it);
/// - converge to per-slot Merkle digests byte-identical to the replicas
///   that never crashed, with a private RMW counter key proving zero
///   lost and zero duplicated executions.
fn kill_restart() -> tempo::util::error::Result<()> {
    let r = 3usize;
    // Small snapshot cadence + fsync window so the run exercises
    // checkpoints, WAL-tail replay AND group commit, not just one.
    let config = Config::new(r, 1)
        .with_tick_interval_us(1_000)
        .with_workers(2)
        .with_retry_interval_ticks(20)
        .with_storage(StorageMode::Disk)
        .with_wal_fsync_batch(4)
        .with_snapshot_every(32);
    let base = std::env::temp_dir().join(format!("tempo-e2e-restart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let dirs: Vec<PathBuf> = (0..r).map(|i| base.join(format!("node{i}"))).collect();
    println!(
        "--- e2e --kill-restart ({r} durable nodes, 2 worker slots each, \
         data under {}) ---",
        base.display()
    );

    let addrs = local_addrs(r)?;
    let mut nodes: Vec<NodeHandle> = {
        let addrs = &addrs;
        let dirs = &dirs;
        let config = &config;
        std::thread::scope(|scope| {
            (0..r as u32)
                .map(|i| {
                    scope.spawn(move || {
                        start_node_in(
                            ProcessId(i),
                            config.clone(),
                            addrs.clone(),
                            Some(dirs[i as usize].clone()),
                        )
                        .unwrap_or_else(|e| panic!("node {i}: {e:#}"))
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|t| t.join().unwrap())
                .collect()
        })
    };
    std::thread::sleep(Duration::from_millis(300)); // mesh up

    // Spray writes across both worker slots so the stores are populated
    // and the snapshot cadence (32) fires several times per slot.
    let mut spray = TcpClient::connect(&addrs[0], ClientId(4_241))?;
    spray.set_timeout(Some(Duration::from_secs(5)))?;
    for i in 0..200u64 {
        spray.submit_single(i, Op::Put, 32)?;
    }

    // The counter session: a private RMW key only this client touches
    // (payload 0 keeps the KvStore RMW step at exactly +1, so the final
    // version counts executions).
    let key = 1u64 << 42;
    let mut tc = TcpClient::connect(&addrs[0], ClientId(4_242))?;
    tc.set_timeout(Some(Duration::from_secs(5)))?;
    let mut submitted = std::collections::HashSet::new();
    let mut completed = std::collections::HashSet::new();
    for _ in 0..40 {
        let rid = tc.submit_async(vec![key], Op::Rmw, 0)?;
        submitted.insert(rid);
        let (done, _) = tc.recv_reply()?;
        assert!(completed.insert(done), "duplicate reply for {done}");
    }

    // One more RMW, executed everywhere but *never acked to the client*:
    // after the restart its re-issue must be absorbed by the dedup
    // window recovered from disk — exactly-once across restart.
    let dup_rid = tc.submit_async(vec![key], Op::Rmw, 0)?;
    submitted.insert(dup_rid);
    std::thread::sleep(Duration::from_millis(800)); // order + execute + journal

    let executed_before = nodes[0].executed();
    let victim = nodes.remove(0);
    victim.shutdown(); // drains the workers (WAL flushed) and frees the port
    println!("  node 0 stopped at executed={executed_before}");

    // Mid-outage traffic at a survivor: the cluster keeps ordering with
    // a quorum of 2 while node 0 is down, so when it comes back its
    // snapshot + WAL recovery genuinely LAGS the survivors and the
    // manifest diff must pull the newer pages over tags 22–24.
    let mut outage = TcpClient::connect(&addrs[1], ClientId(4_244))?;
    outage.set_timeout(Some(Duration::from_secs(5)))?;
    for i in 0..60u64 {
        outage.submit_single(1_000 + i, Op::Put, 32)?;
    }
    println!("  60 writes ordered by the survivors during the outage");

    let restarted = start_node_in(
        ProcessId(0),
        config.clone(),
        addrs.clone(),
        Some(dirs[0].clone()),
    )?;
    std::thread::sleep(Duration::from_millis(500)); // recover + transfer + re-mesh
    let fetched = restarted.counters().chunks_fetched;
    assert!(
        fetched > 0,
        "the restarted node was behind the survivors but fetched no pages"
    );
    println!("  node 0 recovered from disk and fetched {fetched} pages over tags 22–24");
    nodes.insert(0, restarted);

    // Failover back to the restarted node itself: exactly the unacked
    // rid is re-issued, and the recovered dedup window must answer it
    // with the cached response instead of double-executing.
    let reissued = tc.failover(&addrs[0])?;
    assert_eq!(reissued, 1, "exactly the unacked rid must be re-issued");
    let (done, _) = tc.recv_reply()?;
    assert_eq!(done, dup_rid, "the re-issue must complete under its rid");
    completed.insert(done);
    let dedup_hits = nodes[0].counters().dedup_hits;
    assert!(
        dedup_hits > 0,
        "the restarted node did not absorb the re-issue from its recovered dedup window"
    );
    println!("  executed-but-unacked rid absorbed after restart ({dedup_hits} dedup hits)");

    // The restarted node must keep coordinating ordered traffic: the
    // survivors' peer writers redial it, its own retry timer re-drives
    // anything dropped while the mesh healed.
    for _ in 0..20 {
        let rid = tc.submit_async(vec![key], Op::Rmw, 0)?;
        submitted.insert(rid);
    }
    let mut failovers = 0u32;
    while completed.len() < submitted.len() {
        match tc.recv_reply() {
            Ok((rid, _)) => {
                assert!(completed.insert(rid), "duplicate reply for {rid}");
            }
            Err(e) => {
                failovers += 1;
                assert!(failovers <= 5, "post-restart traffic not converging: {e:#}");
                let n = tc.failover(&addrs[2])?;
                println!("  failover #{failovers}: re-issued {n} rids at node 2");
            }
        }
    }
    assert_eq!(completed, submitted, "every rid must complete exactly once");

    // Exactly-once proof at the state machine: the counter key advanced
    // by exactly one step per acknowledged request.
    let expected = submitted.len() as u64;
    let mut check = TcpClient::connect(&addrs[2], ClientId(4_243))?;
    check.set_timeout(Some(Duration::from_secs(5)))?;
    let (_, response) = check.submit_single(key, Op::Get, 0)?;
    assert_eq!(
        response.versions,
        vec![(key, expected)],
        "counter key must show exactly {expected} executions"
    );

    // Convergence: the restarted replica's per-slot Merkle digests must
    // become byte-identical to the never-crashed replicas'.
    let deadline = Instant::now() + Duration::from_secs(10);
    let digests = loop {
        let views: Vec<Vec<u64>> = nodes.iter().map(|n| n.store_digests()).collect();
        if views.windows(2).all(|w| w[0] == w[1]) {
            break views;
        }
        assert!(
            Instant::now() < deadline,
            "replicas did not converge after the restart: {views:x?}"
        );
        std::thread::sleep(Duration::from_millis(100));
    };
    println!("  per-slot digests byte-identical across the restart: {:x?}", digests[0]);

    // Durability counters: the run must have journaled, checkpointed and
    // group-committed for real, and the restart must have fetched at
    // least its peers' newer pages over the transfer plane.
    let c = nodes[1].counters();
    assert!(c.wal_records > 0, "no WAL records journaled: {c:?}");
    assert!(c.wal_fsyncs > 0, "no group-commit fsyncs: {c:?}");
    assert!(c.snapshots_taken > 0, "the snapshot cadence never fired: {c:?}");
    let fetched = nodes[0].counters().chunks_fetched;
    println!(
        "  survivor journaled {} WAL records / {} fsyncs / {} snapshots; \
         restart fetched {fetched} pages over tags 22–24",
        c.wal_records, c.wal_fsyncs, c.snapshots_taken
    );
    println!(
        "\ne2e kill-restart OK: {expected} counter executions exactly once \
         across a crash-restart; digests byte-identical."
    );
    for n in nodes {
        n.shutdown();
    }
    let _ = std::fs::remove_dir_all(&base);
    Ok(())
}

/// `--bench-batching`: the carried-forward batching validation — batched
/// vs unbatched over REAL TCP sockets, the syscall-cost comparison the
/// simulator's amortization model (BENCH_batching.json, `cargo bench
/// --bench microbench`) predicts but cannot measure. Pipelined clients
/// keep a request window in flight so the comparison measures the wire
/// path, not the closed-loop round-trip a 1 ms flush tick would dominate.
fn bench_batching() -> tempo::util::error::Result<()> {
    let r = 3usize;
    let duration = Duration::from_secs(3);
    let clients_per_node = 4;
    let window = 32usize;
    println!(
        "--- e2e --bench-batching ({r} nodes, {} pipelined TCP clients, \
         window {window}, {}s per cell) ---",
        r * clients_per_node,
        duration.as_secs()
    );
    let mut cells: Vec<(String, u64, f64, u64, u64)> = Vec::new();
    for &(mode, batch) in &[("unbatched", 0usize), ("batched", 64)] {
        let mut config = Config::new(r, 1).with_tick_interval_us(1_000).with_workers(2);
        if batch > 0 {
            config = config.with_batching(batch);
        }
        let (nodes, addrs) = boot_cluster(r, &config)?;
        let ops = Arc::new(AtomicU64::new(0));
        let deadline = Instant::now() + duration;
        std::thread::scope(|scope| {
            for (n, addr) in addrs.iter().enumerate() {
                for c in 0..clients_per_node {
                    let ops = ops.clone();
                    scope.spawn(move || {
                        let client = ClientId((n * 100 + c) as u64);
                        let mut tc = TcpClient::connect(addr, client).expect("connect");
                        tc.set_timeout(Some(Duration::from_secs(5))).expect("timeout");
                        let mut rng = Rng::new((n * 100 + c) as u64 + 1);
                        let zipf = Zipf::new(10_000, 0.7);
                        for _ in 0..window {
                            let _ = tc.submit_async(vec![zipf.sample(&mut rng)], Op::Put, 100);
                        }
                        while Instant::now() < deadline {
                            match tc.recv_reply() {
                                Ok(_) => {
                                    ops.fetch_add(1, Ordering::Relaxed);
                                    let key = zipf.sample(&mut rng);
                                    if tc.submit_async(vec![key], Op::Put, 100).is_err() {
                                        break;
                                    }
                                }
                                Err(e) => {
                                    eprintln!("client {client:?}: {e:#}; stopping");
                                    break;
                                }
                            }
                        }
                    });
                }
            }
        });
        let total = ops.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(500)); // drain
        let (mut bytes, mut frames) = (0u64, 0u64);
        for n in &nodes {
            let c = n.counters();
            bytes += c.bytes_sent;
            frames += n.wire_frames();
        }
        let ops_per_s = total as f64 / duration.as_secs_f64();
        println!(
            "  {mode:>9}: {ops_per_s:>10.0} ops/s, {frames} wire frames, \
             {bytes} peer bytes, {:.1} frames/op",
            frames as f64 / total.max(1) as f64
        );
        assert!(total > 0, "no ops in the {mode} cell");
        cells.push((mode.to_string(), total, ops_per_s, frames, bytes));
        for n in nodes {
            n.shutdown();
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    let ratio = cells[1].2 / cells[0].2;
    println!("  batched/unbatched throughput ratio over TCP: {ratio:.2}");
    let rows: String = cells
        .iter()
        .enumerate()
        .map(|(i, (mode, ops, ops_per_s, frames, bytes))| {
            format!(
                "    {{\"mode\": \"{mode}\", \"ops\": {ops}, \"ops_per_s\": \
                 {ops_per_s:.0}, \"wire_frames\": {frames}, \"peer_bytes\": {bytes}, \
                 \"frames_per_op\": {:.2}}}{}\n",
                *frames as f64 / (*ops).max(1) as f64,
                if i + 1 == cells.len() { "" } else { "," }
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"batching_e2e_tcp\",\n  \
         \"workload\": \"3-node Tempo over real TCP, {} pipelined clients x \
         window {window}, zipf(10k, 0.7) puts, {}s per cell; batched cell = \
         batch_max_msgs 64\",\n  \
         \"harness\": \"rust (cargo run --release --example e2e_cluster -- \
         --bench-batching)\",\n  \
         \"cells\": [\n{rows}  ],\n  \
         \"batched_vs_unbatched_ops_ratio\": {ratio:.3},\n  \
         \"regenerate\": \"cargo run --release --example e2e_cluster -- \
         --bench-batching\"\n}}\n",
        r * clients_per_node,
        duration.as_secs()
    );
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => format!("{d}/../BENCH_batching_tcp.json"),
        Err(_) => "BENCH_batching_tcp.json".to_string(),
    };
    match std::fs::write(&path, &json) {
        Ok(()) => println!("e2e TCP batching cells written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    assert!(
        ratio >= 1.0,
        "batching must not cost throughput over TCP (ratio {ratio:.3})"
    );
    Ok(())
}

/// `--sweep-clients`: the client-plane scaling cell — thousands of
/// concurrent TCP sessions multiplexed on each node's **fixed** pool of
/// event-loop threads (`Config::client_event_threads`; no per-connection
/// threads node-side), driven in closed-loop waves. Reports ops/s, p99
/// wave latency and replies-per-flush (the event loop's reply batching),
/// then exercises admission control for real: a tiny per-session window
/// plus one over-pipelining client must produce explicit `ClientBusy`
/// sheds that `resubmit` recovers from. Writes BENCH_clients_tcp.json.
fn sweep_clients() -> tempo::util::error::Result<()> {
    use tempo::client::is_busy_error;
    let r = 3usize;
    let driver_threads = 8usize;
    let wave = 4usize; // submits in flight per session per wave
    let duration = Duration::from_secs(3);
    println!(
        "--- e2e --sweep-clients ({r} nodes, 2 event-loop threads each, \
         wave window {wave}, {}s per cell) ---",
        duration.as_secs()
    );
    let mut cells: Vec<(usize, f64, u64, f64, u64)> = Vec::new();
    for &sessions in &[1_000usize, 10_000] {
        let config = Config::new(r, 1)
            .with_tick_interval_us(1_000)
            .with_workers(2)
            .with_batching(64)
            .with_client_event_threads(2);
        let (nodes, addrs) = boot_cluster(r, &config)?;
        let ops = Arc::new(AtomicU64::new(0));
        let hist = Arc::new(std::sync::Mutex::new(Histogram::new()));
        let deadline = Instant::now() + duration;
        std::thread::scope(|scope| {
            for t in 0..driver_threads {
                let ops = ops.clone();
                let hist = hist.clone();
                let addrs = &addrs;
                scope.spawn(move || {
                    // The driver threads exist only because one OS thread
                    // cannot pump thousands of blocking client sockets;
                    // the *node* side runs them all on its fixed loops.
                    let mut clients: Vec<TcpClient> = Vec::new();
                    for s in (t..sessions).step_by(driver_threads) {
                        let addr = &addrs[s % r];
                        let id = ClientId((1_000_000 + s) as u64);
                        let tc = (0..50)
                            .find_map(|_| match TcpClient::connect(addr, id) {
                                Ok(tc) => Some(tc),
                                Err(_) => {
                                    // Accept backlog overflow under the
                                    // connect storm: back off and redial.
                                    std::thread::sleep(Duration::from_millis(10));
                                    None
                                }
                            })
                            .unwrap_or_else(|| panic!("client {id:?}: connect"));
                        tc.set_timeout(Some(Duration::from_secs(30))).expect("timeout");
                        clients.push(tc);
                    }
                    let mut rng = Rng::new(t as u64 + 1);
                    let zipf = Zipf::new(100_000, 0.7);
                    let mut lat: Vec<u64> = Vec::new();
                    let mut t0s: Vec<Instant> = Vec::with_capacity(clients.len());
                    while Instant::now() < deadline {
                        // One wave: every session pipelines `wave`
                        // submits, then the replies are drained — so the
                        // whole shard is in flight at the node at once.
                        t0s.clear();
                        for tc in clients.iter_mut() {
                            t0s.push(Instant::now());
                            for _ in 0..wave {
                                let key = zipf.sample(&mut rng);
                                tc.submit_async(vec![key], Op::Put, 64).expect("submit");
                            }
                        }
                        for (tc, t0) in clients.iter_mut().zip(&t0s) {
                            for _ in 0..wave {
                                tc.recv_reply().expect("reply");
                            }
                            lat.push(t0.elapsed().as_micros() as u64);
                        }
                        ops.fetch_add((clients.len() * wave) as u64, Ordering::Relaxed);
                    }
                    let mut h = hist.lock().unwrap();
                    for v in lat {
                        h.record(v);
                    }
                });
            }
        });
        let total = ops.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(500)); // drain
        let (mut conns, mut replies, mut flushes, mut wakeups) = (0u64, 0u64, 0u64, 0u64);
        for n in &nodes {
            let c = n.counters();
            conns += c.client_connections;
            replies += c.client_replies;
            flushes += c.client_flushes;
            wakeups += c.client_wakeups;
        }
        let p99 = hist.lock().unwrap().quantile(0.99);
        let ops_per_s = total as f64 / duration.as_secs_f64();
        let rpf = replies as f64 / flushes.max(1) as f64;
        println!(
            "  {sessions:>6} sessions: {ops_per_s:>10.0} ops/s, p99 wave {p99} us, \
             {replies} replies / {flushes} flushes ({rpf:.2}/flush), \
             {wakeups} wakeups, {conns} connections"
        );
        assert!(total > 0, "no ops at {sessions} sessions");
        assert_eq!(
            conns, sessions as u64,
            "every session must land on the event-loop plane (no thread-per-conn)"
        );
        if sessions >= 10_000 {
            assert!(
                rpf > 1.0,
                "the event loop never batched replies per flush at {sessions} sessions"
            );
        }
        cells.push((sessions, ops_per_s, p99, rpf, conns));
        for n in nodes {
            n.shutdown();
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    let ratio = cells[1].1 / cells[0].1;
    println!("  10k/1k throughput ratio: {ratio:.2}");

    // Admission control for real: per-session window 4, one client
    // pipelines 64 — the node must shed with explicit ClientBusy frames
    // and `resubmit` (same rids) must recover every shed request.
    let config = Config::new(r, 1)
        .with_tick_interval_us(1_000)
        .with_workers(2)
        .with_client_event_threads(1)
        .with_max_inflight_per_session(4);
    let (nodes, addrs) = boot_cluster(r, &config)?;
    let mut tc = TcpClient::connect(&addrs[0], ClientId(999_999))?;
    tc.set_timeout(Some(Duration::from_secs(5)))?;
    let burst = 64u64;
    let mut submitted = std::collections::HashSet::new();
    for i in 0..burst {
        submitted.insert(tc.submit_async(vec![1 << 30 | i], Op::Put, 32)?);
    }
    let mut busy_errors = 0u64;
    let mut busy_streak = 0u32;
    let mut completed = std::collections::HashSet::new();
    while tc.in_flight() > 0 {
        match tc.recv_reply() {
            Ok((rid, _)) => {
                busy_streak = 0;
                assert!(completed.insert(rid), "duplicate reply for {rid}");
            }
            Err(e) if is_busy_error(&e) => {
                busy_errors += 1;
                let rid = tc.last_busy().expect("busy rid recorded");
                // The shed request was neither executed nor queued: back
                // off (jittered exponential, growing with the consecutive
                // busy streak so a saturated window is not hammered) and
                // re-issue it under its original rid.
                std::thread::sleep(tc.backoff(
                    busy_streak,
                    Duration::from_millis(1),
                    Duration::from_millis(16),
                ));
                busy_streak += 1;
                tc.resubmit(rid)?;
            }
            Err(e) => return Err(e),
        }
    }
    assert_eq!(completed, submitted, "every shed rid must eventually complete");
    let busy_shed: u64 = nodes.iter().map(|n| n.counters().busy_shed).sum();
    assert!(busy_errors > 0, "pipelining 64 into a window of 4 never surfaced busy");
    assert!(busy_shed > 0, "the node edge never counted a shed");
    println!(
        "  admission control: {burst} pipelined into window 4 -> {busy_shed} \
         sheds at the edge, {busy_errors} busy errors at the client, all \
         {} rids recovered via resubmit",
        completed.len()
    );
    for n in nodes {
        n.shutdown();
    }

    let rows: String = cells
        .iter()
        .enumerate()
        .map(|(i, (sessions, ops_per_s, p99, rpf, conns))| {
            format!(
                "    {{\"sessions\": {sessions}, \"ops_per_s\": {ops_per_s:.0}, \
                 \"p99_wave_us\": {p99}, \"replies_per_flush\": {rpf:.2}, \
                 \"client_connections\": {conns}}}{}\n",
                if i + 1 == cells.len() { "" } else { "," }
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"clients_e2e_tcp\",\n  \
         \"workload\": \"3-node Tempo over real TCP, N concurrent sessions in \
         closed-loop waves of {wave} zipf(100k, 0.7) puts, {}s per cell; \
         2 client event-loop threads per node; busy cell = window 4, one \
         client pipelining 64\",\n  \
         \"harness\": \"rust (cargo run --release --example e2e_cluster -- \
         --sweep-clients)\",\n  \
         \"cells\": [\n{rows}  ],\n  \
         \"ratio_10k_vs_1k_ops\": {ratio:.3},\n  \
         \"busy\": {{\"shed_at_edge\": {busy_shed}, \"busy_errors_at_client\": \
         {busy_errors}, \"recovered\": {}}},\n  \
         \"regenerate\": \"ulimit -n 65536 && cargo run --release --example \
         e2e_cluster -- --sweep-clients\"\n}}\n",
        duration.as_secs(),
        completed.len()
    );
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => format!("{d}/../BENCH_clients_tcp.json"),
        Err(_) => "BENCH_clients_tcp.json".to_string(),
    };
    match std::fs::write(&path, &json) {
        Ok(()) => println!("e2e TCP client-plane cells written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!(
        "\nsweep-clients OK: 10k sessions multiplexed on fixed event-loop \
         pools at {ratio:.2}x the 1k-session throughput; admission control \
         sheds and recovers explicitly."
    );
    Ok(())
}

fn main() -> tempo::util::error::Result<()> {
    if std::env::args().any(|a| a == "--sweep-clients") {
        sweep_clients()?;
        std::process::exit(0);
    }
    if std::env::args().any(|a| a == "--kill-restart") {
        kill_restart()?;
        std::process::exit(0); // stray client reply-writer threads may linger
    }
    if std::env::args().any(|a| a == "--bench-batching") {
        bench_batching()?;
        std::process::exit(0);
    }
    if std::env::args().any(|a| a == "--kill-node") {
        kill_node()?;
        std::process::exit(0); // acceptor threads block on listener
    }
    if std::env::args().any(|a| a == "--sweep-workers") {
        sweep_workers()?;
        std::process::exit(0); // acceptor threads block on listener
    }
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--read-pct") {
        let pct = args
            .get(i + 1)
            .and_then(|v| v.parse().ok())
            .unwrap_or(95u32);
        read_mix(pct)?;
        std::process::exit(0); // acceptor threads block on listener
    }
    let r = 3;
    // Two worker slots per node: each node runs one protocol thread per
    // slot, peer frames carry the worker envelope (WIRE.md tag 19), and
    // clients route by key hash — all exercised under real TCP here.
    let config = Config::new(r, 1).with_tick_interval_us(1_000).with_workers(2);
    println!("starting {r}-node Tempo cluster (2 worker slots each)");
    let (nodes, addrs) = boot_cluster(r, &config)?;

    // Closed-loop TCP clients: 8 per node, each a real socket speaking
    // ClientSubmit/ClientReply; zipfian keys, 50% RMW.
    let clients_per_node = 8;
    let duration = Duration::from_secs(10);
    let hist = Arc::new(std::sync::Mutex::new(Histogram::new()));
    let total = drive_load(&addrs, clients_per_node, duration, Some(&hist));

    let h = hist.lock().unwrap();
    let t = h.tail_summary();
    println!(
        "\ne2e cluster results ({}s, {} closed-loop TCP clients):",
        duration.as_secs(),
        r * clients_per_node
    );
    println!("  throughput: {:.0} ops/s", total as f64 / duration.as_secs_f64());
    println!("  latency: {t}");
    drop(h);

    // Response validity over real TCP: a fresh client works a private key
    // range (untouched by the load phase) and every reply must match a
    // local sequential KvStore oracle replaying the same commands.
    let mut oracle = KvStore::new();
    let mut mirror = Session::new(ClientId(9_999));
    let mut tc = TcpClient::connect(&addrs[0], ClientId(9_999))?;
    tc.set_timeout(Some(Duration::from_secs(5)))?;
    let base = 1u64 << 40;
    let mut checked = 0u32;
    for i in 0..60u64 {
        let key = base + i % 20;
        let op = match i % 3 {
            0 => Op::Put,
            1 => Op::Rmw,
            _ => Op::Get,
        };
        let payload = (i % 128) as u32;
        let expect = oracle.execute(&Command::single(mirror.next_rid(), key, op.clone(), payload));
        let (_, got) = tc.submit_single(key, op, payload)?;
        assert_eq!(
            got, expect,
            "response diverged from the sequential oracle at op {i} (key {key})"
        );
        checked += 1;
    }
    println!("  oracle check: {checked} sequential responses match the KvStore oracle");

    // Pipelining over real TCP: put a window of requests on the wire
    // without waiting, then collect the replies in completion order —
    // the rid-keyed reply routing (and the out-of-order completion the
    // wire protocol always allowed) is what TcpClient now exposes.
    let mut pc = TcpClient::connect(&addrs[1], ClientId(10_000))?;
    pc.set_timeout(Some(Duration::from_secs(5)))?;
    let pipeline_base = 1u64 << 41;
    let window = 16usize;
    let mut submitted = std::collections::HashSet::new();
    for i in 0..window as u64 {
        submitted.insert(pc.submit_async(vec![pipeline_base + i], Op::Put, 64)?);
    }
    assert_eq!(pc.in_flight(), window, "whole window must be in flight at once");
    let mut completed = std::collections::HashSet::new();
    for _ in 0..window {
        let (rid, _) = pc.recv_reply()?;
        assert!(completed.insert(rid), "duplicate reply for {rid}");
    }
    assert_eq!(completed, submitted, "every pipelined rid must complete exactly once");
    assert_eq!(pc.in_flight(), 0);
    println!("  pipelining: {window} requests in flight on one session, all completed");

    // Let in-flight work drain, then verify convergence: the Merkle root
    // over the per-worker-slot digests (equal roots ⇔ equal slot
    // partitions; a mismatch would localize via store_digests()).
    std::thread::sleep(Duration::from_millis(800));
    let digests: Vec<(u64, u64)> =
        nodes.iter().map(|n| (n.executed(), n.store_digest())).collect();
    println!("  per-node (executed, merkle root): {digests:x?}");
    println!("  node-0 per-slot leaves: {:x?}", nodes[0].store_digests());
    let counters = nodes[0].counters();
    println!(
        "  node-0 counters: fast={} slow={} executed={} bytes_sent={} \
         frames_merged={} pooled_hits={} local_reads={} slow_reads={} \
         read_slack_served={} read_path_bytes={}",
        counters.fast_path,
        counters.slow_path,
        counters.executed,
        counters.bytes_sent,
        counters.frames_merged,
        counters.pooled_hits,
        counters.local_reads,
        counters.slow_reads,
        counters.read_slack_served,
        counters.read_path_bytes
    );

    // Steady-state frames must hit the pool: after tens of thousands of
    // frames over these connections, reads land in recycled capacity —
    // the per-frame allocation the seed paid is gone.
    let hits = counters.pooled_hits;
    let misses = tempo::net::wire::pool_stats::misses();
    assert!(
        hits > 1_000 && hits > 10 * misses.max(1),
        "frame pool barely hitting: {hits} hits vs {misses} misses"
    );
    println!(
        "  frame pool: {hits} hits / {misses} misses ({:.2}% hit rate)",
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );

    let max_exec = digests.iter().map(|&(e, _)| e).max().unwrap();
    let min_exec = digests.iter().map(|&(e, _)| e).min().unwrap();
    assert!(total > 0, "no operations completed");
    assert!(
        max_exec - min_exec <= total / 10 + 16,
        "replicas too far apart: {digests:?}"
    );
    // Replicas that executed the same count must agree on the digest.
    println!(
        "\ne2e cluster OK: {total} ops served over real TCP \
         (ClientSubmit in, ClientReply out); replicas converge."
    );
    for n in nodes {
        n.shutdown();
    }
    std::process::exit(0); // acceptor threads block on listener
}
