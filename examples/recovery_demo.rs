//! Recovery demo: crash a coordinator mid-run and watch Tempo's recovery
//! protocol (Algorithm 4 + §B) take over — commands submitted by the
//! surviving processes keep executing and the PSMR spec holds.
//!
//! Run with: `cargo run --release --example recovery_demo`

use tempo::check::{check_psmr, Violation};
use tempo::core::{Config, ProcessId};
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::ConflictWorkload;

fn main() {
    let victim = ProcessId(1);
    let config = Config::new(5, 1).with_recovery_timeout_us(1_000_000);
    let mut opts = SimOpts::new(Topology::ec2());
    opts.clients_per_site = 4;
    opts.warmup_us = 0;
    opts.duration_us = 3_000_000;
    opts.drain_us = 8_000_000;
    opts.seed = 2026;
    opts.record_execution = true;
    opts.crashes = vec![(1_500_000, victim)];
    opts.suspect_delay_us = 300_000;

    println!("5-site Tempo, f=1; crashing {victim} at t=1.5s (simulated) ...");
    let result = run::<Tempo, _>(config.clone(), opts, ConflictWorkload::new(0.2, 100));

    println!("  completed ops: {}", result.metrics.ops);
    println!(
        "  fast={} slow={} recoveries={}",
        result.metrics.counters.fast_path,
        result.metrics.counters.slow_path,
        result.metrics.counters.recoveries
    );
    assert!(result.metrics.counters.recoveries > 0, "no recovery was exercised");

    let violations = check_psmr(&config, &result, true);
    let real: Vec<&Violation> = violations
        .iter()
        .filter(|v| match v {
            // The victim executes nothing after crashing, and commands it
            // originated may never have left it.
            Violation::NotExecuted { process, dot } => {
                *process != victim && dot.origin != victim
            }
            _ => true,
        })
        .collect();
    assert!(real.is_empty(), "PSMR violated: {real:#?}");
    println!(
        "  PSMR holds: every surviving-origin command executed everywhere,\n  \
         timestamps agreed (Property 1), per-key orders identical."
    );
}
