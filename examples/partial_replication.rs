//! Partial replication (the paper's §6.4 setting, abridged): YCSB+T
//! transactions over multiple shards, Tempo vs Janus*, showing genuine
//! scalability and write-ratio independence.
//!
//! Run with: `cargo run --release --example partial_replication`

use tempo::bench_util::{kops, throughput_opts};
use tempo::core::Config;
use tempo::protocol::depsmr::Janus;
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, Topology};
use tempo::workload::YcsbWorkload;

fn main() {
    println!("YCSB+T, 3 sites/shard, zipf 0.7, cluster mode (kops/s):");
    println!("{:<8} {:>14} {:>14} {:>14}", "shards", "tempo w=50%", "janus* w=5%", "janus* w=50%");
    for (i, shards) in [2u32, 4].into_iter().enumerate() {
        let seed = 40 + i as u64 * 10;
        let config = Config::new(3, 1).with_shards(shards);
        let tempo_res = run::<Tempo, _>(
            config.clone(),
            throughput_opts(Topology::ec2_three(), 256, seed),
            YcsbWorkload::new(100_000 * shards as u64, 0.7, 0.5),
        );
        let janus5 = run::<Janus, _>(
            config.clone(),
            throughput_opts(Topology::ec2_three(), 256, seed + 1),
            YcsbWorkload::new(100_000 * shards as u64, 0.7, 0.05),
        );
        let janus50 = run::<Janus, _>(
            config,
            throughput_opts(Topology::ec2_three(), 256, seed + 2),
            YcsbWorkload::new(100_000 * shards as u64, 0.7, 0.5),
        );
        println!(
            "{:<8} {:>14} {:>14} {:>14}",
            shards,
            kops(tempo_res.metrics.throughput_ops_s()),
            kops(janus5.metrics.throughput_ops_s()),
            kops(janus50.metrics.throughput_ops_s()),
        );
    }
    println!("\nTempo scales with shards and is unaffected by the write ratio (§6.4).");
}
