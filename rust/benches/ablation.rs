//! Ablation study (DESIGN.md design-choice callouts):
//!   1. MBump (§4 "Faster stability") on vs off — multi-partition latency.
//!   2. Promise-broadcast tick interval — stability latency vs message
//!      overhead trade-off (the paper flushes every 5 ms).
//!   3. Fault-tolerance level f — fast-quorum size vs latency.

use tempo::bench_util::{ms, print_table};
use tempo::core::{ClientId, Config};
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::util::Rng;
use tempo::workload::{CommandSpec, ConflictWorkload, Workload};

/// Every command touches two random keys in different shards — maximal
/// multi-partition pressure (where MBump matters).
struct CrossShard;
impl Workload for CrossShard {
    fn next(&mut self, _c: ClientId, rng: &mut Rng) -> CommandSpec {
        let a = rng.gen_range(1000);
        let b = 1000 + rng.gen_range(1000);
        CommandSpec { keys: vec![a, b], op: tempo::core::Op::Rmw, payload_len: 64 }
    }
}

fn opts(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 16;
    o.warmup_us = 2_000_000;
    o.duration_us = 10_000_000;
    o.seed = seed;
    o
}

fn main() {
    // 1. MBump on/off over 2 shards.
    let mut rows = Vec::new();
    for (label, bump) in [("MBump ON (paper §4)", true), ("MBump OFF", false)] {
        let config = Config::new(3, 1).with_shards(2).with_bump(bump);
        let r = run::<Tempo, _>(config, opts(1201), CrossShard);
        rows.push(vec![
            label.to_string(),
            ms(r.metrics.latency.quantile(0.5)),
            ms(r.metrics.latency.quantile(0.99)),
            format!("{:.1}", r.metrics.latency.mean() / 1e3),
        ]);
    }
    print_table(
        "Ablation 1: MBump (faster multi-partition stability), 2 shards, cross-shard RMW",
        &["variant", "p50 ms", "p99 ms", "mean ms"],
        &rows,
    );

    // 2. Promise tick interval.
    let mut rows = Vec::new();
    for tick_ms in [1u64, 5, 20, 50] {
        let config = Config::new(5, 1).with_tick_interval_us(tick_ms * 1000);
        let r = run::<Tempo, _>(config, opts_5(1301 + tick_ms), ConflictWorkload::new(0.02, 100));
        rows.push(vec![
            format!("{tick_ms} ms"),
            ms(r.metrics.latency.quantile(0.5)),
            ms(r.metrics.latency.quantile(0.99)),
            format!("{:.1}", r.metrics.latency.mean() / 1e3),
        ]);
    }
    print_table(
        "Ablation 2: promise-broadcast interval (paper: 5 ms), 5 sites, 2% conflicts",
        &["tick", "p50 ms", "p99 ms", "mean ms"],
        &rows,
    );

    // 3. Fault-tolerance level.
    let mut rows = Vec::new();
    for f in [1usize, 2] {
        let config = Config::new(5, f);
        let r = run::<Tempo, _>(config, opts_5(1400 + f as u64), ConflictWorkload::new(0.1, 100));
        rows.push(vec![
            format!("f={f} (fq={})", 5 / 2 + f),
            ms(r.metrics.latency.quantile(0.5)),
            ms(r.metrics.latency.quantile(0.99)),
            format!("{}", r.metrics.counters.slow_path),
        ]);
    }
    print_table(
        "Ablation 3: fault-tolerance level, 5 sites, 10% conflicts",
        &["config", "p50 ms", "p99 ms", "slow paths"],
        &rows,
    );
}

fn opts_5(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 16;
    o.warmup_us = 2_000_000;
    o.duration_us = 10_000_000;
    o.seed = seed;
    o
}
