//! Wire-path bench: serialization cost vs broadcast fan-out. Writes
//! `BENCH_wire.json` at the repo root.
//!
//! Tempo's throughput rests on cheap O(peers) fan-out; before this
//! bench's PR every peer re-serialized the same message, multiplying the
//! encode cost by the fast-path quorum size. Three measurements per
//! (message shape, fan-out) cell, all with a counting global allocator:
//!
//! - **legacy**: encode the routed frame once *per destination* (the old
//!   `write_routed` path) — ns/op and allocs/op scale with fan-out.
//! - **encode-once**: `wire::encode_routed_shared` serializes a single
//!   `Arc<[u8]>` body shared by every destination — ns/op and allocs/op
//!   must stay flat (± O(1)) as fan-out grows 1 → 8.
//! - **bytes/op**: the encoded frame size (identical on both paths; the
//!   byte-equivalence itself is fuzz-pinned in `rust/tests/properties.rs`).
//!
//! The message shapes cover the fan-outs the protocol families send:
//! a command-bearing proposal (Tempo `MPropose` ≈ EPaxos `PreAccept` ≈
//! Caesar `Propose` — cmd + per-key metadata), a commit with collected
//! promise/dependency payloads (Tempo `MCommit` ≈ Caesar commit with
//! deps), and the periodic promise delta (`MPromises`). All encode
//! through the Tempo codec — the one wire codec the runtime ships.
//!
//! Run with: `cargo bench --bench wire` (overwrites the Python-port
//! numbers in BENCH_wire.json with Rust measurements).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;
use tempo::core::{ClientId, Command, Dot, Op, ProcessId, Rid, ShardId};
use tempo::net::wire;
use tempo::protocol::common::shard::Routed;
use tempo::protocol::tempo::msg::{KeyPromises, Msg};
use tempo::protocol::tempo::promises::PromiseSet;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn representative_messages() -> Vec<(&'static str, Msg)> {
    let dot = Dot::new(ProcessId(0), 7);
    let cmd = Command::new(Rid::new(ClientId(3), 11), vec![42, 99], Op::Rmw, 100);
    let quorums = vec![(ShardId(0), vec![ProcessId(0), ProcessId(1), ProcessId(2)])];
    let ps = |n: u64| PromiseSet {
        detached: (0..n).map(|i| (10 * i + 1, 10 * i + 5)).collect(),
        attached: vec![(dot, 10 * n + 1)],
    };
    let kp: KeyPromises = vec![(42, ps(4)), (99, ps(4))];
    vec![
        (
            "propose_cmd100B",
            Msg::MPropose {
                dot,
                cmd: cmd.clone(),
                quorums: quorums.clone().into(),
                ts: vec![(42, 17), (99, 18)],
            },
        ),
        (
            "commit_promises",
            Msg::MCommit {
                dot,
                group: ShardId(0),
                ts: vec![(42, 17), (99, 18)],
                promises: vec![(ProcessId(1), kp.clone()), (ProcessId(2), kp.clone())].into(),
            },
        ),
        ("promise_delta", Msg::MPromises { promises: kp.into() }),
    ]
}

struct Cell {
    fanout: usize,
    legacy_ns: f64,
    legacy_allocs: f64,
    once_ns: f64,
    once_allocs: f64,
}

fn measure(msg: &Msg, fanout: usize, iters: u64) -> Cell {
    // Legacy path: one full encode per destination (the message itself
    // is built once — only serialization is under measurement).
    let routed = Routed { worker: 0, msg: msg.clone() };
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    let mut sink = 0usize;
    for _ in 0..iters {
        for _ in 0..fanout {
            let body = wire::encode_routed(&routed);
            sink = sink.wrapping_add(body.len());
        }
    }
    let legacy_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let legacy_allocs = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / iters as f64;

    // Encode-once path: one shared body, `fanout` Arc handles.
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..iters {
        let body = wire::encode_routed_shared(0, msg);
        for _ in 0..fanout {
            let h = body.clone();
            sink = sink.wrapping_add(h.len());
        }
    }
    let once_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let once_allocs = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / iters as f64;
    std::hint::black_box(sink);
    Cell { fanout, legacy_ns, legacy_allocs, once_ns, once_allocs }
}

fn main() {
    println!("--- wire encode-once fan-out bench ---");
    let iters = 50_000u64;
    let mut rows = String::new();
    let msgs = representative_messages();
    for (mi, (name, msg)) in msgs.iter().enumerate() {
        let bytes = wire::encoded_len(msg) + 2;
        println!("{name} ({bytes} B routed):");
        let mut fan_rows = String::new();
        for (fi, &fanout) in [1usize, 4, 8].iter().enumerate() {
            let c = measure(msg, fanout, iters);
            println!(
                "  fanout {fanout}: legacy {:>8.0} ns/op {:>5.1} allocs/op | \
                 encode-once {:>8.0} ns/op {:>5.1} allocs/op",
                c.legacy_ns, c.legacy_allocs, c.once_ns, c.once_allocs
            );
            fan_rows.push_str(&format!(
                "        {{\"fanout\": {}, \"legacy_ns_per_op\": {:.1}, \
                 \"legacy_allocs_per_op\": {:.2}, \"encode_once_ns_per_op\": {:.1}, \
                 \"encode_once_allocs_per_op\": {:.2}}}{}\n",
                c.fanout,
                c.legacy_ns,
                c.legacy_allocs,
                c.once_ns,
                c.once_allocs,
                if fi == 2 { "" } else { "," }
            ));
        }
        rows.push_str(&format!(
            "    {{\"msg\": \"{name}\", \"bytes_per_encode\": {bytes}, \
             \"fanout_cells\": [\n{fan_rows}    ]}}{}\n",
            if mi + 1 == msgs.len() { "" } else { "," }
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"wire_encode_once\",\n  \
         \"workload\": \"representative command/commit/promise fan-out shapes, \
         routed-frame encode, fan-out 1/4/8\",\n  \
         \"note\": \"legacy = one encode per destination (the pre-PR-5 send \
         path); encode_once = one shared Arc body (wire::encode_routed_shared). \
         The gate: encode_once allocs/op and ns/op stay flat (+-O(1)) as \
         fan-out grows 1->8\",\n  \
         \"harness\": \"rust (cargo bench --bench wire, counting global \
         allocator)\",\n  \"messages\": [\n{rows}  ],\n  \
         \"regenerate\": \"cargo bench --bench wire\"\n}}\n"
    );
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => format!("{d}/../BENCH_wire.json"),
        Err(_) => "BENCH_wire.json".to_string(),
    };
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wire baseline written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
