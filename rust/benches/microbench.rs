//! Component microbenchmarks for the §Perf pass: simulator event rate,
//! promise-store throughput, the scan-based vs incremental stability
//! watermark (results recorded to BENCH_stability.json), message batching
//! on vs off under the CPU/NIC resource model (recorded to
//! BENCH_batching.json), SCC executor, histogram, and (with
//! `--features pjrt`) the PJRT stability kernel.

use std::time::Instant;
use tempo::core::{Config, Dot, ProcessId};
use tempo::executor::DepGraph;
use tempo::metrics::Histogram;
use tempo::protocol::tempo::promises::{PromiseSet, PromiseStore};
use tempo::protocol::tempo::Tempo;
use tempo::runtime::stability::{stable_watermarks_rust, KernelShape};
use tempo::sim::{run, ResourceModel, SimOpts, Topology};
use tempo::util::Rng;
use tempo::workload::ConflictWorkload;

/// Run `f` for `iters` iterations; print and return ns/iter.
fn bench(name: &str, iters: u64, mut f: impl FnMut()) -> f64 {
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let el = start.elapsed();
    let ns_per_iter = el.as_nanos() as f64 / iters as f64;
    println!(
        "{name:<44} {iters:>10} iters  {ns_per_iter:>10.1} ns/iter  {:>12.0} /s",
        iters as f64 / el.as_secs_f64()
    );
    ns_per_iter
}

/// The stability hot path: one promise delta + one watermark query per
/// iteration, over r=5 sources at majority 3. `scan` collects and sorts
/// every source frontier per query (the seed's behaviour);
/// `incremental` reads the cached majority frontier maintained on deltas.
fn stability_watermark_bench() -> (f64, f64) {
    let procs: Vec<ProcessId> = (0..5).map(ProcessId).collect();

    let mut scan_store = PromiseStore::default();
    let mut next = 1u64;
    let scan_ns = bench("stability watermark: scan (seed path)", 1_000_000, || {
        let batch = PromiseSet { detached: vec![(next, next)], attached: vec![] };
        scan_store.add(procs[(next % 5) as usize], &batch, |_| true);
        next += 1;
        std::hint::black_box(scan_store.stable_watermark(&procs, 3));
    });

    let mut inc_store = PromiseStore::default();
    inc_store.init_quorum(&procs, 3);
    let mut next = 1u64;
    let inc_ns = bench("stability watermark: incremental cache", 1_000_000, || {
        let batch = PromiseSet { detached: vec![(next, next)], attached: vec![] };
        inc_store.add(procs[(next % 5) as usize], &batch, |_| true);
        next += 1;
        std::hint::black_box(inc_store.watermark());
    });

    // The two paths must agree on the final watermark.
    assert_eq!(inc_store.watermark(), scan_store.stable_watermark(&procs, 3));
    (scan_ns, inc_ns)
}

fn write_stability_baseline(scan_ns: f64, inc_ns: f64) {
    let speedup = scan_ns / inc_ns;
    let json = format!(
        "{{\n  \"bench\": \"stability_watermark\",\n  \"unit\": \"ns_per_iter\",\n  \
         \"workload\": \"add 1 promise + query majority watermark, r=5, majority=3\",\n  \
         \"scan_ns_per_iter\": {scan_ns:.1},\n  \"incremental_ns_per_iter\": {inc_ns:.1},\n  \
         \"speedup\": {speedup:.2},\n  \
         \"regenerate\": \"cargo bench --bench microbench\"\n}}\n"
    );
    // cargo runs benches with CWD = the package dir (rust/); the baseline
    // lives at the repo root next to ROADMAP.md.
    let path = baseline_path("BENCH_stability.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("stability baseline written to {path} (speedup {speedup:.2}x)"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

/// cargo runs benches with CWD = the package dir (rust/); the baselines
/// live at the repo root next to ROADMAP.md.
fn baseline_path(name: &str) -> String {
    match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => format!("{d}/../{name}"),
        Err(_) => name.to_string(),
    }
}

/// Message batching on vs off: the same saturating Tempo workload under
/// the CPU/NIC resource model, where every delivered frame costs a fixed
/// per-message CPU charge — exactly what `MBatch` amortizes. Records
/// simulated throughput and the observed batching counters.
fn batching_comparison() {
    fn one(config: Config) -> (f64, f64, tempo::metrics::Counters) {
        let mut o = SimOpts::new(Topology::ec2());
        o.clients_per_site = 128;
        o.warmup_us = 1_000_000;
        o.duration_us = 5_000_000;
        o.seed = 7;
        o.resources = Some(ResourceModel::cluster());
        let start = Instant::now();
        let result = run::<Tempo, _>(config, o, ConflictWorkload::new(0.02, 100));
        let wall = start.elapsed().as_secs_f64();
        (result.metrics.throughput_ops_s(), wall, result.metrics.counters)
    }

    let (base_ops_s, base_wall, base_c) = one(Config::new(5, 1));
    let (batch_ops_s, batch_wall, batch_c) = one(Config::new(5, 1).with_batching(16));
    println!(
        "sim throughput (resource model): unbatched {base_ops_s:.0} ops/s, \
         batched {batch_ops_s:.0} ops/s ({:.2}x); \
         {} batches, {:.1} msgs/batch",
        batch_ops_s / base_ops_s,
        batch_c.batches_sent,
        batch_c.mean_batch_size()
    );
    let json = format!(
        "{{\n  \"bench\": \"message_batching\",\n  \
         \"workload\": \"tempo r=5 f=1, 640 closed-loop clients, 2% conflicts, \
         100B payloads, CPU/NIC resource model (c5.2xlarge-like), 5s window\",\n  \
         \"unbatched_ops_per_s\": {base_ops_s:.0},\n  \
         \"batched_ops_per_s\": {batch_ops_s:.0},\n  \
         \"throughput_ratio\": {:.3},\n  \
         \"batch_max_msgs\": 16,\n  \
         \"batches_sent\": {},\n  \
         \"mean_batch_size\": {:.2},\n  \
         \"unbatched_wall_s\": {base_wall:.2},\n  \"batched_wall_s\": {batch_wall:.2},\n  \
         \"unbatched_fast_path_ratio\": {:.3},\n  \"batched_fast_path_ratio\": {:.3},\n  \
         \"regenerate\": \"cargo bench --bench microbench\"\n}}\n",
        batch_ops_s / base_ops_s,
        batch_c.batches_sent,
        batch_c.mean_batch_size(),
        base_c.fast_path_ratio(),
        batch_c.fast_path_ratio(),
    );
    let path = baseline_path("BENCH_batching.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("batching baseline written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_stability_bench(shape: KernelShape, bits: &[u8]) {
    use tempo::runtime::stability::StabilityKernel;
    use tempo::runtime::Runtime;
    if std::path::Path::new("artifacts/stability.hlo.txt").exists() {
        let runtime = Runtime::cpu().unwrap();
        let kernel =
            StabilityKernel::load(&runtime, "artifacts/stability.hlo.txt", shape).unwrap();
        let queue = vec![1i32; shape.partitions * shape.queue];
        bench("stability PJRT artifact [16,5,64]", 2_000, || {
            std::hint::black_box(kernel.tick(bits, &queue).unwrap());
        });
    } else {
        println!("stability PJRT artifact: skipped (run `make artifacts`)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_stability_bench(_shape: KernelShape, _bits: &[u8]) {
    println!("stability PJRT artifact: skipped (build with --features pjrt)");
}

fn main() {
    println!("--- component microbenchmarks ---");

    // Promise store: contiguous adds + watermark queries.
    let procs: Vec<ProcessId> = (0..5).map(ProcessId).collect();
    let mut store = PromiseStore::default();
    store.init_quorum(&procs, 3);
    let mut next = 1u64;
    bench("promise_store add_range + watermark", 1_000_000, || {
        let batch = PromiseSet { detached: vec![(next, next)], attached: vec![] };
        store.add(procs[(next % 5) as usize], &batch, |_| true);
        next += 1;
        std::hint::black_box(store.watermark());
    });

    // Scan-based vs incremental stability watermark (the hot path this
    // refactor optimizes); record the baseline JSON.
    let (scan_ns, inc_ns) = stability_watermark_bench();
    write_stability_baseline(scan_ns, inc_ns);

    // Message batching on vs off under the resource model; records
    // BENCH_batching.json.
    batching_comparison();

    // Histogram record.
    let mut h = Histogram::new();
    let mut rng = Rng::new(1);
    bench("histogram record", 4_000_000, || {
        h.record(rng.gen_between(100, 1_000_000));
    });

    // SCC executor: 1k-node chains.
    bench("dep_graph 1k-chain commit+execute", 200, || {
        let mut g = DepGraph::default();
        let mut prev: Option<Dot> = None;
        for i in 1..=1000u64 {
            let d = Dot::new(ProcessId(0), i);
            g.commit(d, prev.into_iter().collect());
            prev = Some(d);
        }
        let sccs = g.ready_from(prev.unwrap()).unwrap();
        std::hint::black_box(sccs.len());
    });

    // End-to-end simulator event rate (Tempo, 5 sites, 2% conflicts).
    let start = Instant::now();
    let config = Config::new(5, 1);
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 256;
    o.warmup_us = 0;
    o.duration_us = 10_000_000;
    o.seed = 99;
    let result = run::<Tempo, _>(config, o, ConflictWorkload::new(0.02, 100));
    let el = start.elapsed();
    let cmds = result.metrics.ops;
    println!(
        "simulator end-to-end: {cmds} cmds in {:.2}s wall = {:.0} cmds/s (sim-time 10s)",
        el.as_secs_f64(),
        cmds as f64 / el.as_secs_f64()
    );

    // Stability kernel: pure Rust reference, then (optionally) PJRT.
    let shape = KernelShape::default();
    let bits = vec![1u8; shape.partitions * shape.replicas * shape.window];
    bench("stability pure-rust [16,5,64]", 200_000, || {
        std::hint::black_box(stable_watermarks_rust(&bits, &shape));
    });
    pjrt_stability_bench(shape, &bits);
}
