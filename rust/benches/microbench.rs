//! Component microbenchmarks for the §Perf pass: simulator event rate,
//! promise-store throughput, SCC executor, histogram, and the PJRT
//! stability kernel vs the pure-Rust path.

use std::time::Instant;
use tempo::core::{Config, Dot, ProcessId};
use tempo::executor::DepGraph;
use tempo::metrics::Histogram;
use tempo::protocol::tempo::promises::{PromiseSet, PromiseStore};
use tempo::protocol::tempo::Tempo;
use tempo::runtime::stability::{stable_watermarks_rust, KernelShape, StabilityKernel};
use tempo::runtime::Runtime;
use tempo::sim::{run, SimOpts, Topology};
use tempo::util::Rng;
use tempo::workload::ConflictWorkload;

fn bench(name: &str, iters: u64, mut f: impl FnMut()) {
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let el = start.elapsed();
    println!(
        "{name:<44} {iters:>10} iters  {:>10.1} ns/iter  {:>12.0} /s",
        el.as_nanos() as f64 / iters as f64,
        iters as f64 / el.as_secs_f64()
    );
}

fn main() {
    println!("--- component microbenchmarks ---");

    // Promise store: contiguous adds + watermark queries.
    let procs: Vec<ProcessId> = (0..5).map(ProcessId).collect();
    let mut store = PromiseStore::default();
    let mut next = 1u64;
    bench("promise_store add_range + watermark", 1_000_000, || {
        let batch = PromiseSet { detached: vec![(next, next)], attached: vec![] };
        store.add(procs[(next % 5) as usize], &batch, |_| true);
        next += 1;
        std::hint::black_box(store.stable_watermark(&procs, 3));
    });

    // Histogram record.
    let mut h = Histogram::new();
    let mut rng = Rng::new(1);
    bench("histogram record", 4_000_000, || {
        h.record(rng.gen_between(100, 1_000_000));
    });

    // SCC executor: 1k-node chains.
    bench("dep_graph 1k-chain commit+execute", 200, || {
        let mut g = DepGraph::default();
        let mut prev: Option<Dot> = None;
        for i in 1..=1000u64 {
            let d = Dot::new(ProcessId(0), i);
            g.commit(d, prev.into_iter().collect());
            prev = Some(d);
        }
        let sccs = g.ready_from(prev.unwrap()).unwrap();
        std::hint::black_box(sccs.len());
    });

    // End-to-end simulator event rate (Tempo, 5 sites, 2% conflicts).
    let start = Instant::now();
    let config = Config::new(5, 1);
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 256;
    o.warmup_us = 0;
    o.duration_us = 10_000_000;
    o.seed = 99;
    let result = run::<Tempo, _>(config, o, ConflictWorkload::new(0.02, 100));
    let el = start.elapsed();
    let cmds = result.metrics.ops;
    println!(
        "simulator end-to-end: {cmds} cmds in {:.2}s wall = {:.0} cmds/s (sim-time 10s)",
        el.as_secs_f64(),
        cmds as f64 / el.as_secs_f64()
    );

    // Stability kernel: pure Rust vs PJRT artifact.
    let shape = KernelShape::default();
    let bits = vec![1u8; shape.partitions * shape.replicas * shape.window];
    bench("stability pure-rust [16,5,64]", 200_000, || {
        std::hint::black_box(stable_watermarks_rust(&bits, &shape));
    });
    if std::path::Path::new("artifacts/stability.hlo.txt").exists() {
        let runtime = Runtime::cpu().unwrap();
        let kernel =
            StabilityKernel::load(&runtime, "artifacts/stability.hlo.txt", shape).unwrap();
        let queue = vec![1i32; shape.partitions * shape.queue];
        bench("stability PJRT artifact [16,5,64]", 2_000, || {
            std::hint::black_box(kernel.tick(&bits, &queue).unwrap());
        });
    } else {
        println!("stability PJRT artifact: skipped (run `make artifacts`)");
    }
}
