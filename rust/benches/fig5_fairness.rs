//! Figure 5: per-site latency with 5 EC2 sites under a low conflict rate
//! (2%). Paper setup: 512 clients/site; scaled here to 64/site (shape, not
//! absolute numbers — see EXPERIMENTS.md).
//!
//! Expected shape: FPaxos satisfies the leader site ~3x better than remote
//! sites; Tempo/Atlas/Caesar are uniform; Tempo f=2 beats Atlas f=2.

use tempo::bench_util::{latency_opts, ms, print_table};
use tempo::core::Config;
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::Atlas;
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::ConflictWorkload;

const CLIENTS: usize = 64;
const CONFLICT: f64 = 0.02;

fn row<P: Protocol>(name: &str, f: usize, seed: u64) -> Vec<String> {
    let config = Config::new(5, f);
    let opts: SimOpts = latency_opts(Topology::ec2(), CLIENTS, seed);
    let result = run::<P, _>(config, opts, ConflictWorkload::new(CONFLICT, 100));
    let mut cells = vec![format!("{name} f={f}")];
    let mut sum = 0.0;
    for site in 0..5 {
        let m = result
            .metrics
            .site_latency
            .get(&site)
            .map(|h| h.mean() as u64)
            .unwrap_or(0);
        sum += m as f64;
        cells.push(ms(m));
    }
    cells.push(ms((sum / 5.0) as u64));
    cells
}

fn main() {
    let mut rows = Vec::new();
    rows.push(row::<Tempo>("tempo", 1, 501));
    rows.push(row::<Tempo>("tempo", 2, 502));
    rows.push(row::<Atlas>("atlas", 1, 503));
    rows.push(row::<Atlas>("atlas", 2, 504));
    rows.push(row::<FPaxos>("fpaxos", 1, 505));
    rows.push(row::<FPaxos>("fpaxos", 2, 506));
    rows.push(row::<Caesar>("caesar", 2, 507));
    print_table(
        "Figure 5: per-site mean latency (ms), 5 sites, 2% conflicts",
        &["protocol", "Ireland", "N.Calif", "Singapore", "Canada", "S.Paulo", "avg"],
        &rows,
    );
    println!("\nLeader site for FPaxos is Ireland (fairest placement, as in the paper).");
}
