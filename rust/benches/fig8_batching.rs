//! Figure 8: maximum throughput with batching OFF/ON for payload sizes
//! 256 B, 1 KiB and 4 KiB (batch window 5 ms / 10^5 commands, as in the
//! paper). Cluster mode, high load.
//!
//! Expected shape: batching rescues FPaxos at small payloads (the leader
//! thread is the bottleneck) but brings only moderate gains to Tempo,
//! whose load is already spread across replicas.

use tempo::bench_util::{kops, print_table, throughput_opts};
use tempo::core::Config;
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, Topology};
use tempo::workload::ConflictWorkload;

const CLIENTS: usize = 4096;

fn cell<P: Protocol>(payload: u32, batching: bool, seed: u64) -> f64 {
    let config = Config::new(5, 1);
    let mut opts = throughput_opts(Topology::ec2(), CLIENTS, seed);
    if batching {
        opts.batching = Some((100_000, 5_000));
    }
    let result = run::<P, _>(config, opts, ConflictWorkload::new(0.02, payload));
    result.metrics.throughput_ops_s()
}

fn main() {
    let mut rows = Vec::new();
    for (i, &payload) in [256u32, 1024, 4096].iter().enumerate() {
        let s = 800 + 10 * i as u64;
        let f_off = cell::<FPaxos>(payload, false, s + 1);
        let f_on = cell::<FPaxos>(payload, true, s + 2);
        let t_off = cell::<Tempo>(payload, false, s + 3);
        let t_on = cell::<Tempo>(payload, true, s + 4);
        rows.push(vec![
            format!("{payload}B"),
            kops(f_off),
            kops(f_on),
            format!("{:.1}x", f_on / f_off.max(1.0)),
            kops(t_off),
            kops(t_on),
            format!("{:.1}x", t_on / t_off.max(1.0)),
        ]);
    }
    print_table(
        "Figure 8: max throughput (kops/s), batching OFF vs ON (f = 1)",
        &["payload", "fpaxos OFF", "fpaxos ON", "gain", "tempo OFF", "tempo ON", "gain"],
        &rows,
    );
}
