//! Figure 6: latency percentiles (p95–p99.99) with 5 sites under a low
//! conflict rate (2%), at two load levels. Paper: 256 and 512 clients/site;
//! scaled to 64 and 128.
//!
//! Expected shape: Atlas/EPaxos/Caesar tails are several times Tempo's and
//! deteriorate with load; Tempo's tail stays flat (no dependency chains).

use tempo::bench_util::{latency_opts, ms, print_table};
use tempo::core::Config;
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::{Atlas, EPaxos};
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, Topology};
use tempo::workload::ConflictWorkload;

fn row<P: Protocol>(name: &str, f: usize, clients: usize, seed: u64) -> Vec<String> {
    let config = Config::new(5, f);
    let result = run::<P, _>(
        config,
        latency_opts(Topology::ec2(), clients, seed),
        ConflictWorkload::new(0.02, 100),
    );
    let t = result.metrics.latency.tail_summary();
    vec![
        format!("{name} f={f}"),
        clients.to_string(),
        ms(t.p95),
        ms(t.p99),
        ms(t.p99_9),
        ms(t.p99_99),
        t.count.to_string(),
    ]
}

fn main() {
    let mut rows = Vec::new();
    for (i, &clients) in [64usize, 128].iter().enumerate() {
        let s = 600 + 10 * i as u64;
        rows.push(row::<Tempo>("tempo", 1, clients, s + 1));
        rows.push(row::<Tempo>("tempo", 2, clients, s + 2));
        rows.push(row::<Atlas>("atlas", 1, clients, s + 3));
        rows.push(row::<Atlas>("atlas", 2, clients, s + 4));
        rows.push(row::<EPaxos>("epaxos", 1, clients, s + 5));
        rows.push(row::<Caesar>("caesar", 2, clients, s + 6));
    }
    print_table(
        "Figure 6: latency percentiles (ms), 5 sites, 2% conflicts",
        &["protocol", "clients/site", "p95", "p99", "p99.9", "p99.99", "samples"],
        &rows,
    );
}
