//! Durability bench: what the WAL + snapshot subsystem (`store::storage`,
//! `StorageMode::Disk`) costs on the write path and buys at recovery.
//! Writes `BENCH_durability.json` at the repo root.
//!
//! Three measurements:
//!
//! - **throughput cells**: the same zipf workload through the
//!   deterministic simulator under `Memory` and under `Disk` at several
//!   group-commit batch sizes; ops/s-wall plus the physical bytes the
//!   modelled disk absorbed (WAL appends + snapshot pages + manifests).
//! - **write amplification**: physical bytes / logical payload bytes per
//!   disk cell — the CI gate wants ≤ 3×, i.e. the CRC framing, dot/ts
//!   headers and content-addressed checkpoint reuse keep overhead small.
//! - **recovery sweep**: `Durable::recover` wall time vs WAL-tail length
//!   against a backend populated through a real `Executor` — the full
//!   tail must replay and the recovered digest must equal the pre-crash
//!   store's, with and without a snapshot shortening the tail.
//!
//! Run with: `cargo bench --bench durability`

use std::time::Instant;
use tempo::core::{ClientId, Command, Config, Dot, Op, ProcessId, Rid, StorageMode};
use tempo::executor::Executor;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Action;
use tempo::sim::{run, SimOpts, Topology};
use tempo::store::storage::{Durable, MemBackend};
use tempo::store::{KvStore, StateMachine};
use tempo::workload::ZipfWorkload;

const PAYLOAD: u32 = 256;

struct Cell {
    mode: String,
    fsync_batch: usize,
    ops: u64,
    ops_per_s_wall: f64,
    wal_records: u64,
    fsyncs: u64,
    snapshots: u64,
    physical_bytes: u64,
    logical_bytes: u64,
    write_amp: f64,
}

fn sim_opts() -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 16;
    o.warmup_us = 500_000;
    o.duration_us = 4_000_000;
    o.seed = 11;
    o
}

fn throughput_cell(mode: &str, storage: StorageMode, fsync_batch: usize) -> Cell {
    let config = Config::new(3, 1)
        .with_storage(storage)
        .with_wal_fsync_batch(fsync_batch)
        .with_snapshot_every(1024);
    let workload = ZipfWorkload::new(10_000, 0.5, PAYLOAD);
    let t0 = Instant::now();
    let result = run::<Tempo, _>(config, sim_opts(), workload);
    let wall = t0.elapsed().as_secs_f64();
    let c = &result.metrics.counters;
    // Logical: the payload every *replica* applied (wal_records counts
    // per-replica executions, so physical and logical are on the same
    // side of the replication factor).
    let logical = c.wal_records * PAYLOAD as u64;
    Cell {
        mode: mode.to_string(),
        fsync_batch,
        ops: result.metrics.ops,
        ops_per_s_wall: result.metrics.ops as f64 / wall,
        wal_records: c.wal_records,
        fsyncs: c.wal_fsyncs,
        snapshots: c.snapshots_taken,
        physical_bytes: c.wal_bytes,
        logical_bytes: logical,
        write_amp: if logical > 0 { c.wal_bytes as f64 / logical as f64 } else { 0.0 },
    }
}

struct RecoveryCell {
    wal_tail: u64,
    snapshot_every: u64,
    applied: u64,
    snapshot_applied: u64,
    wal_replayed: u64,
    recovery_us: u64,
    us_per_record: f64,
    digest_match: bool,
}

/// Populate a shared [`MemBackend`] by pushing `n` ordered executions
/// through a real `Executor<Durable<KvStore>>` (the production write
/// path: apply → dedup → WAL append → group commit → checkpoint), then
/// time `Durable::recover` against it.
fn recovery_cell(n: u64, fsync_batch: usize, snapshot_every: u64) -> RecoveryCell {
    let backend = MemBackend::new();
    let durable =
        Durable::new(KvStore::new(), Box::new(backend.clone()), fsync_batch, snapshot_every);
    let mut exec = Executor::new(ProcessId(0), durable);
    for i in 0..n {
        let cmd = Command::single(Rid::new(ClientId(i % 64), i / 64 + 1), i % 4096, Op::Put, 64);
        let _ = exec.absorb(vec![Action::Execute {
            dot: Dot::new(ProcessId(0), i + 1),
            cmd,
            ts: i + 1,
        }]);
    }
    exec.state_mut().flush(); // drain the group-commit window
    let digest_before = exec.state().digest();
    let snapshot_applied_expect = if snapshot_every == 0 {
        0
    } else {
        n - n % snapshot_every
    };

    let t0 = Instant::now();
    let (durable, recovery) =
        Durable::<KvStore>::recover(Box::new(backend.clone()), fsync_batch, snapshot_every);
    let dt = t0.elapsed();
    assert_eq!(recovery.snapshot_applied, snapshot_applied_expect);
    assert_eq!(
        recovery.snapshot_applied + recovery.wal_replayed,
        n,
        "recovery must account for every flushed execution"
    );
    RecoveryCell {
        wal_tail: n - snapshot_applied_expect,
        snapshot_every,
        applied: durable.applied(),
        snapshot_applied: recovery.snapshot_applied,
        wal_replayed: recovery.wal_replayed,
        recovery_us: dt.as_micros() as u64,
        us_per_record: if recovery.wal_replayed > 0 {
            dt.as_micros() as f64 / recovery.wal_replayed as f64
        } else {
            0.0
        },
        digest_match: durable.digest() == digest_before,
    }
}

fn main() {
    println!("--- durability bench (tempo r=3 f=1, zipf 10k keys, {PAYLOAD} B payload) ---");

    let mut cells = vec![throughput_cell("memory", StorageMode::Memory, 1)];
    for batch in [1usize, 8, 64] {
        cells.push(throughput_cell("disk", StorageMode::Disk, batch));
    }
    for c in &cells {
        println!(
            "{:>6} fsync_batch={:<3}: {:>8} ops, {:>10.0} ops/s-wall, {:>9} wal B, \
             amp {:.2}x, {} records / {} fsyncs / {} snapshots",
            c.mode, c.fsync_batch, c.ops, c.ops_per_s_wall, c.physical_bytes, c.write_amp,
            c.wal_records, c.fsyncs, c.snapshots
        );
    }
    let mem_rate = cells[0].ops_per_s_wall;
    let disk_rate = cells[1..].iter().map(|c| c.ops_per_s_wall).fold(f64::MAX, f64::min);
    let slowdown = mem_rate / disk_rate;
    println!("worst disk cell vs memory: {slowdown:.2}x slower");

    // Recovery: pure WAL tails of increasing length, then a snapshot
    // cell where only the tail past the checkpoint replays.
    let mut recoveries = Vec::new();
    for n in [1_000u64, 10_000, 50_000] {
        recoveries.push(recovery_cell(n, 8, 0));
    }
    recoveries.push(recovery_cell(50_000, 8, 4_096));
    for r in &recoveries {
        assert!(r.digest_match, "recovered digest diverged (tail {})", r.wal_tail);
        println!(
            "recover: snapshot@{:<5} + {:>6}-record tail -> {:>8} us ({:.2} us/record), \
             applied={}, digest match",
            r.snapshot_every, r.wal_tail, r.recovery_us, r.us_per_record, r.applied
        );
    }

    let mut cell_rows = String::new();
    for (i, c) in cells.iter().enumerate() {
        cell_rows.push_str(&format!(
            "    {{\"mode\": \"{}\", \"fsync_batch\": {}, \"ops\": {}, \
             \"ops_per_s_wall\": {:.0}, \"wal_records\": {}, \"fsyncs\": {}, \
             \"snapshots\": {}, \"physical_bytes\": {}, \"logical_bytes\": {}, \
             \"write_amp\": {:.3}}}{}\n",
            c.mode, c.fsync_batch, c.ops, c.ops_per_s_wall, c.wal_records, c.fsyncs,
            c.snapshots, c.physical_bytes, c.logical_bytes, c.write_amp,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    let mut rec_rows = String::new();
    for (i, r) in recoveries.iter().enumerate() {
        rec_rows.push_str(&format!(
            "    {{\"wal_tail\": {}, \"snapshot_every\": {}, \"applied\": {}, \
             \"snapshot_applied\": {}, \"wal_replayed\": {}, \"recovery_us\": {}, \
             \"us_per_record\": {:.3}, \"digest_match\": {}}}{}\n",
            r.wal_tail, r.snapshot_every, r.applied, r.snapshot_applied, r.wal_replayed,
            r.recovery_us, r.us_per_record, r.digest_match,
            if i + 1 == recoveries.len() { "" } else { "," }
        ));
    }
    let max_amp =
        cells.iter().filter(|c| c.mode == "disk").map(|c| c.write_amp).fold(0.0f64, f64::max);
    let json = format!(
        "{{\n  \"bench\": \"durability\",\n  \
         \"workload\": \"tempo r=3 f=1; zipf theta=0.5 over 10k keys, {PAYLOAD} B \
         payload, 48 closed-loop clients, 4s sim window; recovery sweep \
         drives a real Executor<Durable<KvStore>> and times \
         Durable::recover\",\n  \
         \"write_amp_disk_max\": {max_amp:.3},\n  \
         \"disk_slowdown_vs_memory\": {slowdown:.3},\n  \
         \"harness\": \"rust (cargo bench --bench durability)\",\n  \
         \"cells\": [\n{cell_rows}  ],\n  \
         \"recovery\": [\n{rec_rows}  ],\n  \
         \"regenerate\": \"cargo bench --bench durability\"\n}}\n"
    );
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => format!("{d}/../BENCH_durability.json"),
        Err(_) => "BENCH_durability.json".to_string(),
    };
    match std::fs::write(&path, &json) {
        Ok(()) => println!("durability baseline written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
