//! Stability-powered local-read bench: the cost of serving a read at the
//! coordinator from the stability frontier vs ordering a command through
//! the full write path. Writes `BENCH_reads.json` at the repo root.
//!
//! Three measurements:
//!
//! - **local-read service rate**: a hot loop of `Protocol::submit_read`
//!   calls against one Tempo replica (frontier covering, so every read
//!   serves instantly), absorbed through a real `Executor` so the number
//!   includes the KV apply and reply construction — ns/read and reads/s.
//!   Outbound protocol bytes are *counted*, not assumed: the gate wants
//!   ~zero wire bytes per local read.
//! - **write-path baseline**: ops/s-wall of an all-write single-key zipf
//!   run through the deterministic simulator — the cost of the ordering
//!   path a read skips. The headline ratio (local-read rate / write-path
//!   rate) is what "coordination-free" buys per operation.
//! - **mix cells**: 95/5 and 50/50 read mixes at low/high zipf contention
//!   through the simulator, reporting the local-read share and the
//!   degraded (slow) read count — all reads must serve locally.
//!
//! Run with: `cargo bench --bench reads`

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;
use tempo::client::Session;
use tempo::core::{ClientId, Config, ProcessId};
use tempo::executor::Executor;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::{Action, Protocol};
use tempo::sim::{run, SimOpts, Topology};
use tempo::store::KvStore;
use tempo::workload::ZipfWorkload;

/// Counts every heap allocation the process makes (same harness as
/// `benches/workers.rs`).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Hot loop: `n` instant local reads against one replica, through the
/// executor. Returns (reads/s, wire bytes/read, allocs/read).
fn micro_local_reads(n: u64) -> (f64, f64, f64) {
    let mut p = Tempo::new(ProcessId(0), Config::new(3, 1));
    let mut exec = Executor::new(ProcessId(0), KvStore::new());
    let mut session = Session::new(ClientId(1));
    let mut wire_bytes = 0u64;
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for i in 0..n {
        let cmd = session.read_single(i % 1024);
        let actions = exec.absorb(p.submit_read(cmd, 0, i));
        for action in &actions {
            match action {
                Action::Send { msg, .. } => wire_bytes += Tempo::msg_size(msg),
                Action::SendShared { to, msg } => {
                    wire_bytes += to.len() as u64 * Tempo::msg_size(msg)
                }
                _ => {}
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let allocs = ALLOCS.load(Ordering::Relaxed) - a0;
    assert_eq!(exec.reads_served(), n, "every read must serve locally");
    assert_eq!(p.counters.local_reads, n);
    (n as f64 / wall, wire_bytes as f64 / n as f64, allocs as f64 / n as f64)
}

struct MixCell {
    read_pct: u32,
    theta: f64,
    ops: u64,
    ops_per_s_wall: f64,
    local_reads: u64,
    slow_reads: u64,
}

fn sim_opts() -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 32;
    o.warmup_us = 500_000;
    o.duration_us = 4_000_000;
    o.seed = 7;
    o
}

fn mix(read_ratio: f64, theta: f64) -> MixCell {
    let config = Config::new(3, 1);
    let workload = ZipfWorkload::new(10_000, theta, 100).with_read_ratio(read_ratio);
    let t0 = Instant::now();
    let result = run::<Tempo, _>(config, sim_opts(), workload);
    let wall = t0.elapsed().as_secs_f64();
    MixCell {
        read_pct: (read_ratio * 100.0) as u32,
        theta,
        ops: result.metrics.ops,
        ops_per_s_wall: result.metrics.ops as f64 / wall,
        local_reads: result.metrics.counters.local_reads,
        slow_reads: result.metrics.counters.slow_reads,
    }
}

fn main() {
    println!("--- local-read bench (tempo r=3 f=1) ---");

    let n = 2_000_000;
    let (reads_per_s, bytes_per_read, allocs_per_read) = micro_local_reads(n);
    println!(
        "local reads : {reads_per_s:>12.0} reads/s, {bytes_per_read:.4} wire B/read, \
         {allocs_per_read:.1} allocs/read"
    );

    // Write-path baseline: the same zipf shape, every command ordered.
    let baseline = mix(0.0, 0.5);
    println!(
        "write path  : {:>12.0} ops/s-wall ({} ops)",
        baseline.ops_per_s_wall, baseline.ops
    );
    let speedup = reads_per_s / baseline.ops_per_s_wall;
    println!("read speedup vs write path: {speedup:.1}x");

    let mut cells = Vec::new();
    for &(ratio, theta) in &[(0.95, 0.5), (0.95, 0.99), (0.5, 0.5), (0.5, 0.99)] {
        let c = mix(ratio, theta);
        println!(
            "mix {}/{} theta={:<4}: {:>8} ops, {:>10.0} ops/s-wall, {} local reads, {} slow",
            c.read_pct,
            100 - c.read_pct,
            c.theta,
            c.ops,
            c.ops_per_s_wall,
            c.local_reads,
            c.slow_reads
        );
        cells.push(c);
    }

    let mut rows = String::new();
    for (i, c) in cells.iter().enumerate() {
        let contention = if c.theta < 0.9 { "low" } else { "high" };
        rows.push_str(&format!(
            "    {{\"read_pct\": {}, \"zipf_theta\": {}, \"contention\": \"{}\", \
             \"ops\": {}, \"ops_per_s_wall\": {:.0}, \"local_reads\": {}, \
             \"slow_reads\": {}}}{}\n",
            c.read_pct,
            c.theta,
            contention,
            c.ops,
            c.ops_per_s_wall,
            c.local_reads,
            c.slow_reads,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"local_reads\",\n  \
         \"workload\": \"tempo r=3 f=1; micro loop of {n} instant local reads \
         through a real Executor; write baseline and read mixes are single-key \
         zipf over 10k keys, 96 closed-loop clients, 4s sim window\",\n  \
         \"local_read_ops_per_s\": {reads_per_s:.0},\n  \
         \"wire_bytes_per_local_read\": {bytes_per_read:.4},\n  \
         \"allocs_per_local_read\": {allocs_per_read:.1},\n  \
         \"write_path_ops_per_s\": {base:.0},\n  \
         \"read_speedup_vs_write_path\": {speedup:.1},\n  \
         \"harness\": \"rust (cargo bench --bench reads)\",\n  \
         \"cells\": [\n{rows}  ],\n  \
         \"regenerate\": \"cargo bench --bench reads\"\n}}\n",
        base = baseline.ops_per_s_wall,
    );
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => format!("{d}/../BENCH_reads.json"),
        Err(_) => "BENCH_reads.json".to_string(),
    };
    match std::fs::write(&path, &json) {
        Ok(()) => println!("local-read baseline written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
