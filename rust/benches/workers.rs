//! Worker-scaling bench: protocol throughput and allocation rate vs
//! `Config::workers`, under low- and high-contention single-key zipf
//! workloads. Writes `BENCH_workers.json` at the repo root.
//!
//! Two measurements per (workers, θ) cell, both over the same saturating
//! deterministic simulation:
//!
//! - **ops/s (wall)**: simulated commands completed per second of *host*
//!   wall time. The simulator is single-threaded, so this isolates the
//!   per-op CPU cost of the sharded protocol state (smaller per-slot maps,
//!   cheaper lookups) — it deliberately does *not* include the parallel
//!   speedup real worker threads add on top (`net::start_node` runs one
//!   thread per slot; the deterministic sim cannot, by design).
//! - **allocs/op**: heap allocations per completed command, measured by a
//!   counting global allocator — the zero-clone fan-out claim in numbers.
//!
//! Run with: `cargo bench --bench workers`

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;
use tempo::core::Config;
use tempo::protocol::common::Sharded;
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::ZipfWorkload;

/// Counts every heap allocation the process makes.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

struct Cell {
    workers: usize,
    theta: f64,
    ops: u64,
    ops_per_s_wall: f64,
    allocs_per_op: f64,
}

fn one(workers: usize, theta: f64) -> Cell {
    let config = Config::new(5, 1).with_workers(workers);
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 64;
    o.warmup_us = 500_000;
    o.duration_us = 4_000_000;
    o.seed = 7;
    let workload = ZipfWorkload::new(100_000, theta, 100);
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    let result = run::<Sharded<Tempo>, _>(config, o, workload);
    let wall = t0.elapsed().as_secs_f64();
    let allocs = ALLOCS.load(Ordering::Relaxed) - a0;
    let ops = result.metrics.ops;
    Cell {
        workers,
        theta,
        ops,
        ops_per_s_wall: ops as f64 / wall,
        allocs_per_op: allocs as f64 / ops.max(1) as f64,
    }
}

fn main() {
    println!("--- worker-scaling bench (tempo r=5 f=1, single-key zipf) ---");
    let mut cells = Vec::new();
    for &theta in &[0.5f64, 0.99] {
        for &workers in &[1usize, 2, 4] {
            let c = one(workers, theta);
            println!(
                "theta={:<4} workers={} : {:>8} ops, {:>12.0} ops/s-wall, {:>8.1} allocs/op",
                c.theta, c.workers, c.ops, c.ops_per_s_wall, c.allocs_per_op
            );
            cells.push(c);
        }
    }

    let mut rows = String::new();
    for (i, c) in cells.iter().enumerate() {
        let contention = if c.theta < 0.9 { "low" } else { "high" };
        rows.push_str(&format!(
            "    {{\"workers\": {}, \"zipf_theta\": {}, \"contention\": \"{}\", \
             \"ops\": {}, \"ops_per_s_wall\": {:.0}, \"allocs_per_op\": {:.1}}}{}\n",
            c.workers,
            c.theta,
            contention,
            c.ops,
            c.ops_per_s_wall,
            c.allocs_per_op,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"worker_sharding\",\n  \
         \"workload\": \"tempo r=5 f=1 behind Sharded router, 320 closed-loop \
         clients, single-key zipf over 100k keys, 100B payloads, 4s window\",\n  \
         \"note\": \"deterministic sim is single-threaded: ops_per_s_wall \
         isolates per-op protocol CPU cost, not thread parallelism; \
         allocs_per_op is the zero-clone fan-out measurement\",\n  \
         \"harness\": \"rust (cargo bench --bench workers)\",\n  \
         \"cells\": [\n{rows}  ],\n  \
         \"regenerate\": \"cargo bench --bench workers\"\n}}\n"
    );
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(d) => format!("{d}/../BENCH_workers.json"),
        Err(_) => "BENCH_workers.json".to_string(),
    };
    match std::fs::write(&path, &json) {
        Ok(()) => println!("worker-scaling baseline written to {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
