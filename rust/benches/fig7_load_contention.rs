//! Figure 7: throughput and latency with 5 sites as the load grows, under
//! low (2%) and moderate (10%) conflicts, 4 KB payloads, with the CPU/NIC
//! resource model on ("cluster mode"). Includes the utilization heatmap
//! columns. Paper: 32→20480 clients/site; scaled to 32→2048.
//!
//! Expected shape: FPaxos saturates first (leader NIC/CPU) and is
//! conflict-insensitive; Atlas loses throughput at 10% conflicts
//! (dependency chains); Caesar degrades more; Tempo's maximum throughput
//! is the highest and identical across conflict rates.

use tempo::bench_util::{kops, ms, print_table, throughput_opts};
use tempo::core::Config;
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::Atlas;
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, Topology};
use tempo::workload::ConflictWorkload;

const PAYLOAD: u32 = 4096;
const LOADS: [usize; 3] = [32, 128, 512];

fn sweep<P: Protocol>(name: &str, f: usize, conflict: f64, seed: u64, rows: &mut Vec<Vec<String>>) {
    for (i, &clients) in LOADS.iter().enumerate() {
        let config = Config::new(5, f);
        let result = run::<P, _>(
            config,
            throughput_opts(Topology::ec2(), clients, seed + i as u64),
            ConflictWorkload::new(conflict, PAYLOAD),
        );
        let (cpu, net_in, net_out) = result.metrics.mean_utilization();
        let (max_cpu, _, max_out) = result.metrics.max_utilization();
        eprintln!(
            "  done: {name} f={f} conflicts={:.0}% clients={clients} -> {:.1} kops/s",
            conflict * 100.0,
            result.metrics.throughput_ops_s() / 1e3
        );
        rows.push(vec![
            format!("{name} f={f}"),
            format!("{:.0}%", conflict * 100.0),
            clients.to_string(),
            kops(result.metrics.throughput_ops_s()),
            ms(result.metrics.latency.quantile(0.5)),
            ms(result.metrics.latency.quantile(0.99)),
            format!("{cpu:.0}/{max_cpu:.0}"),
            format!("{net_in:.0}"),
            format!("{net_out:.0}/{max_out:.0}"),
        ]);
    }
}

fn main() {
    let mut rows = Vec::new();
    for (ci, &conflict) in [0.02f64, 0.10].iter().enumerate() {
        let s = 700 + 100 * ci as u64;
        sweep::<Tempo>("tempo", 1, conflict, s + 10, &mut rows);
        sweep::<Tempo>("tempo", 2, conflict, s + 20, &mut rows);
        sweep::<Atlas>("atlas", 1, conflict, s + 30, &mut rows);
        sweep::<Atlas>("atlas", 2, conflict, s + 40, &mut rows);
        sweep::<FPaxos>("fpaxos", 1, conflict, s + 50, &mut rows);
        sweep::<Caesar>("caesar", 2, conflict, s + 60, &mut rows);
    }
    print_table(
        "Figure 7: throughput/latency vs load, 5 sites, 4KB payload (cluster mode)",
        &[
            "protocol",
            "conflicts",
            "clients/site",
            "kops/s",
            "p50 ms",
            "p99 ms",
            "cpu%avg/max",
            "in%",
            "out%avg/max",
        ],
        &rows,
    );
}
