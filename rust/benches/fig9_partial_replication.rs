//! Figure 9: partial replication with YCSB+T — maximum throughput of
//! Tempo vs Janus* across shard counts {2, 4, 6}, contention
//! zipf ∈ {0.5, 0.7} and Janus* write ratios {0%, 5%, 50%}. Each shard is
//! replicated at 3 sites (Ireland, N. California, Singapore), cluster mode.
//! Paper: 1M keys/shard; scaled to 100K keys/shard and fewer clients.
//!
//! Expected shape: Janus* loses throughput as writes/contention grow;
//! Tempo matches Janus*'s read-only ceiling, is unaffected by either knob,
//! and scales with the number of shards.

use tempo::bench_util::{kops, print_table, throughput_opts};
use tempo::core::Config;
use tempo::protocol::depsmr::Janus;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, Topology};
use tempo::workload::YcsbWorkload;

const CLIENTS: usize = 1024;
const KEYS_PER_SHARD: u64 = 100_000;

fn cell<P: Protocol>(shards: u32, zipf: f64, writes: f64, seed: u64) -> f64 {
    let config = Config::new(3, 1).with_shards(shards);
    let opts = throughput_opts(Topology::ec2_three(), CLIENTS, seed);
    let workload = YcsbWorkload::new(KEYS_PER_SHARD * shards as u64, zipf, writes);
    let result = run::<P, _>(config, opts, workload);
    result.metrics.throughput_ops_s()
}

fn main() {
    let mut rows = Vec::new();
    for (zi, &zipf) in [0.5f64, 0.7].iter().enumerate() {
        for (si, &shards) in [2u32, 4, 6].iter().enumerate() {
            let s = 900 + 100 * zi as u64 + 10 * si as u64;
            let tempo = cell::<Tempo>(shards, zipf, 0.5, s + 1);
            let j0 = cell::<Janus>(shards, zipf, 0.0, s + 2);
            let j5 = cell::<Janus>(shards, zipf, 0.05, s + 3);
            let j50 = cell::<Janus>(shards, zipf, 0.5, s + 4);
            rows.push(vec![
                format!("zipf={zipf}"),
                shards.to_string(),
                kops(tempo),
                kops(j0),
                kops(j5),
                kops(j50),
                format!("{:.1}x", tempo / j50.max(1.0)),
            ]);
        }
    }
    print_table(
        "Figure 9: max throughput (kops/s), YCSB+T, 3 sites per shard",
        &[
            "contention",
            "shards",
            "tempo",
            "janus* w=0%",
            "janus* w=5%",
            "janus* w=50%",
            "tempo/janus*w50",
        ],
        &rows,
    );
}
