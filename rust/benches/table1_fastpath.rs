//! Table 1: Tempo fast-path decision examples (r = 5, f ∈ {1, 2}).
//!
//! Reconstructs the four scenarios a)–d) of the paper's Table 1 by driving
//! the Tempo state machine directly with the exact clock interleavings and
//! printing the resulting proposals, match and fast-path columns.

use tempo::core::{ClientId, Command, Config, Dot, Op, ProcessId, Rid};
use tempo::protocol::tempo::msg::Msg;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::{Action, Protocol};

const KEY: u64 = 0;

/// Run one Table-1 scenario: `clocks[j]` is the pre-existing key-0 clock of
/// quorum process j (A = coordinator = index 0). Returns the quorum's
/// non-coordinator proposals and whether the fast path was taken.
fn scenario(f: usize, clocks: &[u64]) -> (Vec<u64>, bool) {
    let r = 5;
    let config = Config::new(r, f);
    let mut procs: Vec<Tempo> =
        (0..r as u32).map(|i| Tempo::new(ProcessId(i), config.clone())).collect();

    // Pre-bump each quorum member's key-0 clock by committing a filler
    // command at the wanted timestamp (clock bumps to it, Alg 1 line 25).
    for (j, &c) in clocks.iter().enumerate() {
        if c > 0 {
            let filler = Dot::new(ProcessId(10 + j as u32), 1);
            let cmd = Command::single(Rid::new(ClientId(99), 1), KEY, Op::Put, 0);
            let _ = procs[j].handle(
                ProcessId(j as u32),
                Msg::MCommitDirect { dot: filler, cmd, quorums: vec![].into(), final_ts: c },
                0,
            );
        }
    }

    // Coordinator A (process 0) submits; route messages synchronously.
    // submit() allocates the dot internally: the first command of P0 is
    // renamed to P0.1.
    let cmd = Command::single(Rid::new(ClientId(1), 1), KEY, Op::Put, 0);
    let mut queue: Vec<(ProcessId, ProcessId, Msg)> = Vec::new();
    let mut proposals: Vec<u64> = Vec::new();
    let mut saw_consensus = false;
    let mut committed = false;
    let actions = procs[0].submit(cmd, 0);
    collect(ProcessId(0), actions, &mut queue, &mut proposals, &mut saw_consensus, &mut committed);
    while let Some((from, to, msg)) = queue.pop() {
        let actions = procs[to.0 as usize].handle(from, msg, 0);
        collect(to, actions, &mut queue, &mut proposals, &mut saw_consensus, &mut committed);
    }
    // "Fast path" = committed without any consensus round (Alg 1 line 20).
    (proposals, committed && !saw_consensus)
}

fn collect(
    at: ProcessId,
    actions: Vec<Action<Msg>>,
    queue: &mut Vec<(ProcessId, ProcessId, Msg)>,
    proposals: &mut Vec<u64>,
    saw_consensus: &mut bool,
    committed: &mut bool,
) {
    // Flatten shared fan-outs into the per-destination sends they model.
    let sends = actions.into_iter().flat_map(|a| match a {
        Action::Send { to, msg } => vec![(to, msg)],
        Action::SendShared { to, msg } => {
            to.into_iter().map(|d| (d, msg.clone())).collect()
        }
        _ => vec![],
    });
    for (to, msg) in sends {
        if let Msg::MProposeAck { ts, .. } = &msg {
            proposals.push(ts[0].1);
        }
        if matches!(&msg, Msg::MConsensus { .. }) {
            *saw_consensus = true;
        }
        if matches!(&msg, Msg::MCommit { .. }) {
            *committed = true;
        }
        queue.push((at, to, msg));
    }
}

fn main() {
    // Paper Table 1: coordinator A (clock 5) proposes 6.
    let rows: Vec<(&str, usize, Vec<u64>, bool, bool, Vec<u64>)> = vec![
        // (case, f, clocks [A,B,C,(D)], expect match, expect fast, expect proposals)
        ("a) f = 2", 2, vec![5, 6, 10, 10], false, true, vec![7, 11, 11]),
        ("b) f = 2", 2, vec![5, 6, 10, 5], false, false, vec![6, 7, 11]),
        ("c) f = 1", 1, vec![5, 6, 10], false, true, vec![7, 11]),
        ("d) f = 1", 1, vec![5, 5, 1], true, true, vec![6, 6]),
    ];
    println!("Table 1: Tempo fast-path examples (r = 5, coordinator A proposes 6)");
    println!(
        "{:<10} {:>12} {:>20} {:>6} {:>10}",
        "case", "coordinator", "quorum proposals", "match", "fast path"
    );
    for (name, f, clocks, exp_match, exp_fast, exp_props) in rows {
        let (mut proposals, fast) = scenario(f, &clocks);
        proposals.sort_unstable();
        let matched = proposals.iter().all(|&t| t == 6);
        println!(
            "{:<10} {:>12} {:>20} {:>6} {:>10}",
            name,
            6,
            format!("{proposals:?}"),
            if matched { "yes" } else { "no" },
            if fast { "yes" } else { "no" }
        );
        assert_eq!(proposals, exp_props, "{name}: proposals diverge from Table 1");
        assert_eq!(matched, exp_match, "{name}: match column diverges from Table 1");
        assert_eq!(fast, exp_fast, "{name}: fast-path column diverges from Table 1");
    }
    println!("\nAll four scenarios reproduce Table 1 exactly.");
}
