//! L3↔L1/L2 bridge: load the AOT artifact through PJRT and cross-check
//! against the pure-Rust stability implementation on the golden vectors
//! shared with python/tests/test_kernel.py.
//!
//! Requires `make artifacts` (skips gracefully when the artifact is absent
//! so `cargo test` works before the Python toolchain ran) and the `pjrt`
//! feature (the offline registry has no `xla` crate; see rust/Cargo.toml).

#![cfg(feature = "pjrt")]

use tempo::runtime::stability::{stable_watermarks_rust, KernelShape, StabilityKernel};
use tempo::runtime::Runtime;

const ARTIFACT: &str = "artifacts/stability.hlo.txt";

fn golden_bits(shape: &KernelShape) -> Vec<u8> {
    // Mirror of test_golden_vectors_shared_with_rust in test_kernel.py:
    // bit(i,j,u) = ((i*7 + j*13 + u*3) % 5) != 0 for u < (i+j+1)*4.
    let (p, r, w) = (shape.partitions, shape.replicas, shape.window);
    let mut bits = vec![0u8; p * r * w];
    for i in 0..p {
        for j in 0..r {
            let limit = w.min((i + j + 1) * 4);
            for u in 0..limit {
                bits[(i * r + j) * w + u] =
                    if (i * 7 + j * 13 + u * 3) % 5 != 0 { 1 } else { 0 };
            }
        }
    }
    bits
}

#[test]
fn pjrt_artifact_matches_rust_reference() {
    if !std::path::Path::new(ARTIFACT).exists() {
        eprintln!("skipping: {ARTIFACT} missing (run `make artifacts`)");
        return;
    }
    let shape = KernelShape::default();
    let runtime = Runtime::cpu().expect("PJRT CPU client");
    let kernel = StabilityKernel::load(&runtime, ARTIFACT, shape).expect("compile artifact");

    let bits = golden_bits(&shape);
    let queue: Vec<i32> = (0..(shape.partitions * shape.queue) as i32).collect();
    let (wm, mask) = kernel.tick(&bits, &queue).expect("execute");

    let expect = stable_watermarks_rust(&bits, &shape);
    assert_eq!(wm, expect, "PJRT artifact disagrees with the Rust reference");

    // Mask semantics: queue_ts executable iff 0 < ts <= watermark.
    for i in 0..shape.partitions {
        for q in 0..shape.queue {
            let ts = queue[i * shape.queue + q];
            let expect_bit = (ts > 0 && ts <= wm[i]) as i32;
            assert_eq!(mask[i * shape.queue + q], expect_bit, "mask at ({i},{q})");
        }
    }
}

#[test]
fn pjrt_artifact_all_promised_window() {
    if !std::path::Path::new(ARTIFACT).exists() {
        return;
    }
    let shape = KernelShape::default();
    let runtime = Runtime::cpu().unwrap();
    let kernel = StabilityKernel::load(&runtime, ARTIFACT, shape).unwrap();
    let bits = vec![1u8; shape.partitions * shape.replicas * shape.window];
    let queue = vec![0i32; shape.partitions * shape.queue];
    let (wm, mask) = kernel.tick(&bits, &queue).unwrap();
    assert!(wm.iter().all(|&w| w == shape.window as i32));
    assert!(mask.iter().all(|&m| m == 0), "empty queue slots never execute");
}
