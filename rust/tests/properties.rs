//! Property-based tests over the core invariants (seeded harness in
//! `tempo::util::prop`; replay failures with `PROP_SEED=<seed>`).

use tempo::core::{ClientId, Command, Config, Dot, Op, ProcessId, Rid};
use tempo::executor::DepGraph;
use tempo::protocol::tempo::clock::Clock;
use tempo::protocol::tempo::promises::{PromiseSet, PromiseStore, SourceTracker};
use tempo::util::prop::{forall_seeds, forall};
use tempo::util::Rng;

#[test]
fn prop_clock_promises_tile_the_timestamp_space() {
    // Whatever interleaving of proposal/bump operations runs, the promises
    // generated tile 1..=Clock exactly once (Lemma 6: LocalPromises is
    // gapless) and proposals are strictly increasing.
    forall_seeds("clock-tiling", |seed| {
        let mut rng = Rng::new(seed);
        let mut clock = Clock::default();
        let mut all = PromiseSet::default();
        let mut last = 0u64;
        for i in 0..200 {
            if rng.gen_bool(0.5) {
                let m = rng.gen_range(20) + last;
                let t = clock.proposal(Dot::new(ProcessId(0), i), m);
                if t < m || t <= last {
                    return Err(format!("proposal {t} not above max({m}, last {last})"));
                }
                last = t;
            } else {
                clock.bump(last + rng.gen_range(10));
                last = clock.value();
            }
            all.merge(&clock.take_outbox());
        }
        // Tile check: every timestamp 1..=Clock appears exactly once.
        let mut covered = vec![0u32; clock.value() as usize + 1];
        for (lo, hi) in &all.detached {
            for u in *lo..=*hi {
                covered[u as usize] += 1;
            }
        }
        for (_, t) in &all.attached {
            covered[*t as usize] += 1;
        }
        for u in 1..=clock.value() as usize {
            if covered[u] != 1 {
                return Err(format!("timestamp {u} promised {} times", covered[u]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_source_tracker_matches_naive_set_model() {
    forall_seeds("tracker-vs-set", |seed| {
        let mut rng = Rng::new(seed);
        let mut tracker = SourceTracker::default();
        let mut model = std::collections::BTreeSet::new();
        for _ in 0..300 {
            if rng.gen_bool(0.7) {
                let u = rng.gen_range(120) + 1;
                tracker.add(u);
                model.insert(u);
            } else {
                let lo = rng.gen_range(100) + 1;
                let hi = lo + rng.gen_range(20);
                tracker.add_range(lo, hi);
                model.extend(lo..=hi);
            }
            let expect = (1..).take_while(|u| model.contains(u)).count() as u64;
            if tracker.highest_contiguous() != expect {
                return Err(format!(
                    "watermark {} != model {expect}",
                    tracker.highest_contiguous()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_promise_store_watermark_monotone_and_bounded() {
    forall_seeds("watermark-monotone", |seed| {
        let mut rng = Rng::new(seed);
        let procs: Vec<ProcessId> = (0..5).map(ProcessId).collect();
        let mut store = PromiseStore::default();
        let mut last = 0;
        for _ in 0..200 {
            let src = procs[rng.gen_range(5) as usize];
            let lo = rng.gen_range(50) + 1;
            let batch =
                PromiseSet { detached: vec![(lo, lo + rng.gen_range(8))], attached: vec![] };
            store.add(src, &batch, |_| true);
            let w = store.stable_watermark(&procs, 3);
            if w < last {
                return Err(format!("stable watermark regressed {last} -> {w}"));
            }
            // Bounded by the maximum single-source watermark.
            let max = procs.iter().map(|p| store.highest_contiguous(*p)).max().unwrap();
            if w > max {
                return Err(format!("watermark {w} above any source ({max})"));
            }
            last = w;
        }
        Ok(())
    });
}

#[test]
fn prop_dep_graph_executes_all_and_respects_order() {
    // Random DAG-ish dependency sets (possibly cyclic): once everything is
    // committed, everything executes, and a command never executes before
    // a dependency in a *different* SCC.
    forall_seeds("graph-total-execution", |seed| {
        let mut rng = Rng::new(seed);
        let n = 60 + rng.gen_range(60);
        let dots: Vec<Dot> = (0..n).map(|i| Dot::new(ProcessId((i % 5) as u32), i)).collect();
        let mut deps: Vec<Vec<Dot>> = Vec::new();
        for i in 0..n as usize {
            let mut d = Vec::new();
            for _ in 0..rng.gen_range(4) {
                let j = rng.gen_range(n) as usize;
                if j != i {
                    d.push(dots[j]);
                }
            }
            deps.push(d);
        }
        let mut g = DepGraph::default();
        let mut order: Vec<usize> = (0..n as usize).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            g.commit(dots[i], deps[i].clone());
        }
        // Execute everything reachable.
        let mut executed = Vec::new();
        for &d in &dots {
            if g.is_executed(d) {
                continue;
            }
            if let Some(sccs) = g.ready_from(d) {
                for scc in sccs {
                    for m in scc {
                        if !g.is_executed(m) {
                            g.mark_executed(m);
                            executed.push(m);
                        }
                    }
                }
            }
        }
        if executed.len() != n as usize {
            return Err(format!("only {}/{n} executed", executed.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_watermark_matches_scan() {
    // The O(1) cached majority watermark must agree with the scan-based
    // reference under any interleaving of detached ranges, gated attached
    // promises, and commits.
    forall_seeds("incremental-watermark", |seed| {
        let mut rng = Rng::new(seed);
        let procs: Vec<ProcessId> = (0..5).map(ProcessId).collect();
        let mut store = PromiseStore::default();
        store.init_quorum(&procs, 3);
        let mut gated: Vec<Dot> = Vec::new();
        for i in 0..300u64 {
            let src = procs[rng.gen_range(5) as usize];
            if rng.gen_bool(0.6) {
                let lo = rng.gen_range(80) + 1;
                let batch =
                    PromiseSet { detached: vec![(lo, lo + rng.gen_range(8))], attached: vec![] };
                store.add(src, &batch, |_| true);
            } else {
                let dot = Dot::new(src, i + 1);
                let batch = PromiseSet {
                    detached: vec![],
                    attached: vec![(dot, rng.gen_range(90) + 1)],
                };
                store.add(src, &batch, |_| false);
                gated.push(dot);
            }
            if !gated.is_empty() && rng.gen_bool(0.4) {
                let dot = gated.swap_remove(rng.gen_range(gated.len() as u64) as usize);
                store.on_commit(dot);
            }
            let scan = store.stable_watermark(&procs, 3);
            if store.watermark() != scan {
                return Err(format!("cached {} != scan {scan} at step {i}", store.watermark()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_decode_never_panics_on_corrupt_input() {
    // Malformed frames — random bytes, truncations, bit flips — must
    // return Err, never panic (the seed panicked on bad phase bytes).
    use tempo::net::wire::{decode, encode};
    use tempo::protocol::tempo::msg::{Msg, Phase};
    forall_seeds("wire-fuzz", |seed| {
        let mut rng = Rng::new(seed);
        // 1. Pure random bytes.
        let n = rng.gen_range(96) as usize;
        let junk: Vec<u8> = (0..n).map(|_| rng.gen_range(256) as u8).collect();
        let _ = decode(&junk);
        // 2. Truncations and single-bit corruptions of a valid frame.
        let dot = Dot::new(ProcessId(rng.gen_range(8) as u32), rng.gen_range(1 << 16) + 1);
        let msg = match rng.gen_range(5) {
            0 => Msg::MRecAck {
                dot,
                ts: vec![(rng.gen_range(100), rng.gen_range(100))],
                phase: Phase::RecoverR,
                abal: 1,
                bal: 2,
            },
            1 => Msg::MGarbageCollect {
                executed: vec![(ProcessId(rng.gen_range(8) as u32), rng.gen_range(1 << 20))],
            },
            2 => Msg::MPromises {
                promises: vec![(
                    rng.gen_range(1 << 20),
                    tempo::protocol::tempo::promises::PromiseSet {
                        detached: vec![(1, rng.gen_range(50) + 1)],
                        attached: vec![(dot, rng.gen_range(50) + 1)],
                    },
                )]
                .into(),
            },
            3 => Msg::MBatch {
                msgs: vec![
                    Msg::MStable { dot },
                    Msg::MBump { dot, ts: rng.gen_range(1 << 16) },
                ],
            },
            _ => Msg::MStable { dot },
        };
        let enc = encode(&msg);
        let cut = rng.gen_range(enc.len() as u64 + 1) as usize;
        let _ = decode(&enc[..cut]);
        let mut flipped = enc.clone();
        let at = rng.gen_range(enc.len() as u64) as usize;
        flipped[at] ^= 1u8 << (rng.gen_range(8) as u32);
        let _ = decode(&flipped); // Err or a different message — no panic
        Ok(())
    });
}

#[test]
fn prop_wire_codec_roundtrips_random_messages() {
    use tempo::net::wire::{decode, encode};
    use tempo::protocol::tempo::msg::Msg;
    forall(
        "wire-roundtrip",
        |rng| {
            let dot = Dot::new(ProcessId(rng.gen_range(16) as u32), rng.gen_range(1 << 20));
            let keys: Vec<u64> =
                (0..1 + rng.gen_range(4)).map(|_| rng.gen_range(1 << 30)).collect();
            let cmd = Command::new(
                Rid::new(ClientId(rng.gen_range(1 << 16)), 1 + rng.gen_range(1 << 10)),
                keys.clone(),
                match rng.gen_range(3) {
                    0 => Op::Put,
                    1 => Op::Get,
                    _ => Op::Read,
                },
                rng.gen_range(4096) as u32,
            );
            let ts: Vec<(u64, u64)> =
                keys.iter().map(|&k| (k, rng.gen_range(1 << 16))).collect();
            match rng.gen_range(4) {
                0 => Msg::MPropose { dot, cmd, quorums: vec![].into(), ts },
                1 => Msg::MCommit {
                    dot,
                    group: tempo::core::ShardId(0),
                    ts,
                    promises: vec![].into(),
                },
                2 => Msg::MProposeAck {
                    dot,
                    ts,
                    promises: vec![(
                        keys[0],
                        tempo::protocol::tempo::promises::PromiseSet {
                            detached: vec![(1, rng.gen_range(100) + 1)],
                            attached: vec![(dot, rng.gen_range(100) + 1)],
                        },
                    )],
                },
                _ => Msg::MConsensus { dot, ts, bal: rng.gen_range(1 << 10) },
            }
        },
        |msg| {
            let bytes = encode(msg);
            let back = decode(&bytes).map_err(|e| e.to_string())?;
            if format!("{msg:?}") != format!("{back:?}") {
                return Err(format!("round-trip mismatch: {msg:?} vs {back:?}"));
            }
            Ok(())
        },
    );
}

/// A random client-plane frame over every tag of that plane: Submit
/// (17), Reply (18), or the admission-control Busy shed (25).
fn random_client_frame(rng: &mut Rng) -> tempo::net::wire::ClientFrame {
    use tempo::core::Response;
    use tempo::net::wire::ClientFrame;
    let rid = Rid::new(ClientId(rng.gen_range(1 << 16)), 1 + rng.gen_range(1 << 20));
    match rng.gen_range(3) {
        0 => {
            let keys: Vec<u64> =
                (0..1 + rng.gen_range(4)).map(|_| rng.gen_range(1 << 30)).collect();
            let op = match rng.gen_range(4) {
                0 => Op::Get,
                1 => Op::Put,
                2 => Op::Rmw,
                _ => Op::Read,
            };
            ClientFrame::Submit {
                cmd: Command::new(rid, keys, op, rng.gen_range(512) as u32),
                floor: rng.gen_range(1 << 40),
            }
        }
        1 => {
            let versions: Vec<(u64, u64)> = (0..rng.gen_range(5))
                .map(|_| (rng.gen_range(1 << 30), rng.gen_range(1 << 20)))
                .collect();
            ClientFrame::Reply { rid, response: Response { versions }, ts: rng.gen_range(1 << 40) }
        }
        _ => ClientFrame::Busy { rid },
    }
}

#[test]
fn prop_client_frames_roundtrip_and_survive_corruption() {
    // Tags 17–18 and 25 (docs/WIRE.md): random client frames round-trip
    // through encode_client/decode_client, and truncations/bit-flips
    // return Err or a different frame — never a panic.
    use tempo::net::wire::{decode_client, encode_client};
    forall_seeds("client-frame-fuzz", |seed| {
        let mut rng = Rng::new(seed);
        let frame = random_client_frame(&mut rng);
        let enc = encode_client(&frame);
        let back = decode_client(&enc).map_err(|e| e.to_string())?;
        if back != frame {
            return Err(format!("round-trip mismatch: {frame:?} vs {back:?}"));
        }
        let cut = rng.gen_range(enc.len() as u64) as usize;
        if decode_client(&enc[..cut]).is_ok() {
            return Err(format!("truncation at {cut} decoded"));
        }
        let mut flipped = enc.clone();
        let at = rng.gen_range(enc.len() as u64) as usize;
        flipped[at] ^= 1u8 << (rng.gen_range(8) as u32);
        let _ = decode_client(&flipped); // Err or a different frame — no panic
        Ok(())
    });
}

#[test]
fn prop_incremental_decode_matches_whole_frame_decode_on_any_split() {
    // The event loop's nonblocking `FrameDecoder` must agree with the
    // whole-buffer reference on every chunking of the same byte stream:
    // random client frames (tags 17, 18, 25) wrapped in transport
    // framing, fed byte-by-byte AND at random split points, decode to
    // exactly the frames that went in — and a truncated stream leaves
    // the decoder incomplete without error (the frame simply has not
    // arrived yet), while header corruption errors instead of panicking.
    use tempo::net::wire::{decode_client, encode_client, FrameDecoder};
    forall_seeds("incremental-decode", |seed| {
        let mut rng = Rng::new(seed);
        let frames: Vec<_> =
            (0..1 + rng.gen_range(6)).map(|_| random_client_frame(&mut rng)).collect();
        let mut stream = Vec::new();
        for f in &frames {
            let body = encode_client(f);
            stream.extend_from_slice(&(body.len() as u32).to_le_bytes());
            stream.extend_from_slice(&u32::MAX.to_le_bytes()); // CLIENT_FROM
            stream.extend_from_slice(&body);
        }
        // Decode the stream under a given chunking; compare to `frames`.
        let run = |chunks: &[&[u8]]| -> Result<(), String> {
            let mut dec = FrameDecoder::new();
            let mut out = Vec::new();
            for chunk in chunks {
                let mut rest = *chunk;
                while !rest.is_empty() {
                    let (used, done) = dec.feed(rest).map_err(|e| e.to_string())?;
                    rest = &rest[used..];
                    if done {
                        if dec.sender() != u32::MAX {
                            return Err(format!("sender {} != CLIENT_FROM", dec.sender()));
                        }
                        out.push(decode_client(dec.body()).map_err(|e| e.to_string())?);
                        dec.clear();
                    }
                }
            }
            if dec.is_complete() {
                return Err("decoder complete after a fully-consumed stream".into());
            }
            dec.recycle();
            if out != frames {
                return Err(format!("{} frames in, {} out (or reordered)", frames.len(), out.len()));
            }
            Ok(())
        };
        // 1. One byte at a time — every header/body boundary is crossed.
        let bytes: Vec<&[u8]> = stream.chunks(1).collect();
        run(&bytes)?;
        // 2. Random split points.
        let mut splits = Vec::new();
        let mut off = 0;
        while off < stream.len() {
            let n = 1 + rng.gen_range(40) as usize;
            let end = (off + n).min(stream.len());
            splits.push(&stream[off..end]);
            off = end;
        }
        run(&splits)?;
        // 3. The whole stream in one feed.
        run(&[&stream])?;
        // 4. Truncation: the decoder waits (incomplete), never errors.
        let cut = rng.gen_range(stream.len() as u64) as usize;
        let mut dec = FrameDecoder::new();
        let mut rest = &stream[..cut];
        while !rest.is_empty() {
            let (used, done) = dec.feed(rest).map_err(|e| e.to_string())?;
            rest = &rest[used..];
            if done {
                dec.clear();
            }
        }
        if dec.is_complete() {
            return Err("truncated stream left a complete frame pending".into());
        }
        dec.recycle();
        // 5. An absurd length header errors instead of allocating/panicking.
        let mut huge = FrameDecoder::new();
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&u32::MAX.to_le_bytes()); // len >> MAX_FRAME_BYTES
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        if huge.feed(&hdr).is_ok() {
            return Err("oversized frame header accepted".into());
        }
        Ok(())
    });
}

#[test]
fn prop_read_flagged_submits_roundtrip_and_stay_on_the_client_plane() {
    // The local-read class on the wire: a `ClientSubmit` whose command
    // carries op tag 3 (`Op::Read`, docs/WIRE.md). Round-trips exactly
    // (payload length included — reads carry 0), every truncation is an
    // Err, bit-flips never panic, and the frame stays on the client
    // plane: the peer decoder must reject it whole and as a nested
    // `MBatch` member.
    use tempo::net::wire::{decode, decode_client, encode_client, ClientFrame};
    forall_seeds("read-submit-fuzz", |seed| {
        let mut rng = Rng::new(seed);
        let rid = Rid::new(ClientId(rng.gen_range(1 << 16)), 1 + rng.gen_range(1 << 20));
        let keys: Vec<u64> =
            (0..1 + rng.gen_range(4)).map(|_| rng.gen_range(1 << 30)).collect();
        let frame = ClientFrame::Submit {
            cmd: Command::read(rid, keys),
            floor: rng.gen_range(1 << 40),
        };
        let enc = encode_client(&frame);
        let back = decode_client(&enc).map_err(|e| e.to_string())?;
        if back != frame {
            return Err(format!("round-trip mismatch: {frame:?} vs {back:?}"));
        }
        match &back {
            ClientFrame::Submit { cmd, .. } => {
                if cmd.op != Op::Read || cmd.payload_len != 0 {
                    return Err(format!("read flag lost: {cmd:?}"));
                }
            }
            other => return Err(format!("decoded as {other:?}")),
        }
        let cut = rng.gen_range(enc.len() as u64) as usize;
        if decode_client(&enc[..cut]).is_ok() {
            return Err(format!("truncation at {cut} decoded"));
        }
        let mut flipped = enc.clone();
        let at = rng.gen_range(enc.len() as u64) as usize;
        flipped[at] ^= 1u8 << (rng.gen_range(8) as u32);
        let _ = decode_client(&flipped); // Err or a different frame — no panic
        // Plane separation: the peer decoder rejects the client frame...
        if decode(&enc).is_ok() {
            return Err("read submit decoded on the peer plane".into());
        }
        // ...including smuggled inside an MBatch (tag 16).
        let mut batch = vec![16u8];
        batch.extend_from_slice(&1u16.to_le_bytes());
        batch.extend_from_slice(&(enc.len() as u32).to_le_bytes());
        batch.extend_from_slice(&enc);
        if decode(&batch).is_ok() {
            return Err("read submit decoded inside an MBatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_batches_reject_nested_client_frames() {
    // An MBatch member carrying a client frame (tag 17/18) is malformed
    // the same way a nested batch is — rejected from the tag peek,
    // whatever the member contents.
    use tempo::core::Response;
    use tempo::net::wire::{decode, encode_client, ClientFrame};
    forall_seeds("batch-rejects-client-frames", |seed| {
        let mut rng = Rng::new(seed);
        let rid = Rid::new(ClientId(rng.gen_range(1 << 10)), 1 + rng.gen_range(1 << 10));
        let member = if rng.gen_bool(0.5) {
            encode_client(&ClientFrame::Submit {
                cmd: Command::single(rid, rng.gen_range(1 << 20), Op::Put, 16),
                floor: rng.gen_range(1 << 30),
            })
        } else {
            encode_client(&ClientFrame::Reply {
                rid,
                response: Response { versions: vec![(rng.gen_range(1 << 20), 1)] },
                ts: rng.gen_range(1 << 30),
            })
        };
        // Hand-build: tag 16, one member, the client frame as its body.
        let mut frame = vec![16u8];
        frame.extend_from_slice(&1u16.to_le_bytes());
        frame.extend_from_slice(&(member.len() as u32).to_le_bytes());
        frame.extend_from_slice(&member);
        match decode(&frame) {
            Err(_) => Ok(()),
            Ok(m) => Err(format!("client frame inside MBatch decoded as {m:?}")),
        }
    });
}

/// Random message over every wire tag 0–16 plus the epoch vote (tag
/// 21; nested `MBatch` members included when `allow_batch`).
fn random_msg(rng: &mut Rng, allow_batch: bool) -> tempo::protocol::tempo::msg::Msg {
    use tempo::protocol::tempo::msg::{Msg, Phase};
    use tempo::protocol::tempo::promises::PromiseSet;
    let dot = Dot::new(ProcessId(rng.gen_range(16) as u32), 1 + rng.gen_range(1 << 20));
    let keys: Vec<u64> = (0..1 + rng.gen_range(4)).map(|_| rng.gen_range(1 << 30)).collect();
    let cmd = Command::new(
        Rid::new(ClientId(rng.gen_range(1 << 16)), 1 + rng.gen_range(1 << 10)),
        keys.clone(),
        match rng.gen_range(4) {
            0 => Op::Get,
            1 => Op::Put,
            2 => Op::Rmw,
            _ => Op::Read,
        },
        rng.gen_range(512) as u32,
    );
    let quorums: tempo::protocol::tempo::msg::Quorums = vec![(
        tempo::core::ShardId(0),
        (0..1 + rng.gen_range(4)).map(|i| ProcessId(i as u32)).collect(),
    )]
    .into();
    let ts: Vec<(u64, u64)> = keys.iter().map(|&k| (k, rng.gen_range(1 << 16))).collect();
    let ps = |rng: &mut Rng| PromiseSet {
        detached: (0..rng.gen_range(3)).map(|i| (20 * i + 1, 20 * i + 9)).collect(),
        attached: if rng.gen_bool(0.5) { vec![(dot, rng.gen_range(100) + 1)] } else { vec![] },
    };
    let kp = |rng: &mut Rng| -> Vec<(u64, PromiseSet)> {
        keys.iter().map(|&k| (k, ps(rng))).collect()
    };
    let phases = [
        Phase::Start,
        Phase::Payload,
        Phase::Propose,
        Phase::RecoverR,
        Phase::RecoverP,
        Phase::Commit,
        Phase::Execute,
    ];
    match rng.gen_range(if allow_batch { 18 } else { 17 }) {
        0 => Msg::MSubmit { dot, cmd, quorums },
        1 => Msg::MPropose { dot, cmd, quorums, ts },
        2 => Msg::MProposeAck { dot, ts, promises: kp(rng) },
        3 => Msg::MPayload { dot, cmd, quorums },
        4 => Msg::MCommit {
            dot,
            group: tempo::core::ShardId(rng.gen_range(4) as u32),
            ts,
            promises: (0..rng.gen_range(3))
                .map(|i| (ProcessId(i as u32), kp(rng)))
                .collect::<Vec<_>>()
                .into(),
        },
        5 => Msg::MCommitDirect { dot, cmd, quorums, final_ts: rng.gen_range(1 << 16) },
        6 => Msg::MConsensus { dot, ts, bal: rng.gen_range(1 << 10) },
        7 => Msg::MConsensusAck { dot, bal: rng.gen_range(1 << 10) },
        8 => Msg::MPromises { promises: kp(rng).into() },
        9 => Msg::MBump { dot, ts: rng.gen_range(1 << 16) },
        10 => Msg::MStable { dot },
        11 => Msg::MRec { dot, bal: rng.gen_range(1 << 10) },
        12 => Msg::MRecAck {
            dot,
            ts,
            phase: phases[rng.gen_range(7) as usize],
            abal: rng.gen_range(1 << 10),
            bal: rng.gen_range(1 << 10),
        },
        13 => Msg::MRecNAck { dot, bal: rng.gen_range(1 << 10) },
        14 => Msg::MCommitRequest { dot },
        15 => Msg::MGarbageCollect {
            executed: (0..rng.gen_range(5))
                .map(|i| (ProcessId(i as u32), rng.gen_range(1 << 20)))
                .collect(),
        },
        16 => Msg::MEpoch {
            epoch: 1 + rng.gen_range(1 << 20),
            evicted: (0..rng.gen_range(4)).map(|i| ProcessId(i as u32)).collect(),
        },
        _ => Msg::MBatch {
            msgs: (0..rng.gen_range(4)).map(|_| random_msg(rng, false)).collect(),
        },
    }
}

#[test]
fn prop_encode_into_matches_encode_byte_for_byte() {
    // The tentpole equivalence pin: for every tag 0–19 — nested MBatch
    // members and Routed envelopes included — the append-into encoders
    // produce exactly the legacy wrappers' bytes, the exact-size
    // functions equal the measured lengths, and the encode-once shared
    // broadcast body is the per-peer encoding byte-for-byte.
    use tempo::net::wire::{
        client_encoded_len, encode, encode_client, encode_client_into, encode_into,
        encode_routed, encode_routed_shared, encoded_len, routed_encoded_len, ClientFrame,
        Writer,
    };
    use tempo::protocol::common::shard::Routed;
    forall_seeds("encode-into-equivalence", |seed| {
        let mut rng = Rng::new(seed);
        for _ in 0..20 {
            let msg = random_msg(&mut rng, true);
            let legacy = encode(&msg);
            if encoded_len(&msg) != legacy.len() {
                return Err(format!(
                    "encoded_len {} != encode().len() {} for {msg:?}",
                    encoded_len(&msg),
                    legacy.len()
                ));
            }
            // Appending must reproduce the wrapper bytes after any prefix.
            let prefix_len = rng.gen_range(4) as usize;
            let mut w = Writer::from_vec(vec![0xA5; prefix_len]);
            encode_into(&mut w, &msg);
            if w.buf[prefix_len..] != legacy[..] {
                return Err(format!("encode_into != encode for {msg:?}"));
            }
            // Routed envelope (tag 19) and the shared broadcast body.
            let worker = rng.gen_range(256) as u32;
            let routed = Routed { worker, msg: msg.clone() };
            let renc = encode_routed(&routed);
            if routed_encoded_len(&routed) != renc.len() {
                return Err("routed_encoded_len out of sync".into());
            }
            let shared = encode_routed_shared(worker, &msg);
            if shared[..] != renc[..] {
                return Err("encode_routed_shared != encode_routed".into());
            }
        }
        // Client frames (tags 17–18).
        let rid = Rid::new(ClientId(rng.gen_range(1 << 16)), 1 + rng.gen_range(1 << 10));
        let frame = if rng.gen_bool(0.5) {
            ClientFrame::Submit {
                cmd: Command::single(rid, rng.gen_range(1 << 20), Op::Put, 32),
                floor: rng.gen_range(1 << 40),
            }
        } else {
            ClientFrame::Reply {
                rid,
                response: tempo::core::Response {
                    versions: (0..rng.gen_range(4)).map(|i| (i, i + 1)).collect(),
                },
                ts: rng.gen_range(1 << 40),
            }
        };
        let legacy = encode_client(&frame);
        if client_encoded_len(&frame) != legacy.len() {
            return Err("client_encoded_len out of sync".into());
        }
        let mut w = Writer::new();
        encode_client_into(&mut w, &frame);
        if w.buf != legacy {
            return Err("encode_client_into != encode_client".into());
        }
        Ok(())
    });
}

#[test]
fn prop_merged_frames_decode_to_the_same_members_in_slot_order() {
    // The per-peer merger's frame (tag 20): whatever routed frames go
    // in, the decoder returns the same member multiset in the same
    // order — so each worker slot's per-peer FIFO survives merging —
    // and truncations/bit-flips never panic.
    use tempo::net::wire::{decode_merged, encode_merged, encode_routed};
    use tempo::protocol::common::shard::Routed;
    forall_seeds("merged-frame-multiset", |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.gen_range(6) as usize;
        let members: Vec<Routed<_>> = (0..n)
            .map(|_| Routed {
                worker: rng.gen_range(4) as u32,
                msg: random_msg(&mut rng, true),
            })
            .collect();
        let bodies: Vec<Vec<u8>> = members.iter().map(encode_routed).collect();
        let body_refs: Vec<&[u8]> = bodies.iter().map(|b| b.as_slice()).collect();
        let frame = encode_merged(&body_refs);
        let back = decode_merged(&frame).map_err(|e| e.to_string())?;
        if back.len() != members.len() {
            return Err(format!("{} members in, {} out", members.len(), back.len()));
        }
        for (i, (a, b)) in members.iter().zip(&back).enumerate() {
            if a.worker != b.worker || format!("{:?}", a.msg) != format!("{:?}", b.msg) {
                return Err(format!("member {i} changed across the merge"));
            }
        }
        // Malformed inputs: truncation and bit flips error or decode
        // differently — never panic.
        let cut = rng.gen_range(frame.len() as u64) as usize;
        if decode_merged(&frame[..cut]).is_ok() {
            return Err(format!("truncation at {cut} decoded"));
        }
        let mut flipped = frame.clone();
        let at = rng.gen_range(frame.len() as u64) as usize;
        flipped[at] ^= 1u8 << (rng.gen_range(8) as u32);
        let _ = decode_merged(&flipped);
        Ok(())
    });
}

#[test]
fn prop_epoch_frames_roundtrip_and_stay_on_the_protocol_plane() {
    // The reconfiguration vote on the wire (tag 21, docs/WIRE.md):
    // random `MEpoch` frames round-trip exactly, every truncation is an
    // Err, bit-flips never panic, the client decoder rejects the frame
    // whole, and the frame is a *legal* MBatch member (it is a
    // protocol-plane message, unlike tags 16–20).
    use tempo::net::wire::{decode, decode_client, encode};
    use tempo::protocol::tempo::msg::Msg;
    forall_seeds("epoch-frame-fuzz", |seed| {
        let mut rng = Rng::new(seed);
        let msg = Msg::MEpoch {
            epoch: rng.gen_range(1 << 40),
            evicted: (0..rng.gen_range(5))
                .map(|_| ProcessId(rng.gen_range(16) as u32))
                .collect(),
        };
        let enc = encode(&msg);
        let back = decode(&enc).map_err(|e| e.to_string())?;
        if format!("{msg:?}") != format!("{back:?}") {
            return Err(format!("round-trip mismatch: {msg:?} vs {back:?}"));
        }
        let cut = rng.gen_range(enc.len() as u64) as usize;
        if decode(&enc[..cut]).is_ok() {
            return Err(format!("truncation at {cut} decoded"));
        }
        let mut flipped = enc.clone();
        let at = rng.gen_range(enc.len() as u64) as usize;
        flipped[at] ^= 1u8 << (rng.gen_range(8) as u32);
        let _ = decode(&flipped); // Err or a different message — no panic
        // Plane separation: never a client frame.
        if decode_client(&enc).is_ok() {
            return Err("epoch vote decoded on the client plane".into());
        }
        // A protocol-plane message batches like any other: tag 21 inside
        // an MBatch member must decode back to the same vote.
        let mut batch = vec![16u8];
        batch.extend_from_slice(&1u16.to_le_bytes());
        batch.extend_from_slice(&(enc.len() as u32).to_le_bytes());
        batch.extend_from_slice(&enc);
        match decode(&batch) {
            Ok(Msg::MBatch { msgs }) if msgs.len() == 1 => {
                if format!("{:?}", msgs[0]) != format!("{msg:?}") {
                    return Err("batched epoch vote changed in transit".into());
                }
            }
            other => return Err(format!("batched epoch vote decoded as {other:?}")),
        }
        Ok(())
    });
}

#[test]
fn prop_transfer_frames_roundtrip_and_stay_on_the_transfer_plane() {
    // State-transfer frames (tags 22–24, docs/WIRE.md): random manifest
    // requests, manifest replies, and chunk frames round-trip exactly
    // through encode_transfer/decode_transfer; every truncation is an
    // Err; bit-flips never panic; and the transfer plane is strictly
    // separated — the peer and client decoders reject the frames whole
    // and as smuggled MBatch members, while decode_transfer rejects
    // every other plane's frames.
    use tempo::core::Response;
    use tempo::net::wire::{
        decode, decode_client, decode_transfer, encode, encode_client, encode_transfer,
        transfer_encoded_len, ClientFrame, TransferFrame,
    };
    forall_seeds("transfer-frame-fuzz", |seed| {
        let mut rng = Rng::new(seed);
        let frame = match rng.gen_range(3) {
            0 => TransferFrame::ManifestRequest { slot: rng.gen_range(64) as u32 },
            1 => TransferFrame::ManifestReply {
                slot: rng.gen_range(64) as u32,
                applied: rng.gen_range(1 << 40),
                chunks: (0..rng.gen_range(8)).map(|_| rng.gen_range(1 << 60)).collect(),
                dot_floors: (0..rng.gen_range(5))
                    .map(|i| (ProcessId(i as u32), rng.gen_range(1 << 30)))
                    .collect(),
                dedup: (0..rng.gen_range(64)).map(|_| rng.gen_range(256) as u8).collect(),
            },
            _ => TransferFrame::Chunk {
                slot: rng.gen_range(64) as u32,
                hash: rng.gen_range(1 << 60),
                present: rng.gen_bool(0.5),
                data: (0..rng.gen_range(128)).map(|_| rng.gen_range(256) as u8).collect(),
            },
        };
        let enc = encode_transfer(&frame);
        if transfer_encoded_len(&frame) != enc.len() {
            return Err(format!(
                "transfer_encoded_len {} != encode_transfer().len() {}",
                transfer_encoded_len(&frame),
                enc.len()
            ));
        }
        let back = decode_transfer(&enc).map_err(|e| e.to_string())?;
        if back != frame {
            return Err(format!("round-trip mismatch: {frame:?} vs {back:?}"));
        }
        let cut = rng.gen_range(enc.len() as u64) as usize;
        if decode_transfer(&enc[..cut]).is_ok() {
            return Err(format!("truncation at {cut} decoded"));
        }
        let mut flipped = enc.clone();
        let at = rng.gen_range(enc.len() as u64) as usize;
        flipped[at] ^= 1u8 << (rng.gen_range(8) as u32);
        let _ = decode_transfer(&flipped); // Err or a different frame — no panic
        // Plane separation, outbound: never a peer or client frame...
        if decode(&enc).is_ok() {
            return Err("transfer frame decoded on the peer plane".into());
        }
        if decode_client(&enc).is_ok() {
            return Err("transfer frame decoded on the client plane".into());
        }
        // ...including smuggled inside an MBatch member (tag 16).
        let mut batch = vec![16u8];
        batch.extend_from_slice(&1u16.to_le_bytes());
        batch.extend_from_slice(&(enc.len() as u32).to_le_bytes());
        batch.extend_from_slice(&enc);
        if decode(&batch).is_ok() {
            return Err("transfer frame decoded inside an MBatch".into());
        }
        // Plane separation, inbound: a protocol or client frame never
        // decodes as a transfer frame.
        let other = random_msg(&mut rng, true);
        if decode_transfer(&encode(&other)).is_ok() {
            return Err("peer frame decoded on the transfer plane".into());
        }
        let client = ClientFrame::Reply {
            rid: Rid::new(ClientId(1), 1),
            response: Response { versions: vec![] },
            ts: rng.gen_range(1 << 30),
        };
        if decode_transfer(&encode_client(&client)).is_ok() {
            return Err("client frame decoded on the transfer plane".into());
        }
        Ok(())
    });
}

#[test]
fn prop_tempo_sim_agreement_across_seeds() {
    // End-to-end safety sweep: random seeds, random conflict rates — the
    // PSMR checker must pass every time (liveness included; no crashes).
    forall_seeds("tempo-psmr-sweep", |seed| {
        let conflict = (seed % 11) as f64 / 10.0;
        let config = Config::new(3, 1);
        let mut o = tempo::sim::SimOpts::new(tempo::sim::Topology::ec2_three());
        o.clients_per_site = 3;
        o.warmup_us = 0;
        o.duration_us = 1_000_000;
        o.drain_us = 2_000_000;
        o.seed = seed;
        o.record_execution = true;
        let result = tempo::sim::run::<tempo::protocol::tempo::Tempo, _>(
            config.clone(),
            o,
            tempo::workload::ConflictWorkload::new(conflict, 64),
        );
        let violations = tempo::check::check_psmr(&config, &result, true);
        if violations.is_empty() {
            Ok(())
        } else {
            Err(format!("{} violations at conflict={conflict}", violations.len()))
        }
    });
}
