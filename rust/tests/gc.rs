//! Garbage-collection boundedness: long-running sims must prune executed
//! command state (`protocol/common::GCTrack`) — the seed kept every `Info`
//! record forever, so memory grew with the run. Each protocol family is
//! checked: the per-command info maps stay small relative to the number of
//! executed commands when GC is on, and provably grow when it is off.

use tempo::check::assert_psmr;
use tempo::core::{ClientId, Config, Op};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::{Atlas, EPaxos};
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, SimOpts, SimResult, Topology};
use tempo::util::Rng;
use tempo::workload::{CommandSpec, ConflictWorkload, Workload};

fn opts(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 8;
    o.warmup_us = 0;
    o.duration_us = 8_000_000;
    o.drain_us = 4_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

/// The info maps must hold far fewer entries than the run executed, and
/// the GC counters must show real pruning happened.
fn assert_bounded(result: &SimResult, min_ops: u64) {
    let ops = result.metrics.ops;
    assert!(ops > min_ops, "need traffic for a meaningful GC test, ops={ops}");
    assert!(
        result.metrics.counters.gc_pruned > 0,
        "GC never pruned anything: {:?}",
        result.metrics.counters
    );
    for (p, fp) in result.footprints.iter().enumerate() {
        assert!(
            fp.infos < ops as usize / 4,
            "P{p} retains {} info entries after {} ops — GC not bounding memory",
            fp.infos,
            ops
        );
    }
}

#[test]
fn tempo_info_map_stays_bounded_under_gc() {
    let config = Config::new(3, 1); // gc_interval_ticks defaults on
    let result = run::<Tempo, _>(config.clone(), opts(81), ConflictWorkload::new(0.2, 100));
    assert_psmr(&config, &result, true);
    assert_bounded(&result, 400);
}

#[test]
fn tempo_info_map_grows_without_gc() {
    let config = Config::new(3, 1).with_gc_interval_ticks(0);
    let result = run::<Tempo, _>(config.clone(), opts(81), ConflictWorkload::new(0.2, 100));
    assert_psmr(&config, &result, true);
    let ops = result.metrics.ops as usize;
    assert!(ops > 400);
    assert_eq!(result.metrics.counters.gc_pruned, 0);
    assert!(
        result.footprints.iter().any(|fp| fp.infos >= ops),
        "without GC every process should retain an info entry per command \
         (ops={ops}, footprints={:?})",
        result.footprints
    );
}

#[test]
fn tempo_incremental_watermarks_advance() {
    // The incremental stability cache is the execution gate: it must have
    // advanced (counted per key) for anything to execute at all.
    let config = Config::new(3, 1);
    let result = run::<Tempo, _>(config, opts(82), ConflictWorkload::new(0.1, 100));
    assert!(result.metrics.ops > 100);
    assert!(
        result.metrics.counters.wm_advances > 0,
        "stability watermarks never advanced: {:?}",
        result.metrics.counters
    );
}

#[test]
fn atlas_info_map_stays_bounded_under_gc() {
    let config = Config::new(3, 1);
    let result = run::<Atlas, _>(config.clone(), opts(83), ConflictWorkload::new(0.2, 100));
    assert_psmr(&config, &result, true);
    assert_bounded(&result, 400);
}

#[test]
fn epaxos_info_map_stays_bounded_under_gc() {
    let config = Config::new(3, 1);
    let result = run::<EPaxos, _>(config.clone(), opts(84), ConflictWorkload::new(0.2, 100));
    assert_psmr(&config, &result, true);
    assert_bounded(&result, 400);
}

#[test]
fn caesar_info_and_conflict_maps_stay_bounded_under_gc() {
    let config = Config::new(3, 1);
    let result = run::<Caesar, _>(config.clone(), opts(85), ConflictWorkload::new(0.2, 100));
    assert_psmr(&config, &result, true);
    assert_bounded(&result, 400);
    // Caesar's per-key `seen` tables are the growth the §3.3 baseline
    // notoriously suffers; GC must scrub them too. Unique keys are never
    // reused, so bounded == far fewer keys than commands executed.
    let ops = result.metrics.ops as usize;
    for (p, fp) in result.footprints.iter().enumerate() {
        assert!(
            fp.keys < ops / 4,
            "P{p} retains {} conflict-table keys after {ops} ops",
            fp.keys
        );
    }
}

#[test]
fn fpaxos_log_stays_bounded_under_gc() {
    let config = Config::new(3, 1);
    let result = run::<FPaxos, _>(config.clone(), opts(86), ConflictWorkload::new(0.2, 100));
    assert_psmr(&config, &result, true);
    assert_bounded(&result, 400);
}

/// Every client reads the same hot key forever — the regime where
/// `reads_since_write` used to grow without bound between GC rounds
/// (ROADMAP PR 1 item).
#[derive(Clone)]
struct HotKeyReads;

impl Workload for HotKeyReads {
    fn next(&mut self, _client: ClientId, _rng: &mut Rng) -> CommandSpec {
        CommandSpec { keys: vec![0], op: Op::Get, payload_len: 16 }
    }
}

#[test]
fn read_heavy_hot_key_state_is_bounded_by_fragments() {
    // GC off: nothing scrubs the read sets, so the *representation* alone
    // must bound memory. Each origin's reads on the hot key carry
    // contiguous sequence numbers (every command of the run touches it),
    // so the coalesced ranges collapse to a handful of fragments per
    // replica while the read count grows with the run.
    let config = Config::new(3, 1).with_gc_interval_ticks(0);
    let mut o = opts(90);
    o.duration_us = 6_000_000;
    let result = run::<EPaxos, _>(config.clone(), o, HotKeyReads);
    let ops = result.metrics.ops as usize;
    assert!(ops > 400, "need real read traffic, ops={ops}");
    assert_psmr(&config, &result, true);
    for (p, fp) in result.footprints.iter().enumerate() {
        assert!(
            fp.fragments <= 3 * 4,
            "P{p} holds {} read-range fragments after {ops} reads — \
             reads_since_write is growing again",
            fp.fragments
        );
    }
}

#[test]
fn gc_exchange_is_deterministic() {
    let config = Config::new(3, 1);
    let a = run::<Tempo, _>(config.clone(), opts(87), ConflictWorkload::new(0.2, 100));
    let b = run::<Tempo, _>(config, opts(87), ConflictWorkload::new(0.2, 100));
    assert_eq!(a.metrics.ops, b.metrics.ops);
    assert_eq!(a.metrics.counters.gc_pruned, b.metrics.counters.gc_pruned);
    assert_eq!(a.execution_logs, b.execution_logs);
}

/// GC must never execute-starve a protocol: everything still executes
/// everywhere (liveness) with an aggressive 1-tick GC cadence.
#[test]
fn aggressive_gc_cadence_preserves_liveness() {
    let config = Config::new(3, 1).with_gc_interval_ticks(1);
    let mut o = opts(88);
    o.duration_us = 3_000_000;
    let result = run::<Tempo, _>(config.clone(), o, ConflictWorkload::new(0.3, 100));
    assert!(result.metrics.ops > 100);
    assert_psmr(&config, &result, true);
    assert!(result.metrics.counters.gc_pruned > 0);
}

/// Footprint sanity for a protocol with no GC configured at all.
#[test]
fn footprint_reports_are_wired_for_all_protocols() {
    let config = Config::new(3, 1);
    let result = run::<Tempo, _>(config, opts(89), ConflictWorkload::new(0.02, 100));
    assert_eq!(result.footprints.len(), 3);
    // After the drain every stalled buffer should be empty.
    for fp in &result.footprints {
        assert_eq!(fp.stalled, 0, "stalled buffers must drain: {:?}", result.footprints);
    }
    let _ = <Tempo as Protocol>::name();
}
