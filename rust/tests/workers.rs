//! Per-key worker sharding (`protocol::common::shard`) is
//! behavior-transparent and safe.
//!
//! Three layers of evidence, in the style of `rust/tests/batching.rs`:
//!
//! 1. **Exact equivalence**: with a jitter-free topology and an rng-free
//!    single-key workload, a `workers = 4` run must execute the *same
//!    commands at the same instants* as the `workers = 1` run at every
//!    process, observe byte-identical client responses, and agree exactly
//!    on every per-key execution order. (Command identity is compared via
//!    rids: worker slots mint interleaved dot strides, so dots are the one
//!    thing that legitimately differs.) Proven for Tempo, EPaxos, Atlas,
//!    Janus* and Caesar. FPaxos is excluded by design: it orders *all*
//!    commands into one log, so per-key sharding genuinely changes (and
//!    improves) cross-key scheduling — safety for it is covered by layer 2.
//! 2. **PSMR + response validity** with `workers = 4` for all six
//!    protocols, drained.
//! 3. **Routing properties** (fuzzed): key→worker is total, stable and
//!    balanced; a command routes to exactly one worker slot, the slot of
//!    every key it carries; the dot a slot mints names that same slot, so
//!    recovery-side routing by dot agrees with submit-side routing by key.

use tempo::check::assert_psmr;
use tempo::core::{ClientId, Config, Dot, DotGen, Key, Op, ProcessId, Response, Rid};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::common::{worker_of_cmd, worker_of_dot, worker_of_key, Sharded};
use tempo::protocol::depsmr::{Atlas, EPaxos, Janus};
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, SimOpts, SimResult, Topology};
use tempo::store::{diverging_slots, merkle_root, KvStore};
use tempo::util::prop::forall_seeds;
use tempo::util::Rng;
use tempo::workload::{CommandSpec, ConflictWorkload, Workload};
use std::collections::{BTreeMap, HashMap};

/// Deterministic single-key workload: never reads the rng (runs that
/// consume different amounts of randomness stay comparable) and hammers a
/// small key set so commands genuinely conflict — the keys spread across
/// worker slots at `workers = 4`.
#[derive(Clone)]
struct FixedWorkload;

impl Workload for FixedWorkload {
    fn next(&mut self, client: ClientId, _rng: &mut Rng) -> CommandSpec {
        CommandSpec { keys: vec![client.0 % 5], op: Op::Put, payload_len: 64 }
    }
}

fn flat_topology() -> Topology {
    let mut t = Topology::ec2();
    t.jitter = 0.0;
    t
}

fn opts(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(flat_topology());
    o.clients_per_site = 2;
    o.warmup_us = 0;
    o.duration_us = 4_000_000;
    o.drain_us = 4_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

/// The worker-count-independent view of a run: per-process execution
/// instants (rid-keyed, sorted within an instant — independent commands
/// that execute in the same handler step commute), per-process per-key
/// execution orders (exact sequences), and the full client observation
/// per request (submit/complete instants and the response bytes).
struct Canon {
    ops: u64,
    sorted_logs: Vec<Vec<(u64, Rid)>>,
    key_orders: Vec<BTreeMap<Key, Vec<Rid>>>,
    observations: BTreeMap<Rid, (u64, u64, Response)>,
}

fn canon(result: &SimResult) -> Canon {
    let rid_of: HashMap<Dot, Rid> =
        result.submitted.iter().map(|(d, c)| (*d, c.rid)).collect();
    let keys_of: HashMap<Dot, Vec<Key>> =
        result.submitted.iter().map(|(d, c)| (*d, c.keys.to_vec())).collect();
    let mut sorted_logs = Vec::with_capacity(result.execution_logs.len());
    let mut key_orders = Vec::with_capacity(result.execution_logs.len());
    for log in &result.execution_logs {
        let mut entries: Vec<(u64, Rid)> =
            log.iter().map(|&(d, t)| (t, rid_of[&d])).collect();
        let mut per_key: BTreeMap<Key, Vec<Rid>> = BTreeMap::new();
        for &(d, _) in log {
            for &k in &keys_of[&d] {
                per_key.entry(k).or_default().push(rid_of[&d]);
            }
        }
        entries.sort_unstable();
        sorted_logs.push(entries);
        key_orders.push(per_key);
    }
    let observations = result
        .completions
        .iter()
        .map(|c| (c.rid, (c.submitted_at, c.completed_at, c.response.clone())))
        .collect();
    Canon { ops: result.metrics.ops, sorted_logs, key_orders, observations }
}

fn assert_equivalent(mono: &SimResult, sharded: &SimResult, what: &str) {
    let (a, b) = (canon(mono), canon(sharded));
    assert_eq!(a.ops, b.ops, "{what}: op counts differ");
    assert_eq!(
        a.sorted_logs.len(),
        b.sorted_logs.len(),
        "{what}: process counts differ"
    );
    for (p, (la, lb)) in a.sorted_logs.iter().zip(&b.sorted_logs).enumerate() {
        assert_eq!(
            la, lb,
            "{what}: P{p} executed different commands/instants under sharding"
        );
    }
    for (p, (ka, kb)) in a.key_orders.iter().zip(&b.key_orders).enumerate() {
        assert_eq!(ka, kb, "{what}: P{p} per-key execution order diverged");
    }
    assert_eq!(
        a.observations, b.observations,
        "{what}: client-observed responses/timings diverged"
    );
}

/// Run `P` monolithic and behind the 4-worker router; require equivalent
/// executions and PSMR on both.
///
/// GC is off here on purpose: per-slot frontiers legitimately prune
/// *earlier* than the monolithic all-keys frontier, and for the
/// dep-based families a pruned conflict-table entry can flip a quorum
/// member's dependency report (and with it an EPaxos fast/slow decision)
/// — a timing difference, not a safety one. GC-enabled sharded behavior
/// is covered by the PSMR sweep and the footprint-boundedness test below.
fn worker_equivalence<P: Protocol>(seed: u64) {
    let config = Config::new(5, 1).with_gc_interval_ticks(0);
    let mono = run::<P, _>(config.clone(), opts(seed), FixedWorkload);
    assert!(
        mono.metrics.ops > 40,
        "{}: need traffic for a meaningful comparison, ops={}",
        P::name(),
        mono.metrics.ops
    );
    let sharded_config = config.clone().with_workers(4);
    let sharded = run::<Sharded<P>, _>(sharded_config.clone(), opts(seed), FixedWorkload);
    assert_equivalent(&mono, &sharded, P::name());
    assert_psmr(&config, &mono, true);
    assert_psmr(&sharded_config, &sharded, true);
}

#[test]
fn tempo_workers4_executes_identically() {
    worker_equivalence::<Tempo>(7);
}

#[test]
fn epaxos_workers4_executes_identically() {
    worker_equivalence::<EPaxos>(11);
}

#[test]
fn atlas_workers4_executes_identically() {
    worker_equivalence::<Atlas>(13);
}

#[test]
fn janus_workers4_executes_identically() {
    worker_equivalence::<Janus>(17);
}

/// Single-key workload over a fixed key *set* (keys chosen by the test).
#[derive(Clone)]
struct KeySetWorkload {
    keys: Vec<Key>,
}

impl Workload for KeySetWorkload {
    fn next(&mut self, client: ClientId, _rng: &mut Rng) -> CommandSpec {
        let key = self.keys[(client.0 as usize) % self.keys.len()];
        CommandSpec { keys: vec![key], op: Op::Put, payload_len: 64 }
    }
}

#[test]
fn caesar_workers4_executes_identically() {
    // Caesar is the one family whose *proposal clock* is global — it
    // couples timestamps across keys, so decoupling the clocks per worker
    // slot legitimately changes timestamp values once traffic spans slots
    // (safety under that regime is layer 2's PSMR sweep). The byte-exact
    // claim is therefore proven on a key set that co-hashes into a single
    // slot: the run still crosses every router mechanism — envelopes,
    // strided dots, per-slot GC frontiers — and must be identical.
    let keys: Vec<Key> = (0..).filter(|&k| worker_of_key(k, 4) == 0).take(5).collect();
    let workload = KeySetWorkload { keys };
    let config = Config::new(5, 1).with_gc_interval_ticks(0); // see worker_equivalence
    let mono = run::<Caesar, _>(config.clone(), opts(19), workload.clone());
    assert!(mono.metrics.ops > 40, "caesar: ops={}", mono.metrics.ops);
    let sharded_config = config.clone().with_workers(4);
    let sharded = run::<Sharded<Caesar>, _>(sharded_config.clone(), opts(19), workload);
    assert_equivalent(&mono, &sharded, "caesar");
    assert_psmr(&config, &mono, true);
    assert_psmr(&sharded_config, &sharded, true);
}

#[test]
fn router_with_one_worker_is_dot_for_dot_the_monolith() {
    // workers = 1 behind the router must be *literally* the monolithic
    // run — same dots, same times — not just equivalent modulo renaming.
    let config = Config::new(5, 1);
    let raw = run::<Tempo, _>(config.clone(), opts(23), FixedWorkload);
    let routed = run::<Sharded<Tempo>, _>(config.clone(), opts(23), FixedWorkload);
    assert_eq!(raw.metrics.ops, routed.metrics.ops);
    for (p, (a, b)) in raw.execution_logs.iter().zip(&routed.execution_logs).enumerate() {
        assert_eq!(a, b, "P{p}: the 1-worker router changed the run");
    }
}

#[test]
fn workers4_psmr_and_response_validity_for_every_family() {
    // Safety sweep with real (rng-driven) single-key traffic, drained:
    // PSMR *and* the response-validity oracle for all six protocols —
    // including FPaxos, whose sharded form is safe but (by design) not
    // execution-equivalent to its single-log monolith.
    fn sweep<P: Protocol>(seed: u64) {
        let config = Config::new(3, 1).with_workers(4);
        let mut o = SimOpts::new(Topology::ec2_three());
        o.clients_per_site = 4;
        o.warmup_us = 0;
        o.duration_us = 2_000_000;
        o.drain_us = 6_000_000;
        o.seed = seed;
        o.record_execution = true;
        let result = run::<Sharded<P>, _>(config.clone(), o, ConflictWorkload::new(0.2, 100));
        assert!(result.metrics.ops > 40, "{}: ops={}", P::name(), result.metrics.ops);
        assert_psmr(&config, &result, true);
    }
    sweep::<Tempo>(31);
    sweep::<EPaxos>(32);
    sweep::<Atlas>(33);
    sweep::<Janus>(34);
    sweep::<Caesar>(35);
    sweep::<FPaxos>(36);
}

#[test]
fn workers_gc_keeps_footprints_bounded() {
    // The stride-aware frontiers must keep GC effective per worker slot:
    // after a drained sharded run, per-command state is pruned, not
    // retained for the whole run.
    let config = Config::new(3, 1).with_workers(4);
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 8;
    o.warmup_us = 0;
    o.duration_us = 4_000_000;
    o.drain_us = 6_000_000;
    o.seed = 41;
    o.record_execution = true;
    let result = run::<Sharded<Tempo>, _>(config.clone(), o, ConflictWorkload::new(0.1, 100));
    let ops = result.metrics.ops as usize;
    assert!(ops > 200, "ops={ops}");
    assert!(result.metrics.counters.gc_pruned > 0, "sharded GC never pruned");
    for (p, fp) in result.footprints.iter().enumerate() {
        assert!(
            fp.infos < ops / 4,
            "P{p} retains {} infos after {ops} ops — stride GC ineffective",
            fp.infos
        );
    }
    assert_psmr(&config, &result, true);
}

#[test]
fn merkle_store_digest_localizes_divergence_to_a_worker_slot() {
    // The TCP runtime's NodeHandle::store_digest is a Merkle-style root
    // over the per-worker-slot KV partition digests. Reconstruct the
    // per-slot partitions from a sharded sim run (replay every process's
    // execution log into one KvStore per slot, routed by the same
    // worker_of_key hash the runtime uses): converged replicas must
    // agree leaf-wise and on the root, and a corrupted slot must flip
    // the root while diverging_slots names exactly that slot — the
    // debugging story the XOR digest could not offer.
    let workers = 4;
    let config = Config::new(3, 1).with_workers(workers);
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 4;
    o.warmup_us = 0;
    o.duration_us = 2_000_000;
    o.drain_us = 6_000_000;
    o.seed = 51;
    o.record_execution = true;
    let result = run::<Sharded<Tempo>, _>(config, o, ConflictWorkload::new(0.2, 100));
    assert!(result.metrics.ops > 40, "ops={}", result.metrics.ops);
    let cmd_of: HashMap<Dot, _> =
        result.submitted.iter().map(|(d, c)| (*d, c.clone())).collect();
    let leaves_of = |log: &[(Dot, u64)]| -> Vec<u64> {
        let mut slots: Vec<KvStore> = (0..workers).map(|_| KvStore::new()).collect();
        for &(dot, _) in log {
            let cmd = &cmd_of[&dot];
            let w = worker_of_key(cmd.keys[0], workers);
            slots[w].execute(cmd);
        }
        slots.iter().map(|s| s.digest()).collect()
    };
    let all_leaves: Vec<Vec<u64>> =
        result.execution_logs.iter().map(|l| leaves_of(l.as_slice())).collect();
    let roots: Vec<u64> = all_leaves.iter().map(|l| merkle_root(l)).collect();
    for (p, leaves) in all_leaves.iter().enumerate() {
        assert_eq!(
            diverging_slots(&all_leaves[0], leaves),
            Vec::<usize>::new(),
            "P{p} disagrees with P0 on a slot partition"
        );
        assert_eq!(roots[p], roots[0], "equal leaves must give equal roots");
    }
    // Every slot saw traffic (the workload spreads keys across slots),
    // so the localization below is meaningful.
    let busy = all_leaves[0].iter().filter(|&&d| d != KvStore::new().digest()).count();
    assert!(busy >= 2, "want multiple busy slots, got {busy}");
    // Corrupt one slot of one replica: root flips, divergence localizes.
    let mut bad = all_leaves[1].clone();
    bad[2] = bad[2].wrapping_add(1);
    assert_ne!(merkle_root(&bad), roots[0], "a diverged slot must flip the root");
    assert_eq!(diverging_slots(&all_leaves[0], &bad), vec![2]);
}

#[test]
fn prop_routing_is_consistent_and_stable() {
    forall_seeds("key-worker-routing", |seed| {
        let mut rng = Rng::new(seed);
        let workers = 1 + (rng.gen_range(7) as usize);
        // Single-key commands: route by key, land on exactly one slot.
        for _ in 0..64 {
            let key = rng.gen_range(1 << 40);
            let w = worker_of_key(key, workers);
            if w >= workers {
                return Err(format!("worker_of_key({key}, {workers}) = {w} out of range"));
            }
            if w != worker_of_key(key, workers) {
                return Err("worker_of_key is not stable".into());
            }
            let cmd = tempo::core::Command::single(
                Rid::new(ClientId(1), 1),
                key,
                Op::Put,
                0,
            );
            match worker_of_cmd(&cmd, workers) {
                Ok(got) if got == w => {}
                other => {
                    return Err(format!(
                        "command on key {key} routed to {other:?}, its key lives on {w}"
                    ))
                }
            }
        }
        // Multi-key commands whose keys co-hash route to that one slot;
        // the router never silently splits a command across slots.
        let target = rng.gen_range(workers as u64) as usize;
        let keys: Vec<Key> = (0..)
            .filter(|&k| worker_of_key(k, workers) == target)
            .take(3)
            .collect();
        let cmd =
            tempo::core::Command::new(Rid::new(ClientId(2), 1), keys, Op::Put, 0);
        if worker_of_cmd(&cmd, workers) != Ok(target) {
            return Err("co-hashing multi-key command not routed to its slot".into());
        }
        // Dot-side routing agrees with key-side routing and is stable
        // across recovery: any process recomputes the same owner from the
        // dot alone, for every dot the slot's generator will ever mint.
        let origin = ProcessId(rng.gen_range(16) as u32);
        for w in 0..workers {
            let mut g = DotGen::strided(origin, w, workers);
            for _ in 0..32 {
                let d = g.next();
                if worker_of_dot(d, workers) != w {
                    return Err(format!(
                        "dot {d} minted by slot {w} routes to {}",
                        worker_of_dot(d, workers)
                    ));
                }
            }
        }
        Ok(())
    });
}
