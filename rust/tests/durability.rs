//! Crash-*recovery* fault model: replicas journal executions to a
//! per-slot WAL under `StorageMode::Disk` (`store::storage`), checkpoint
//! content-addressed snapshots, and on restart rebuild from the surviving
//! disk before fetching the manifest diff from a live peer. These tests
//! drive the deterministic simulator through kill-and-restart schedules
//! and hold the recoveries to the durability contract
//! (`check::check_recovery`):
//!
//! - local replay arithmetic is exact (`snapshot_applied + wal_replayed`),
//! - a crash can only destroy records still inside the group-commit
//!   window (`wal_fsync_batch == 1` ⇒ zero loss),
//! - a transferred rejoin is byte-identical to its donor's store.
//!
//! Safety (`check_psmr` without the liveness arm) must hold across every
//! schedule: a restarted replica executes a *suffix* of the history —
//! transferred state installs results without execution-log entries — so
//! the liveness oracle does not apply, but agreement and per-key order do.

use tempo::check::{assert_recovery, check_psmr, check_recovery};
use tempo::core::{Config, ProcessId, StorageMode};
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, SimResult, Topology};
use tempo::util::prop::forall_seeds;
use tempo::workload::ZipfWorkload;

/// A schedule that crashes `victim` and restarts it later in the same
/// run. Suspicion is pushed past the end of the run so the *restart*
/// (not an epoch eviction) is what brings the replica back — the
/// restarted process re-issues its own orphaned rids.
fn restart_opts(seed: u64, crash_at_us: u64, restart_at_us: u64, victim: u32) -> SimOpts {
    assert!(crash_at_us < restart_at_us);
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 4;
    o.warmup_us = 0;
    o.duration_us = 2_000_000;
    o.drain_us = 6_000_000;
    o.seed = seed;
    o.record_execution = true;
    o.crashes = vec![(crash_at_us, ProcessId(victim))];
    o.restarts = vec![(restart_at_us, ProcessId(victim))];
    o.suspect_delay_us = 60_000_000; // never fires: restart precedes eviction
    o
}

fn disk_config() -> Config {
    Config::new(3, 1)
        .with_recovery_timeout_us(1_000_000)
        .with_storage(StorageMode::Disk)
        .with_wal_fsync_batch(4)
        .with_snapshot_every(32)
}

fn assert_safety(config: &Config, result: &SimResult) {
    let violations = check_psmr(config, result, false);
    assert!(
        violations.is_empty(),
        "safety violated across the restart: {:#?}",
        violations.iter().take(8).collect::<Vec<_>>()
    );
}

#[test]
fn crash_restart_recovers_from_disk_and_rejoins_byte_identical() {
    let config = disk_config();
    let result = run::<Tempo, _>(
        config.clone(),
        restart_opts(71, 900_000, 1_600_000, 0),
        ZipfWorkload::new(1_000, 0.5, 64),
    );
    assert_eq!(result.recoveries.len(), 1, "exactly one restart scheduled");
    let rec = &result.recoveries[0];
    assert_eq!(rec.process, ProcessId(0));
    // The victim executed real work before the crash, and the disk gave
    // most of it back: a snapshot fired (cadence 32) and a WAL tail
    // replayed on top of it.
    assert!(rec.pre_crash_applied > 0, "no pre-crash executions: {rec:?}");
    assert!(rec.snapshot_applied > 0, "the snapshot cadence never fired: {rec:?}");
    assert!(rec.recovered_applied > 0, "local recovery rebuilt nothing: {rec:?}");
    // The survivors kept executing during the outage, so the manifest
    // diff must pull the newer pages — and leave the rejoining store
    // byte-identical to the donor's (assert_recovery checks the digest).
    assert!(rec.peer.is_some(), "no live donor found for the transfer");
    assert!(rec.chunks_fetched > 0, "the rejoin was behind but fetched no pages: {rec:?}");
    assert!(rec.dedup_seeded > 0, "no exactly-once state recovered: {rec:?}");
    assert_recovery(&config, &result);
    assert_safety(&config, &result);
    // The storage counters surface in the run metrics like any other.
    let c = &result.metrics.counters;
    assert!(c.wal_records > 0, "no WAL records journaled: {c:?}");
    assert!(c.wal_fsyncs > 0, "no group commits: {c:?}");
    assert!(c.snapshots_taken > 0, "no snapshots taken: {c:?}");
    assert_eq!(c.chunks_fetched, rec.chunks_fetched);
}

#[test]
fn fsync_every_record_loses_nothing_across_a_crash() {
    // wal_fsync_batch == 1: every executed command is on disk before the
    // crash can happen, so local recovery alone reproduces the exact
    // pre-crash store — digest and applied count — before any transfer.
    let config = disk_config().with_wal_fsync_batch(1);
    let result = run::<Tempo, _>(
        config.clone(),
        restart_opts(72, 700_000, 1_500_000, 1),
        ZipfWorkload::new(500, 0.5, 64),
    );
    let rec = &result.recoveries[0];
    assert_eq!(rec.wal_lost, 0, "fsync-per-record must never lose a record: {rec:?}");
    assert_eq!(
        rec.recovered_applied, rec.pre_crash_applied,
        "local recovery must reproduce every pre-crash execution: {rec:?}"
    );
    assert_eq!(
        rec.recovered_digest, rec.pre_crash_digest,
        "local recovery must reproduce the exact pre-crash store: {rec:?}"
    );
    assert_recovery(&config, &result);
    assert_safety(&config, &result);
}

#[test]
fn group_commit_window_loss_is_legal_and_bounded() {
    // The flip side of the group-commit bargain: with a huge fsync batch
    // and snapshots disabled, a crash destroys the entire unsynced tail.
    // That loss is LEGAL — the contract only promises what fsync
    // acknowledged — so the recovery oracle stays quiet, the rejoin is
    // visibly stale (transfer disabled to expose it), and safety still
    // holds because the replica re-executes forward from what survived.
    let config = disk_config().with_wal_fsync_batch(1 << 20).with_snapshot_every(u64::MAX);
    let mut o = restart_opts(73, 900_000, 1_600_000, 2);
    o.transfer_on_restart = false;
    let result = run::<Tempo, _>(config.clone(), o, ZipfWorkload::new(500, 0.5, 64));
    let rec = &result.recoveries[0];
    assert!(rec.pre_crash_applied > 0, "no pre-crash executions: {rec:?}");
    assert!(rec.wal_lost > 0, "the unsynced tail should have died with the crash: {rec:?}");
    assert_eq!(rec.snapshot_applied, 0, "snapshots were disabled: {rec:?}");
    assert!(
        rec.recovered_applied < rec.pre_crash_applied,
        "without fsync or transfer the rejoin must be stale: {rec:?}"
    );
    assert!(rec.peer.is_none(), "transfer was disabled: {rec:?}");
    assert!(
        check_recovery(&config, &result).is_empty(),
        "losing only the unsynced window is within the durability contract"
    );
    assert_safety(&config, &result);
}

#[test]
fn memory_mode_restart_is_healed_entirely_by_state_transfer() {
    // Under `StorageMode::Memory` (the default) the disk model is inert:
    // a restarted replica comes back EMPTY and owes everything to the
    // manifest-diff transfer — the crash-stop model upgraded to
    // crash-recovery purely by the wire protocol (tags 22–24 in the TCP
    // runtime). assert_recovery still holds: the rejoin must be
    // byte-identical to the donor.
    let config = Config::new(3, 1).with_recovery_timeout_us(1_000_000);
    assert!(matches!(config.storage, StorageMode::Memory));
    let result = run::<Tempo, _>(
        config.clone(),
        restart_opts(74, 600_000, 1_400_000, 0),
        ZipfWorkload::new(500, 0.5, 64),
    );
    let rec = &result.recoveries[0];
    assert_eq!(rec.snapshot_applied, 0, "memory mode has no snapshots: {rec:?}");
    assert_eq!(rec.wal_replayed, 0, "memory mode has no WAL: {rec:?}");
    assert_eq!(rec.recovered_applied, 0, "memory mode recovers empty: {rec:?}");
    assert!(rec.peer.is_some() && rec.chunks_fetched > 0, "transfer must heal it: {rec:?}");
    assert_recovery(&config, &result);
    assert_safety(&config, &result);
}

#[test]
fn repeated_crash_restart_of_the_same_replica() {
    // Two full kill/recover cycles in one run: the second recovery reads
    // a disk state that itself was produced by a recovery (snapshot +
    // WAL + installed transfer pages). Both must satisfy the contract.
    let config = disk_config().with_snapshot_every(32);
    let mut o = restart_opts(75, 500_000, 1_100_000, 0);
    o.crashes.push((1_700_000, ProcessId(0)));
    o.restarts.push((2_300_000, ProcessId(0)));
    let result = run::<Tempo, _>(config.clone(), o, ZipfWorkload::new(1_000, 0.5, 64));
    assert_eq!(result.recoveries.len(), 2, "both restarts must recover");
    assert!(
        result.recoveries[1].recovered_applied > 0,
        "the second recovery must replay state the first recovery persisted: {:?}",
        result.recoveries[1]
    );
    assert_recovery(&config, &result);
    assert_safety(&config, &result);
}

#[test]
fn crash_restart_sweep_holds_the_durability_contract_across_seeds() {
    // Property: whatever the victim, crash/restart instants, fsync batch
    // and snapshot cadence, every recovery satisfies the durability
    // contract and the run stays safe.
    forall_seeds("tempo-crash-restart-sweep", |seed| {
        let victim = (seed % 3) as u32;
        let crash_at = 300_000 + (seed % 5) * 200_000;
        let restart_at = crash_at + 400_000 + (seed % 3) * 300_000;
        let config = disk_config()
            .with_wal_fsync_batch([1, 4, 64][(seed % 3) as usize])
            .with_snapshot_every([16, 64, 1024][((seed / 3) % 3) as usize]);
        let result = run::<Tempo, _>(
            config.clone(),
            restart_opts(seed, crash_at, restart_at, victim),
            ZipfWorkload::new(1_000, 0.5, 64),
        );
        if result.recoveries.len() != 1 {
            return Err(format!("expected one recovery, got {}", result.recoveries.len()));
        }
        let violations = check_recovery(&config, &result);
        if !violations.is_empty() {
            return Err(format!(
                "victim=P{victim} crash={crash_at} restart={restart_at}: {:?}",
                violations.iter().take(4).collect::<Vec<_>>()
            ));
        }
        let safety = check_psmr(&config, &result, false);
        if !safety.is_empty() {
            return Err(format!(
                "safety violated: {:?}",
                safety.iter().take(4).collect::<Vec<_>>()
            ));
        }
        Ok(())
    });
}

#[test]
fn nemesis_crash_restart_under_link_faults() {
    // The nemesis schedules the same crash/restart cycle while the links
    // between the survivors jitter and duplicate — recovery must still
    // hand back a byte-identical rejoin once a donor is reachable.
    use tempo::sim::nemesis::Nemesis;
    let config = disk_config();
    let mut o = restart_opts(76, 600_000, 1_500_000, 1);
    o.nemesis = Nemesis::new()
        .crash(600_000, 1)
        .restart(1_500_000, 1)
        .delay(800_000, 1_200_000, 20_000)
        .duplicate(1_200_000, 1_600_000, 0.2);
    o.crashes.clear(); // the nemesis owns the schedule in this run
    o.restarts.clear();
    let result = run::<Tempo, _>(config.clone(), o, ZipfWorkload::new(1_000, 0.5, 64));
    assert_eq!(result.recoveries.len(), 1);
    assert!(result.recoveries[0].peer.is_some());
    assert_recovery(&config, &result);
    assert_safety(&config, &result);
}
