//! Fault injection: crash coordinators mid-protocol and verify that
//! Tempo's recovery (Algorithm 4 + §B liveness) preserves the PSMR spec —
//! in particular Property 1 (timestamp agreement) and Liveness.

use std::collections::{HashMap, HashSet};
use tempo::check::{check_psmr, Violation};
use tempo::core::{Config, Dot, ProcessId, Rid};
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::util::prop::forall_seeds;
use tempo::workload::ConflictWorkload;

fn crash_opts(seed: u64, crash_at_us: u64, victim: u32) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 2;
    o.warmup_us = 0;
    o.duration_us = 2_000_000;
    o.drain_us = 8_000_000; // recovery timers need time to fire
    o.seed = seed;
    o.record_execution = true;
    o.crashes = vec![(crash_at_us, ProcessId(victim))];
    o.suspect_delay_us = 300_000;
    o
}

/// PSMR violations that survive the *precise* crash excuse.
///
/// A `NotExecuted` is excused only when:
/// - `process` is a victim (crashed replicas stop executing), or
/// - the command's origin is a victim **and no surviving replica
///   executed any dot of its request** — i.e. the submission died with
///   its coordinator before reaching a surviving quorum member.
///
/// The second arm is the tightened rule: the seed's blanket
/// `dot.origin != victim` filter excused *every* victim-origin command,
/// including ones a survivor demonstrably executed — exactly the case
/// where recovery (Algorithm 4) owes execution everywhere. Liveness is
/// rid-grouped in the checker (a retried rid is live if *any* of its
/// dots executed), so we resolve the reported dot back to its rid and
/// test all of that rid's dots against every survivor's log.
fn unexcused_violations(
    config: &Config,
    result: &tempo::sim::SimResult,
    victims: &[u32],
) -> Vec<Violation> {
    let violations = check_psmr(config, result, true);
    let executed: Vec<HashSet<Dot>> = result
        .execution_logs
        .iter()
        .map(|log| log.iter().map(|&(d, _)| d).collect())
        .collect();
    let mut rid_dots: HashMap<Rid, Vec<Dot>> = HashMap::new();
    for (dot, cmd) in &result.submitted {
        rid_dots.entry(cmd.rid).or_default().push(*dot);
    }
    let dot_rid: HashMap<Dot, Rid> =
        result.submitted.iter().map(|(d, c)| (*d, c.rid)).collect();
    let survivor_executed_rid = |dot: &Dot| -> bool {
        let Some(dots) = dot_rid.get(dot).and_then(|r| rid_dots.get(r)) else {
            return false;
        };
        dots.iter().any(|d| {
            executed
                .iter()
                .enumerate()
                .any(|(p, ex)| !victims.contains(&(p as u32)) && ex.contains(d))
        })
    };
    violations
        .into_iter()
        .filter(|v| match v {
            Violation::NotExecuted { process, dot } => {
                if victims.contains(&process.0) {
                    return false;
                }
                if victims.contains(&dot.origin.0) {
                    // Excused only if the request died with its
                    // coordinator; once any survivor executed it, every
                    // live replica must.
                    return survivor_executed_rid(dot);
                }
                true
            }
            _ => true,
        })
        .collect()
}

fn assert_psmr_with_crash(config: &Config, result: &tempo::sim::SimResult, victim: u32) {
    let filtered = unexcused_violations(config, result, &[victim]);
    assert!(
        filtered.is_empty(),
        "PSMR violated under crash of P{victim}: {} violation(s): {:#?}",
        filtered.len(),
        filtered.iter().take(8).collect::<Vec<_>>()
    );
}

#[test]
fn coordinator_crash_is_recovered_r3() {
    let config = Config::new(3, 1).with_recovery_timeout_us(1_000_000);
    let mut o = crash_opts(51, 500_000, 0);
    o.topology = Topology::ec2_three();
    let result = run::<Tempo, _>(config.clone(), o, ConflictWorkload::new(0.1, 100));
    assert!(result.metrics.counters.recoveries > 0, "{:?}", result.metrics.counters);
    assert_psmr_with_crash(&config, &result, 0);
}

#[test]
fn coordinator_crash_is_recovered_r5_f2() {
    let config = Config::new(5, 2).with_recovery_timeout_us(1_000_000);
    let result = run::<Tempo, _>(
        config.clone(),
        crash_opts(52, 500_000, 1),
        ConflictWorkload::new(0.5, 100),
    );
    assert_psmr_with_crash(&config, &result, 1);
}

#[test]
fn two_crashes_tolerated_with_f2() {
    let config = Config::new(5, 2).with_recovery_timeout_us(1_000_000);
    let mut o = crash_opts(53, 400_000, 3);
    o.crashes.push((900_000, ProcessId(4)));
    let result = run::<Tempo, _>(config.clone(), o, ConflictWorkload::new(0.2, 100));
    let filtered = unexcused_violations(&config, &result, &[3, 4]);
    assert!(filtered.is_empty(), "{:#?}", filtered.iter().take(8).collect::<Vec<_>>());
}

#[test]
fn crash_sweep_property_random_times_and_victims() {
    // Property: whatever the crash time and victim, safety (agreement,
    // per-key order) holds and surviving-origin commands execute.
    forall_seeds("tempo-crash-sweep", |seed| {
        let victim = (seed % 5) as u32;
        let crash_at = 200_000 + (seed % 7) * 150_000;
        let config = Config::new(5, 1).with_recovery_timeout_us(800_000);
        let result = run::<Tempo, _>(
            config.clone(),
            crash_opts(seed, crash_at, victim),
            ConflictWorkload::new(0.3, 100),
        );
        let filtered = unexcused_violations(&config, &result, &[victim]);
        if filtered.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "victim=P{victim} crash_at={crash_at}: {} violations: {:?}",
                filtered.len(),
                filtered.iter().take(4).collect::<Vec<_>>()
            ))
        }
    });
}

#[test]
fn recovery_converges_after_gc_pruned_executed_commands() {
    // GC prunes executed command info before the crash; recovery of the
    // commands in flight at the crash must still converge — pruned state
    // is exactly the state no recovery can need (everyone executed it).
    let config = Config::new(3, 1)
        .with_recovery_timeout_us(1_000_000)
        .with_gc_interval_ticks(8);
    let mut o = crash_opts(55, 1_200_000, 0);
    o.topology = Topology::ec2_three();
    o.duration_us = 2_000_000;
    let result = run::<Tempo, _>(config.clone(), o, ConflictWorkload::new(0.2, 100));
    assert!(
        result.metrics.counters.gc_pruned > 0,
        "GC should have pruned executed commands before the crash: {:?}",
        result.metrics.counters
    );
    assert_psmr_with_crash(&config, &result, 0);
}

#[test]
fn no_recovery_when_nothing_crashes() {
    let config = Config::new(5, 1).with_recovery_timeout_us(2_000_000);
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 2;
    o.warmup_us = 0;
    o.duration_us = 2_000_000;
    o.drain_us = 4_000_000;
    o.seed = 54;
    o.record_execution = true;
    let result = run::<Tempo, _>(config, o, ConflictWorkload::new(0.1, 100));
    assert_eq!(result.metrics.counters.recoveries, 0, "spurious recovery triggered");
}
