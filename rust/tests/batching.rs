//! Message batching (`protocol/common::batch`) is behavior-transparent.
//!
//! Two layers of evidence:
//!
//! 1. **Exact equivalence** under the per-step flush policy
//!    (`Config::batch_hold == false`): batching only regroups the messages
//!    one protocol step emits to the same destination, so with a
//!    jitter-free topology and an rng-free workload a batched run must
//!    execute *identically* to the unbatched run — same dots, same order,
//!    same times, at every process. The simulator's canonical
//!    intra-timestamp event ordering (`sim::EventKey`) makes this exact,
//!    not just true-for-this-seed.
//! 2. **Safety + liveness** under the hold-until-tick policy (the
//!    throughput configuration, which deliberately delays messages up to
//!    one tick): the PSMR checker must still pass, drained.

use tempo::check::assert_psmr;
use tempo::core::{ClientId, Config, Op};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::Atlas;
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, SimOpts, SimResult, Topology};
use tempo::util::Rng;
use tempo::workload::{CommandSpec, ConflictWorkload, Workload};

/// Deterministic workload: never reads the rng, so runs whose protocols
/// consume different amounts of randomness (batched vs unbatched draw one
/// latency sample per frame) still see the same command stream. Clients
/// hammer a small shared key set, so commands genuinely conflict.
#[derive(Clone)]
struct FixedWorkload;

impl Workload for FixedWorkload {
    fn next(&mut self, client: ClientId, _rng: &mut Rng) -> CommandSpec {
        CommandSpec { keys: vec![client.0 % 3], op: Op::Put, payload_len: 64 }
    }
}

/// Jitter-free wide-area topology: latency depends only on the site pair,
/// so delivery times are identical across the two runs.
fn flat_topology() -> Topology {
    let mut t = Topology::ec2();
    t.jitter = 0.0;
    t
}

fn opts(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(flat_topology());
    o.clients_per_site = 2;
    o.warmup_us = 0;
    o.duration_us = 4_000_000;
    o.drain_us = 4_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

/// Per-process execution logs (dot and time) must match exactly.
fn assert_identical_execution(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.metrics.ops, b.metrics.ops, "{what}: op counts differ");
    assert_eq!(
        a.execution_logs.len(),
        b.execution_logs.len(),
        "{what}: process counts differ"
    );
    for (p, (la, lb)) in a.execution_logs.iter().zip(&b.execution_logs).enumerate() {
        assert_eq!(
            la, lb,
            "{what}: P{p} executed a different sequence with batching on"
        );
    }
}

/// Run `P` with and without per-step batching and require identical
/// executions. Returns the batched run for protocol-specific checks.
fn eager_equivalence<P: Protocol>(config: Config, seed: u64) -> SimResult {
    let unbatched = run::<P, _>(config.clone(), opts(seed), FixedWorkload);
    let batched_config = config.clone().with_batching(8).with_batch_hold(false);
    let batched = run::<P, _>(batched_config.clone(), opts(seed), FixedWorkload);
    assert!(
        unbatched.metrics.ops > 40,
        "{}: need traffic for a meaningful comparison, ops={}",
        P::name(),
        unbatched.metrics.ops
    );
    assert_identical_execution(&unbatched, &batched, P::name());
    assert_eq!(
        unbatched.metrics.counters.batches_sent, 0,
        "{}: unbatched run must not emit batch frames",
        P::name()
    );
    assert_psmr(&config, &unbatched, true);
    assert_psmr(&batched_config, &batched, true);
    batched
}

#[test]
fn tempo_batched_run_executes_identically() {
    // A long recovery timeout enables the periodic full promise
    // re-broadcast, which shares its tick (every 32nd) with the GC
    // exchange (every 16th): two messages to each peer in one step, so
    // the eager batcher is guaranteed to produce real multi-message
    // frames — and the run must still be identical.
    let config = Config::new(5, 1).with_recovery_timeout_us(60_000_000);
    let batched = eager_equivalence::<Tempo>(config, 7);
    assert!(
        batched.metrics.counters.batches_sent > 0,
        "per-step batching never produced a multi-message frame \
         (counters: {:?})",
        batched.metrics.counters
    );
}

#[test]
fn atlas_batched_run_executes_identically() {
    eager_equivalence::<Atlas>(Config::new(5, 1), 11);
}

#[test]
fn caesar_batched_run_executes_identically() {
    eager_equivalence::<Caesar>(Config::new(5, 1), 13);
}

#[test]
fn fpaxos_batched_run_executes_identically() {
    eager_equivalence::<FPaxos>(Config::new(5, 1), 17);
}

#[test]
fn tempo_hold_batching_preserves_psmr_and_amortizes() {
    // The throughput configuration: queues held across steps, flushed on
    // the size threshold or the next tick. Messages are delayed (so no
    // exact-equality claim); safety, liveness and real amortization are
    // asserted instead.
    let config = Config::new(3, 1).with_batching(16);
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 16;
    o.warmup_us = 0;
    o.duration_us = 4_000_000;
    o.drain_us = 6_000_000;
    o.seed = 23;
    o.record_execution = true;
    let result = run::<Tempo, _>(config.clone(), o, ConflictWorkload::new(0.1, 100));
    assert!(result.metrics.ops > 200, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
    let c = &result.metrics.counters;
    assert!(c.batches_sent > 0, "hold-mode batching never flushed a batch");
    assert!(
        c.mean_batch_size() >= 2.0,
        "batch frames must amortize at least two messages, got {:.2}",
        c.mean_batch_size()
    );
    // Nothing may be left sitting in a queue after the drain.
    for (p, fp) in result.footprints.iter().enumerate() {
        assert_eq!(fp.queued, 0, "P{p} still holds {} queued messages", fp.queued);
    }
}

#[test]
fn age_based_flush_bounds_the_delay_of_lone_messages() {
    // Config::batch_max_delay_us holds sub-threshold queues across ticks
    // (for bigger batches) but must flush every queued message within one
    // delay bound: with a huge size threshold and barely any traffic,
    // every command still completes (liveness through the age flush
    // alone), PSMR holds, and nothing is left queued after the drain.
    let config = Config::new(3, 1)
        .with_batching(10_000) // count threshold never fires
        .with_batch_max_delay_us(25_000); // 5 tick intervals
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 2; // lone messages, not bursts
    o.warmup_us = 0;
    o.duration_us = 4_000_000;
    o.drain_us = 6_000_000;
    o.seed = 41;
    o.record_execution = true;
    let result = run::<Tempo, _>(config.clone(), o, ConflictWorkload::new(0.1, 100));
    assert!(result.metrics.ops > 20, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
    for (p, fp) in result.footprints.iter().enumerate() {
        assert_eq!(fp.queued, 0, "P{p} still holds {} queued messages", fp.queued);
    }
    // The delay bound is real: commands take at most the wide-area
    // round trips plus a handful of 25 ms holds, not seconds.
    let p99 = result.metrics.latency.quantile(0.99);
    assert!(p99 < 1_000_000, "age flush too slow: p99={p99}µs");
}

#[test]
fn hold_batching_is_safe_for_every_family() {
    // One drained PSMR sweep per protocol family under hold-mode batching.
    fn sweep<P: Protocol>(seed: u64) {
        let config = Config::new(3, 1).with_batching(8);
        let mut o = SimOpts::new(Topology::ec2_three());
        o.clients_per_site = 4;
        o.warmup_us = 0;
        o.duration_us = 2_000_000;
        o.drain_us = 6_000_000;
        o.seed = seed;
        o.record_execution = true;
        let result = run::<P, _>(config.clone(), o, ConflictWorkload::new(0.2, 100));
        assert!(result.metrics.ops > 40, "{}: ops={}", P::name(), result.metrics.ops);
        assert_psmr(&config, &result, true);
    }
    sweep::<Tempo>(31);
    sweep::<Atlas>(32);
    sweep::<Caesar>(33);
    sweep::<FPaxos>(34);
}
