//! Integration tests: Tempo through the simulator, checked against the
//! PSMR specification (Validity / Ordering / Liveness).

use tempo::check::assert_psmr;
use tempo::core::Config;
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::ConflictWorkload;

fn opts(topology: Topology, seed: u64) -> SimOpts {
    let mut o = SimOpts::new(topology);
    o.clients_per_site = 4;
    o.warmup_us = 0;
    o.duration_us = 3_000_000;
    o.drain_us = 3_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

#[test]
fn tempo_r3_f1_low_conflict_satisfies_psmr() {
    let config = Config::new(3, 1);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2_three(), 7),
        ConflictWorkload::new(0.02, 100),
    );
    assert!(result.metrics.ops > 50, "too few ops: {}", result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn tempo_r5_f1_satisfies_psmr() {
    let config = Config::new(5, 1);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2(), 8),
        ConflictWorkload::new(0.02, 100),
    );
    assert!(result.metrics.ops > 50);
    assert_psmr(&config, &result, true);
}

#[test]
fn tempo_r5_f2_satisfies_psmr() {
    let config = Config::new(5, 2);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2(), 9),
        ConflictWorkload::new(0.02, 100),
    );
    assert!(result.metrics.ops > 50);
    assert_psmr(&config, &result, true);
}

#[test]
fn tempo_full_conflict_satisfies_psmr() {
    // Every command conflicts: the hardest ordering workload.
    let config = Config::new(5, 2);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2(), 10),
        ConflictWorkload::new(1.0, 100),
    );
    assert!(result.metrics.ops > 50);
    assert_psmr(&config, &result, true);
}

#[test]
fn tempo_f1_always_takes_fast_path() {
    // With f = 1, count(max proposal) >= 1 trivially holds (§3.1).
    let config = Config::new(5, 1);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2(), 11),
        ConflictWorkload::new(0.5, 100),
    );
    assert_eq!(result.metrics.counters.slow_path, 0);
    assert!(result.metrics.counters.fast_path > 0);
}

#[test]
fn tempo_f2_contention_uses_slow_path_sometimes() {
    let config = Config::new(5, 2);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2(), 12),
        ConflictWorkload::new(1.0, 100),
    );
    // With full conflicts and f=2 some commands can't match f proposals.
    assert!(
        result.metrics.counters.slow_path > 0,
        "expected some slow paths under full conflicts: {:?}",
        result.metrics.counters
    );
    assert_psmr(&config, &result, true);
}

#[test]
fn tempo_partial_replication_two_shards() {
    let config = Config::new(3, 1).with_shards(2);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2_three(), 13),
        tempo::workload::YcsbWorkload::new(10_000, 0.5, 0.5),
    );
    assert!(result.metrics.ops > 50, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn tempo_partial_replication_four_shards_zipf_hot() {
    let config = Config::new(3, 1).with_shards(4);
    let result = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2_three(), 14),
        tempo::workload::YcsbWorkload::new(1_000, 0.7, 0.5),
    );
    assert!(result.metrics.ops > 50, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn tempo_deterministic_given_seed() {
    let config = Config::new(3, 1);
    let a = run::<Tempo, _>(
        config.clone(),
        opts(Topology::ec2_three(), 42),
        ConflictWorkload::new(0.1, 100),
    );
    let b = run::<Tempo, _>(
        config,
        opts(Topology::ec2_three(), 42),
        ConflictWorkload::new(0.1, 100),
    );
    assert_eq!(a.metrics.ops, b.metrics.ops);
    assert_eq!(a.execution_logs, b.execution_logs);
}
