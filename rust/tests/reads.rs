//! Stability-powered local reads (`Op::Read` / `Protocol::submit_read`).
//!
//! Four layers of evidence:
//!
//! 1. **Mechanism**: an instant local read emits exactly one
//!    `Action::ExecuteRead` and *zero* protocol messages; a read behind
//!    the frontier parks and is released once the frontier covers its
//!    timestamp (driven directly against a 3-replica Tempo cluster).
//! 2. **Oracle sweeps**: mixed read/write runs pass the PSMR checker —
//!    including its local-read linearizability extension — for all six
//!    protocol families, monolithic and behind the 4-worker router, at
//!    the paper-style 95/5 and 50/50 mixes under low and high zipf
//!    contention.
//! 3. **The oracle bites**: `Config::read_frontier_skew` deliberately
//!    inflates the observed frontier; the checker must report
//!    `Violation::StaleLocalRead` for such a run.
//! 4. **Encode-once crediting** (`SimOpts::encode_once`): the flag is a
//!    pure *charging* change (identical executions without a resource
//!    model) and charges strictly less sender CPU per op with one.
//! 5. **Read-your-writes sessions**: the client `Session` tracks the
//!    highest decided write timestamp and passes it as the read floor;
//!    a failed-over read parks until the frontier covers that floor
//!    (positive), and demonstrably serves stale state without it
//!    (negative).

use tempo::check::{assert_psmr, check_psmr, Violation};
use tempo::client::Session;
use tempo::core::{ClientId, Command, Config, Op, ProcessId};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::common::Sharded;
use tempo::protocol::depsmr::{Atlas, EPaxos, Janus};
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::msg::Msg;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::{Action, Protocol};
use tempo::sim::{run, ResourceModel, SimOpts, SimResult, Topology};
use tempo::workload::{Workload, ZipfWorkload};

fn opts(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 4;
    o.warmup_us = 0;
    o.duration_us = 2_000_000;
    o.drain_us = 5_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

// --- Layer 1: mechanism ---------------------------------------------------

#[test]
fn instant_local_read_sends_no_messages() {
    // A fresh key's frontier trivially covers timestamp 0: the read is
    // served in the submit call itself, with no outbound traffic.
    let mut p = Tempo::new(ProcessId(0), Config::new(3, 1));
    let mut s = Session::new(ClientId(1));
    let actions = p.submit_read(s.read_single(42), 0, 0);
    assert_eq!(actions.len(), 1, "expected exactly one action: {actions:?}");
    match &actions[0] {
        Action::ExecuteRead { cmd, covered, slack } => {
            assert_eq!(&cmd.keys[..], &[42]);
            assert_eq!(*covered, 0);
            assert!(!slack);
        }
        other => panic!("expected ExecuteRead, got {other:?}"),
    }
    assert_eq!(p.counters.local_reads, 1);
    assert_eq!(p.counters.slow_reads, 0);
}

/// Deliver every Send/SendShared in `actions` (emitted by `from`)
/// immediately, recursing into the actions the deliveries produce, and
/// collect any `ExecuteRead` emitted along the way.
fn drain(
    procs: &mut Vec<Tempo>,
    from: ProcessId,
    actions: Vec<Action<Msg>>,
    time: u64,
    reads: &mut Vec<(ProcessId, Command, u64)>,
) {
    for action in actions {
        match action {
            Action::Send { to, msg } => {
                let acts = procs[to.0 as usize].handle(from, msg, time);
                drain(procs, to, acts, time, reads);
            }
            Action::SendShared { to, msg } => {
                for dest in to {
                    let acts = procs[dest.0 as usize].handle(from, msg.clone(), time);
                    drain(procs, dest, acts, time, reads);
                }
            }
            Action::ExecuteRead { cmd, covered, .. } => reads.push((from, cmd, covered)),
            _ => {}
        }
    }
}

#[test]
fn parked_read_is_released_when_the_frontier_catches_up() {
    let config = Config::new(3, 1);
    let mut procs: Vec<Tempo> =
        (0..3).map(|i| Tempo::new(ProcessId(i), config.clone())).collect();
    let mut session = Session::new(ClientId(1));
    let mut reads = Vec::new();

    // Propose a write on key 7 but do not deliver anything yet: the
    // coordinator's clock moves past its stability frontier.
    let write_actions = procs[0].submit(session.single(7, Op::Put, 8), 0);

    // A read on key 7 now targets the write's timestamp — not yet
    // covered, so it parks: no actions at all, and no local-read credit.
    let read = session.read_single(7);
    let rid = read.rid;
    let parked = procs[0].submit_read(read, 0, 0);
    assert!(parked.is_empty(), "read must park, got {parked:?}");
    assert_eq!(procs[0].counters.local_reads, 0);

    // Deliver the write's protocol traffic; then tick until the promise
    // exchange advances the majority watermark over the read's target.
    drain(&mut procs, ProcessId(0), write_actions, 1, &mut reads);
    let mut t = 1_000;
    while reads.is_empty() && t < 100_000 {
        for i in 0..3 {
            let acts = procs[i].tick(t);
            let at = ProcessId(i as u32);
            drain(&mut procs, at, acts, t, &mut reads);
        }
        t += 1_000;
    }
    assert_eq!(reads.len(), 1, "parked read never released");
    let (at, cmd, covered) = &reads[0];
    assert_eq!(*at, ProcessId(0), "read must be served at its coordinator");
    assert_eq!(cmd.rid, rid);
    assert!(*covered >= 1, "release must cover the write's timestamp");
    assert_eq!(procs[0].counters.local_reads, 1);
    assert_eq!(procs[0].counters.slow_reads, 0);
}

// --- Layer 2: oracle sweeps ----------------------------------------------

/// Run one family over a 50/50 zipf mix and require a clean checker
/// verdict (PSMR + response validity + local-read linearizability).
fn family_passes_read_oracle<P: Protocol>(seed: u64, workers: usize) {
    let config = if workers > 1 {
        Config::new(3, 1).with_workers(workers)
    } else {
        Config::new(3, 1)
    };
    let workload = ZipfWorkload::new(100, 0.5, 64).with_read_ratio(0.5);
    let result = run::<P, _>(config.clone(), opts(seed), workload);
    assert!(result.metrics.ops > 40, "{}: ops={}", P::name(), result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn all_six_families_pass_the_read_oracle_monolithic() {
    family_passes_read_oracle::<Tempo>(71, 1);
    family_passes_read_oracle::<Atlas>(72, 1);
    family_passes_read_oracle::<EPaxos>(73, 1);
    family_passes_read_oracle::<Janus>(74, 1);
    family_passes_read_oracle::<Caesar>(75, 1);
    family_passes_read_oracle::<FPaxos>(76, 1);
}

#[test]
fn all_six_families_pass_the_read_oracle_sharded() {
    family_passes_read_oracle::<Sharded<Tempo>>(81, 4);
    family_passes_read_oracle::<Sharded<Atlas>>(82, 4);
    family_passes_read_oracle::<Sharded<EPaxos>>(83, 4);
    family_passes_read_oracle::<Sharded<Janus>>(84, 4);
    family_passes_read_oracle::<Sharded<Caesar>>(85, 4);
    family_passes_read_oracle::<Sharded<FPaxos>>(86, 4);
}

/// The local-read accounting of one Tempo run: every `Op::Read` was
/// served locally (sentinel dot, an audit, a `local_reads` credit) and
/// none fell back to the ordering path.
fn assert_local_read_accounting(result: &SimResult) {
    let local = result.metrics.counters.local_reads;
    assert!(local > 0, "no local reads served: {:?}", result.metrics.counters);
    assert_eq!(
        result.metrics.counters.slow_reads, 0,
        "single-key single-group reads must never degrade"
    );
    // Each served read leaves exactly one audit and one sentinel-dot
    // completion (seq 0 is never minted for ordered commands).
    let audits: usize = result.read_audits.iter().map(|a| a.len()).sum();
    assert_eq!(audits as u64, local);
    let sentinel_completions =
        result.completions.iter().filter(|c| c.dot.seq == 0).count();
    assert_eq!(sentinel_completions as u64, local, "a local read did not complete");
}

#[test]
fn tempo_read_mix_sweeps_serve_every_read_locally() {
    // The tentpole's perf claim, functionally: 95/5 and 50/50 mixes at
    // low and high zipf contention, all reads served at the coordinator
    // with zero protocol messages, and the full checker stays green.
    for (read_ratio, theta, seed) in
        [(0.95, 0.1, 91), (0.95, 0.99, 92), (0.5, 0.1, 93), (0.5, 0.99, 94)]
    {
        let config = Config::new(3, 1);
        let workload = ZipfWorkload::new(50, theta, 64).with_read_ratio(read_ratio);
        let result = run::<Tempo, _>(config.clone(), opts(seed), workload);
        assert!(
            result.metrics.ops > 40,
            "mix {read_ratio}/{theta}: ops={}",
            result.metrics.ops
        );
        assert_psmr(&config, &result, true);
        assert_local_read_accounting(&result);
    }
}

#[test]
fn read_slack_serves_below_the_frontier_and_stays_linearizable() {
    // Bounded staleness: with slack, a read may be released while the
    // strict frontier still lags its timestamp (`read_slack_served`); the
    // checker still passes because the audit's `covered` target is the
    // slackened one — the read observes a consistent, bounded-stale
    // prefix, never an impossible state.
    let config = Config::new(3, 1).with_read_slack(1_000);
    let workload = ZipfWorkload::new(1, 0.0, 64).with_read_ratio(0.5);
    let result = run::<Tempo, _>(config.clone(), opts(95), workload);
    assert!(result.metrics.ops > 40, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
    assert_local_read_accounting(&result);
    assert!(
        result.metrics.counters.read_slack_served > 0,
        "slack never kicked in on a contended key: {:?}",
        result.metrics.counters
    );
}

// --- Layer 3: the oracle bites -------------------------------------------

#[test]
fn skewed_frontier_is_caught_by_the_read_oracle() {
    // `read_frontier_skew` pretends the watermark is further along than
    // it is, which breaks exactly the stability argument local reads
    // rest on: proposed-but-uncommitted writes with timestamps at or
    // below the claimed frontier are invisible to the release check.
    // One hot key + write-heavy traffic makes such writes plentiful; the
    // checker must catch at least one stale read.
    let config = Config::new(3, 1).with_read_frontier_skew(10_000);
    let workload = ZipfWorkload::new(1, 0.0, 64).with_read_ratio(0.3);
    let result = run::<Tempo, _>(config.clone(), opts(96), workload);
    assert!(
        result.metrics.counters.local_reads > 0,
        "skew must not stop reads from serving: {:?}",
        result.metrics.counters
    );
    let violations = check_psmr(&config, &result, false);
    assert!(
        violations.iter().any(|v| matches!(v, Violation::StaleLocalRead { .. })),
        "lagged-frontier reads were not flagged; violations: {violations:?}"
    );
}

// --- Layer 4: encode-once crediting (satellite) ---------------------------

#[test]
fn encode_once_without_resources_is_a_pure_noop() {
    // The flag only changes how broadcasts are *charged*; with no
    // resource model there is nothing to charge and runs must be
    // bit-identical.
    let config = Config::new(3, 1);
    let mk = |flag: bool| {
        let mut o = opts(101);
        o.encode_once = flag;
        o
    };
    let workload = ZipfWorkload::new(50, 0.5, 64).with_read_ratio(0.2);
    let legacy = run::<Tempo, _>(config.clone(), mk(false), workload.clone());
    let flagged = run::<Tempo, _>(config.clone(), mk(true), workload);
    assert_eq!(legacy.metrics.ops, flagged.metrics.ops);
    assert_eq!(legacy.execution_logs, flagged.execution_logs);
}

#[test]
fn encode_once_charges_less_sender_cpu_per_op() {
    // With a resource model, the legacy path re-charges the serialize
    // CPU per broadcast destination while the flag charges it once (the
    // TCP runtime's actual cost shape, `net::encode_fanout`). Commit
    // broadcasts fan out to every peer, so the per-op CPU charge must
    // drop. (Per-op, not total: cheaper sends let the closed loop fit
    // more ops into the same window.)
    let config = Config::new(3, 1);
    let mk = |flag: bool| {
        let mut o = opts(102);
        o.duration_us = 1_000_000;
        o.resources = Some(ResourceModel::cluster());
        o.encode_once = flag;
        o
    };
    let workload = ZipfWorkload::new(50, 0.5, 64);
    let legacy = run::<Tempo, _>(config.clone(), mk(false), workload.clone());
    let flagged = run::<Tempo, _>(config.clone(), mk(true), workload);
    assert_psmr(&config, &legacy, true);
    assert_psmr(&config, &flagged, true);
    let cpu_per_op = |r: &SimResult| {
        let cpu: f64 = r.metrics.utilization.iter().map(|u| u.cpu).sum();
        cpu / r.metrics.ops as f64
    };
    assert!(legacy.metrics.ops > 40 && flagged.metrics.ops > 40);
    assert!(
        cpu_per_op(&flagged) < cpu_per_op(&legacy),
        "encode-once must charge less sender CPU per op: flagged={} legacy={}",
        cpu_per_op(&flagged),
        cpu_per_op(&legacy)
    );
}

// --- Layer 5: read-your-writes sessions ------------------------------------

#[test]
fn session_floor_parks_a_failed_over_read_until_the_write_is_covered() {
    // RYW, positive case. A client writes key 7 at replica 0 and records
    // the decided timestamp in its session watermark. It then fails over
    // and reads the same key at replica 1, whose key state is still bare
    // (the write's traffic has not been delivered). The session floor
    // must force the read to park — serving instantly would return state
    // older than the client's own acked write.
    let config = Config::new(3, 1);
    let mut procs: Vec<Tempo> =
        (0..3).map(|i| Tempo::new(ProcessId(i), config.clone())).collect();
    let mut session = Session::new(ClientId(1));
    let mut reads = Vec::new();

    let write_actions = procs[0].submit(session.single(7, Op::Put, 9), 0);
    // The decided timestamp of the first write on a fresh key is 1; in
    // the runtimes this value arrives on the client's write ack.
    session.note_write(1);
    assert_eq!(session.read_floor(), 1);

    let read = session.read_single(7);
    let rid = read.rid;
    let parked = procs[1].submit_read(read, session.read_floor(), 0);
    assert!(parked.is_empty(), "read below the floor must park, got {parked:?}");
    assert_eq!(procs[1].counters.local_reads, 0);

    // Deliver the write and tick until the promise exchange lifts the
    // majority watermark over the floor: the read releases at replica 1,
    // covering the session's write.
    drain(&mut procs, ProcessId(0), write_actions, 1, &mut reads);
    let mut t = 1_000;
    while reads.is_empty() && t < 100_000 {
        for i in 0..3 {
            let acts = procs[i].tick(t);
            let at = ProcessId(i as u32);
            drain(&mut procs, at, acts, t, &mut reads);
        }
        t += 1_000;
    }
    assert_eq!(reads.len(), 1, "floored read never released");
    let (at, cmd, covered) = &reads[0];
    assert_eq!(*at, ProcessId(1), "read must serve at the failover replica");
    assert_eq!(cmd.rid, rid);
    assert!(
        *covered >= session.read_floor(),
        "release must cover the session watermark: covered={covered}"
    );
    assert_eq!(procs[1].counters.local_reads, 1);
    assert_eq!(procs[1].counters.slow_reads, 0);
}

#[test]
fn without_the_floor_the_failed_over_read_serves_stale_state() {
    // RYW, negative case — why the floor exists. Identical scenario with
    // the floor omitted: replica 1's bare frontier trivially covers
    // timestamp 0, so the read is served instantly *below* the session's
    // write watermark. This is precisely the stale read the session floor
    // turns into the park above.
    let config = Config::new(3, 1);
    let mut procs: Vec<Tempo> =
        (0..3).map(|i| Tempo::new(ProcessId(i), config.clone())).collect();
    let mut session = Session::new(ClientId(1));
    let _in_flight = procs[0].submit(session.single(7, Op::Put, 9), 0);
    session.note_write(1);

    let served = procs[1].submit_read(session.read_single(7), 0, 0);
    match &served[..] {
        [Action::ExecuteRead { covered, .. }] => assert!(
            *covered < session.read_floor(),
            "expected a stale serve below the watermark, covered={covered}"
        ),
        other => panic!("expected an instant (stale) ExecuteRead, got {other:?}"),
    }
    assert_eq!(procs[1].counters.local_reads, 1);
}

// --- Workload plumbing ----------------------------------------------------

#[test]
fn zipf_read_ratio_is_respected() {
    let mut w = ZipfWorkload::new(1_000, 0.5, 64).with_read_ratio(0.95);
    let mut rng = tempo::util::Rng::new(7);
    let n = 100_000;
    let reads = (0..n)
        .filter(|_| w.next(ClientId(1), &mut rng).op == Op::Read)
        .count();
    let ratio = reads as f64 / n as f64;
    assert!((0.94..0.96).contains(&ratio), "ratio={ratio}");
    // Reads carry no payload; writes keep theirs.
    let mut w = ZipfWorkload::new(10, 0.5, 64).with_read_ratio(1.0);
    let spec = w.next(ClientId(1), &mut rng);
    assert_eq!(spec.op, Op::Read);
    assert_eq!(spec.payload_len, 0);
}
