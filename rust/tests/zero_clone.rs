//! Broadcast fan-out performs zero per-peer `Command` deep copies.
//!
//! `Command`'s only heap storage is its `Arc`-backed key buffer, and the
//! constructors are the only places that allocate one
//! (`core::clone_stats` counts them). A replica fans every command out to
//! its fast quorum (`MPropose`), the remaining group members
//! (`MPayload`) and the whole cluster (`MCommit`) — ≥ 2(r − 1) message
//! copies per command at r = 5. If any of those copies deep-copied the
//! command, key-buffer allocations would scale with peers × commands;
//! the invariant is that they scale with commands alone.
//!
//! This lives in its own integration-test binary (= its own process), so
//! no concurrently running test can touch the process-wide counter.

use tempo::check::assert_psmr;
use tempo::core::{clone_stats, Config};
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::ConflictWorkload;

#[test]
fn command_fanout_allocates_per_command_not_per_peer() {
    let config = Config::new(5, 1);
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 4;
    o.warmup_us = 0;
    o.duration_us = 3_000_000;
    o.drain_us = 3_000_000;
    o.seed = 9;
    o.record_execution = true;

    let before = clone_stats::key_buffer_allocs();
    let result = run::<Tempo, _>(config.clone(), o, ConflictWorkload::new(0.1, 100));
    let allocated = clone_stats::key_buffer_allocs() - before;

    let submitted = result.submitted.len() as u64;
    assert!(submitted > 100, "need real traffic, submitted={submitted}");
    assert_psmr(&config, &result, true);

    // Exactly one key buffer per submitted command (the constructor call
    // in the sim's submit path) plus a tiny slack for test plumbing —
    // nothing per peer. With deep copies this would be ≥ 2(r-1)× larger.
    assert!(
        allocated <= submitted + 8,
        "{allocated} key-buffer allocations for {submitted} commands: \
         the fan-out is deep-copying commands per peer"
    );
    // And the run really did fan out: every command executed at all 5
    // replicas, so peer copies existed and were shared, not re-allocated.
    let per_replica_executions: usize =
        result.execution_logs.iter().map(|l| l.len()).sum();
    assert!(
        per_replica_executions as u64 >= submitted * 5,
        "commands did not replicate ({per_replica_executions} executions \
         for {submitted} submissions)"
    );
}
