//! Adversarial nemesis sweep: seeded link faults, crash + epoch
//! eviction, and client failover, checked against the PSMR oracles.
//!
//! Layers of evidence:
//!
//! 1. **Fault sweep**: four nemesis plans (symmetric partition,
//!    asymmetric isolation, delay + reordering, probabilistic
//!    drop + duplication) run against all six protocol families,
//!    monolithic and behind the 4-worker router. Every run must stay
//!    safe *and live* — once the window closes, retransmission
//!    (`Config::retry_interval_ticks`) and recovery must finish every
//!    submitted request — and end with a bounded memory footprint.
//! 2. **Determinism**: the same composed plan and seed produce
//!    bit-identical `SimResult`s; a different seed does not; a plan
//!    whose windows never activate is bit-identical to no plan at all
//!    (inactive windows draw nothing from the RNG).
//! 3. **Crash + eviction**: a crashed replica is suspected, voted out,
//!    and the survivors install epoch 1 with the victim in the evicted
//!    set — in every family — while clients keep completing requests.
//!    Tempo (§B takeover) and the dep-graph families (ballot-based
//!    prepare + quorum dep reads) are held to the full liveness oracle:
//!    every orphan a survivor can finish must finish.
//! 4. **Eviction unfreezes GC**: with epochs enabled a crash does not
//!    freeze the executed-frontier GC; survivor footprints stay
//!    strictly below the epochs-off run of the same seed.
//! 5. **Negative oracles**: the `epoch_fence_off` and `dedup_window=0`
//!    knobs each produce the violation their oracle exists to catch
//!    (`EpochRegression`, `DuplicateRequest`), and the default
//!    configuration does not.
//! 6. **False suspicion**: a live, merely-presumed-dead replica is
//!    suspected and evicted (`SimOpts::suspicions`); safety must not
//!    depend on the detector being right — epoch fencing walls the
//!    victim off, its clients fail over exactly once, and the oracles
//!    stay clean while it keeps running.

use std::collections::{HashMap, HashSet};
use tempo::check::{check_psmr, Violation};
use tempo::core::{Config, Dot, ProcessId, Rid};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::common::Sharded;
use tempo::protocol::depsmr::{Atlas, EPaxos, Janus};
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, Nemesis, SimOpts, SimResult, Topology};
use tempo::workload::ZipfWorkload;

/// Every fault window in the plans below closes by 1.4 s; liveness
/// assertions demand completions after this point.
const HEAL_BY: u64 = 1_400_000;

fn opts(seed: u64, plan: &Nemesis) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 3;
    o.warmup_us = 0;
    o.duration_us = 3_000_000;
    o.drain_us = 8_000_000; // retries + recovery need room after heal
    o.seed = seed;
    o.record_execution = true;
    o.suspect_delay_us = 300_000;
    o.nemesis = plan.clone();
    o
}

fn config(workers: usize) -> Config {
    let c = Config::new(3, 1)
        .with_recovery_timeout_us(1_000_000)
        .with_retry_interval_ticks(4);
    if workers > 1 {
        c.with_workers(workers)
    } else {
        c
    }
}

fn workload() -> ZipfWorkload {
    ZipfWorkload::new(100, 0.5, 64).with_read_ratio(0.2)
}

/// The four link-fault plans of the sweep. Each window opens after
/// traffic is flowing and closes before `HEAL_BY`.
fn fault_plans() -> Vec<(&'static str, Nemesis)> {
    vec![
        (
            "partition-heal",
            Nemesis::new().partition(300_000, 1_100_000, &[&[0], &[1, 2]]),
        ),
        (
            "asym-isolate",
            Nemesis::new().isolate(300_000, 1_000_000, &[0], &[1, 2]),
        ),
        (
            "delay-reorder",
            Nemesis::new()
                .delay(200_000, 1_200_000, 50_000)
                .reorder(200_000, 1_200_000, 30_000),
        ),
        (
            "flaky-links",
            Nemesis::new()
                .drop_prob(300_000, 1_400_000, 0.05)
                .duplicate(300_000, 1_400_000, 0.10),
        ),
    ]
}

/// PSMR violations that survive the precise crash excuse (the same rule
/// `rust/tests/recovery.rs` enforces): a `NotExecuted` is excused only
/// at a victim, or for a victim-origin request no survivor executed.
fn unexcused_violations(
    config: &Config,
    result: &SimResult,
    victims: &[u32],
) -> Vec<Violation> {
    let violations = check_psmr(config, result, true);
    let executed: Vec<HashSet<Dot>> = result
        .execution_logs
        .iter()
        .map(|log| log.iter().map(|&(d, _)| d).collect())
        .collect();
    let mut rid_dots: HashMap<Rid, Vec<Dot>> = HashMap::new();
    for (dot, cmd) in &result.submitted {
        rid_dots.entry(cmd.rid).or_default().push(*dot);
    }
    let dot_rid: HashMap<Dot, Rid> =
        result.submitted.iter().map(|(d, c)| (*d, c.rid)).collect();
    let survivor_executed_rid = |dot: &Dot| -> bool {
        let Some(dots) = dot_rid.get(dot).and_then(|r| rid_dots.get(r)) else {
            return false;
        };
        dots.iter().any(|d| {
            executed
                .iter()
                .enumerate()
                .any(|(p, ex)| !victims.contains(&(p as u32)) && ex.contains(d))
        })
    };
    violations
        .into_iter()
        .filter(|v| match v {
            Violation::NotExecuted { process, dot } => {
                if victims.contains(&process.0) {
                    return false;
                }
                if victims.contains(&dot.origin.0) {
                    return survivor_executed_rid(dot);
                }
                true
            }
            _ => true,
        })
        .collect()
}

// --- Layer 1: fault sweep -------------------------------------------------

/// One family under one plan: safe, live after heal, bounded footprint.
fn survives_plan<P: Protocol>(seed: u64, workers: usize, plan_name: &str, plan: &Nemesis) {
    let config = config(workers);
    let result = run::<P, _>(config.clone(), opts(seed, plan), workload());
    let label = format!("{} under {plan_name} (workers={workers}, seed={seed})", P::name());
    assert!(result.metrics.ops > 15, "{label}: ops={}", result.metrics.ops);
    let violations = check_psmr(&config, &result, true);
    assert!(
        violations.is_empty(),
        "{label}: {} violation(s): {:#?}",
        violations.len(),
        violations.iter().take(8).collect::<Vec<_>>()
    );
    assert!(
        result.completions.iter().any(|c| c.completed_at >= HEAL_BY),
        "{label}: no completion after the fault window closed"
    );
    for (p, fp) in result.footprints.iter().enumerate() {
        assert!(
            fp.infos < 128,
            "{label}: P{p} footprint not GC-bounded after drain: {fp:?}"
        );
    }
}

fn sweep_plan(plan_idx: usize, workers: usize) {
    let (plan_name, plan) = &fault_plans()[plan_idx];
    let base = 110 + (plan_idx as u64) * 10 + if workers > 1 { 50 } else { 0 };
    if workers > 1 {
        survives_plan::<Sharded<Tempo>>(base, workers, plan_name, plan);
        survives_plan::<Sharded<Atlas>>(base + 1, workers, plan_name, plan);
        survives_plan::<Sharded<EPaxos>>(base + 2, workers, plan_name, plan);
        survives_plan::<Sharded<Janus>>(base + 3, workers, plan_name, plan);
        survives_plan::<Sharded<Caesar>>(base + 4, workers, plan_name, plan);
        survives_plan::<Sharded<FPaxos>>(base + 5, workers, plan_name, plan);
    } else {
        survives_plan::<Tempo>(base, workers, plan_name, plan);
        survives_plan::<Atlas>(base + 1, workers, plan_name, plan);
        survives_plan::<EPaxos>(base + 2, workers, plan_name, plan);
        survives_plan::<Janus>(base + 3, workers, plan_name, plan);
        survives_plan::<Caesar>(base + 4, workers, plan_name, plan);
        survives_plan::<FPaxos>(base + 5, workers, plan_name, plan);
    }
}

#[test]
fn all_families_survive_a_symmetric_partition() {
    sweep_plan(0, 1);
}

#[test]
fn all_families_survive_asymmetric_isolation() {
    sweep_plan(1, 1);
}

#[test]
fn all_families_survive_delay_and_reordering() {
    sweep_plan(2, 1);
}

#[test]
fn all_families_survive_drops_and_duplication() {
    sweep_plan(3, 1);
}

#[test]
fn all_families_survive_a_symmetric_partition_sharded() {
    sweep_plan(0, 4);
}

#[test]
fn all_families_survive_asymmetric_isolation_sharded() {
    sweep_plan(1, 4);
}

#[test]
fn all_families_survive_delay_and_reordering_sharded() {
    sweep_plan(2, 4);
}

#[test]
fn all_families_survive_drops_and_duplication_sharded() {
    sweep_plan(3, 4);
}

// --- Layer 2: determinism -------------------------------------------------

/// Everything observable about a run, as one comparable string. Debug
/// formatting is stable for a fixed binary, which is all bit-identical
/// replay needs.
fn fingerprint(r: &SimResult) -> String {
    format!(
        "{:?}",
        (
            &r.execution_logs,
            &r.completions,
            &r.submitted,
            &r.decided_ts,
            &r.epoch_views,
            &r.footprints,
            &r.metrics.counters,
            r.metrics.ops,
        )
    )
}

fn composed_plan() -> Nemesis {
    Nemesis::new()
        .partition(250_000, 700_000, &[&[0], &[1, 2]])
        .delay(700_000, 900_000, 40_000)
        .reorder(700_000, 1_000_000, 25_000)
        .drop_prob(1_000_000, 1_300_000, 0.08)
        .duplicate(1_000_000, 1_300_000, 0.15)
        .crash(1_500_000, 2)
}

#[test]
fn same_plan_and_seed_replay_bit_identically() {
    let plan = composed_plan();
    let a = run::<Tempo, _>(config(1), opts(140, &plan), workload());
    let b = run::<Tempo, _>(config(1), opts(140, &plan), workload());
    assert_eq!(fingerprint(&a), fingerprint(&b), "same plan+seed diverged");
    let c = run::<Tempo, _>(config(1), opts(141, &plan), workload());
    assert_ne!(
        fingerprint(&a),
        fingerprint(&c),
        "different seeds produced identical runs"
    );
}

#[test]
fn inactive_fault_windows_draw_nothing() {
    // Windows that never open must not perturb the RNG: the run is
    // bit-identical to one with no nemesis at all, even though the
    // non-empty plan takes the full fate-evaluation path per message.
    let dormant = Nemesis::new()
        .drop_prob(50_000_000, 60_000_000, 0.5)
        .reorder(50_000_000, 60_000_000, 10_000);
    let clean = Nemesis::new();
    let a = run::<Tempo, _>(config(1), opts(145, &dormant), workload());
    let b = run::<Tempo, _>(config(1), opts(145, &clean), workload());
    assert_eq!(
        fingerprint(&a),
        fingerprint(&b),
        "a dormant plan perturbed the run"
    );
}

// --- Layer 3: crash + epoch eviction, every family ------------------------

/// Crash P2 (never P0: it is FPaxos's leader and Tempo's initial Ω
/// leader). Survivors must vote the victim into epoch 1, keep the run
/// safe, and keep completing requests. `precise_liveness` additionally
/// applies the recovery-grade excuse filter — and holds for every family
/// with a real per-dot recovery path: Tempo (§B timestamp takeover) and
/// the dep-graph families, whose ballot-based coordinator recovery
/// (`MRecDep` prepare, highest-ballot NAck, quorum dep reads) re-drives
/// a dead coordinator's pending proposals to commit. Caesar and FPaxos
/// keep the safety + progress check.
fn crash_evicts_victim<P: Protocol>(seed: u64, workers: usize, precise_liveness: bool) {
    let plan = Nemesis::new().crash(600_000, 2);
    let config = config(workers);
    let result =
        run::<P, _>(config.clone(), opts(seed, &plan), ZipfWorkload::new(100, 0.5, 64));
    let label = format!("{} crash+evict (workers={workers}, seed={seed})", P::name());
    let violations = if precise_liveness {
        unexcused_violations(&config, &result, &[2])
    } else {
        check_psmr(&config, &result, false)
    };
    assert!(
        violations.is_empty(),
        "{label}: {} violation(s): {:#?}",
        violations.len(),
        violations.iter().take(8).collect::<Vec<_>>()
    );
    assert!(
        result.metrics.counters.evictions >= 1,
        "{label}: no eviction counted: {:?}",
        result.metrics.counters
    );
    for p in [0usize, 1] {
        assert_eq!(
            result.epoch_views[p].last(),
            Some(&(1, vec![ProcessId(2)])),
            "{label}: P{p} did not install epoch 1 evicting P2: {:?}",
            result.epoch_views[p]
        );
    }
    assert_eq!(
        result.epoch_views[2],
        vec![(0, Vec::new())],
        "{label}: the crashed victim moved epochs"
    );
    assert!(
        result.completions.iter().any(|c| c.completed_at > 1_500_000),
        "{label}: no client progress after suspicion + eviction"
    );
}

#[test]
fn crash_leads_to_eviction_in_every_family() {
    crash_evicts_victim::<Tempo>(170, 1, true);
    crash_evicts_victim::<Atlas>(171, 1, true);
    crash_evicts_victim::<EPaxos>(172, 1, true);
    crash_evicts_victim::<Janus>(173, 1, true);
    crash_evicts_victim::<Caesar>(174, 1, false);
    crash_evicts_victim::<FPaxos>(175, 1, false);
    crash_evicts_victim::<Sharded<Tempo>>(176, 4, true);
    crash_evicts_victim::<Sharded<Atlas>>(177, 4, true);
    crash_evicts_victim::<Sharded<EPaxos>>(178, 4, true);
    crash_evicts_victim::<Sharded<Janus>>(179, 4, true);
}

// --- Layer 4: eviction unfreezes GC ---------------------------------------

#[test]
fn eviction_unfreezes_gc_and_bounds_survivor_footprints() {
    // Same seed, same crash; the only difference is whether epochs may
    // remove the dead member from the GC frontier.
    let plan = Nemesis::new().crash(600_000, 2);
    let base = config(1).with_gc_interval_ticks(8);
    let on = run::<Tempo, _>(base.clone(), opts(190, &plan), ZipfWorkload::new(100, 0.5, 64));
    let off = run::<Tempo, _>(
        base.clone().with_epochs(false),
        opts(190, &plan),
        ZipfWorkload::new(100, 0.5, 64),
    );
    assert!(on.metrics.counters.evictions > 0, "{:?}", on.metrics.counters);
    assert_eq!(off.metrics.counters.evictions, 0, "{:?}", off.metrics.counters);
    let on_infos = on.footprints[0].infos + on.footprints[1].infos;
    let off_infos = off.footprints[0].infos + off.footprints[1].infos;
    assert!(
        on_infos < off_infos,
        "eviction did not shrink survivor footprints: epochs-on {on_infos} \
         vs epochs-off {off_infos} ({:?} vs {:?})",
        &on.footprints[..2],
        &off.footprints[..2]
    );
    assert!(
        on.metrics.counters.gc_pruned > off.metrics.counters.gc_pruned,
        "GC did not unfreeze after eviction: pruned {} (epochs on) vs {} (off)",
        on.metrics.counters.gc_pruned,
        off.metrics.counters.gc_pruned
    );
    let filtered = unexcused_violations(&base, &on, &[2]);
    assert!(filtered.is_empty(), "{:#?}", filtered.iter().take(8).collect::<Vec<_>>());
}

// --- Layer 5: negative oracles --------------------------------------------

#[test]
fn fence_off_knob_is_caught_by_the_epoch_oracle() {
    // With fencing disabled, the stale votes still in flight when the
    // survivors install epoch 1 re-land in the history and break
    // monotonicity — exactly what `EpochRegression` watches for.
    let plan = Nemesis::new().crash(600_000, 2);
    let unfenced = config(1).with_epoch_fence_off(true);
    let bad = run::<Tempo, _>(unfenced.clone(), opts(195, &plan), ZipfWorkload::new(100, 0.5, 64));
    let violations = check_psmr(&unfenced, &bad, false);
    assert!(
        violations.iter().any(|v| matches!(v, Violation::EpochRegression { .. })),
        "fence-off run produced no EpochRegression: {:?}",
        violations.iter().take(8).collect::<Vec<_>>()
    );
    // Positive twin: same seed with fencing on is epoch-clean.
    let fenced = config(1);
    let good = run::<Tempo, _>(fenced.clone(), opts(195, &plan), ZipfWorkload::new(100, 0.5, 64));
    let violations = check_psmr(&fenced, &good, false);
    assert!(
        !violations.iter().any(|v| matches!(
            v,
            Violation::EpochRegression { .. } | Violation::EpochDivergence { .. }
        )),
        "fenced run violated the epoch oracle: {violations:?}"
    );
}

#[test]
fn dedup_window_zero_is_caught_and_the_default_is_exactly_once() {
    // A crash orphans in-flight requests; the simulator's clients fail
    // over and re-issue them at a survivor. Without a dedup window the
    // recovered original AND the re-issue both execute — the checker
    // must call that out. With the default window the re-issues are
    // absorbed (counted as dedup_hits) and no duplicate ever executes.
    let plan = Nemesis::new().crash(600_000, 2);
    let mut duplicate_seen = false;
    let mut dedup_hits = 0;
    for seed in [201, 202, 203] {
        let undeduped = config(1).with_dedup_window(0);
        let bad = run::<Tempo, _>(
            undeduped.clone(),
            opts(seed, &plan),
            ZipfWorkload::new(100, 0.5, 64),
        );
        duplicate_seen |= check_psmr(&undeduped, &bad, false)
            .iter()
            .any(|v| matches!(v, Violation::DuplicateRequest { .. }));

        let deduped = config(1);
        let good = run::<Tempo, _>(
            deduped.clone(),
            opts(seed, &plan),
            ZipfWorkload::new(100, 0.5, 64),
        );
        let violations = check_psmr(&deduped, &good, false);
        assert!(
            violations.is_empty(),
            "seed {seed}: default dedup window left violations: {:#?}",
            violations.iter().take(8).collect::<Vec<_>>()
        );
        dedup_hits += good.metrics.counters.dedup_hits;
    }
    assert!(
        duplicate_seen,
        "dedup_window=0 never produced a DuplicateRequest across the seeds"
    );
    assert!(dedup_hits > 0, "failover re-issues never hit the dedup window");
}

// --- Layer 6: false suspicion of a live node ------------------------------

/// The wrong call every timeout-based detector eventually makes: P2 is
/// *not* crashed, merely presumed dead. Every live peer suspects it at
/// once, its clients fail over, and the survivors evict it into epoch 1
/// — while P2 keeps running, keeps its in-flight coordinations going,
/// and may race recovery for its own dots. Safety must not depend on the
/// detector being right: ballots/epoch fencing keep the histories
/// consistent, the re-issues are absorbed exactly once, and the full
/// oracle set stays clean (the excuse filter applies only to the fenced
/// victim's own log, which legitimately stops growing once it is walled
/// off).
fn false_suspicion_stays_safe<P: Protocol>(seed: u64) {
    let mut o = opts(seed, &Nemesis::new());
    o.suspicions = vec![(600_000, ProcessId(2))];
    let config = config(1);
    let result = run::<P, _>(config.clone(), o, ZipfWorkload::new(100, 0.5, 64));
    let label = format!("{} false suspicion (seed={seed})", P::name());
    let violations = unexcused_violations(&config, &result, &[2]);
    assert!(
        violations.is_empty(),
        "{label}: {} violation(s): {:#?}",
        violations.len(),
        violations.iter().take(8).collect::<Vec<_>>()
    );
    assert!(
        result.metrics.counters.evictions >= 1,
        "{label}: no eviction counted: {:?}",
        result.metrics.counters
    );
    for p in [0usize, 1] {
        assert_eq!(
            result.epoch_views[p].last(),
            Some(&(1, vec![ProcessId(2)])),
            "{label}: P{p} did not install epoch 1 evicting P2: {:?}",
            result.epoch_views[p]
        );
    }
    assert!(
        result.completions.iter().any(|c| c.completed_at > 1_500_000),
        "{label}: no client progress after the false suspicion"
    );
}

#[test]
fn false_suspicion_of_a_live_node_is_safe_in_the_recovering_families() {
    false_suspicion_stays_safe::<Tempo>(210);
    false_suspicion_stays_safe::<Atlas>(211);
    false_suspicion_stays_safe::<EPaxos>(212);
    false_suspicion_stays_safe::<Janus>(213);
}
