//! Integration tests: every baseline protocol through the simulator,
//! checked against the PSMR specification.

use tempo::check::assert_psmr;
use tempo::core::Config;
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::{Atlas, EPaxos, Janus};
use tempo::protocol::fpaxos::FPaxos;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::{ConflictWorkload, YcsbWorkload};

fn opts(topology: Topology, seed: u64) -> SimOpts {
    let mut o = SimOpts::new(topology);
    o.clients_per_site = 4;
    o.warmup_us = 0;
    o.duration_us = 3_000_000;
    o.drain_us = 4_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

#[test]
fn atlas_r5_f1_low_conflict() {
    let config = Config::new(5, 1);
    let result =
        run::<Atlas, _>(
            config.clone(),
            opts(Topology::ec2(), 31),
            ConflictWorkload::new(0.02, 100),
        );
    assert!(result.metrics.ops > 50);
    assert_psmr(&config, &result, true);
    // Atlas f=1 always takes the fast path (§6 intro).
    assert_eq!(result.metrics.counters.slow_path, 0);
}

#[test]
fn atlas_r5_f2_high_conflict() {
    let config = Config::new(5, 2);
    let result =
        run::<Atlas, _>(config.clone(), opts(Topology::ec2(), 32), ConflictWorkload::new(1.0, 100));
    assert!(result.metrics.ops > 20, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn epaxos_low_conflict() {
    let config = Config::new(5, 2);
    let result = run::<EPaxos, _>(
        config.clone(),
        opts(Topology::ec2(), 33),
        ConflictWorkload::new(0.02, 100),
    );
    assert!(result.metrics.ops > 50);
    assert_psmr(&config, &result, true);
}

#[test]
fn epaxos_more_slow_paths_than_atlas_under_conflicts() {
    // EPaxos' identical-deps condition fails more often than Atlas'
    // f-supported-union condition (§6 intro).
    let conflict = ConflictWorkload::new(0.5, 100);
    let config = Config::new(5, 1);
    let e = run::<EPaxos, _>(config.clone(), opts(Topology::ec2(), 34), conflict.clone());
    let a = run::<Atlas, _>(config, opts(Topology::ec2(), 34), conflict);
    assert_eq!(a.metrics.counters.slow_path, 0);
    assert!(
        e.metrics.counters.slow_path > 0,
        "EPaxos should take slow paths under 50% conflicts: {:?}",
        e.metrics.counters
    );
}

#[test]
fn caesar_low_conflict() {
    let config = Config::new(5, 2);
    let result = run::<Caesar, _>(
        config.clone(),
        opts(Topology::ec2(), 35),
        ConflictWorkload::new(0.02, 100),
    );
    assert!(result.metrics.ops > 50);
    assert_psmr(&config, &result, true);
}

#[test]
fn caesar_contention_degrades_latency() {
    // Caesar's wait condition blocks replies under contention (§3.3).
    let config = Config::new(5, 2);
    let low = run::<Caesar, _>(
        config.clone(),
        opts(Topology::ec2(), 36),
        ConflictWorkload::new(0.02, 100),
    );
    let high = run::<Caesar, _>(
        config.clone(),
        opts(Topology::ec2(), 36),
        ConflictWorkload::new(0.5, 100),
    );
    assert!(
        high.metrics.latency.mean() > low.metrics.latency.mean(),
        "contention should raise Caesar latency: low={:.0} high={:.0}",
        low.metrics.latency.mean(),
        high.metrics.latency.mean()
    );
}

#[test]
fn fpaxos_all_sites_complete() {
    let config = Config::new(3, 1);
    let result = run::<FPaxos, _>(
        config.clone(),
        opts(Topology::ec2_three(), 37),
        ConflictWorkload::new(0.1, 100),
    );
    assert!(result.metrics.ops > 50);
    assert_psmr(&config, &result, true);
    // All three sites observed completions.
    assert_eq!(result.metrics.site_latency.len(), 3);
}

#[test]
fn janus_partial_replication_two_shards() {
    let config = Config::new(3, 1).with_shards(2);
    let result = run::<Janus, _>(
        config.clone(),
        opts(Topology::ec2_three(), 38),
        YcsbWorkload::new(100_000, 0.5, 0.05),
    );
    assert!(result.metrics.ops > 50, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn janus_update_heavy_zipf() {
    let config = Config::new(3, 1).with_shards(4);
    let result = run::<Janus, _>(
        config.clone(),
        opts(Topology::ec2_three(), 39),
        YcsbWorkload::new(100_000, 0.7, 0.5),
    );
    assert!(result.metrics.ops > 50, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn janus_read_only_never_slow_paths() {
    // Reads don't conflict with reads: YCSB-C is Janus*'s best case (§6.4).
    let config = Config::new(3, 1).with_shards(2);
    let result = run::<Janus, _>(
        config.clone(),
        opts(Topology::ec2_three(), 40),
        YcsbWorkload::new(1_000, 0.7, 0.0),
    );
    assert!(result.metrics.ops > 50);
    assert_eq!(result.metrics.counters.slow_path, 0);
    assert_psmr(&config, &result, true);
}
