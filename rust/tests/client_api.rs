//! Client service API integration tests: sessions and rifl-style request
//! ids flow through every protocol family, replies come back as
//! first-class protocol output, and the PSMR checker's response-validity
//! extension actually bites.

use tempo::check::{assert_psmr, check_psmr, Violation};
use tempo::client::Session;
use tempo::core::{ClientId, Config, Response, Rid};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::{Atlas, EPaxos, Janus};
use tempo::protocol::fpaxos::FPaxos;
use tempo::protocol::tempo::Tempo;
use tempo::protocol::Protocol;
use tempo::sim::{run, SimOpts, SimResult, Topology};
use tempo::workload::ConflictWorkload;

fn opts(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 4;
    o.warmup_us = 0;
    o.duration_us = 2_000_000;
    o.drain_us = 5_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

fn run_family<P: Protocol>(seed: u64) -> (Config, SimResult) {
    let config = Config::new(3, 1);
    let result = run::<P, _>(config.clone(), opts(seed), ConflictWorkload::new(0.2, 64));
    assert!(result.metrics.ops > 40, "{}: ops={}", P::name(), result.metrics.ops);
    (config, result)
}

/// The acceptance bar: response validity (inside `assert_psmr`) passes
/// for all five protocol families.
#[test]
fn all_five_families_serve_valid_responses() {
    let (c, r) = run_family::<Tempo>(61);
    assert_psmr(&c, &r, true);
    let (c, r) = run_family::<Atlas>(62);
    assert_psmr(&c, &r, true);
    let (c, r) = run_family::<EPaxos>(63);
    assert_psmr(&c, &r, true);
    let (c, r) = run_family::<Caesar>(64);
    assert_psmr(&c, &r, true);
    let (c, r) = run_family::<FPaxos>(65);
    assert_psmr(&c, &r, true);
}

#[test]
fn janus_partial_replication_serves_valid_responses() {
    let config = Config::new(3, 1).with_shards(2);
    let result = run::<Janus, _>(
        config.clone(),
        opts(66),
        tempo::workload::YcsbWorkload::new(10_000, 0.5, 0.5),
    );
    assert!(result.metrics.ops > 40, "ops={}", result.metrics.ops);
    assert_psmr(&config, &result, true);
}

#[test]
fn completions_carry_session_rids_and_responses() {
    let (_, result) = run_family::<Tempo>(67);
    assert!(!result.completions.is_empty());
    for c in &result.completions {
        // The rid names the issuing client and the response covers the
        // command's keys.
        assert_eq!(c.rid.client(), ClientId(c.client.0));
        assert!(c.rid.seq() >= 1);
        assert!(!c.response.versions.is_empty(), "empty response for {:?}", c.rid);
    }
    // Per client, observed rids are unique (each request answered once).
    let mut seen = std::collections::HashSet::new();
    for c in &result.completions {
        assert!(seen.insert(c.rid), "request {:?} completed twice", c.rid);
    }
}

#[test]
fn response_validity_catches_a_corrupted_response() {
    // The semantics-aware half of the checker: take a passing run and
    // corrupt one client-observed response — the order checks still pass,
    // ResponseMismatch must fire.
    let (config, mut result) = run_family::<Tempo>(68);
    assert!(check_psmr(&config, &result, true).is_empty());
    let victim = result.completions[0].clone();
    result.completions[0].response =
        Response { versions: vec![(u64::MAX, u64::MAX)] };
    let violations = check_psmr(&config, &result, true);
    assert!(
        violations.iter().any(|v| matches!(
            v,
            Violation::ResponseMismatch { rid, .. } if *rid == victim.rid
        )),
        "corrupted response not caught: {violations:?}"
    );
}

#[test]
fn submit_allocates_dots_internally_and_in_order() {
    // Drive a protocol directly through the new submit(cmd, time) API: the
    // caller supplies no dot; Action::Submitted reports sequential dots
    // minted at the submitting replica.
    use tempo::core::{Op, ProcessId};
    use tempo::protocol::Action;
    let config = Config::new(3, 1);
    let mut p = Tempo::new(ProcessId(2), config);
    let mut session = Session::new(ClientId(9));
    for expect_seq in 1..=3u64 {
        let cmd = session.single(7, Op::Put, 16);
        let rid = cmd.rid;
        let actions = p.submit(cmd, 0);
        let dots: Vec<_> = actions
            .iter()
            .filter_map(|a| match a {
                Action::Submitted { dot } => Some(*dot),
                _ => None,
            })
            .collect();
        assert_eq!(dots.len(), 1, "exactly one Submitted per submit");
        assert_eq!(dots[0].origin, ProcessId(2));
        assert_eq!(dots[0].seq, expect_seq);
        assert_eq!(rid, Rid::new(ClientId(9), expect_seq));
    }
}
