//! §D pathological scenarios: the dependency-chain and blocking behaviours
//! that motivate Tempo, demonstrated on our baseline implementations.

use tempo::core::{ClientId, Command, Config, Op, Rid};
use tempo::protocol::caesar::Caesar;
use tempo::protocol::depsmr::Atlas;
use tempo::protocol::tempo::Tempo;
use tempo::sim::{run, SimOpts, Topology};
use tempo::workload::ConflictWorkload;

fn opts(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2_three());
    o.clients_per_site = 8;
    o.warmup_us = 0;
    o.duration_us = 4_000_000;
    o.drain_us = 4_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

#[test]
fn tempo_tail_beats_atlas_tail_under_contention() {
    // The §3.3/Fig. 6 claim in miniature: under contention, dependency
    // chains inflate Atlas' tail latency while Tempo's stays flat.
    let config = Config::new(3, 1);
    let w = ConflictWorkload::new(0.5, 100);
    let t = run::<Tempo, _>(config.clone(), opts(71), w.clone());
    let a = run::<Atlas, _>(config, opts(71), w);
    let tp = t.metrics.latency.quantile(0.999);
    let ap = a.metrics.latency.quantile(0.999);
    assert!(
        ap > tp,
        "atlas p99.9 ({ap}µs) should exceed tempo p99.9 ({tp}µs) at 50% conflicts"
    );
}

#[test]
fn caesar_blocking_inflates_commit_latency() {
    let config = Config::new(5, 2);
    let w_low = ConflictWorkload::new(0.02, 100);
    let w_high = ConflictWorkload::new(0.8, 100);
    let low = run::<Caesar, _>(config.clone(), opts_5(72), w_low);
    let high = run::<Caesar, _>(config, opts_5(72), w_high);
    assert!(high.metrics.latency.quantile(0.99) > low.metrics.latency.quantile(0.99));
}

fn opts_5(seed: u64) -> SimOpts {
    let mut o = SimOpts::new(Topology::ec2());
    o.clients_per_site = 8;
    o.warmup_us = 0;
    o.duration_us = 4_000_000;
    o.drain_us = 4_000_000;
    o.seed = seed;
    o.record_execution = true;
    o
}

#[test]
fn tempo_throughput_insensitive_to_conflicts() {
    // §6.3: Tempo's performance is independent of the conflict rate.
    let config = Config::new(3, 1);
    let lo = run::<Tempo, _>(config.clone(), opts(73), ConflictWorkload::new(0.0, 100));
    let hi = run::<Tempo, _>(config, opts(73), ConflictWorkload::new(0.1, 100));
    let ratio = hi.metrics.ops as f64 / lo.metrics.ops as f64;
    assert!(
        ratio > 0.8,
        "10% conflicts cost Tempo {:.0}% throughput (lo={} hi={})",
        (1.0 - ratio) * 100.0,
        lo.metrics.ops,
        hi.metrics.ops
    );
}

#[test]
fn multi_key_commands_respect_all_partitions() {
    // Submit explicit two-key commands through the simulator and check the
    // per-key agreement on both keys (Ordering across partitions).
    struct TwoKey(u64);
    impl tempo::workload::Workload for TwoKey {
        fn next(
            &mut self,
            _c: ClientId,
            rng: &mut tempo::util::Rng,
        ) -> tempo::workload::CommandSpec {
            let a = rng.gen_range(self.0);
            let b = (a + 1 + rng.gen_range(self.0 - 1)) % self.0;
            tempo::workload::CommandSpec { keys: vec![a, b], op: Op::Rmw, payload_len: 16 }
        }
    }
    let config = Config::new(3, 1).with_shards(2);
    let result = run::<Tempo, _>(config.clone(), opts(74), TwoKey(40));
    assert!(result.metrics.ops > 20);
    tempo::check::assert_psmr(&config, &result, true);
    let _ = Command::new(Rid::new(ClientId(0), 1), vec![0], Op::Get, 0); // keep import used
}
