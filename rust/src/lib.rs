//! Tempo: efficient replication via timestamp stability (EuroSys'21).
//!
//! A from-scratch reproduction of the Tempo leaderless SMR protocol, its
//! baselines (FPaxos, EPaxos, Atlas, Caesar, Janus*), the paper's
//! evaluation harness (wide-area simulator, workloads, metrics), a real
//! TCP cluster runtime, and a PJRT bridge to the AOT-compiled Pallas
//! stability kernel. See DESIGN.md for the system inventory.

// Message handlers mirror the paper's pseudocode and thread
// (from, dot, fields..., time, out) through as-is; bundling those into
// structs would only obscure the Algorithm 1-6 mapping.
#![allow(clippy::too_many_arguments)]

pub mod bench_util;
pub mod check;
pub mod client;
pub mod core;
pub mod executor;
pub mod metrics;
pub mod protocol;
pub mod net;
pub mod sim;
pub mod store;
pub mod workload;
pub mod runtime;
pub mod util;
