//! Latency histogram with HDR-style logarithmic bucketing.
//!
//! Bucket layout: 64 exponential tiers × 32 linear sub-buckets, covering
//! 1 µs .. ~2^63 µs with <= ~3% relative error — plenty for the paper's
//! p95–p99.99 plots (Fig. 6).

/// Logarithmic-bucket latency histogram (values in microseconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB: usize = 32;
const SUB_BITS: u32 = 5;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 64 * SUB], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    fn index(value: u64) -> usize {
        let v = value.max(1);
        let tier = 63 - v.leading_zeros() as usize; // floor(log2 v)
        if tier < SUB_BITS as usize {
            v as usize
        } else {
            let sub = ((v >> (tier as u32 - SUB_BITS)) - SUB as u64) as usize;
            ((tier - SUB_BITS as usize + 1) << SUB_BITS) + sub
        }
    }

    /// Lower bound of the bucket with the given index (inverse of `index`).
    fn bucket_low(idx: usize) -> u64 {
        if idx < (1 << SUB_BITS) {
            idx as u64
        } else {
            let tier = (idx >> SUB_BITS) - 1 + SUB_BITS as usize;
            let sub = (idx & (SUB - 1)) as u64;
            (SUB as u64 + sub) << (tier as u32 - SUB_BITS)
        }
    }

    pub fn record(&mut self, value_us: u64) {
        self.buckets[Self::index(value_us)] += 1;
        self.count += 1;
        self.sum += value_us as u128;
        self.min = self.min.min(value_us);
        self.max = self.max.max(value_us);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Value at quantile `q` in [0, 1]. Returns the lower bound of the
    /// bucket containing the q-th sample (conservative).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        if rank >= self.count {
            return self.max;
        }
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_low(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// The percentile series used by Fig. 6.
    pub fn tail_summary(&self) -> TailSummary {
        TailSummary {
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            p99_9: self.quantile(0.999),
            p99_99: self.quantile(0.9999),
            mean: self.mean(),
            count: self.count,
        }
    }
}

/// Summary row for tail-latency reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TailSummary {
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub p99_9: u64,
    pub p99_99: u64,
    pub mean: f64,
    pub count: u64,
}

impl std::fmt::Display for TailSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms p99.9={:.1}ms p99.99={:.1}ms (n={})",
            self.mean / 1e3,
            self.p50 as f64 / 1e3,
            self.p95 as f64 / 1e3,
            self.p99 as f64 / 1e3,
            self.p99_9 as f64 / 1e3,
            self.p99_99 as f64 / 1e3,
            self.count
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn index_bucket_roundtrip_monotone() {
        let mut last = 0;
        for v in [1u64, 2, 31, 32, 33, 100, 1000, 12345, 1 << 20, 1 << 40] {
            let i = Histogram::index(v);
            assert!(i >= last, "index must be monotone in value");
            last = i;
            let low = Histogram::bucket_low(i);
            assert!(low <= v, "bucket_low({i})={low} > {v}");
            // Relative error of the bucket lower bound is < 1/32.
            assert!((v - low) as f64 <= v as f64 / 16.0, "v={v} low={low}");
        }
    }

    #[test]
    fn exact_quantiles_on_uniform_ramp() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((4_700..=5_100).contains(&p50), "p50={p50}");
        assert!((9_500..=9_950).contains(&p99), "p99={p99}");
        assert_eq!(h.quantile(1.0), 10_000);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 10_000);
        assert!((h.mean() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut r = Rng::new(11);
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for _ in 0..5_000 {
            let v = r.gen_between(100, 1_000_000);
            if r.gen_bool(0.5) {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [0.5, 0.95, 0.99, 0.999] {
            assert_eq!(a.quantile(q), all.quantile(q));
        }
    }

    #[test]
    fn tail_summary_ordering() {
        let mut h = Histogram::new();
        let mut r = Rng::new(12);
        for _ in 0..100_000 {
            // long-tailed: 1ms typical, occasional 1s
            let v = if r.gen_bool(0.001) { 1_000_000 } else { r.gen_between(500, 2_000) };
            h.record(v);
        }
        let t = h.tail_summary();
        assert!(t.p50 <= t.p95 && t.p95 <= t.p99 && t.p99 <= t.p99_9 && t.p99_9 <= t.p99_99);
        assert!(t.p99_99 >= 900_000, "tail should catch the 1s outliers: {t}");
    }
}
