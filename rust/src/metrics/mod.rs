//! Measurement: latency histograms, throughput accounting, and the
//! dstat-style resource utilization the paper's heatmaps report (Fig. 7).

pub mod histogram;

pub use histogram::{Histogram, TailSummary};

use std::collections::BTreeMap;

/// Throughput + latency measured over a run, per site and aggregate.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Aggregate client-observed latency.
    pub latency: Histogram,
    /// Per-site client latency (Fig. 5).
    pub site_latency: BTreeMap<usize, Histogram>,
    /// Completed operations (batched ops count individually).
    pub ops: u64,
    /// Wall/simulated duration of the measured window, µs.
    pub duration_us: u64,
    /// Resource utilization collected from the simulator, per process.
    pub utilization: Vec<Utilization>,
    /// Protocol counters (fast path, slow path, recoveries...).
    pub counters: Counters,
}

impl RunMetrics {
    pub fn throughput_ops_s(&self) -> f64 {
        if self.duration_us == 0 {
            0.0
        } else {
            self.ops as f64 * 1e6 / self.duration_us as f64
        }
    }

    pub fn record_completion(&mut self, site: usize, latency_us: u64, ops: u32) {
        self.latency.record(latency_us);
        self.site_latency.entry(site).or_default().record(latency_us);
        self.ops += ops as u64;
    }

    /// Mean utilization across processes: (cpu%, net_in%, net_out%).
    pub fn mean_utilization(&self) -> (f64, f64, f64) {
        if self.utilization.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let n = self.utilization.len() as f64;
        let sum = self.utilization.iter().fold((0.0, 0.0, 0.0), |acc, u| {
            (acc.0 + u.cpu, acc.1 + u.net_in, acc.2 + u.net_out)
        });
        (sum.0 / n, sum.1 / n, sum.2 / n)
    }

    /// Peak utilization across processes (the leader in FPaxos).
    pub fn max_utilization(&self) -> (f64, f64, f64) {
        self.utilization.iter().fold((0.0, 0.0, 0.0), |acc: (f64, f64, f64), u| {
            (acc.0.max(u.cpu), acc.1.max(u.net_in), acc.2.max(u.net_out))
        })
    }
}

/// dstat-like utilization of one process over the measured window,
/// each in [0, 100] percent.
#[derive(Clone, Copy, Debug, Default)]
pub struct Utilization {
    pub cpu: f64,
    pub net_in: f64,
    pub net_out: f64,
}

/// Protocol event counters, aggregated across processes.
#[derive(Clone, Copy, Debug, Default)]
pub struct Counters {
    pub fast_path: u64,
    pub slow_path: u64,
    pub recoveries: u64,
    pub messages: u64,
    pub executed: u64,
    /// Per-command `Info` records pruned by the GC exchange.
    pub gc_pruned: u64,
    /// Incremental stability-watermark advances observed by the executor.
    pub wm_advances: u64,
    /// `MBatch` frames flushed by the outgoing message batcher.
    pub batches_sent: u64,
    /// Protocol messages carried inside those `MBatch` frames.
    pub batched_msgs: u64,
    /// Bytes written to peer sockets by the TCP runtime (frame headers
    /// included).
    pub bytes_sent: u64,
    /// Peer frames coalesced away by the per-peer outbound merger: each
    /// merged wire frame carrying `k` routed frames counts `k - 1` here
    /// (0 when every frame went out alone).
    pub frames_merged: u64,
    /// Wire buffers served from the frame pool without allocating
    /// (`net::wire::pool_stats`; process-wide, so node-level counters
    /// report the runtime's pooling behaviour as a whole).
    pub pooled_hits: u64,
    /// Reads served by the coordination-free local path (released by the
    /// stability frontier, zero protocol messages).
    pub local_reads: u64,
    /// Reads degraded to the full ordering path (multi-group key sets,
    /// or a protocol family without a stability frontier).
    pub slow_reads: u64,
    /// Local reads whose release needed the bounded-staleness slack
    /// (`Config::read_slack`): the strict frontier had not reached their
    /// timestamp yet, the slackened one had.
    pub read_slack_served: u64,
    /// Bytes of peer wire traffic caused by the read path (the TCP
    /// runtime attributes the encoded protocol frames a read submission
    /// produced; a local read contributes 0 — the observable
    /// zero-wire-traffic claim).
    pub read_path_bytes: u64,
    /// Members evicted into a new epoch by the reconfiguration vote
    /// (counted once per (process, evicted member) pair).
    pub evictions: u64,
    /// Re-submitted requests absorbed by the executor's per-client dedup
    /// window (exactly-once across client failover).
    pub dedup_hits: u64,
    /// Protocol-level retransmissions sent by the opt-in retry timer
    /// (`config.retry_interval_ticks`): re-proposals to silent quorum
    /// members plus commit re-broadcasts.
    pub retransmits: u64,
    /// WAL records appended by the durability layer (fresh ordered
    /// executions under `StorageMode::Disk`; 0 in Memory mode).
    pub wal_records: u64,
    /// Group-commit fsync calls issued by the WAL.
    pub wal_fsyncs: u64,
    /// Bytes written by the storage backend (WAL + chunks + manifests).
    pub wal_bytes: u64,
    /// Content-addressed snapshots (checkpoints) taken.
    pub snapshots_taken: u64,
    /// Snapshot pages fetched from a donor during restart state transfer
    /// (pages the recovering replica could not produce locally).
    pub chunks_fetched: u64,
    /// Client connections accepted onto the event-loop plane over the
    /// node's lifetime (peer/transfer connections are not counted —
    /// they run on dedicated threads).
    pub client_connections: u64,
    /// Event-loop wakeups: poller returns with at least one ready
    /// client connection or queued reply batch.
    pub client_wakeups: u64,
    /// Client-plane frames written to sessions (replies and busy sheds).
    pub client_replies: u64,
    /// Vectored flushes of per-connection reply queues. Replies ÷
    /// flushes > 1 means the event loop batched replies per wakeup.
    pub client_flushes: u64,
    /// Submits shed at the edge with an explicit `ClientBusy` reply
    /// because the session's in-flight window
    /// (`Config::max_inflight_per_session`) was full.
    pub busy_shed: u64,
    /// Heartbeat frames (docs/WIRE.md tag 26) written to idle peer links
    /// by the TCP runtime's per-peer writers. Transport-plane traffic:
    /// excluded from `bytes_sent`/`wire_frames` so protocol byte
    /// accounting is unchanged by the failure detector.
    pub heartbeats_sent: u64,
    /// Heartbeat frames received from peers and consumed at the
    /// transport layer (they never reach the protocol codec).
    pub heartbeats_seen: u64,
    /// Peers this node's failure detector reported as suspected after
    /// `Config::suspect_delay_us` of silence (sticky — each peer counts
    /// at most once per node lifetime).
    pub suspicions: u64,
}

impl Counters {
    pub fn fast_path_ratio(&self) -> f64 {
        let total = self.fast_path + self.slow_path;
        if total == 0 {
            0.0
        } else {
            self.fast_path as f64 / total as f64
        }
    }

    pub fn merge(&mut self, o: &Counters) {
        self.fast_path += o.fast_path;
        self.slow_path += o.slow_path;
        self.recoveries += o.recoveries;
        self.messages += o.messages;
        self.executed += o.executed;
        self.gc_pruned += o.gc_pruned;
        self.wm_advances += o.wm_advances;
        self.batches_sent += o.batches_sent;
        self.batched_msgs += o.batched_msgs;
        self.bytes_sent += o.bytes_sent;
        self.frames_merged += o.frames_merged;
        self.pooled_hits += o.pooled_hits;
        self.local_reads += o.local_reads;
        self.slow_reads += o.slow_reads;
        self.read_slack_served += o.read_slack_served;
        self.read_path_bytes += o.read_path_bytes;
        self.evictions += o.evictions;
        self.dedup_hits += o.dedup_hits;
        self.retransmits += o.retransmits;
        self.wal_records += o.wal_records;
        self.wal_fsyncs += o.wal_fsyncs;
        self.wal_bytes += o.wal_bytes;
        self.snapshots_taken += o.snapshots_taken;
        self.chunks_fetched += o.chunks_fetched;
        self.client_connections += o.client_connections;
        self.client_wakeups += o.client_wakeups;
        self.client_replies += o.client_replies;
        self.client_flushes += o.client_flushes;
        self.busy_shed += o.busy_shed;
        self.heartbeats_sent += o.heartbeats_sent;
        self.heartbeats_seen += o.heartbeats_seen;
        self.suspicions += o.suspicions;
    }

    /// Mean number of messages per flushed batch (0 when batching never
    /// produced a multi-message frame).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches_sent == 0 {
            0.0
        } else {
            self.batched_msgs as f64 / self.batches_sent as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut m = RunMetrics::default();
        m.duration_us = 2_000_000; // 2 s
        for _ in 0..1000 {
            m.record_completion(0, 1_000, 4);
        }
        assert_eq!(m.ops, 4000);
        assert!((m.throughput_ops_s() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn per_site_latency_separated() {
        let mut m = RunMetrics::default();
        m.record_completion(0, 100_000, 1);
        m.record_completion(1, 300_000, 1);
        assert_eq!(m.site_latency[&0].count(), 1);
        assert_eq!(m.site_latency[&1].count(), 1);
        assert!(m.site_latency[&1].quantile(0.5) > m.site_latency[&0].quantile(0.5));
    }

    #[test]
    fn utilization_aggregates() {
        let mut m = RunMetrics::default();
        m.utilization = vec![
            Utilization { cpu: 90.0, net_in: 10.0, net_out: 20.0 },
            Utilization { cpu: 10.0, net_in: 30.0, net_out: 40.0 },
        ];
        let (cpu, ni, no) = m.mean_utilization();
        assert!((cpu - 50.0).abs() < 1e-9 && (ni - 20.0).abs() < 1e-9 && (no - 30.0).abs() < 1e-9);
        assert_eq!(m.max_utilization().0, 90.0);
    }

    #[test]
    fn fast_path_ratio() {
        let mut c = Counters::default();
        c.fast_path = 9;
        c.slow_path = 1;
        assert!((c.fast_path_ratio() - 0.9).abs() < 1e-9);
        let mut d = Counters::default();
        d.merge(&c);
        assert_eq!(d.fast_path, 9);
    }
}
