//! Dependency-graph executor (EPaxos/Atlas/Janus* execution, §3.3).
//!
//! Committed commands carry explicit dependency sets. A command may execute
//! only when the *transitive closure* of its dependencies is committed; the
//! closure is then partitioned into strongly connected components which
//! execute one at a time (components in dependency order, members of a
//! component in identifier order). Closures — and SCCs — are unbounded
//! under contention (§D), which is exactly the pathology the paper's tail
//! latency experiments expose.

use crate::core::Dot;
use crate::protocol::common::stability::ExecutedSet;
use std::collections::{HashMap, HashSet};

#[derive(Clone, Debug)]
struct Node {
    deps: Vec<Dot>,
}

/// The committed-but-unexecuted dependency graph of one partition/group.
///
/// Executed dots are remembered as per-origin contiguous frontiers
/// ([`ExecutedSet`]) rather than a `HashSet` of every dot ever executed,
/// so the graph's memory is bounded in steady state while dependencies on
/// long-executed (even GC'd) commands still read as satisfied.
#[derive(Clone, Debug, Default)]
pub struct DepGraph {
    nodes: HashMap<Dot, Node>,
    executed: ExecutedSet,
}

impl DepGraph {
    /// Graph for worker slot `worker` of `workers`: the executed-set
    /// frontier folds the slot's interleaved dot stride into a dense index
    /// space, so it stays contiguous (and bounded) under worker sharding.
    /// `DepGraph::default()` is the identity stride.
    pub fn strided(worker: usize, workers: usize) -> Self {
        DepGraph { nodes: HashMap::new(), executed: ExecutedSet::strided(worker, workers) }
    }

    /// Record a committed command with its final dependencies.
    pub fn commit(&mut self, dot: Dot, deps: Vec<Dot>) {
        if self.executed.contains(dot) {
            return;
        }
        self.nodes.entry(dot).or_insert(Node { deps });
    }

    pub fn is_committed(&self, dot: Dot) -> bool {
        self.nodes.contains_key(&dot) || self.executed.contains(dot)
    }

    pub fn is_executed(&self, dot: Dot) -> bool {
        self.executed.contains(dot)
    }

    /// Number of committed-unexecuted nodes (diagnostics).
    pub fn pending(&self) -> usize {
        self.nodes.len()
    }

    /// Mark `dot` as executed and drop its node.
    pub fn mark_executed(&mut self, dot: Dot) {
        self.nodes.remove(&dot);
        self.executed.insert(dot);
    }

    /// If the transitive dependency closure of `root` is fully committed,
    /// return its strongly connected components in execution order
    /// (dependencies first; members of an SCC sorted by identifier).
    /// Returns `None` if some (transitive) dependency is not yet committed.
    pub fn ready_from(&self, root: Dot) -> Option<Vec<Vec<Dot>>> {
        self.ready_or_missing(root).ok()
    }

    /// Like [`Self::ready_from`], but a blocked closure reports *which*
    /// uncommitted dependency blocks it — callers index their retries by it
    /// instead of rescanning every pending command (§Perf iteration 6).
    pub fn ready_or_missing(&self, root: Dot) -> Result<Vec<Vec<Dot>>, Dot> {
        if self.executed.contains(root) {
            return Ok(Vec::new());
        }
        if !self.nodes.contains_key(&root) {
            return Err(root);
        }
        // Iterative DFS to collect the closure, failing on unknown deps.
        let mut closure: HashSet<Dot> = HashSet::new();
        let mut stack = vec![root];
        while let Some(d) = stack.pop() {
            if closure.contains(&d) || self.executed.contains(d) {
                continue;
            }
            match self.nodes.get(&d) {
                None => return Err(d), // uncommitted dep → blocked on it
                Some(node) => {
                    closure.insert(d);
                    for &dep in &node.deps {
                        if !closure.contains(&dep) && !self.executed.contains(dep) {
                            stack.push(dep);
                        }
                    }
                }
            }
        }
        Ok(self.tarjan(&closure))
    }

    /// Iterative Tarjan over `closure` (edges point command → dependency).
    /// SCCs are emitted with dependencies first, which is execution order.
    fn tarjan(&self, closure: &HashSet<Dot>) -> Vec<Vec<Dot>> {
        #[derive(Clone, Copy)]
        struct VState {
            index: u32,
            lowlink: u32,
            on_stack: bool,
        }
        let mut state: HashMap<Dot, VState> = HashMap::with_capacity(closure.len());
        let mut stack: Vec<Dot> = Vec::new();
        let mut next_index = 0u32;
        let mut sccs: Vec<Vec<Dot>> = Vec::new();

        // Explicit DFS frames: (node, next dep index to visit).
        let mut roots: Vec<Dot> = closure.iter().copied().collect();
        roots.sort_unstable(); // determinism across replicas
        for &start in &roots {
            if state.contains_key(&start) {
                continue;
            }
            let mut frames: Vec<(Dot, usize)> = vec![(start, 0)];
            state.insert(
                start,
                VState { index: next_index, lowlink: next_index, on_stack: true },
            );
            next_index += 1;
            stack.push(start);
            while let Some(&mut (v, ref mut di)) = frames.last_mut() {
                let deps = &self.nodes[&v].deps;
                // Find next unvisited in-closure dep.
                let mut advanced = false;
                while *di < deps.len() {
                    let w = deps[*di];
                    *di += 1;
                    if !closure.contains(&w) {
                        continue;
                    }
                    match state.get(&w) {
                        None => {
                            state.insert(
                                w,
                                VState {
                                    index: next_index,
                                    lowlink: next_index,
                                    on_stack: true,
                                },
                            );
                            next_index += 1;
                            stack.push(w);
                            frames.push((w, 0));
                            advanced = true;
                            break;
                        }
                        Some(ws) if ws.on_stack => {
                            let wi = ws.index;
                            let vs = state.get_mut(&v).unwrap();
                            vs.lowlink = vs.lowlink.min(wi);
                        }
                        _ => {}
                    }
                }
                if advanced {
                    continue;
                }
                // v is finished.
                frames.pop();
                let vs = *state.get(&v).unwrap();
                if let Some(&mut (parent, _)) = frames.last_mut() {
                    let ps = state.get_mut(&parent).unwrap();
                    ps.lowlink = ps.lowlink.min(vs.lowlink);
                }
                if vs.lowlink == vs.index {
                    // Pop an SCC.
                    let mut scc = Vec::new();
                    while let Some(w) = stack.pop() {
                        state.get_mut(&w).unwrap().on_stack = false;
                        scc.push(w);
                        if w == v {
                            break;
                        }
                    }
                    scc.sort_unstable(); // execute members in dot order
                    sccs.push(scc);
                }
            }
        }
        sccs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ProcessId;

    fn dot(p: u32, s: u64) -> Dot {
        Dot::new(ProcessId(p), s)
    }

    #[test]
    fn linear_chain_executes_in_dependency_order() {
        let mut g = DepGraph::default();
        let (a, b, c) = (dot(0, 1), dot(1, 1), dot(2, 1));
        g.commit(c, vec![b]);
        assert!(g.ready_from(c).is_none(), "b not committed yet");
        g.commit(b, vec![a]);
        assert!(g.ready_from(c).is_none(), "a not committed yet");
        g.commit(a, vec![]);
        let sccs = g.ready_from(c).unwrap();
        assert_eq!(sccs, vec![vec![a], vec![b], vec![c]]);
    }

    #[test]
    fn cycle_collapses_into_single_scc_in_dot_order() {
        // EPaxos example from Figure 3: w ↔ y ↔ z cycles.
        let mut g = DepGraph::default();
        let (w, y, z) = (dot(0, 1), dot(1, 1), dot(2, 1));
        g.commit(w, vec![y]);
        g.commit(y, vec![z]);
        g.commit(z, vec![w]);
        let sccs = g.ready_from(w).unwrap();
        assert_eq!(sccs, vec![vec![w, y, z]]);
    }

    #[test]
    fn figure3_uncommitted_dependency_blocks_component() {
        // dep[w]={y}, dep[y]={z}, dep[z]={w, x} with x never committed:
        // nothing can execute (the pathology Tempo avoids).
        let mut g = DepGraph::default();
        let (w, x, y, z) = (dot(0, 1), dot(0, 2), dot(1, 1), dot(2, 1));
        g.commit(w, vec![y]);
        g.commit(y, vec![z]);
        g.commit(z, vec![w, x]);
        assert!(g.ready_from(w).is_none());
        assert!(g.ready_from(y).is_none());
        assert!(g.ready_from(z).is_none());
        // Once x commits, the whole component unblocks.
        g.commit(x, vec![]);
        let sccs = g.ready_from(w).unwrap();
        assert_eq!(sccs.last().unwrap(), &vec![w, y, z]);
    }

    #[test]
    fn executed_dependencies_are_satisfied() {
        let mut g = DepGraph::default();
        let (a, b) = (dot(0, 1), dot(0, 2));
        g.commit(a, vec![]);
        g.mark_executed(a);
        g.commit(b, vec![a]);
        let sccs = g.ready_from(b).unwrap();
        assert_eq!(sccs, vec![vec![b]]);
    }

    #[test]
    fn diamond_dependencies() {
        //   d depends on b, c; both depend on a.
        let mut g = DepGraph::default();
        let (a, b, c, d) = (dot(0, 1), dot(1, 1), dot(2, 1), dot(3, 1));
        g.commit(d, vec![b, c]);
        g.commit(b, vec![a]);
        g.commit(c, vec![a]);
        g.commit(a, vec![]);
        let sccs = g.ready_from(d).unwrap();
        // a must be first, d must be last.
        assert_eq!(sccs.first().unwrap(), &vec![a]);
        assert_eq!(sccs.last().unwrap(), &vec![d]);
        assert_eq!(sccs.len(), 4);
    }

    #[test]
    fn long_chain_does_not_overflow_stack() {
        // 50k-deep chain: the iterative Tarjan must handle it.
        let mut g = DepGraph::default();
        let mut prev = None;
        for i in 1..=50_000u64 {
            let d = dot(0, i);
            g.commit(d, prev.into_iter().collect());
            prev = Some(d);
        }
        let sccs = g.ready_from(dot(0, 50_000)).unwrap();
        assert_eq!(sccs.len(), 50_000);
        assert_eq!(sccs[0], vec![dot(0, 1)]);
    }

    #[test]
    fn unbounded_scc_from_appendix_d() {
        // §D: dep[1]={2}, dep[2]={3}, dep[3]={1,4}, dep[4]={1,2,5}, ...
        // committing a prefix never yields an executable component because
        // each SCC depends on the next uncommitted command.
        let mut g = DepGraph::default();
        let d = |i: u64| dot((i % 3) as u32, i);
        g.commit(d(1), vec![d(2)]);
        g.commit(d(2), vec![d(3)]);
        g.commit(d(3), vec![d(1), d(4)]);
        g.commit(d(4), vec![d(1), d(2), d(5)]);
        g.commit(d(5), vec![d(2), d(3), d(6)]);
        for i in 1..=5 {
            assert!(g.ready_from(d(i)).is_none(), "command {i} must stay blocked");
        }
    }
}
