//! Execution engines.
//!
//! [`Executor`] is the replica-side bridge between a protocol's ordering
//! decisions and the replicated [`StateMachine`]: it consumes
//! `Action::Execute` upcalls in the order the protocol emits them,
//! applies each command, and emits `Action::Reply { rid, response }` at
//! the command's coordinator only — so client responses are a
//! first-class protocol output, not test-side reconstruction. Both
//! runtimes (the simulator and the TCP cluster) own one `Executor` per
//! replica and route its replies back to the issuing session.
//!
//! [`DepGraph`] is the dependency-graph execution engine used by the
//! dependency-based baselines (EPaxos, Atlas, Janus*): committed commands
//! execute via strongly-connected components — the mechanism whose
//! unbounded chains cause the tail latencies the paper measures (§3.3,
//! §D). Tempo executes by timestamp stability (inside `protocol::tempo`).

pub mod graph;

pub use graph::DepGraph;

use crate::core::{ClientId, Command, Dot, ProcessId, Response};
use crate::protocol::Action;
use crate::store::{KvStore, StateMachine};
use std::collections::{BTreeMap, HashMap};

/// Per-replica execution engine: applies `Action::Execute` upcalls to a
/// pluggable [`StateMachine`] in order and emits `Action::Reply` for
/// commands this replica coordinates (`dot.origin == id`).
///
/// ## Exactly-once across client failover
///
/// A client that loses its replica re-issues unacked requests at another
/// replica under the *same* [`crate::core::Rid`] — the re-issue gets a
/// fresh dot, so the protocol orders and delivers it a second time. The
/// executor absorbs the duplicate with a per-client dedup window
/// (`Config::dedup_window`, [`Executor::with_dedup_window`]): the second
/// delivery of an in-window rid skips the state machine, its `Execute`
/// action is dropped from the stream, and the cached response is replayed
/// at the duplicate's coordinator so the failed-over client still gets
/// its answer. The skip decision depends only on per-client rid history
/// (never on cross-client interleaving), so all replicas — which each see
/// both deliveries — agree on which copy applied and stay convergent.
/// A window of `n` tolerates up to `n` newer same-client commands between
/// the two deliveries; window 0 disables dedup (the checker's
/// `DuplicateRequest` negative knob).
#[derive(Clone, Debug)]
pub struct Executor<S: StateMachine = KvStore> {
    id: ProcessId,
    sm: S,
    executed: u64,
    reads_served: u64,
    /// Per-client window of recently applied rids → their responses.
    dedup: HashMap<ClientId, BTreeMap<u64, Response>>,
    dedup_window: usize,
    dedup_hits: u64,
}

impl<S: StateMachine> Executor<S> {
    /// Build the executor of replica `id` over state machine `sm` with
    /// the default dedup window.
    pub fn new(id: ProcessId, sm: S) -> Self {
        Executor {
            id,
            sm,
            executed: 0,
            reads_served: 0,
            dedup: HashMap::new(),
            dedup_window: crate::core::Config::DEFAULT_DEDUP_WINDOW,
            dedup_hits: 0,
        }
    }

    /// Override the per-client dedup window (0 disables deduplication —
    /// re-issued requests then apply twice, which `check_psmr` flags as
    /// `DuplicateRequest`).
    pub fn with_dedup_window(mut self, window: usize) -> Self {
        self.dedup_window = window;
        self
    }

    /// Rebuild an executor after crash-restart: the recovered state
    /// machine plus the dedup windows captured by the snapshot and the
    /// responses recomputed during WAL-tail replay — so a client re-issue
    /// of a pre-crash request is absorbed exactly like before the crash.
    pub fn recovered(
        id: ProcessId,
        sm: S,
        window: usize,
        dedup_blob: &[u8],
        replayed: &[(crate::core::Rid, Response)],
    ) -> Self {
        let mut e = Executor::new(id, sm).with_dedup_window(window);
        e.seed_dedup(dedup_blob);
        for (rid, response) in replayed {
            e.remember(*rid, response.clone());
            e.executed += 1;
        }
        e
    }

    /// The wrapped state machine (digest checks, test oracles).
    pub fn state(&self) -> &S {
        &self.sm
    }

    /// Mutable access to the state machine (restart/state-transfer path).
    pub fn state_mut(&mut self) -> &mut S {
        &mut self.sm
    }

    /// Total rids currently held across all per-client dedup windows.
    pub fn dedup_len(&self) -> usize {
        self.dedup.values().map(|w| w.len()).sum()
    }

    /// Serialize the dedup windows for a snapshot (LE): `nclients u32`,
    /// then per client (sorted by id) `client u64, n u16`, then per entry
    /// `seq u64, nversions u16, (key u64, version u64)*`.
    pub fn dedup_blob(&self) -> Vec<u8> {
        let mut clients: Vec<_> = self.dedup.iter().collect();
        clients.sort_by_key(|(c, _)| **c);
        let mut out = Vec::new();
        out.extend_from_slice(&(clients.len() as u32).to_le_bytes());
        for (client, window) in clients {
            out.extend_from_slice(&client.0.to_le_bytes());
            out.extend_from_slice(&(window.len() as u16).to_le_bytes());
            for (seq, response) in window {
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&(response.versions.len() as u16).to_le_bytes());
                for &(k, v) in &response.versions {
                    out.extend_from_slice(&k.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        out
    }

    /// Re-seed the dedup windows from a [`Executor::dedup_blob`] (replaces
    /// current contents; a truncated blob keeps what parsed).
    pub fn seed_dedup(&mut self, blob: &[u8]) {
        self.dedup.clear();
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Option<&[u8]> {
            let s = blob.get(*at..*at + n)?;
            *at += n;
            Some(s)
        };
        let mut parse = || -> Option<()> {
            let nclients =
                u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
            for _ in 0..nclients {
                let client =
                    ClientId(u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap()));
                let n = u16::from_le_bytes(take(&mut at, 2)?.try_into().unwrap());
                for _ in 0..n {
                    let seq =
                        u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
                    let nv = u16::from_le_bytes(take(&mut at, 2)?.try_into().unwrap())
                        as usize;
                    let mut versions = Vec::with_capacity(nv);
                    for _ in 0..nv {
                        let k =
                            u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
                        let v =
                            u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
                        versions.push((k, v));
                    }
                    self.dedup
                        .entry(client)
                        .or_default()
                        .insert(seq, Response { versions });
                }
            }
            Some(())
        };
        let _ = parse();
        if self.dedup_window == 0 {
            self.dedup.clear();
        }
        // A blob recorded under a larger window is trimmed to ours.
        for w in self.dedup.values_mut() {
            while w.len() > self.dedup_window {
                w.pop_first();
            }
        }
    }

    /// Insert one rid → response pair, respecting the window bound.
    fn remember(&mut self, rid: crate::core::Rid, response: Response) {
        if self.dedup_window == 0 {
            return;
        }
        let w = self.dedup.entry(rid.client()).or_default();
        w.insert(rid.seq(), response);
        while w.len() > self.dedup_window {
            w.pop_first();
        }
    }

    /// Commands applied so far. Local reads are counted separately
    /// ([`Executor::reads_served`]): they execute only at their
    /// coordinator, so folding them in here would make replicas'
    /// executed counts diverge on read-heavy workloads.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Local reads served by this replica (`Action::ExecuteRead`).
    pub fn reads_served(&self) -> u64 {
        self.reads_served
    }

    /// Re-submitted requests absorbed by the per-client dedup window.
    pub fn dedup_hits(&self) -> u64 {
        self.dedup_hits
    }

    /// Apply one executed command; returns the reply to route to the
    /// client if this replica is the command's coordinator.
    pub fn apply(&mut self, dot: Dot, cmd: &Command) -> Option<Response> {
        let (response, _fresh) = self.apply_dedup(cmd);
        (dot.origin == self.id).then_some(response)
    }

    /// Apply with duplicate detection: returns the response plus whether
    /// the command was *fresh* (actually applied to the state machine).
    /// A duplicate rid inside the window returns its cached response and
    /// `false` without touching the store.
    fn apply_dedup(&mut self, cmd: &Command) -> (Response, bool) {
        let (client, seq) = (cmd.rid.client(), cmd.rid.seq());
        if self.dedup_window > 0 {
            if let Some(cached) = self.dedup.get(&client).and_then(|w| w.get(&seq)) {
                self.dedup_hits += 1;
                return (cached.clone(), false);
            }
        }
        let response = self.sm.apply(cmd);
        self.executed += 1;
        if self.dedup_window > 0 {
            let w = self.dedup.entry(client).or_default();
            w.insert(seq, response.clone());
            while w.len() > self.dedup_window {
                w.pop_first();
            }
        }
        (response, true)
    }

    /// Run one protocol step's action stream through the executor:
    /// `Execute` actions are applied in order (each immediately followed
    /// by its `Reply` when this replica coordinates the command);
    /// everything else passes through untouched. Runtimes call this on
    /// every action batch a protocol step returns.
    pub fn absorb<M>(&mut self, actions: Vec<Action<M>>) -> Vec<Action<M>> {
        if !actions
            .iter()
            .any(|a| matches!(a, Action::Execute { .. } | Action::ExecuteRead { .. }))
        {
            return actions;
        }
        let mut out = Vec::with_capacity(actions.len() + 1);
        for action in actions {
            match action {
                Action::Execute { dot, cmd, ts } => {
                    let (response, fresh) = self.apply_dedup(&cmd);
                    let rid = cmd.rid;
                    if fresh {
                        // Durability hook: a fresh ordered execution is
                        // WAL-logged (no-op on the in-memory store).
                        self.sm.log_execution(dot, ts, &cmd);
                        out.push(Action::Execute { dot, cmd, ts });
                        if dot.origin == self.id {
                            out.push(Action::Reply { rid, response, ts });
                        }
                    } else if dot.origin == self.id {
                        // Duplicate delivery (client failover re-issue):
                        // the state machine was skipped, but the re-issue's
                        // coordinator still owes the client its answer —
                        // replay the cached response. The duplicate
                        // `Execute` is dropped from the stream so recorded
                        // executions stay exactly-once.
                        out.push(Action::Reply { rid, response, ts });
                    }
                }
                Action::ExecuteRead { cmd, covered, slack } => {
                    // A local read exists only at its coordinator (it was
                    // never broadcast and never acquired a dot), so the
                    // reply is unconditional. Its reply timestamp is the
                    // covered target — a session's read floor never moves
                    // backwards from it.
                    let response = self.sm.apply(&cmd);
                    self.reads_served += 1;
                    let rid = cmd.rid;
                    out.push(Action::ExecuteRead { cmd, covered, slack });
                    out.push(Action::Reply { rid, response, ts: covered });
                }
                other => out.push(other),
            }
        }
        if self.sm.wants_checkpoint() {
            let blob = self.dedup_blob();
            self.sm.checkpoint(&blob);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Op, Rid};

    type TestMsg = ();

    fn cmd(client: u64, seq: u64, key: u64) -> Command {
        Command::single(Rid::new(ClientId(client), seq), key, Op::Put, 8)
    }

    #[test]
    fn replies_only_at_the_coordinator() {
        let origin = ProcessId(1);
        let mut coord = Executor::new(origin, KvStore::new());
        let mut other = Executor::new(ProcessId(2), KvStore::new());
        let c = cmd(7, 1, 5);
        let dot = Dot::new(origin, 1);
        let at_coord =
            coord.absorb::<TestMsg>(vec![Action::Execute { dot, cmd: c.clone(), ts: 1 }]);
        let at_other =
            other.absorb::<TestMsg>(vec![Action::Execute { dot, cmd: c.clone(), ts: 1 }]);
        assert_eq!(at_coord.len(), 2, "coordinator must emit the reply");
        match &at_coord[1] {
            Action::Reply { rid, response, .. } => {
                assert_eq!(*rid, c.rid);
                assert_eq!(response.versions, vec![(5, 1)]);
            }
            other => panic!("expected a reply, got {other:?}"),
        }
        assert_eq!(at_other.len(), 1, "non-coordinator must stay silent");
        // Both replicas applied the command.
        assert_eq!(coord.executed(), 1);
        assert_eq!(other.executed(), 1);
        assert_eq!(coord.state().digest(), other.state().digest());
    }

    #[test]
    fn absorb_preserves_order_and_passthrough() {
        let me = ProcessId(0);
        let mut e = Executor::new(me, KvStore::new());
        let c1 = cmd(1, 1, 9);
        let c2 = cmd(1, 2, 9);
        let actions: Vec<Action<TestMsg>> = vec![
            Action::Committed { dot: Dot::new(me, 1), fast: true },
            Action::Execute { dot: Dot::new(me, 1), cmd: c1.clone(), ts: 1 },
            Action::Execute { dot: Dot::new(me, 2), cmd: c2.clone(), ts: 2 },
        ];
        let out = e.absorb(actions);
        assert_eq!(out.len(), 5);
        assert!(matches!(out[0], Action::Committed { .. }));
        // Execute → its reply, in application order: the second Put on the
        // same key must observe version 2.
        match (&out[2], &out[4]) {
            (Action::Reply { response: r1, .. }, Action::Reply { response: r2, .. }) => {
                assert_eq!(r1.versions, vec![(9, 1)]);
                assert_eq!(r2.versions, vec![(9, 2)]);
            }
            other => panic!("replies misplaced: {other:?}"),
        }
    }

    #[test]
    fn local_reads_always_reply_and_never_mutate() {
        let me = ProcessId(0);
        let mut e = Executor::new(me, KvStore::new());
        e.absorb::<TestMsg>(vec![Action::Execute {
            dot: Dot::new(me, 1),
            cmd: cmd(1, 1, 5),
            ts: 1,
        }]);
        let digest = e.state().digest();
        // The read carries no dot — the reply must come anyway, the
        // store must not change, and `executed` must not move.
        let read = Command::read(Rid::new(ClientId(2), 1), vec![5]);
        let out = e.absorb::<TestMsg>(vec![Action::ExecuteRead {
            cmd: read.clone(),
            covered: 1,
            slack: false,
        }]);
        assert_eq!(out.len(), 2);
        match &out[1] {
            Action::Reply { rid, response, .. } => {
                assert_eq!(*rid, read.rid);
                assert_eq!(response.versions, vec![(5, 1)]);
            }
            other => panic!("expected a reply, got {other:?}"),
        }
        assert_eq!(e.state().digest(), digest);
        assert_eq!(e.executed(), 1);
        assert_eq!(e.reads_served(), 1);
    }

    #[test]
    fn duplicate_rids_are_absorbed_and_replayed() {
        // Client failover: the same rid arrives twice under two dots —
        // first via the crashed coordinator P1, then re-issued at P2.
        let c = cmd(7, 1, 5);
        let first = Dot::new(ProcessId(1), 1);
        let reissue = Dot::new(ProcessId(2), 1);
        let mut e = Executor::new(ProcessId(2), KvStore::new());
        let out1 = e.absorb::<TestMsg>(vec![Action::Execute { dot: first, cmd: c.clone(), ts: 1 }]);
        assert_eq!(out1.len(), 1, "P2 does not coordinate the first copy");
        let digest = e.state().digest();
        let out2 =
            e.absorb::<TestMsg>(vec![Action::Execute { dot: reissue, cmd: c.clone(), ts: 2 }]);
        // The duplicate Execute is dropped; only the replayed Reply remains.
        assert_eq!(out2.len(), 1);
        match &out2[0] {
            Action::Reply { rid, response, .. } => {
                assert_eq!(*rid, c.rid);
                assert_eq!(response.versions, vec![(5, 1)], "cached, not re-applied");
            }
            other => panic!("expected a replayed reply, got {other:?}"),
        }
        assert_eq!(e.state().digest(), digest, "store must not change");
        assert_eq!(e.executed(), 1);
        assert_eq!(e.dedup_hits(), 1);
    }

    #[test]
    fn dedup_window_zero_applies_duplicates() {
        // The negative knob: with the window off, the duplicate applies
        // twice (state divergence the checker's DuplicateRequest oracle
        // exists to catch).
        let c = cmd(7, 1, 5);
        let mut e = Executor::new(ProcessId(1), KvStore::new()).with_dedup_window(0);
        e.absorb::<TestMsg>(vec![Action::Execute { dot: Dot::new(ProcessId(1), 1), cmd: c.clone(), ts: 1 }]);
        let out =
            e.absorb::<TestMsg>(vec![Action::Execute { dot: Dot::new(ProcessId(2), 1), cmd: c.clone(), ts: 2 }]);
        assert_eq!(out.len(), 1, "duplicate Execute passes through");
        assert!(matches!(out[0], Action::Execute { .. }));
        assert_eq!(e.executed(), 2);
        assert_eq!(e.dedup_hits(), 0);
    }

    #[test]
    fn dedup_window_evicts_oldest_entries() {
        let mut e = Executor::new(ProcessId(1), KvStore::new()).with_dedup_window(2);
        for seq in 1..=3u64 {
            e.absorb::<TestMsg>(vec![Action::Execute {
                dot: Dot::new(ProcessId(1), seq),
                cmd: cmd(7, seq, seq),
                ts: seq,
            }]);
        }
        // seq 1 fell out of the window: its duplicate re-applies.
        e.absorb::<TestMsg>(vec![Action::Execute {
            dot: Dot::new(ProcessId(1), 4),
            cmd: cmd(7, 1, 1),
            ts: 4,
        }]);
        assert_eq!(e.executed(), 4);
        assert_eq!(e.dedup_hits(), 0);
        // seq 3 is still inside: absorbed.
        e.absorb::<TestMsg>(vec![Action::Execute {
            dot: Dot::new(ProcessId(2), 1),
            cmd: cmd(7, 3, 3),
            ts: 5,
        }]);
        assert_eq!(e.executed(), 4);
        assert_eq!(e.dedup_hits(), 1);
    }

    #[test]
    fn replies_carry_the_decided_timestamp() {
        let me = ProcessId(0);
        let mut e = Executor::new(me, KvStore::new());
        let out = e.absorb::<TestMsg>(vec![Action::Execute {
            dot: Dot::new(me, 1),
            cmd: cmd(1, 1, 5),
            ts: 42,
        }]);
        match &out[1] {
            Action::Reply { ts, .. } => assert_eq!(*ts, 42),
            other => panic!("expected a reply, got {other:?}"),
        }
        // A local read's reply carries its covered target.
        let read = Command::read(Rid::new(ClientId(2), 1), vec![5]);
        let out = e.absorb::<TestMsg>(vec![Action::ExecuteRead {
            cmd: read,
            covered: 42,
            slack: false,
        }]);
        match &out[1] {
            Action::Reply { ts, .. } => assert_eq!(*ts, 42),
            other => panic!("expected a reply, got {other:?}"),
        }
    }

    #[test]
    fn dedup_blob_roundtrips_and_seeds_a_recovered_executor() {
        let mut e = Executor::new(ProcessId(1), KvStore::new()).with_dedup_window(4);
        for client in [3u64, 1, 2] {
            for seq in 1..=3u64 {
                e.absorb::<TestMsg>(vec![Action::Execute {
                    dot: Dot::new(ProcessId(1), client * 10 + seq),
                    cmd: cmd(client, seq, client * 100 + seq),
                    ts: seq,
                }]);
            }
        }
        let blob = e.dedup_blob();
        assert_eq!(e.dedup_len(), 9);
        // Determinism: re-serializing an executor seeded from the blob
        // yields the same bytes (clients are sorted).
        let mut r = Executor::new(ProcessId(1), KvStore::new()).with_dedup_window(4);
        r.seed_dedup(&blob);
        assert_eq!(r.dedup_blob(), blob);
        assert_eq!(r.dedup_len(), 9);
        // A re-issue of a seeded rid is absorbed with the cached response.
        let out = r.absorb::<TestMsg>(vec![Action::Execute {
            dot: Dot::new(ProcessId(1), 99),
            cmd: cmd(3, 2, 302),
            ts: 9,
        }]);
        assert_eq!(out.len(), 1);
        assert!(matches!(&out[0], Action::Reply { .. }));
        assert_eq!(r.dedup_hits(), 1);
        assert_eq!(r.executed(), 0, "the duplicate never touched the store");
        // A truncated blob keeps what parsed instead of panicking.
        let mut t = Executor::new(ProcessId(1), KvStore::new()).with_dedup_window(4);
        t.seed_dedup(&blob[..blob.len() / 2]);
        assert!(t.dedup_len() < 9);
    }

    #[test]
    fn recovered_executor_absorbs_replayed_rids() {
        // Snapshot-era rids come from the blob, tail rids from the replay
        // list; both must be absorbed after restart.
        let mut pre = Executor::new(ProcessId(1), KvStore::new()).with_dedup_window(8);
        pre.absorb::<TestMsg>(vec![Action::Execute {
            dot: Dot::new(ProcessId(1), 1),
            cmd: cmd(7, 1, 5),
            ts: 1,
        }]);
        let blob = pre.dedup_blob();
        let tail = vec![(Rid::new(ClientId(7), 2), Response { versions: vec![(6, 1)] })];
        let mut r =
            Executor::recovered(ProcessId(1), KvStore::new(), 8, &blob, &tail);
        assert_eq!(r.dedup_len(), 2);
        assert_eq!(r.executed(), 1, "replayed tail counts as executed");
        for (seq, key) in [(1u64, 5u64), (2, 6)] {
            let out = r.absorb::<TestMsg>(vec![Action::Execute {
                dot: Dot::new(ProcessId(1), 90 + seq),
                cmd: cmd(7, seq, key),
                ts: 9,
            }]);
            assert_eq!(out.len(), 1, "seq {seq} must be absorbed");
            assert!(matches!(&out[0], Action::Reply { .. }));
        }
        assert_eq!(r.dedup_hits(), 2);
    }

    #[test]
    fn absorb_without_executes_is_identity() {
        let mut e = Executor::new(ProcessId(0), KvStore::new());
        let actions: Vec<Action<TestMsg>> =
            vec![Action::Submitted { dot: Dot::new(ProcessId(0), 1) }];
        let out = e.absorb(actions);
        assert_eq!(out.len(), 1);
        assert_eq!(e.executed(), 0);
    }
}
