//! Execution engines.
//!
//! [`Executor`] is the replica-side bridge between a protocol's ordering
//! decisions and the replicated [`StateMachine`]: it consumes
//! `Action::Execute` upcalls in the order the protocol emits them,
//! applies each command, and emits `Action::Reply { rid, response }` at
//! the command's coordinator only — so client responses are a
//! first-class protocol output, not test-side reconstruction. Both
//! runtimes (the simulator and the TCP cluster) own one `Executor` per
//! replica and route its replies back to the issuing session.
//!
//! [`DepGraph`] is the dependency-graph execution engine used by the
//! dependency-based baselines (EPaxos, Atlas, Janus*): committed commands
//! execute via strongly-connected components — the mechanism whose
//! unbounded chains cause the tail latencies the paper measures (§3.3,
//! §D). Tempo executes by timestamp stability (inside `protocol::tempo`).

pub mod graph;

pub use graph::DepGraph;

use crate::core::{Command, Dot, ProcessId, Response};
use crate::protocol::Action;
use crate::store::{KvStore, StateMachine};

/// Per-replica execution engine: applies `Action::Execute` upcalls to a
/// pluggable [`StateMachine`] in order and emits `Action::Reply` for
/// commands this replica coordinates (`dot.origin == id`).
#[derive(Clone, Debug)]
pub struct Executor<S: StateMachine = KvStore> {
    id: ProcessId,
    sm: S,
    executed: u64,
    reads_served: u64,
}

impl<S: StateMachine> Executor<S> {
    /// Build the executor of replica `id` over state machine `sm`.
    pub fn new(id: ProcessId, sm: S) -> Self {
        Executor { id, sm, executed: 0, reads_served: 0 }
    }

    /// The wrapped state machine (digest checks, test oracles).
    pub fn state(&self) -> &S {
        &self.sm
    }

    /// Commands applied so far. Local reads are counted separately
    /// ([`Executor::reads_served`]): they execute only at their
    /// coordinator, so folding them in here would make replicas'
    /// executed counts diverge on read-heavy workloads.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Local reads served by this replica (`Action::ExecuteRead`).
    pub fn reads_served(&self) -> u64 {
        self.reads_served
    }

    /// Apply one executed command; returns the reply to route to the
    /// client if this replica is the command's coordinator.
    pub fn apply(&mut self, dot: Dot, cmd: &Command) -> Option<Response> {
        let response = self.sm.apply(cmd);
        self.executed += 1;
        (dot.origin == self.id).then_some(response)
    }

    /// Run one protocol step's action stream through the executor:
    /// `Execute` actions are applied in order (each immediately followed
    /// by its `Reply` when this replica coordinates the command);
    /// everything else passes through untouched. Runtimes call this on
    /// every action batch a protocol step returns.
    pub fn absorb<M>(&mut self, actions: Vec<Action<M>>) -> Vec<Action<M>> {
        if !actions
            .iter()
            .any(|a| matches!(a, Action::Execute { .. } | Action::ExecuteRead { .. }))
        {
            return actions;
        }
        let mut out = Vec::with_capacity(actions.len() + 1);
        for action in actions {
            match action {
                Action::Execute { dot, cmd, ts } => {
                    let reply = self.apply(dot, &cmd);
                    let rid = cmd.rid;
                    out.push(Action::Execute { dot, cmd, ts });
                    if let Some(response) = reply {
                        out.push(Action::Reply { rid, response });
                    }
                }
                Action::ExecuteRead { cmd, covered, slack } => {
                    // A local read exists only at its coordinator (it was
                    // never broadcast and never acquired a dot), so the
                    // reply is unconditional.
                    let response = self.sm.apply(&cmd);
                    self.reads_served += 1;
                    let rid = cmd.rid;
                    out.push(Action::ExecuteRead { cmd, covered, slack });
                    out.push(Action::Reply { rid, response });
                }
                other => out.push(other),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ClientId, Op, Rid};

    type TestMsg = ();

    fn cmd(client: u64, seq: u64, key: u64) -> Command {
        Command::single(Rid::new(ClientId(client), seq), key, Op::Put, 8)
    }

    #[test]
    fn replies_only_at_the_coordinator() {
        let origin = ProcessId(1);
        let mut coord = Executor::new(origin, KvStore::new());
        let mut other = Executor::new(ProcessId(2), KvStore::new());
        let c = cmd(7, 1, 5);
        let dot = Dot::new(origin, 1);
        let at_coord =
            coord.absorb::<TestMsg>(vec![Action::Execute { dot, cmd: c.clone(), ts: 1 }]);
        let at_other =
            other.absorb::<TestMsg>(vec![Action::Execute { dot, cmd: c.clone(), ts: 1 }]);
        assert_eq!(at_coord.len(), 2, "coordinator must emit the reply");
        match &at_coord[1] {
            Action::Reply { rid, response } => {
                assert_eq!(*rid, c.rid);
                assert_eq!(response.versions, vec![(5, 1)]);
            }
            other => panic!("expected a reply, got {other:?}"),
        }
        assert_eq!(at_other.len(), 1, "non-coordinator must stay silent");
        // Both replicas applied the command.
        assert_eq!(coord.executed(), 1);
        assert_eq!(other.executed(), 1);
        assert_eq!(coord.state().digest(), other.state().digest());
    }

    #[test]
    fn absorb_preserves_order_and_passthrough() {
        let me = ProcessId(0);
        let mut e = Executor::new(me, KvStore::new());
        let c1 = cmd(1, 1, 9);
        let c2 = cmd(1, 2, 9);
        let actions: Vec<Action<TestMsg>> = vec![
            Action::Committed { dot: Dot::new(me, 1), fast: true },
            Action::Execute { dot: Dot::new(me, 1), cmd: c1.clone(), ts: 1 },
            Action::Execute { dot: Dot::new(me, 2), cmd: c2.clone(), ts: 2 },
        ];
        let out = e.absorb(actions);
        assert_eq!(out.len(), 5);
        assert!(matches!(out[0], Action::Committed { .. }));
        // Execute → its reply, in application order: the second Put on the
        // same key must observe version 2.
        match (&out[2], &out[4]) {
            (Action::Reply { response: r1, .. }, Action::Reply { response: r2, .. }) => {
                assert_eq!(r1.versions, vec![(9, 1)]);
                assert_eq!(r2.versions, vec![(9, 2)]);
            }
            other => panic!("replies misplaced: {other:?}"),
        }
    }

    #[test]
    fn local_reads_always_reply_and_never_mutate() {
        let me = ProcessId(0);
        let mut e = Executor::new(me, KvStore::new());
        e.absorb::<TestMsg>(vec![Action::Execute {
            dot: Dot::new(me, 1),
            cmd: cmd(1, 1, 5),
            ts: 1,
        }]);
        let digest = e.state().digest();
        // The read carries no dot — the reply must come anyway, the
        // store must not change, and `executed` must not move.
        let read = Command::read(Rid::new(ClientId(2), 1), vec![5]);
        let out = e.absorb::<TestMsg>(vec![Action::ExecuteRead {
            cmd: read.clone(),
            covered: 1,
            slack: false,
        }]);
        assert_eq!(out.len(), 2);
        match &out[1] {
            Action::Reply { rid, response } => {
                assert_eq!(*rid, read.rid);
                assert_eq!(response.versions, vec![(5, 1)]);
            }
            other => panic!("expected a reply, got {other:?}"),
        }
        assert_eq!(e.state().digest(), digest);
        assert_eq!(e.executed(), 1);
        assert_eq!(e.reads_served(), 1);
    }

    #[test]
    fn absorb_without_executes_is_identity() {
        let mut e = Executor::new(ProcessId(0), KvStore::new());
        let actions: Vec<Action<TestMsg>> =
            vec![Action::Submitted { dot: Dot::new(ProcessId(0), 1) }];
        let out = e.absorb(actions);
        assert_eq!(out.len(), 1);
        assert_eq!(e.executed(), 0);
    }
}
