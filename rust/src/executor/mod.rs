//! Execution engines.
//!
//! Tempo executes by timestamp stability (implemented inside
//! `protocol::tempo`); the dependency-based baselines (EPaxos, Atlas,
//! Janus*) execute committed dependency graphs via strongly-connected
//! components — the mechanism whose unbounded chains cause the tail
//! latencies the paper measures (§3.3, §D).

pub mod graph;

pub use graph::DepGraph;
