//! Deterministic link-fault injection ("nemesis") plans for the
//! simulator.
//!
//! A [`Nemesis`] composes *fault windows* over the run's timeline —
//! partitions (symmetric and asymmetric), delay spikes, reordering,
//! duplication, probabilistic drop — plus a crash schedule, e.g.
//! "partition {0,1}|{2,3,4} from t=1s to t=2.5s, heal, then crash P2".
//! The simulator consults the plan once per message *send*
//! ([`Nemesis::fate`]); every probabilistic decision draws from the
//! simulation's seeded [`Rng`], so a run under a fault plan is exactly as
//! reproducible as a fault-free one: same plan + same seed ⇒ bit-identical
//! schedule (`rust/tests/nemesis.rs` pins this).
//!
//! **Determinism discipline.** `fate` consumes random draws *only* for
//! probabilistic windows (reorder/duplicate/drop) that are active at the
//! send instant and do not sit behind a partition block. When no window
//! is active — in particular, for every run without a nemesis — it
//! returns without touching the RNG at all, so adding this layer cannot
//! perturb the draw sequence of existing seeded runs (the batching and
//! worker-sharding equivalence proofs depend on that).

use crate::core::ProcessId;
use crate::util::Rng;

/// One fault, active on the half-open interval `[from_us, until_us)`.
#[derive(Clone, Debug)]
pub struct FaultWindow {
    /// Window start (inclusive), in simulated µs.
    pub from_us: u64,
    /// Window end (exclusive) — the fault *heals* at this instant.
    pub until_us: u64,
    /// What the fault does to links while active.
    pub kind: FaultKind,
}

impl FaultWindow {
    fn active(&self, now: u64) -> bool {
        self.from_us <= now && now < self.until_us
    }
}

/// The injectable link faults.
#[derive(Clone, Debug)]
pub enum FaultKind {
    /// Symmetric partition: processes in different groups cannot reach
    /// each other in either direction. A process named in no group
    /// communicates freely (it is on "both sides" — useful for modelling
    /// a partial partition).
    Partition { groups: Vec<Vec<ProcessId>> },
    /// Asymmetric partition: messages from any process in `from` to any
    /// process in `to` are dropped; the reverse direction is untouched.
    Isolate { from: Vec<ProcessId>, to: Vec<ProcessId> },
    /// Delay spike: every delivery gains `extra_us` of latency.
    Delay { extra_us: u64 },
    /// Reordering: every delivery gains an *independent uniform* extra
    /// latency in `[0, spread_us)`, scrambling arrival order across the
    /// spread (consumes one RNG draw per affected send).
    Reorder { spread_us: u64 },
    /// Duplicate each message with probability `prob` (the copy arrives
    /// at the same instant as the original but as a distinct delivery;
    /// consumes one RNG draw per affected send).
    Duplicate { prob: f64 },
    /// Drop each message with probability `prob` (consumes one RNG draw
    /// per affected send).
    Drop { prob: f64 },
}

/// What the nemesis decided for one message send.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkFate {
    /// Deliver, with `extra_us` added to the link latency; `duplicate`
    /// schedules a second, independent delivery of the same message.
    Deliver { extra_us: u64, duplicate: bool },
    /// The link eats the message.
    Drop,
}

impl LinkFate {
    /// The fate of a send no fault touches.
    pub const CLEAN: LinkFate = LinkFate::Deliver { extra_us: 0, duplicate: false };
}

/// A composed fault plan: link-fault windows plus a crash schedule
/// (merged with `SimOpts::crashes` by the simulator).
#[derive(Clone, Debug, Default)]
pub struct Nemesis {
    /// Link-fault windows, evaluated in order (see [`Nemesis::fate`]).
    pub windows: Vec<FaultWindow>,
    /// Crash schedule: (time, process), same semantics as
    /// `SimOpts::crashes`.
    pub crashes: Vec<(u64, ProcessId)>,
    /// Restart schedule: (time, process). A restarted process recovers
    /// from its storage backend (snapshot + WAL tail under
    /// `StorageMode::Disk`, nothing under `Memory`), state-transfers the
    /// diff from a live shard peer, and rejoins — the crash-*recovery*
    /// fault model (see `store::storage`).
    pub restarts: Vec<(u64, ProcessId)>,
}

fn pids(raw: &[u32]) -> Vec<ProcessId> {
    raw.iter().copied().map(ProcessId).collect()
}

impl Nemesis {
    /// An empty plan (injects nothing).
    pub fn new() -> Self {
        Nemesis::default()
    }

    /// Add a symmetric partition window; `groups` lists the process ids
    /// of each side, e.g. `&[&[0, 1], &[2, 3, 4]]`.
    pub fn partition(mut self, from_us: u64, until_us: u64, groups: &[&[u32]]) -> Self {
        let groups = groups.iter().map(|g| pids(g)).collect();
        self.windows.push(FaultWindow {
            from_us,
            until_us,
            kind: FaultKind::Partition { groups },
        });
        self
    }

    /// Add an asymmetric partition window: `from` → `to` messages drop,
    /// the reverse direction still flows.
    pub fn isolate(mut self, from_us: u64, until_us: u64, from: &[u32], to: &[u32]) -> Self {
        self.windows.push(FaultWindow {
            from_us,
            until_us,
            kind: FaultKind::Isolate { from: pids(from), to: pids(to) },
        });
        self
    }

    /// Add a delay-spike window: all links gain `extra_us`.
    pub fn delay(mut self, from_us: u64, until_us: u64, extra_us: u64) -> Self {
        self.windows
            .push(FaultWindow { from_us, until_us, kind: FaultKind::Delay { extra_us } });
        self
    }

    /// Add a reordering window: deliveries gain uniform extra latency in
    /// `[0, spread_us)`.
    pub fn reorder(mut self, from_us: u64, until_us: u64, spread_us: u64) -> Self {
        self.windows
            .push(FaultWindow { from_us, until_us, kind: FaultKind::Reorder { spread_us } });
        self
    }

    /// Add a duplication window: each message is duplicated with
    /// probability `prob`.
    pub fn duplicate(mut self, from_us: u64, until_us: u64, prob: f64) -> Self {
        self.windows
            .push(FaultWindow { from_us, until_us, kind: FaultKind::Duplicate { prob } });
        self
    }

    /// Add a probabilistic-drop window: each message is dropped with
    /// probability `prob`.
    pub fn drop_prob(mut self, from_us: u64, until_us: u64, prob: f64) -> Self {
        self.windows
            .push(FaultWindow { from_us, until_us, kind: FaultKind::Drop { prob } });
        self
    }

    /// Crash `p` at `at_us` (composes with the link windows; the
    /// simulator merges these with `SimOpts::crashes`).
    pub fn crash(mut self, at_us: u64, p: u32) -> Self {
        self.crashes.push((at_us, ProcessId(p)));
        self
    }

    /// Restart `p` at `at_us`: recover from its storage backend and
    /// rejoin via state transfer (no-op if `p` is alive at that instant).
    pub fn restart(mut self, at_us: u64, p: u32) -> Self {
        self.restarts.push((at_us, ProcessId(p)));
        self
    }

    /// True when the plan injects nothing at all (the simulator's cheap
    /// fast-path guard).
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Decide the fate of a message sent `from → to` at `now`.
    ///
    /// Evaluation order is fixed (so the draw sequence is a pure function
    /// of the plan, the send, and the RNG state): first the draw-free
    /// blocking windows (partition / isolate) — a blocked link returns
    /// [`LinkFate::Drop`] without consuming randomness; then delay and
    /// reorder extras accumulate; then drop windows (a hit returns
    /// immediately, skipping later draws); then duplication.
    pub fn fate(&self, now: u64, from: ProcessId, to: ProcessId, rng: &mut Rng) -> LinkFate {
        if from == to {
            return LinkFate::CLEAN; // self-delivery is never faulted
        }
        // Pass 1: blocking windows, no randomness.
        for w in &self.windows {
            if !w.active(now) {
                continue;
            }
            match &w.kind {
                FaultKind::Partition { groups } => {
                    let side = |p: ProcessId| groups.iter().position(|g| g.contains(&p));
                    if let (Some(a), Some(b)) = (side(from), side(to)) {
                        if a != b {
                            return LinkFate::Drop;
                        }
                    }
                }
                FaultKind::Isolate { from: f, to: t } => {
                    if f.contains(&from) && t.contains(&to) {
                        return LinkFate::Drop;
                    }
                }
                _ => {}
            }
        }
        // Pass 2: latency shaping and probabilistic faults, in window
        // order within each class.
        let mut extra_us = 0u64;
        for w in &self.windows {
            if !w.active(now) {
                continue;
            }
            match &w.kind {
                FaultKind::Delay { extra_us: e } => extra_us += e,
                FaultKind::Reorder { spread_us } => {
                    extra_us += (rng.gen_f64() * *spread_us as f64) as u64;
                }
                _ => {}
            }
        }
        for w in &self.windows {
            if w.active(now) {
                if let FaultKind::Drop { prob } = &w.kind {
                    if rng.gen_f64() < *prob {
                        return LinkFate::Drop;
                    }
                }
            }
        }
        let mut duplicate = false;
        for w in &self.windows {
            if w.active(now) {
                if let FaultKind::Duplicate { prob } = &w.kind {
                    if rng.gen_f64() < *prob {
                        duplicate = true;
                    }
                }
            }
        }
        LinkFate::Deliver { extra_us, duplicate }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(i: u32) -> ProcessId {
        ProcessId(i)
    }

    #[test]
    fn partition_blocks_both_directions_and_heals() {
        let n = Nemesis::new().partition(1_000, 2_000, &[&[0, 1], &[2, 3, 4]]);
        let mut rng = Rng::new(7);
        // Across the cut, both directions, while active.
        assert_eq!(n.fate(1_000, p(0), p(2), &mut rng), LinkFate::Drop);
        assert_eq!(n.fate(1_500, p(4), p(1), &mut rng), LinkFate::Drop);
        // Same side flows.
        assert_eq!(n.fate(1_500, p(0), p(1), &mut rng), LinkFate::CLEAN);
        assert_eq!(n.fate(1_500, p(2), p(4), &mut rng), LinkFate::CLEAN);
        // Before the window and at/after the heal instant: clean.
        assert_eq!(n.fate(999, p(0), p(2), &mut rng), LinkFate::CLEAN);
        assert_eq!(n.fate(2_000, p(0), p(2), &mut rng), LinkFate::CLEAN);
    }

    #[test]
    fn isolate_blocks_one_direction_only() {
        let n = Nemesis::new().isolate(0, 100, &[0], &[1, 2]);
        let mut rng = Rng::new(7);
        assert_eq!(n.fate(50, p(0), p(1), &mut rng), LinkFate::Drop);
        assert_eq!(n.fate(50, p(0), p(2), &mut rng), LinkFate::Drop);
        assert_eq!(n.fate(50, p(1), p(0), &mut rng), LinkFate::CLEAN);
        assert_eq!(n.fate(50, p(2), p(0), &mut rng), LinkFate::CLEAN);
        assert_eq!(n.fate(50, p(1), p(2), &mut rng), LinkFate::CLEAN);
    }

    #[test]
    fn delay_windows_accumulate_without_randomness() {
        let n = Nemesis::new().delay(0, 100, 250).delay(50, 100, 100);
        let mut rng = Rng::new(7);
        assert_eq!(
            n.fate(10, p(0), p(1), &mut rng),
            LinkFate::Deliver { extra_us: 250, duplicate: false }
        );
        assert_eq!(
            n.fate(60, p(0), p(1), &mut rng),
            LinkFate::Deliver { extra_us: 350, duplicate: false }
        );
        // No draw was consumed: a fresh RNG from the same seed agrees on
        // the next value.
        let mut fresh = Rng::new(7);
        assert_eq!(rng.gen_f64(), fresh.gen_f64());
    }

    #[test]
    fn inactive_plan_consumes_no_randomness() {
        let n = Nemesis::new()
            .drop_prob(1_000, 2_000, 0.5)
            .duplicate(1_000, 2_000, 0.5)
            .reorder(1_000, 2_000, 10_000);
        let mut rng = Rng::new(42);
        // Outside every window: clean, draw-free.
        assert_eq!(n.fate(500, p(0), p(1), &mut rng), LinkFate::CLEAN);
        assert_eq!(n.fate(2_000, p(0), p(1), &mut rng), LinkFate::CLEAN);
        let mut fresh = Rng::new(42);
        assert_eq!(rng.gen_f64(), fresh.gen_f64());
    }

    #[test]
    fn blocked_links_skip_probabilistic_draws() {
        // A partitioned pair returns Drop before any probabilistic window
        // is consulted, so the draw sequence is independent of them.
        let n = Nemesis::new()
            .partition(0, 100, &[&[0], &[1]])
            .drop_prob(0, 100, 0.5)
            .duplicate(0, 100, 0.5);
        let mut rng = Rng::new(9);
        assert_eq!(n.fate(10, p(0), p(1), &mut rng), LinkFate::Drop);
        let mut fresh = Rng::new(9);
        assert_eq!(rng.gen_f64(), fresh.gen_f64());
    }

    #[test]
    fn drop_and_duplicate_follow_the_seeded_rng() {
        let n = Nemesis::new().drop_prob(0, 100, 1.0);
        let mut rng = Rng::new(3);
        assert_eq!(n.fate(10, p(0), p(1), &mut rng), LinkFate::Drop);
        let n = Nemesis::new().duplicate(0, 100, 1.0);
        assert_eq!(
            n.fate(10, p(0), p(1), &mut rng),
            LinkFate::Deliver { extra_us: 0, duplicate: true }
        );
        // prob 0.0 never fires.
        let n = Nemesis::new().drop_prob(0, 100, 0.0).duplicate(0, 100, 0.0);
        assert_eq!(n.fate(10, p(0), p(1), &mut rng), LinkFate::CLEAN);
    }

    #[test]
    fn same_seed_same_fates() {
        let n = Nemesis::new()
            .drop_prob(0, 1_000, 0.3)
            .duplicate(0, 1_000, 0.3)
            .reorder(0, 1_000, 5_000);
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..200)
                .map(|i| n.fate(i * 5, p((i % 3) as u32), p(((i + 1) % 3) as u32), &mut rng))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12), "different seeds should differ somewhere");
    }
}
