//! Deterministic discrete-event simulator for wide-area (P)SMR.
//!
//! This is the reproduction's testbed (see DESIGN.md §3): protocols run
//! unchanged against a latency matrix (Table 2 by default), an optional
//! CPU/NIC resource model (for the throughput/saturation experiments,
//! Figs. 7–9), closed-loop clients, optional site-level batching, and a
//! crash/suspect schedule for the recovery experiments. Runs are fully
//! deterministic given the seed.
//!
//! Clients are real [`Session`]s: each closed-loop client allocates
//! rifl-style request ids, `Protocol::submit(cmd, time)` renames the
//! request to a dot internally, and every replica owns an
//! [`Executor`] that applies `Action::Execute` to a KV store and emits
//! `Action::Reply` at the coordinator — the reply (not origin execution)
//! is what completes a client and is recorded, with its [`Response`],
//! for the checker's response-validity oracle.
//!
//! Two distinct batching layers meet here. *Site-level client batching*
//! (`SimOpts::batching`, Fig. 8) merges several clients' commands into one
//! submitted command before the protocol sees them. *Message batching*
//! (`Config::batch_max_msgs`, `protocol::common::batch`) coalesces a
//! process's outgoing protocol messages per destination into `MBatch`
//! frames; it happens inside the protocols, so a batch is one `Deliver`
//! event whose `msg_size` covers all members — the resource model charges
//! one per-message CPU cost instead of many, and `SimResult::footprints`
//! plus `Counters::{batches_sent, batched_msgs}` report what batching did.

pub mod nemesis;
pub mod resource;
pub mod topology;

pub use nemesis::{FaultKind, FaultWindow, LinkFate, Nemesis};
pub use resource::{ResourceModel, ResourceState};
pub use topology::Topology;

use crate::client::Session;
use crate::core::{
    key_to_shard, ClientId, Command, Completion, Config, Dot, Op, ProcessId, Response, Rid,
    StorageMode,
};
use crate::executor::Executor;
use crate::metrics::{Counters, RunMetrics};
use crate::protocol::{Action, Footprint, Protocol, RESTART_DOT_SLACK};
use crate::store::storage::{assemble, plan_transfer, Durable, MemBackend, Recovery};
use crate::store::{KvStore, StateMachine};
use crate::util::Rng;
use crate::workload::batching::Batcher;
use crate::workload::Workload;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOpts {
    pub topology: Topology,
    /// `None` disregards CPU/network (the paper's "simulator mode");
    /// `Some` models them (our "cluster mode" substitute).
    pub resources: Option<ResourceModel>,
    pub clients_per_site: usize,
    /// Measurement starts after `warmup_us`.
    pub warmup_us: u64,
    /// Measurement window length.
    pub duration_us: u64,
    /// Extra time after the window during which no new commands are
    /// submitted but in-flight ones keep running (for liveness checks).
    pub drain_us: u64,
    pub seed: u64,
    /// Site-level batching: (max batch size, max delay µs).
    pub batching: Option<(usize, u64)>,
    /// Record per-process execution logs and completions (test oracles).
    pub record_execution: bool,
    /// Crash schedule: (time, process).
    pub crashes: Vec<(u64, ProcessId)>,
    /// Restart schedule: (time, process) — each restarts a previously
    /// crashed process from its storage backend (crash-recovery fault
    /// model; merged with `nemesis.restarts`). Under `StorageMode::Disk`
    /// the process recovers snapshot + WAL tail from its surviving
    /// [`MemBackend`]; under `Memory` it comes back empty. Either way it
    /// then state-transfers the diff from a live shard peer (unless
    /// `transfer_on_restart` is off) and rejoins.
    pub restarts: Vec<(u64, ProcessId)>,
    /// Failure-detection delay after a crash.
    pub suspect_delay_us: u64,
    /// False-suspicion schedule: (time, process). At `time` every live
    /// peer suspects `process` — which is *not* crashed, merely presumed
    /// dead (the slow-node case a timeout-based detector cannot tell from
    /// a real crash). The victim keeps running and keeps its in-flight
    /// coordinations going while the others evict it and its clients fail
    /// over; the safety oracles (epoch fencing, PSMR, response validity,
    /// exactly-once) must hold regardless.
    pub suspicions: Vec<(u64, ProcessId)>,
    /// Negative knob: skip the manifest-diff state transfer on restart.
    /// A replica that crashed with unsynced WAL records (or snapshots
    /// behind its peers) then rejoins stale — the recovery oracle's
    /// divergence check exists to catch exactly this.
    pub transfer_on_restart: bool,
    /// Link-fault plan (partitions, delay spikes, reorder, duplicate,
    /// drop) plus extra crashes; empty by default. Fault decisions draw
    /// from the run's seeded RNG only while a window is active, so a run
    /// with an empty plan is bit-identical to one before this field
    /// existed (see [`nemesis`]).
    pub nemesis: Nemesis,
    /// Credit the TCP runtime's encode-once broadcast in the resource
    /// model: a `SendShared` fan-out charges the serialize CPU cost once
    /// and only the NIC per destination. Off by default — the legacy
    /// model conservatively re-charged CPU per destination, and existing
    /// saturation results are pinned against it.
    pub encode_once: bool,
}

impl SimOpts {
    pub fn new(topology: Topology) -> Self {
        SimOpts {
            topology,
            resources: None,
            clients_per_site: 16,
            warmup_us: 2_000_000,
            duration_us: 10_000_000,
            drain_us: 0,
            seed: 1,
            batching: None,
            record_execution: false,
            crashes: Vec::new(),
            restarts: Vec::new(),
            suspect_delay_us: 500_000,
            suspicions: Vec::new(),
            transfer_on_restart: true,
            nemesis: Nemesis::default(),
            encode_once: false,
        }
    }
}

/// One locally-served read (`Action::ExecuteRead`), recorded for the
/// read-linearizability oracle (when `record_execution`).
#[derive(Clone, Debug)]
pub struct ReadAudit {
    /// Length of the serving replica's execution log at the instant the
    /// read executed: entries `[..pos]` are exactly the writes the read
    /// observed.
    pub pos: usize,
    /// The timestamp the protocol claimed the frontier covered: every
    /// write with decided timestamp <= `covered` on the read's keys must
    /// appear in `[..pos]`.
    pub covered: u64,
    /// Whether the bounded-staleness slack enabled the release.
    pub slack: bool,
    /// The read command itself.
    pub cmd: Command,
}

/// One crash-restart recovery, recorded for the recovery oracle
/// (`check::check_recovery`). Captures what the replica lost at the
/// crash, what it rebuilt locally from snapshot + WAL tail, and what the
/// manifest-diff state transfer contributed.
#[derive(Clone, Debug)]
pub struct RecoveryAudit {
    /// The restarted process and the simulated restart instant.
    pub process: ProcessId,
    pub at_us: u64,
    /// Store digest / applied count at the crash instant (what a
    /// loss-free recovery would reproduce).
    pub pre_crash_digest: u64,
    pub pre_crash_applied: u64,
    /// WAL records in the group-commit window the crash destroyed.
    pub wal_lost: u64,
    /// Store digest / applied count after *local* recovery only
    /// (snapshot + valid WAL tail, before any state transfer).
    pub recovered_digest: u64,
    pub recovered_applied: u64,
    /// Applied count the snapshot manifest claimed.
    pub snapshot_applied: u64,
    /// WAL tail records replayed on top of the snapshot.
    pub wal_replayed: u64,
    /// The donor replica (None: no live shard peer, or transfer disabled)
    /// and its store digest at transfer time.
    pub peer: Option<ProcessId>,
    pub peer_digest: u64,
    /// Donor pages fetched vs. produced locally during the transfer.
    pub chunks_fetched: u64,
    pub chunks_reused: u64,
    /// Store digest after recovery + transfer — what the replica rejoins
    /// with; must equal `peer_digest` when a transfer happened.
    pub post_digest: u64,
    /// Rids re-seeded into the executor's dedup windows (blob + replay).
    pub dedup_seeded: usize,
}

/// Result of a run: metrics plus optional test-oracle material.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub metrics: RunMetrics,
    /// Per-process execution order (when `record_execution`).
    pub execution_logs: Vec<Vec<(Dot, u64)>>,
    /// Client-observed completions (when `record_execution`).
    pub completions: Vec<Completion>,
    /// All submitted dots with their commands (when `record_execution`).
    pub submitted: Vec<(Dot, Command)>,
    /// Per-process locally-served reads (when `record_execution`).
    pub read_audits: Vec<Vec<ReadAudit>>,
    /// Decided ordering timestamps observed on `Action::Execute` upcalls,
    /// `(dot, ts)`, duplicated per replica (when `record_execution`);
    /// 0 for protocol families without a timestamp order.
    pub decided_ts: Vec<(Dot, u64)>,
    /// End-of-run memory footprint of each process (GC diagnostics).
    pub footprints: Vec<Footprint>,
    /// Per-process epoch install history (`Protocol::epoch_view`): the
    /// `(epoch, cumulative evicted set)` entries each process installed,
    /// in install order. Fault-free runs report `[(0, [])]` everywhere.
    pub epoch_views: Vec<Vec<(u64, Vec<ProcessId>)>>,
    /// One entry per crash-restart recovery, in restart order (always
    /// recorded — restarts are rare and the audit is small).
    pub recoveries: Vec<RecoveryAudit>,
}

#[derive(Clone, Debug)]
enum Event<M> {
    Deliver { from: ProcessId, to: ProcessId, msg: M, bytes: u64 },
    Tick { p: ProcessId },
    ClientSubmit { client: usize },
    BatchFlush { site: usize },
    Crash { p: ProcessId },
    Suspect { at: ProcessId, suspected: ProcessId },
    /// A live process is falsely suspected (`SimOpts::suspicions`): every
    /// live peer suspects it at once and its clients fail over, but the
    /// victim itself keeps running.
    FalseSuspect { suspected: ProcessId },
    /// Session failover: the client re-issues an unacked rid at a
    /// surviving replica after its coordinator crashed.
    ClientRetry { rid: Rid },
    /// Crash-recovery: the process comes back, recovers from its storage
    /// backend, state-transfers the diff from a live peer, and rejoins.
    Restart { p: ProcessId },
}

/// Heap key: `(time, kind rank, actor, co-actor, sequence)`.
///
/// Events at the same timestamp are ordered *canonically* — by what the
/// event is (crashes, then ticks, then client submits, then site-batch
/// flushes, then message deliveries ordered by destination/sender/FIFO
/// rank) — never by heap-insertion order. This makes the schedule a pure
/// function of the delivered-message multiset, so regrouping deliveries
/// (message batching under `Config::batch_hold == false`) provably cannot
/// change a run: `rust/tests/batching.rs` asserts batched and unbatched
/// runs execute identically, and that assertion is schedule-stable rather
/// than true-for-this-seed.
type EventKey = (u64, u8, u32, u32, u64);

struct InFlight {
    /// Protocol identity the origin replica assigned at submit
    /// (`Action::Submitted`).
    dot: Dot,
    /// (client index, submit time) — batches carry several members.
    members: Vec<(usize, u64)>,
    site: usize,
    ops: u32,
    /// The command as submitted (`Arc`-backed, cheap to keep): a session
    /// re-issues it verbatim — same rid — if its coordinator crashes.
    cmd: Command,
}

/// The simulator.
pub struct Simulation<P: Protocol, W: Workload> {
    config: Config,
    opts: SimOpts,
    procs: Vec<P>,
    dead: Vec<bool>,
    /// Falsely-suspected processes (`SimOpts::suspicions`): alive, but
    /// evicted by their peers — clients route around them like the dead.
    shunned: Vec<bool>,
    /// Per-replica executors: apply `Action::Execute` to the replicated
    /// KV store and emit `Action::Reply` at the coordinator. The store is
    /// always wrapped in [`Durable`] — under `StorageMode::Memory` (the
    /// default) with an inert backend, so nothing changes; under `Disk`
    /// with a deterministic in-memory [`MemBackend`] that models the
    /// machine's disk (survives the crash, loses the unsynced WAL tail).
    executors: Vec<Executor<Durable<KvStore>>>,
    /// The simulated disks, indexed like `procs`; kept outside the
    /// executors so a crash can destroy the executor while the disk
    /// survives for [`Durable::recover`].
    backends: Vec<MemBackend>,
    /// (digest, applied, wal_lost) captured at each crash instant, for
    /// the recovery audit of a later restart.
    pre_crash: HashMap<ProcessId, (u64, u64, u64)>,
    /// One session per closed-loop client: allocates the rifl-style
    /// request ids commands carry.
    sessions: Vec<Session>,
    resources: Vec<ResourceState>,
    heap: BinaryHeap<Reverse<EventKey>>,
    payloads: HashMap<EventKey, Event<P::Message>>,
    /// Per-(from, to) delivery rank: preserves sender FIFO order at equal
    /// delivery times (see [`EventKey`]).
    pair_seq: HashMap<(ProcessId, ProcessId), u64>,
    /// Rank for the event classes without a natural identity counter.
    aux_seq: u64,
    now: u64,
    workload: W,
    rng: Rng,
    in_flight: HashMap<Rid, InFlight>,
    batchers: Vec<Batcher>,
    result: SimResult,
    warmup_snapshot: Option<Vec<(f64, f64, f64)>>,
    end_time: u64,
    final_time: u64,
}

impl<P: Protocol, W: Workload> Simulation<P, W> {
    pub fn new(config: Config, opts: SimOpts, workload: W) -> Self {
        assert_eq!(
            config.sites,
            opts.topology.sites(),
            "config.sites must match the topology"
        );
        let n = config.n_processes();
        let procs: Vec<P> = (0..n).map(|i| P::new(ProcessId(i as u32), config.clone())).collect();
        let backends: Vec<MemBackend> = (0..n).map(|_| MemBackend::new()).collect();
        let executors = (0..n)
            .map(|i| {
                let sm = match config.storage {
                    StorageMode::Memory => Durable::memory(KvStore::new()),
                    StorageMode::Disk => Durable::new(
                        KvStore::new(),
                        Box::new(backends[i].clone()),
                        config.wal_fsync_batch,
                        config.snapshot_every,
                    ),
                };
                Executor::new(ProcessId(i as u32), sm)
                    .with_dedup_window(config.dedup_window)
            })
            .collect();
        let n_clients = opts.clients_per_site * config.sites;
        let sessions = (0..n_clients).map(|c| Session::new(ClientId(c as u64))).collect();
        let resources = (0..n).map(|_| ResourceState::default()).collect();
        let batchers = match opts.batching {
            Some((max, delay)) => {
                (0..config.sites).map(|_| Batcher::new(max, delay)).collect()
            }
            None => Vec::new(),
        };
        let end_time = opts.warmup_us + opts.duration_us;
        let final_time = end_time + opts.drain_us;
        let rng = Rng::new(opts.seed);
        let record = opts.record_execution;
        let mut sim = Simulation {
            config,
            opts,
            procs,
            dead: vec![false; n],
            shunned: vec![false; n],
            executors,
            backends,
            pre_crash: HashMap::new(),
            sessions,
            resources,
            heap: BinaryHeap::new(),
            payloads: HashMap::new(),
            pair_seq: HashMap::new(),
            aux_seq: 0,
            now: 0,
            workload,
            rng,
            in_flight: HashMap::new(),
            batchers,
            result: SimResult::default(),
            warmup_snapshot: None,
            end_time,
            final_time,
        };
        if record {
            sim.result.execution_logs = vec![Vec::new(); n];
            sim.result.read_audits = vec![Vec::new(); n];
        }
        sim
    }

    fn push(&mut self, time: u64, ev: Event<P::Message>) {
        let key: EventKey = match &ev {
            // A process crashes before anything else it would do at the
            // same instant (matching the pre-canonical push order, where
            // crashes were scheduled first).
            Event::Crash { p } => {
                self.aux_seq += 1;
                (time, 0, p.0, 0, self.aux_seq)
            }
            // Ticks of one process sit at distinct times (interval >= 1).
            Event::Tick { p } => (time, 1, p.0, 0, 0),
            // A closed-loop client has at most one pending submit event.
            Event::ClientSubmit { client } => (time, 2, *client as u32, 0, 0),
            Event::BatchFlush { site } => {
                self.aux_seq += 1;
                (time, 3, *site as u32, 0, self.aux_seq)
            }
            Event::Deliver { from, to, .. } => {
                let c = self.pair_seq.entry((*from, *to)).or_insert(0);
                *c += 1;
                (time, 4, to.0, from.0, *c)
            }
            Event::Suspect { at, suspected } => {
                self.aux_seq += 1;
                (time, 5, at.0, suspected.0, self.aux_seq)
            }
            // Shares the Suspect rank (it *is* a suspicion, just fanned
            // out); `u32::MAX` as the actor keeps it disjoint from any
            // real (at, suspected) pair.
            Event::FalseSuspect { suspected } => {
                self.aux_seq += 1;
                (time, 5, u32::MAX, suspected.0, self.aux_seq)
            }
            // A closed-loop client has at most one in-flight rid, so
            // (client, seq) identifies the retry without an aux rank —
            // keeping the key a pure function of the event (insertion
            // order from the crash scan cannot leak into the schedule).
            Event::ClientRetry { rid } => {
                (time, 6, rid.client().0 as u32, rid.seq() as u32, rid.seq() >> 32)
            }
            // A restart happens after everything else at its instant: the
            // recovered state observes all same-instant deliveries to the
            // rest of the cluster.
            Event::Restart { p } => {
                self.aux_seq += 1;
                (time, 7, p.0, 0, self.aux_seq)
            }
        };
        self.heap.push(Reverse(key));
        self.payloads.insert(key, ev);
    }

    /// Run to completion and return the collected result.
    pub fn run(mut self) -> SimResult {
        // Initial ticks, staggered across processes to avoid lockstep.
        let interval = self.config.tick_interval_us.max(1);
        for i in 0..self.procs.len() {
            let offset = (i as u64 * 97) % interval;
            self.push(offset + 1, Event::Tick { p: ProcessId(i as u32) });
        }
        // Client start events, staggered inside the first tick interval.
        let n_clients = self.opts.clients_per_site * self.config.sites;
        for c in 0..n_clients {
            let offset = (c as u64 * 131) % 1_000;
            self.push(offset + 1, Event::ClientSubmit { client: c });
        }
        let mut crashes = self.opts.crashes.clone();
        crashes.extend(self.opts.nemesis.crashes.iter().copied());
        for (t, p) in crashes {
            self.push(t, Event::Crash { p });
        }
        let mut restarts = self.opts.restarts.clone();
        restarts.extend(self.opts.nemesis.restarts.iter().copied());
        for (t, p) in restarts {
            self.push(t, Event::Restart { p });
        }
        for (t, p) in self.opts.suspicions.clone() {
            self.push(t, Event::FalseSuspect { suspected: p });
        }

        while let Some(Reverse(key)) = self.heap.pop() {
            let time = key.0;
            if time > self.final_time {
                break;
            }
            self.now = time;
            if self.warmup_snapshot.is_none() && time >= self.opts.warmup_us {
                self.warmup_snapshot = Some(
                    self.resources
                        .iter()
                        .map(|r| (r.cpu_busy_us, r.in_busy_us, r.out_busy_us))
                        .collect(),
                );
            }
            let ev = self.payloads.remove(&key).expect("event payload");
            self.step(time, ev);
        }
        self.finalize()
    }

    fn step(&mut self, time: u64, ev: Event<P::Message>) {
        match ev {
            Event::Tick { p } => {
                let interval = self.config.tick_interval_us.max(1);
                if time + interval <= self.final_time {
                    self.push(time + interval, Event::Tick { p });
                }
                if self.dead[p.0 as usize] {
                    return;
                }
                let actions = self.procs[p.0 as usize].tick(time);
                self.process_actions(p, actions, time);
            }
            Event::Deliver { from, to, msg, bytes } => {
                if self.dead[to.0 as usize] {
                    return;
                }
                let handle_at = if let Some(model) = self.opts.resources {
                    let res = &mut self.resources[to.0 as usize];
                    let ready = res.use_in(time as f64, model.wire_us(bytes));
                    res.use_cpu(ready, model.cpu_cost_us(bytes)) as u64
                } else {
                    time
                };
                let actions = self.procs[to.0 as usize].handle(from, msg, handle_at);
                self.process_actions(to, actions, handle_at);
            }
            Event::ClientSubmit { client } => {
                if time > self.end_time {
                    return; // submissions stop at the end of the window
                }
                self.client_submit(client, time);
            }
            Event::BatchFlush { site } => {
                if let Some(batch) = self.batchers[site].flush_if_due(time) {
                    self.submit_batch(site, batch.spec, batch.members, time);
                }
            }
            Event::Crash { p } => {
                self.dead[p.0 as usize] = true;
                self.procs[p.0 as usize].crash();
                // The machine's memory is gone; its disk survives minus
                // the unsynced group-commit window. Capture what a
                // loss-free recovery would have to reproduce.
                let idx = p.0 as usize;
                let digest = self.executors[idx].state().digest();
                let applied = self.executors[idx].state().applied();
                let lost = match self.config.storage {
                    StorageMode::Disk => self.backends[idx].crash(),
                    StorageMode::Memory => 0,
                };
                self.pre_crash.insert(p, (digest, applied, lost));
                let delay = self.opts.suspect_delay_us;
                for q in 0..self.procs.len() {
                    if !self.dead[q] {
                        self.push(
                            time + delay,
                            Event::Suspect { at: ProcessId(q as u32), suspected: p },
                        );
                    }
                }
                // Session failover: rids coordinated by the dead process
                // are re-issued once the failure is detected. Collect and
                // sort so the schedule does not depend on map iteration
                // order.
                if delay < u64::MAX - time {
                    let mut orphans: Vec<Rid> = self
                        .in_flight
                        .iter()
                        .filter(|(_, inf)| inf.dot.origin == p)
                        .map(|(rid, _)| *rid)
                        .collect();
                    orphans.sort_unstable();
                    for rid in orphans {
                        self.push(time + delay, Event::ClientRetry { rid });
                    }
                }
            }
            Event::Suspect { at, suspected } => {
                if !self.dead[at.0 as usize] {
                    self.procs[at.0 as usize].suspect(suspected);
                }
            }
            Event::FalseSuspect { suspected } => {
                let idx = suspected.0 as usize;
                // A real crash already handled suspicion the usual way.
                if !self.dead[idx] && !self.shunned[idx] {
                    self.shunned[idx] = true;
                    for q in 0..self.procs.len() {
                        if q != idx && !self.dead[q] {
                            self.procs[q].suspect(suspected);
                        }
                    }
                    // Session failover away from the shunned coordinator:
                    // same re-issue path as a crash, fired immediately —
                    // the suspicion instant *is* the detector giving up.
                    let mut orphans: Vec<Rid> = self
                        .in_flight
                        .iter()
                        .filter(|(_, inf)| inf.dot.origin == suspected)
                        .map(|(rid, _)| *rid)
                        .collect();
                    orphans.sort_unstable();
                    for rid in orphans {
                        self.push(time, Event::ClientRetry { rid });
                    }
                }
            }
            Event::ClientRetry { rid } => {
                self.client_retry(rid, time);
            }
            Event::Restart { p } => {
                self.restart_process(p, time);
            }
        }
    }

    /// Crash-recovery: rebuild the executor of `p` from its surviving
    /// backend (snapshot + valid WAL tail), fetch the state diff from a
    /// live shard peer via a manifest diff, re-seed the dedup windows, and
    /// hand a *fresh* protocol instance a dot floor it must never re-mint
    /// under. The pre-crash protocol state is gone — exactly the
    /// crash-recovery model: disks survive, memory does not.
    fn restart_process(&mut self, p: ProcessId, time: u64) {
        let idx = p.0 as usize;
        if !self.dead[idx] {
            return; // restarting a live process is a no-op
        }
        let (pre_digest, pre_applied, wal_lost) =
            self.pre_crash.remove(&p).unwrap_or((0, 0, 0));
        // 1. Local recovery from the surviving disk.
        let (mut durable, recovery) = match self.config.storage {
            StorageMode::Disk => Durable::<KvStore>::recover(
                Box::new(self.backends[idx].clone()),
                self.config.wal_fsync_batch,
                self.config.snapshot_every,
            ),
            StorageMode::Memory => (Durable::memory(KvStore::new()), Recovery::default()),
        };
        let recovered_digest = durable.digest();
        let recovered_applied = durable.applied();
        // 2. Manifest-diff state transfer from a live peer of the shard.
        let shard = self.config.shard_of(p);
        let donor = self
            .config
            .shard_processes(shard)
            .into_iter()
            .find(|q| *q != p && !self.dead[q.0 as usize])
            .filter(|_| self.opts.transfer_on_restart);
        let mut audit = RecoveryAudit {
            process: p,
            at_us: time,
            pre_crash_digest: pre_digest,
            pre_crash_applied: pre_applied,
            wal_lost,
            recovered_digest,
            recovered_applied,
            snapshot_applied: recovery.snapshot_applied,
            wal_replayed: recovery.wal_replayed,
            peer: donor,
            peer_digest: 0,
            chunks_fetched: 0,
            chunks_reused: 0,
            post_digest: recovered_digest,
            dedup_seeded: 0,
        };
        let mut dedup_blob = recovery.dedup;
        let mut dot_floor = recovery.dot_floor(p);
        if let Some(q) = donor {
            let qi = q.0 as usize;
            audit.peer_digest = self.executors[qi].state().digest();
            let donor_blob = self.executors[qi].dedup_blob();
            let (manifest, pages) = self.executors[qi].state().serve_manifest(donor_blob);
            let plan = plan_transfer(durable.store(), &manifest);
            audit.chunks_fetched = plan.need.len() as u64;
            audit.chunks_reused = (manifest.chunks.len() - plan.need.len()) as u64;
            let donor_pages: HashMap<u64, &Vec<u8>> =
                manifest.chunks.iter().copied().zip(pages.iter()).collect();
            let store: KvStore = assemble(&manifest, |h| {
                plan.local.get(&h).cloned().or_else(|| donor_pages.get(&h).map(|pg| (*pg).clone()))
            })
            .expect("the donor serves every page of its own manifest");
            for (origin, seq) in &manifest.dot_floors {
                if *origin == p {
                    dot_floor = dot_floor.max(*seq);
                }
            }
            durable.install(store, &manifest.dedup, &manifest.dot_floors);
            // The donor's windows are the freshest exactly-once state:
            // they cover everything the cluster applied, including the
            // records our own WAL lost.
            dedup_blob = manifest.dedup;
        }
        audit.post_digest = durable.digest();
        // 3. Rebuild the executor around the recovered machine.
        let exec = Executor::recovered(
            p,
            durable,
            self.config.dedup_window,
            &dedup_blob,
            &recovery.replayed,
        );
        audit.dedup_seeded = exec.dedup_len();
        self.executors[idx] = exec;
        // 4. A fresh protocol instance that will never re-mint a dot its
        // pre-crash incarnation minted (floor from WAL + peer manifests,
        // plus slack for in-flight proposals the floors cannot see).
        let mut proc = P::new(p, self.config.clone());
        proc.note_restart(dot_floor + RESTART_DOT_SLACK);
        self.procs[idx] = proc;
        self.dead[idx] = false; // ticks resume at the next interval
        self.result.recoveries.push(audit);
        // 5. Unacked rids this replica coordinated died with its protocol
        // state: their sessions re-issue now (same rid; the re-seeded
        // dedup windows keep any copy that *did* survive exactly-once).
        let mut orphans: Vec<Rid> = self
            .in_flight
            .iter()
            .filter(|(_, inf)| inf.dot.origin == p)
            .map(|(rid, _)| *rid)
            .collect();
        orphans.sort_unstable();
        for rid in orphans {
            self.reissue(rid, time);
        }
    }

    /// Re-issue an unacked rid whose coordinator died: the session sends
    /// the *same command* (same rid) to a surviving replica of the shard.
    /// The per-client dedup window at the executors keeps the retry
    /// exactly-once if the original submission also survives (e.g. it was
    /// committed just before the crash and recovery finishes it).
    fn client_retry(&mut self, rid: Rid, time: u64) {
        match self.in_flight.get(&rid) {
            // Replied (or superseded) in the meantime: nothing to do.
            None => return,
            Some(inf) => {
                // Only retry while the current coordinator is dead or
                // shunned; a live, trusted one may still reply. (A
                // *restarted* coordinator re-issues its orphans itself,
                // see `restart_process`.)
                let o = inf.dot.origin.0 as usize;
                if !self.dead[o] && !self.shunned[o] {
                    return;
                }
            }
        }
        self.reissue(rid, time);
    }

    /// The re-issue itself (shared by the failover retry and the restart
    /// path, which skips the dead-coordinator guard).
    fn reissue(&mut self, rid: Rid, time: u64) {
        let (cmd, site) = match self.in_flight.get(&rid) {
            None => return,
            Some(inf) => (inf.cmd.clone(), inf.site),
        };
        let shard = key_to_shard(cmd.keys[0], self.config.shards);
        let origin = match self.live_origin(shard.0, site) {
            Some(o) => o,
            None => return, // whole shard down; nothing can serve this rid
        };
        let submit_at = time + self.opts.topology.local_us;
        let is_read = cmd.op == Op::Read;
        let recorded = self.opts.record_execution.then(|| cmd.clone());
        let actions = if is_read {
            let floor = self
                .sessions
                .get(rid.client().0 as usize)
                .map_or(0, |s| s.read_floor());
            self.procs[origin.0 as usize].submit_read(cmd, floor, submit_at)
        } else {
            self.procs[origin.0 as usize].submit(cmd, submit_at)
        };
        let dot = actions
            .iter()
            .find_map(|a| match a {
                Action::Submitted { dot } => Some(*dot),
                _ => None,
            })
            .unwrap_or_else(|| Dot::new(origin, 0));
        if let Some(c) = recorded {
            // Local reads keep the sentinel seq-0 dot and are not part of
            // the liveness universe, exactly like first submissions.
            if dot.seq != 0 {
                self.result.submitted.push((dot, c));
            }
        }
        if let Some(inf) = self.in_flight.get_mut(&rid) {
            inf.dot = dot; // the retry's identity supersedes the orphan
        }
        self.process_actions(origin, actions, submit_at);
    }

    /// The replica a client of `site` should talk to in `shard`: its own
    /// site's replica when alive and trusted, otherwise the lowest-id
    /// surviving non-shunned member (deterministic failover target). A
    /// falsely-suspected replica is routed around like a dead one — after
    /// eviction its proposals cannot gather a quorum in the new epoch.
    fn live_origin(&self, shard: u32, site: usize) -> Option<ProcessId> {
        let base = shard * self.config.r as u32;
        let usable = |q: &ProcessId| {
            let i = q.0 as usize;
            !self.dead[i] && !self.shunned[i]
        };
        let preferred = ProcessId(base + site as u32);
        if usable(&preferred) {
            return Some(preferred);
        }
        (0..self.config.r as u32).map(|i| ProcessId(base + i)).find(usable)
    }

    fn client_submit(&mut self, client: usize, time: u64) {
        let site = client % self.config.sites;
        let cid = ClientId(client as u64);
        let spec = self.workload.next(cid, &mut self.rng);
        if spec.op == Op::Read {
            // Reads take the local path (`Protocol::submit_read`) and
            // bypass site-level batching: there is no broadcast for a
            // batch to amortize.
            self.submit_read(site, spec, client, time);
            return;
        }
        if self.batchers.is_empty() {
            self.submit_batch(site, spec, vec![(client, time)], time);
        } else {
            let (deadline, flushed) = self.batchers[site].push(client, spec, time);
            if let Some(d) = deadline {
                self.push(d, Event::BatchFlush { site });
            }
            if let Some(batch) = flushed {
                self.submit_batch(site, batch.spec, batch.members, time);
            }
        }
    }

    fn submit_batch(
        &mut self,
        site: usize,
        spec: crate::workload::CommandSpec,
        members: Vec<(usize, u64)>,
        time: u64,
    ) {
        // The origin process: the replica at the client's site of the shard
        // holding the first key (i ∈ I_c as PSMR requires) — or, when that
        // replica is dead, the deterministic failover target (session
        // failover; the paper's clients do the same).
        let shard = key_to_shard(spec.keys[0], self.config.shards);
        let origin = match self.live_origin(shard.0, site) {
            Some(o) => o,
            None => return, // whole shard down: clients of this shard stop
        };
        // The (first) member's session allocates the request id; a
        // site-level batch is one request whose response all members
        // observe.
        let rid = self.sessions[members[0].0].next_rid();
        let mut cmd = Command::new(rid, spec.keys, spec.op, spec.payload_len);
        cmd.batched = members.len() as u32;
        let ops = cmd.batched;
        // Cheap clones (`Arc`-backed): one for the test oracle, one kept
        // in flight for crash re-issue.
        let kept = cmd.clone();
        let recorded = self.opts.record_execution.then(|| cmd.clone());
        // Client → local replica hop.
        let submit_at = time + self.opts.topology.local_us;
        let actions = self.procs[origin.0 as usize].submit(cmd, submit_at);
        // The protocol renamed the request to a dot (Action::Submitted).
        let dot = match actions.iter().find_map(|a| match a {
            Action::Submitted { dot } => Some(*dot),
            _ => None,
        }) {
            Some(d) => d,
            None => return, // replica refused the command (crashed)
        };
        debug_assert_eq!(dot.origin, origin, "submitter must be the dot origin");
        if let Some(c) = recorded {
            self.result.submitted.push((dot, c));
        }
        self.in_flight.insert(rid, InFlight { dot, members, site, ops, cmd: kept });
        self.process_actions(origin, actions, submit_at);
    }

    /// Submit a read-only command at the client's local replica via
    /// `Protocol::submit_read`. A local read never acquires a dot (it
    /// never travels), so the in-flight entry uses the sentinel
    /// `Dot::new(origin, 0)` — sequence 0 is never minted by a `DotGen`;
    /// a degraded (slow) read announces a real dot via `Submitted` and is
    /// tracked like any ordinary command.
    fn submit_read(
        &mut self,
        site: usize,
        spec: crate::workload::CommandSpec,
        client: usize,
        time: u64,
    ) {
        let shard = key_to_shard(spec.keys[0], self.config.shards);
        let origin = match self.live_origin(shard.0, site) {
            Some(o) => o,
            None => return,
        };
        let rid = self.sessions[client].next_rid();
        let cmd = Command::new(rid, spec.keys, spec.op, spec.payload_len);
        let kept = cmd.clone();
        let recorded = self.opts.record_execution.then(|| cmd.clone());
        let submit_at = time + self.opts.topology.local_us;
        // Read-your-writes: the session's reads must observe at least its
        // last acknowledged write's decided timestamp.
        let floor = self.sessions[client].read_floor();
        let actions = self.procs[origin.0 as usize].submit_read(cmd, floor, submit_at);
        let dot = actions
            .iter()
            .find_map(|a| match a {
                Action::Submitted { dot } => Some(*dot),
                _ => None,
            })
            .unwrap_or_else(|| Dot::new(origin, 0));
        if let Some(c) = recorded {
            if dot.seq != 0 {
                self.result.submitted.push((dot, c));
            }
        }
        self.in_flight
            .insert(rid, InFlight { dot, members: vec![(client, time)], site, ops: 1, cmd: kept });
        self.process_actions(origin, actions, submit_at);
    }

    /// Put one message on the (modeled) wire: charge the sender's
    /// CPU/NIC resources, consult the nemesis plan, and schedule the
    /// delivery (or eat it). Fault decisions apply at *send* time — the
    /// sender pays CPU/NIC for dropped messages (they left the process;
    /// the link ate them), which also keeps resource accounting identical
    /// in shape to a fault-free run.
    fn send_one(&mut self, at: ProcessId, to: ProcessId, msg: P::Message, time: u64) {
        let bytes = P::msg_size(&msg);
        let from_site = self.config.site_of(at);
        let to_site = self.config.site_of(to);
        let depart = if let Some(model) = self.opts.resources {
            let res = &mut self.resources[at.0 as usize];
            let cpu_done = res.use_cpu(time as f64, model.cpu_cost_us(bytes));
            res.use_out(cpu_done, model.wire_us(bytes)) as u64
        } else {
            time
        };
        let fate = if self.opts.nemesis.is_empty() {
            LinkFate::CLEAN
        } else {
            self.opts.nemesis.fate(time, at, to, &mut self.rng)
        };
        let (extra_us, duplicate) = match fate {
            LinkFate::Drop => return,
            LinkFate::Deliver { extra_us, duplicate } => (extra_us, duplicate),
        };
        let latency = self.opts.topology.latency_us(from_site, to_site, self.rng.gen_f64());
        if duplicate {
            // A second, independent delivery of the same bytes (same
            // arrival instant, distinct FIFO rank).
            self.push(
                depart + latency + extra_us,
                Event::Deliver { from: at, to, msg: msg.clone(), bytes },
            );
        }
        self.push(depart + latency + extra_us, Event::Deliver { from: at, to, msg, bytes });
    }

    /// Encode-once fan-out charging (`SimOpts::encode_once`): one
    /// serialize-CPU charge for the whole broadcast, then the NIC per
    /// destination — the TCP runtime's actual cost shape
    /// (`net::encode_fanout` serializes once and shares the bytes).
    /// Deliveries are otherwise identical to the per-`Send` expansion.
    fn send_fanout(&mut self, at: ProcessId, to: Vec<ProcessId>, msg: P::Message, time: u64) {
        let model = self.opts.resources.expect("fan-out charging needs a resource model");
        let bytes = P::msg_size(&msg);
        let from_site = self.config.site_of(at);
        let cpu_done =
            self.resources[at.0 as usize].use_cpu(time as f64, model.cpu_cost_us(bytes));
        for dest in to {
            if dest == at {
                let acts = self.procs[at.0 as usize].handle(at, msg.clone(), time);
                self.process_actions(at, acts, time);
                continue;
            }
            let depart =
                self.resources[at.0 as usize].use_out(cpu_done, model.wire_us(bytes)) as u64;
            let fate = if self.opts.nemesis.is_empty() {
                LinkFate::CLEAN
            } else {
                self.opts.nemesis.fate(time, at, dest, &mut self.rng)
            };
            let (extra_us, duplicate) = match fate {
                LinkFate::Drop => continue,
                LinkFate::Deliver { extra_us, duplicate } => (extra_us, duplicate),
            };
            let to_site = self.config.site_of(dest);
            let latency = self.opts.topology.latency_us(from_site, to_site, self.rng.gen_f64());
            if duplicate {
                self.push(
                    depart + latency + extra_us,
                    Event::Deliver { from: at, to: dest, msg: msg.clone(), bytes },
                );
            }
            self.push(
                depart + latency + extra_us,
                Event::Deliver { from: at, to: dest, msg: msg.clone(), bytes },
            );
        }
    }

    fn process_actions(&mut self, at: ProcessId, actions: Vec<Action<P::Message>>, time: u64) {
        // The replica's executor applies Execute upcalls in order and
        // emits the Reply at the coordinator.
        let actions = self.executors[at.0 as usize].absorb(actions);
        for action in actions {
            match action {
                Action::Send { to, msg } => {
                    if to == at {
                        // Protocols handle self-sends inline; any residual
                        // self-send is delivered immediately.
                        let acts = self.procs[at.0 as usize].handle(at, msg, time);
                        self.process_actions(at, acts, time);
                        continue;
                    }
                    self.send_one(at, to, msg, time);
                }
                Action::SendShared { to, msg } => {
                    // Expand the fan-out into per-destination typed
                    // deliveries, identical (same order, same per-message
                    // resource charges, same event keys) to the
                    // equivalent sequence of `Send`s — so the
                    // determinism/equivalence proofs see no difference.
                    // By default the sim does not credit the TCP runtime's
                    // encode-once saving (the legacy conservative model);
                    // `SimOpts::encode_once` switches to charging the
                    // serialize CPU once and the NIC per destination, the
                    // cost shape the runtime actually has.
                    if self.opts.encode_once && self.opts.resources.is_some() {
                        self.send_fanout(at, to, msg, time);
                    } else {
                        for dest in to {
                            if dest == at {
                                let acts =
                                    self.procs[at.0 as usize].handle(at, msg.clone(), time);
                                self.process_actions(at, acts, time);
                            } else {
                                self.send_one(at, dest, msg.clone(), time);
                            }
                        }
                    }
                }
                Action::SendBytes { .. } => {
                    // Net-runtime-only lowering; protocols never emit it.
                    debug_assert!(false, "SendBytes reached the simulator");
                }
                Action::Execute { dot, cmd, ts } => {
                    if self.opts.record_execution {
                        self.result.execution_logs[at.0 as usize].push((dot, time));
                        self.result.decided_ts.push((dot, ts));
                    }
                    let _ = cmd;
                }
                Action::ExecuteRead { cmd, covered, slack } => {
                    // The executor already applied the read and emitted
                    // its Reply; record the audit point for the oracle.
                    if self.opts.record_execution {
                        let pos = self.result.execution_logs[at.0 as usize].len();
                        self.result.read_audits[at.0 as usize].push(ReadAudit {
                            pos,
                            covered,
                            slack,
                            cmd,
                        });
                    }
                }
                Action::Reply { rid, response, ts } => {
                    self.complete(rid, response, ts, time);
                }
                Action::Submitted { .. }
                | Action::Committed { .. }
                | Action::RecoveryStarted { .. } => {}
            }
        }
    }

    /// The coordinator's executor replied: clients observe the response
    /// one local hop later and immediately submit their next command
    /// (closed loop).
    fn complete(&mut self, rid: Rid, response: Response, ts: u64, time: u64) {
        let inf = match self.in_flight.remove(&rid) {
            Some(x) => x,
            None => return, // duplicate Reply would be a protocol bug
        };
        let done_at = time + self.opts.topology.local_us;
        let in_window = done_at >= self.opts.warmup_us && done_at < self.end_time;
        let is_write = inf.cmd.op != Op::Read;
        for &(client, submitted_at) in &inf.members {
            if is_write {
                // Raise every member session's read-your-writes floor to
                // the batch's decided timestamp.
                self.sessions[client].note_write(ts);
            }
            let latency = done_at.saturating_sub(submitted_at);
            if in_window {
                self.result.metrics.record_completion(inf.site, latency, 1);
            }
            if self.opts.record_execution {
                self.result.completions.push(Completion {
                    dot: inf.dot,
                    rid,
                    client: ClientId(client as u64),
                    submitted_at,
                    completed_at: done_at,
                    response: response.clone(),
                });
            }
            self.push(done_at, Event::ClientSubmit { client });
        }
        // Batched entries record `ops = members`; already counted above.
        debug_assert_eq!(inf.ops as usize, inf.members.len());
    }

    fn finalize(mut self) -> SimResult {
        self.result.metrics.duration_us = self.opts.duration_us;
        // Utilization over the measurement window.
        if self.opts.resources.is_some() {
            let snap = self
                .warmup_snapshot
                .unwrap_or_else(|| self.resources.iter().map(|_| (0.0, 0.0, 0.0)).collect());
            let window = self.opts.duration_us as f64;
            self.result.metrics.utilization = self
                .resources
                .iter()
                .zip(snap)
                .map(|(r, (c0, i0, o0))| {
                    let adj = ResourceState {
                        cpu_busy_us: r.cpu_busy_us - c0,
                        in_busy_us: r.in_busy_us - i0,
                        out_busy_us: r.out_busy_us - o0,
                        ..ResourceState::default()
                    };
                    adj.utilization(window)
                })
                .collect();
        }
        let mut counters = Counters::default();
        for p in &self.procs {
            counters.merge(&p.counters());
        }
        self.result.metrics.counters = counters;
        self.result.metrics.counters.dedup_hits =
            self.executors.iter().map(|e| e.dedup_hits()).sum();
        for e in &self.executors {
            let d = e.state();
            let s = d.stats();
            let c = &mut self.result.metrics.counters;
            c.wal_records += s.wal_records;
            c.snapshots_taken += s.snapshots;
            c.wal_fsyncs += d.backend_syncs();
            c.wal_bytes += d.backend_bytes_written();
        }
        self.result.metrics.counters.chunks_fetched =
            self.result.recoveries.iter().map(|r| r.chunks_fetched).sum();
        self.result.footprints = self.procs.iter().map(|p| p.footprint()).collect();
        self.result.epoch_views = self.procs.iter().map(|p| p.epoch_view()).collect();
        self.result
    }
}

/// Convenience: run protocol `P` under `opts` with `workload`.
pub fn run<P: Protocol, W: Workload>(config: Config, opts: SimOpts, workload: W) -> SimResult {
    Simulation::<P, W>::new(config, opts, workload).run()
}
