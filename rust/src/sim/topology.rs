//! Wide-area topologies: inter-site latency matrices.
//!
//! The default matrix is the paper's Table 2 (§A): average ping (RTT)
//! latencies between the five EC2 sites used in the evaluation — Ireland
//! (eu-west-1), N. California (us-west-1), Singapore (ap-southeast-1),
//! Canada (ca-central-1) and São Paulo (sa-east-1).

/// Names of the five EC2 sites of the paper's evaluation.
pub const EC2_SITES: [&str; 5] = ["Ireland", "N.California", "Singapore", "Canada", "S.Paulo"];

/// Table 2: ping (round-trip) latencies in milliseconds.
pub const EC2_PING_MS: [[u64; 5]; 5] = [
    // IE    NC    SG    CA    SP
    [0, 141, 186, 72, 183],   // Ireland
    [141, 0, 181, 78, 190],   // N. California
    [186, 181, 0, 221, 338],  // Singapore
    [72, 78, 221, 0, 123],    // Canada
    [183, 190, 338, 123, 0],  // São Paulo
];

/// One-way inter-site latencies in microseconds.
#[derive(Clone, Debug)]
pub struct Topology {
    /// `one_way_us[a][b]`: one-way latency site a → site b.
    one_way_us: Vec<Vec<u64>>,
    /// Latency between co-located processes (same site), one-way µs.
    pub local_us: u64,
    /// Symmetric jitter bound as a fraction of the latency (e.g. 0.01).
    pub jitter: f64,
}

impl Topology {
    /// The paper's five-site EC2 topology (Table 2).
    pub fn ec2() -> Self {
        let one_way = EC2_PING_MS
            .iter()
            .map(|row| row.iter().map(|rtt_ms| rtt_ms * 1_000 / 2).collect())
            .collect();
        Topology { one_way_us: one_way, local_us: 125, jitter: 0.01 }
    }

    /// First `n` sites of the EC2 topology (n <= 5).
    pub fn ec2_subset(n: usize) -> Self {
        assert!(n >= 1 && n <= 5);
        let one_way = (0..n)
            .map(|a| (0..n).map(|b| EC2_PING_MS[a][b] * 1_000 / 2).collect())
            .collect();
        Topology { one_way_us: one_way, local_us: 125, jitter: 0.01 }
    }

    /// The 3-site topology used in the partial-replication evaluation
    /// (§6.4): Ireland, N. California, Singapore.
    pub fn ec2_three() -> Self {
        let idx = [0usize, 1, 2];
        let one_way = idx
            .iter()
            .map(|&a| idx.iter().map(|&b| EC2_PING_MS[a][b] * 1_000 / 2).collect())
            .collect();
        Topology { one_way_us: one_way, local_us: 125, jitter: 0.01 }
    }

    /// Uniform synthetic topology: every pair of distinct sites at
    /// `one_way_ms` one-way.
    pub fn uniform(sites: usize, one_way_ms: u64) -> Self {
        let one_way = (0..sites)
            .map(|a| {
                (0..sites).map(|b| if a == b { 0 } else { one_way_ms * 1_000 }).collect()
            })
            .collect();
        Topology { one_way_us: one_way, local_us: 125, jitter: 0.01 }
    }

    pub fn sites(&self) -> usize {
        self.one_way_us.len()
    }

    /// Base one-way latency between two sites (no jitter), µs.
    pub fn base_latency_us(&self, from_site: usize, to_site: usize) -> u64 {
        if from_site == to_site {
            self.local_us
        } else {
            self.one_way_us[from_site][to_site]
        }
    }

    /// One-way latency with deterministic pseudo-jitter derived from `u`
    /// (a uniform random value in [0,1)).
    pub fn latency_us(&self, from_site: usize, to_site: usize, u: f64) -> u64 {
        let base = self.base_latency_us(from_site, to_site) as f64;
        // jitter in [-jitter, +jitter]
        let factor = 1.0 + self.jitter * (2.0 * u - 1.0);
        (base * factor) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_symmetry_and_diagonal() {
        for a in 0..5 {
            assert_eq!(EC2_PING_MS[a][a], 0);
            for b in 0..5 {
                assert_eq!(EC2_PING_MS[a][b], EC2_PING_MS[b][a]);
            }
        }
    }

    #[test]
    fn one_way_is_half_rtt() {
        let t = Topology::ec2();
        // Ireland ↔ Canada: 72ms RTT → 36ms one-way.
        assert_eq!(t.base_latency_us(0, 3), 36_000);
        // Singapore ↔ São Paulo: 338ms RTT → 169ms one-way.
        assert_eq!(t.base_latency_us(2, 4), 169_000);
    }

    #[test]
    fn local_latency_is_small() {
        let t = Topology::ec2();
        assert!(t.base_latency_us(1, 1) < 1_000);
    }

    #[test]
    fn jitter_is_bounded() {
        let t = Topology::ec2();
        let base = t.base_latency_us(0, 2);
        for u in [0.0, 0.25, 0.5, 0.75, 0.999] {
            let l = t.latency_us(0, 2, u);
            let lo = (base as f64 * 0.99) as u64;
            let hi = (base as f64 * 1.01) as u64 + 1;
            assert!(l >= lo && l <= hi, "latency {l} outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn uniform_topology() {
        let t = Topology::uniform(7, 50);
        assert_eq!(t.sites(), 7);
        assert_eq!(t.base_latency_us(0, 6), 50_000);
        assert_eq!(t.base_latency_us(3, 3), t.local_us);
    }
}
