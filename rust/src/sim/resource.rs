//! Per-process resource model: CPU and NIC bandwidth.
//!
//! The paper's own simulator "computes the observed client latency when CPU
//! and network bottlenecks are disregarded" (§6.1). To also reproduce the
//! *throughput* experiments (Figs. 7–9), which saturate CPU or NIC on the
//! local cluster, we add an explicit resource model: each message costs CPU
//! time at the sender and receiver and wire time proportional to its size.
//! Utilization percentages feed the Fig. 7 heatmap.

/// Resource parameters of one process (machine).
#[derive(Clone, Copy, Debug)]
pub struct ResourceModel {
    /// CPU cost to process (send or receive) one message, µs.
    pub cpu_per_msg_us: f64,
    /// Additional CPU cost per KiB of message payload, µs.
    pub cpu_per_kib_us: f64,
    /// NIC bandwidth, bytes per µs (10 Gbit/s ≈ 1250 B/µs).
    pub nic_bytes_per_us: f64,
}

impl ResourceModel {
    /// Roughly a c5.2xlarge-like server as used in the paper's cluster:
    /// ~2 µs of CPU per protocol message + 0.4 µs/KiB, 10 Gbit NIC.
    pub fn cluster() -> Self {
        ResourceModel { cpu_per_msg_us: 2.0, cpu_per_kib_us: 0.4, nic_bytes_per_us: 1250.0 }
    }

    pub fn cpu_cost_us(&self, bytes: u64) -> f64 {
        self.cpu_per_msg_us + self.cpu_per_kib_us * (bytes as f64 / 1024.0)
    }

    pub fn wire_us(&self, bytes: u64) -> f64 {
        bytes as f64 / self.nic_bytes_per_us
    }
}

/// Mutable resource state of one process during simulation.
#[derive(Clone, Debug, Default)]
pub struct ResourceState {
    /// Time until which the CPU is busy.
    pub cpu_free_at: f64,
    /// Time until which the outbound NIC is busy.
    pub out_free_at: f64,
    /// Time until which the inbound NIC is busy.
    pub in_free_at: f64,
    /// Accumulated busy time (for utilization), µs.
    pub cpu_busy_us: f64,
    pub out_busy_us: f64,
    pub in_busy_us: f64,
}

impl ResourceState {
    /// Occupy the CPU for `cost` µs starting no earlier than `now`.
    /// Returns the completion time.
    pub fn use_cpu(&mut self, now: f64, cost: f64) -> f64 {
        let start = self.cpu_free_at.max(now);
        self.cpu_free_at = start + cost;
        self.cpu_busy_us += cost;
        self.cpu_free_at
    }

    /// Serialize `bytes` onto the outbound wire. Returns departure time.
    pub fn use_out(&mut self, now: f64, wire_us: f64) -> f64 {
        let start = self.out_free_at.max(now);
        self.out_free_at = start + wire_us;
        self.out_busy_us += wire_us;
        self.out_free_at
    }

    /// Deserialize `bytes` from the inbound wire. Returns ready time.
    pub fn use_in(&mut self, now: f64, wire_us: f64) -> f64 {
        let start = self.in_free_at.max(now);
        self.in_free_at = start + wire_us;
        self.in_busy_us += wire_us;
        self.in_free_at
    }

    /// Utilization over a window of `window_us`, in percent (capped 100).
    pub fn utilization(&self, window_us: f64) -> crate::metrics::Utilization {
        let pct = |busy: f64| (100.0 * busy / window_us).min(100.0);
        crate::metrics::Utilization {
            cpu: pct(self.cpu_busy_us),
            net_in: pct(self.in_busy_us),
            net_out: pct(self.out_busy_us),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_queueing_delays_when_busy() {
        let mut s = ResourceState::default();
        let done1 = s.use_cpu(0.0, 5.0);
        assert_eq!(done1, 5.0);
        // Arrives at t=2 but CPU busy until 5 → finishes at 8.
        let done2 = s.use_cpu(2.0, 3.0);
        assert_eq!(done2, 8.0);
        // Idle gap: arrives at 100 → finishes at 101.
        let done3 = s.use_cpu(100.0, 1.0);
        assert_eq!(done3, 101.0);
        assert_eq!(s.cpu_busy_us, 9.0);
    }

    #[test]
    fn utilization_percent() {
        let mut s = ResourceState::default();
        s.use_cpu(0.0, 50.0);
        let u = s.utilization(100.0);
        assert!((u.cpu - 50.0).abs() < 1e-9);
        assert_eq!(u.net_in, 0.0);
    }

    #[test]
    fn wire_time_scales_with_bytes() {
        let m = ResourceModel::cluster();
        // 10 Gbit/s: 12500 bytes take ~10 µs.
        assert!((m.wire_us(12_500) - 10.0).abs() < 0.01);
        assert!(m.cpu_cost_us(4096) > m.cpu_cost_us(100));
    }
}
