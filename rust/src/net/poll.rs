//! Readiness polling for the client event loop: a thin `poll(2)` shim
//! behind a [`Poller`] trait, keeping the zero-heavy-deps discipline —
//! no `libc` crate, no async runtime. The one syscall the standard
//! library does not expose is declared by hand (`extern "C" fn poll`;
//! the symbol comes from the C runtime std already links), fd plumbing
//! goes through `std::os::fd`, and the wake token is a connected
//! loopback UDP pair (one byte sent = one poll wakeup), so waking a
//! sleeping loop needs no signals and no self-dial of the listener.
//!
//! The trait exists so tests can drive the readiness machinery
//! deterministically: [`ScriptedPoller`] replays a scripted sequence of
//! readiness batches with no sockets and no time, while the production
//! [`PollPoller`] multiplexes real nonblocking fds. Both surface the
//! same wake-token semantics (a [`Waker`] is `Clone + Send`, coalesces
//! redundant wakes, and interrupts a blocked [`Poller::poll`]).

use crate::util::error::{Context, Result};
use std::collections::BTreeMap;
use std::net::UdpSocket;
use std::os::fd::RawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Caller-chosen identity of one registered fd (the event loop uses the
/// connection id; the acceptor uses a fixed token for the listener).
pub type Token = usize;

/// What a registration wants to hear about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    /// Read-only interest — the steady state of an idle connection.
    pub const READ: Interest = Interest { readable: true, writable: false };
    /// Read + write interest — a connection with a partially-flushed
    /// outbound queue waiting for the socket to drain.
    pub const READ_WRITE: Interest = Interest { readable: true, writable: true };
}

/// What the poller observed on one fd.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Readiness {
    pub readable: bool,
    pub writable: bool,
    /// Error/hangup: the owner should read to completion and drop the
    /// connection (a read on such an fd returns 0 or an error).
    pub error: bool,
}

/// Handle that interrupts a blocked [`Poller::poll`] from any thread.
/// Cloneable and cheap; redundant wakes coalesce — between two polls at
/// most one wake byte travels, however many threads called [`Waker::wake`].
#[derive(Clone)]
pub enum Waker {
    /// Production: one byte over a connected loopback UDP pair.
    Udp { sock: Arc<UdpSocket>, pending: Arc<AtomicBool> },
    /// Deterministic tests: a flag the scripted poller observes.
    Flag(Arc<AtomicBool>),
}

impl Waker {
    /// Wake the poller (idempotent between polls).
    pub fn wake(&self) {
        match self {
            Waker::Udp { sock, pending } => {
                if !pending.swap(true, Ordering::AcqRel) {
                    let _ = sock.send(&[1u8]);
                }
            }
            Waker::Flag(flag) => flag.store(true, Ordering::Release),
        }
    }
}

/// Readiness selector the client event loop runs on. Implementations
/// must be drivable from one thread while [`Waker`]s fire from others.
pub trait Poller: Send {
    /// Start watching `fd` as `token`. A token is registered at most
    /// once; re-registering replaces the previous fd/interest.
    fn register(&mut self, token: Token, fd: RawFd, interest: Interest);
    /// Change what `token` wants to hear about (no-op if unregistered).
    fn set_interest(&mut self, token: Token, interest: Interest);
    /// Stop watching `token` (no-op if unregistered).
    fn deregister(&mut self, token: Token);
    /// Block until at least one registration is ready, the timeout
    /// elapses, or a [`Waker`] fires; `events` is cleared and filled
    /// with what happened (possibly nothing — a pure wake delivers an
    /// empty batch). `None` blocks indefinitely (modulo wakes).
    fn poll(&mut self, events: &mut Vec<(Token, Readiness)>, timeout: Option<Duration>)
        -> Result<()>;
    /// A wake handle for this poller.
    fn waker(&self) -> Waker;
}

/// The `poll(2)` ABI, declared by hand: no `libc` crate in the tree.
/// Linux's `nfds_t` is `unsigned long`; the struct layout is the
/// kernel's `struct pollfd`.
#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_ulong};

    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }
}

/// Production poller over `poll(2)`. The registration table is a
/// `BTreeMap` so the pollfd array (and therefore event delivery order)
/// is deterministic in token order — useful when replaying bugs. The
/// wake token is slot 0 of every pollfd array: a connected loopback UDP
/// pair whose receive side is drained (and the coalescing flag cleared)
/// before events are reported.
#[cfg(unix)]
pub struct PollPoller {
    fds: BTreeMap<Token, (RawFd, Interest)>,
    wake_rx: UdpSocket,
    waker: Waker,
}

#[cfg(unix)]
impl PollPoller {
    pub fn new() -> Result<PollPoller> {
        let wake_rx = UdpSocket::bind("127.0.0.1:0").context("bind wake socket")?;
        wake_rx.set_nonblocking(true)?;
        let wake_tx = UdpSocket::bind("127.0.0.1:0").context("bind wake sender")?;
        wake_tx.connect(wake_rx.local_addr()?).context("connect wake pair")?;
        Ok(PollPoller {
            fds: BTreeMap::new(),
            wake_rx,
            waker: Waker::Udp {
                sock: Arc::new(wake_tx),
                pending: Arc::new(AtomicBool::new(false)),
            },
        })
    }
}

#[cfg(unix)]
impl Poller for PollPoller {
    fn register(&mut self, token: Token, fd: RawFd, interest: Interest) {
        self.fds.insert(token, (fd, interest));
    }

    fn set_interest(&mut self, token: Token, interest: Interest) {
        if let Some(entry) = self.fds.get_mut(&token) {
            entry.1 = interest;
        }
    }

    fn deregister(&mut self, token: Token) {
        self.fds.remove(&token);
    }

    fn poll(
        &mut self,
        events: &mut Vec<(Token, Readiness)>,
        timeout: Option<Duration>,
    ) -> Result<()> {
        use std::os::fd::AsRawFd;
        events.clear();
        let mut pollfds: Vec<sys::PollFd> = Vec::with_capacity(self.fds.len() + 1);
        pollfds.push(sys::PollFd {
            fd: self.wake_rx.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        });
        let mut tokens: Vec<Token> = Vec::with_capacity(self.fds.len());
        for (&token, &(fd, interest)) in &self.fds {
            let mut ev = 0i16;
            if interest.readable {
                ev |= sys::POLLIN;
            }
            if interest.writable {
                ev |= sys::POLLOUT;
            }
            pollfds.push(sys::PollFd { fd, events: ev, revents: 0 });
            tokens.push(token);
        }
        let timeout_ms: std::os::raw::c_int = match timeout {
            None => -1,
            Some(d) => d.as_millis().min(i32::MAX as u128) as i32,
        };
        let rc = unsafe {
            sys::poll(
                pollfds.as_mut_ptr(),
                pollfds.len() as std::os::raw::c_ulong,
                timeout_ms,
            )
        };
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                // A stray signal: report an empty batch, caller re-polls.
                return Ok(());
            }
            return Err(err).context("poll(2)");
        }
        // Drain the wake pair first so the next wake() sends a fresh byte.
        if pollfds[0].revents & sys::POLLIN != 0 {
            let mut byte = [0u8; 8];
            while self.wake_rx.recv(&mut byte).is_ok() {}
            if let Waker::Udp { pending, .. } = &self.waker {
                pending.store(false, Ordering::Release);
            }
        }
        for (pfd, &token) in pollfds[1..].iter().zip(&tokens) {
            if pfd.revents == 0 {
                continue;
            }
            events.push((
                token,
                Readiness {
                    readable: pfd.revents & sys::POLLIN != 0,
                    writable: pfd.revents & sys::POLLOUT != 0,
                    error: pfd.revents & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0,
                },
            ));
        }
        Ok(())
    }

    fn waker(&self) -> Waker {
        self.waker.clone()
    }
}

/// Deterministic poller for tests: replays a scripted sequence of
/// readiness batches, never touches an fd, never blocks. Registrations
/// are tracked (so a test can assert interest transitions), a wake
/// observed between polls injects an empty batch ahead of the script
/// (exactly the production contract: a pure wake delivers no events),
/// and an exhausted script keeps returning empty batches.
pub struct ScriptedPoller {
    script: std::collections::VecDeque<Vec<(Token, Readiness)>>,
    /// Registration table, public so tests can assert on it.
    pub registered: BTreeMap<Token, Interest>,
    woken: Arc<AtomicBool>,
}

impl ScriptedPoller {
    pub fn new(script: Vec<Vec<(Token, Readiness)>>) -> ScriptedPoller {
        ScriptedPoller {
            script: script.into(),
            registered: BTreeMap::new(),
            woken: Arc::new(AtomicBool::new(false)),
        }
    }
}

impl Poller for ScriptedPoller {
    fn register(&mut self, token: Token, _fd: RawFd, interest: Interest) {
        self.registered.insert(token, interest);
    }

    fn set_interest(&mut self, token: Token, interest: Interest) {
        if let Some(i) = self.registered.get_mut(&token) {
            *i = interest;
        }
    }

    fn deregister(&mut self, token: Token) {
        self.registered.remove(&token);
    }

    fn poll(
        &mut self,
        events: &mut Vec<(Token, Readiness)>,
        _timeout: Option<Duration>,
    ) -> Result<()> {
        events.clear();
        if self.woken.swap(false, Ordering::AcqRel) {
            return Ok(()); // a wake: empty batch, script untouched
        }
        if let Some(batch) = self.script.pop_front() {
            // Only deliver events for tokens still registered — a
            // deregistered connection must never come back readable.
            events.extend(batch.into_iter().filter(|(t, _)| self.registered.contains_key(t)));
        }
        Ok(())
    }

    fn waker(&self) -> Waker {
        Waker::Flag(self.woken.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn poll_reports_readable_after_write() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let mut tx = TcpStream::connect(addr).expect("connect");
        let (rx, _) = listener.accept().expect("accept");
        rx.set_nonblocking(true).expect("nonblocking");

        let mut poller = PollPoller::new().expect("poller");
        poller.register(7, rx.as_raw_fd(), Interest::READ);
        let mut events = Vec::new();

        // Nothing pending: a zero timeout returns an empty batch.
        poller.poll(&mut events, Some(Duration::from_millis(0))).expect("poll");
        assert!(events.is_empty(), "spurious readiness: {events:?}");

        tx.write_all(b"ping").expect("write");
        poller.poll(&mut events, Some(Duration::from_secs(5))).expect("poll");
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].0, 7);
        assert!(events[0].1.readable);

        // Deregistered fds never surface again, however ready.
        poller.deregister(7);
        poller.poll(&mut events, Some(Duration::from_millis(0))).expect("poll");
        assert!(events.is_empty());
        let mut sink = [0u8; 8];
        let mut rx = rx;
        let _ = rx.read(&mut sink);
    }

    #[test]
    fn waker_interrupts_a_blocked_poll_and_coalesces() {
        let mut poller = PollPoller::new().expect("poller");
        let waker = poller.waker();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            // Many wakes from another thread: at most one byte flies.
            for _ in 0..64 {
                waker.wake();
            }
        });
        let mut events = Vec::new();
        // Blocks until the waker fires (5 s is the failure backstop).
        poller.poll(&mut events, Some(Duration::from_secs(5))).expect("poll");
        assert!(events.is_empty(), "a pure wake has no events");
        handle.join().expect("join");
        // The wake was drained: the next zero-timeout poll is quiet.
        poller.poll(&mut events, Some(Duration::from_millis(0))).expect("poll");
        assert!(events.is_empty());
        // And the waker works again after the drain (flag was reset).
        poller.waker().wake();
        poller.poll(&mut events, Some(Duration::from_secs(5))).expect("poll");
        assert!(events.is_empty());
    }

    #[test]
    fn poll_reports_writable_when_asked() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let tx = TcpStream::connect(addr).expect("connect");
        tx.set_nonblocking(true).expect("nonblocking");
        let mut poller = PollPoller::new().expect("poller");
        // Read-only interest on an idle socket: quiet.
        poller.register(1, tx.as_raw_fd(), Interest::READ);
        let mut events = Vec::new();
        poller.poll(&mut events, Some(Duration::from_millis(0))).expect("poll");
        assert!(events.is_empty());
        // Add write interest: an empty socket buffer is instantly writable.
        poller.set_interest(1, Interest::READ_WRITE);
        poller.poll(&mut events, Some(Duration::from_secs(5))).expect("poll");
        assert_eq!(events.len(), 1);
        assert!(events[0].1.writable);
        assert!(!events[0].1.readable);
    }

    #[test]
    fn scripted_poller_replays_batches_and_respects_wakes() {
        let mut p = ScriptedPoller::new(vec![
            vec![(1, Readiness { readable: true, ..Default::default() })],
            vec![
                (1, Readiness { readable: true, ..Default::default() }),
                (2, Readiness { writable: true, ..Default::default() }),
            ],
        ]);
        p.register(1, 0, Interest::READ);
        p.register(2, 0, Interest::READ_WRITE);
        let waker = p.waker();
        let mut events = Vec::new();

        p.poll(&mut events, None).expect("poll");
        assert_eq!(events, vec![(1, Readiness { readable: true, ..Default::default() })]);

        // A wake injects an empty batch *before* the script continues.
        waker.wake();
        p.poll(&mut events, None).expect("poll");
        assert!(events.is_empty());

        // Deregistering filters scripted events for that token.
        p.deregister(2);
        p.poll(&mut events, None).expect("poll");
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].0, 1);

        // Script exhausted: quiet forever.
        p.poll(&mut events, None).expect("poll");
        assert!(events.is_empty());
    }
}
