//! Real TCP cluster runtime (std::net + threads; Python is never on this
//! path — the Tempo state machine runs exactly as in the simulator, fed by
//! length-prefixed frames from peer sockets).
//!
//! Topology: one [`NodeHandle`] per process, full mesh of TCP connections,
//! plus a *client plane*: real clients ([`TcpClient`]) dial any node,
//! send `ClientSubmit` frames (docs/WIRE.md tag 17) and receive
//! `ClientReply` frames (tag 18) — request/response over the same
//! listener, distinguished by the frame header's sender field
//! ([`CLIENT_FROM`]). Each node runs (a) a poll-based acceptor thread,
//! (b) `Config::client_event_threads` **client event loops** (see
//! below), (c) **one protocol thread per worker slot**
//! (`Config::workers`, `protocol::common::shard`): each owns its own
//! Tempo instance over the keys that hash to it, its own
//! [`Executor`]/KV partition and its own rid→reply routing table, and
//! (d) a tick timer fanning ticks to every worker. Peer frames travel
//! inside the worker-routed envelope (docs/WIRE.md tag 19), so frames
//! route by the envelope tag and client submits route by key hash — the
//! monolithic deployment is simply `workers == 1`.
//!
//! **Client edge (event loops, not threads).** Inbound connections are
//! handed round-robin to a fixed pool of event-loop threads
//! (`net::poll`: a hand-rolled `poll(2)` shim behind the [`poll::Poller`]
//! trait — no `libc` crate, no async runtime). Each loop multiplexes
//! many nonblocking sessions: reads run through an incremental frame
//! decoder over the pooled buffer machinery (`wire::FrameDecoder`),
//! replies queue per connection and flush as **one vectored write per
//! wakeup**, and a bounded per-session in-flight window
//! (`Config::max_inflight_per_session`) sheds overload at the edge with
//! an explicit `ClientBusy` frame (tag 25) instead of queueing
//! unboundedly. A connection whose first frame is *not* client-plane (a
//! peer or a state-transfer dial) is handed off to a dedicated blocking
//! thread — the peer plane keeps its thread-per-connection model, which
//! is right for a full mesh of long-lived firehose links. Connection
//! count therefore costs file descriptors, not threads;
//! `Counters::{client_connections, client_wakeups, client_replies,
//! client_flushes, busy_shed}` make the edge observable.
//!
//! **Send path (encode-once + per-peer frame merging).** A protocol
//! step's outbound actions are lowered to bytes exactly once: a
//! point-to-point `Action::Send` encodes into a pooled buffer
//! (`wire::FrameBuf`, recycled after the write), and a broadcast
//! `Action::SendShared` is serialized a **single time** into an
//! `Arc<[u8]>` body shared by every destination (`Action::SendBytes` —
//! the fan-out cost the paper amortizes is paid once, not per peer).
//! Below the per-slot batchers sits a **per-peer outbound stage**: one
//! writer thread per peer drains a channel of encoded frames and, when
//! several are pending (typically the ≤ `Config::workers` per-slot
//! `MBatch` flushes of one tick), coalesces them into a single merged
//! wire frame (`wire::TAG_MERGED`) written with one vectored syscall of
//! `[len-prefix, shared bodies…]` — no re-encoding, no copying of the
//! bodies. `Counters::{bytes_sent, frames_merged, pooled_hits}` make
//! the path observable.
//!
//! With `Config::batch_max_msgs > 0` each worker's protocol layer
//! additionally coalesces the messages bound for one peer into single
//! `MBatch` frames (`protocol::common::batch`); the frame merger then
//! restores the one-frame-per-(peer, tick) send that per-worker
//! batchers alone cannot provide. Frame layout and limits are
//! documented in `docs/WIRE.md`.

pub mod poll;
pub mod wire;

use crate::client::{Session, BUSY_ERROR_PREFIX};
use crate::core::{
    ClientId, Command, Config, Key, Op, ProcessId, Response, Rid, StorageMode,
};
use crate::executor::Executor;
use crate::metrics::Counters;
use crate::protocol::common::shard::worker_of_cmd;
use crate::protocol::tempo::msg::Msg;
use crate::protocol::tempo::Tempo;
use crate::protocol::{Action, Protocol, RESTART_DOT_SLACK};
use crate::store::storage::{assemble, plan_transfer, Durable, FileBackend, Manifest};
use crate::store::{merkle_root, KvStore};
use crate::util::error::{bail, Context, Error, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sender field of frames on the client plane: a connection whose frames
/// carry this value is a client session, not a protocol peer (no real
/// `ProcessId` can collide — process ids are dense and small).
pub const CLIENT_FROM: u32 = u32::MAX;

/// Sender field of frames on the state-transfer plane (docs/WIRE.md tags
/// 22–24): a recovering replica dialing a donor. Like [`CLIENT_FROM`], no
/// real `ProcessId` can collide with it.
pub const TRANSFER_FROM: u32 = u32::MAX - 1;

/// Events fed to one worker's protocol thread.
enum Event {
    Message { from: ProcessId, msg: Msg },
    /// A client submission; `floor` is the session's read-your-writes
    /// floor (consumed by `Protocol::submit_read`, 0 for writes).
    Submit { cmd: Command, floor: u64, done: Done },
    /// A state-transfer connection asks for this slot's current manifest
    /// and pages (served from the worker's executor so the snapshot is
    /// taken between protocol steps, never mid-execution).
    Manifest { done: Sender<(Manifest, Vec<Vec<u8>>)> },
    Tick,
    /// The node's failure detector reports `suspected` silent past
    /// `Config::suspect_delay_us`: forwarded to `Protocol::suspect`,
    /// which feeds the epoch eviction vote exactly like the simulator's
    /// nemesis — but here the suspicion came from real heartbeat
    /// silence, with no harness involved.
    Suspect { suspected: ProcessId },
    Shutdown,
}

/// Commands fed to one client event loop from outside its thread
/// (always paired with a [`poll::Waker::wake`] so a sleeping loop
/// notices).
enum LoopCmd {
    /// A freshly-accepted connection, plane still unknown — the loop
    /// reads its first frame to find out (client stays, peer/transfer
    /// hands off to a blocking thread).
    Conn(TcpStream),
    /// A completed request bound for the session at `token`.
    Reply { token: poll::Token, rid: Rid, response: Response, ts: u64 },
}

/// Completion route of one in-flight client request: how the owning
/// worker's `Action::Reply` travels back to the session that submitted.
enum Done {
    /// In-process submission ([`NodeHandle::submit`]): a plain channel.
    Chan(Sender<(Rid, Response, u64)>),
    /// A session multiplexed on a client event loop: the reply is queued
    /// on the loop's command channel and the loop is woken to encode and
    /// flush it (batched with whatever else that wakeup finds).
    Loop { token: poll::Token, tx: Sender<LoopCmd>, waker: poll::Waker },
}

impl Done {
    fn complete(self, rid: Rid, response: Response, ts: u64) {
        match self {
            Done::Chan(tx) => {
                let _ = tx.send((rid, response, ts));
            }
            Done::Loop { token, tx, waker } => {
                if tx.send(LoopCmd::Reply { token, rid, response, ts }).is_ok() {
                    waker.wake();
                }
            }
        }
    }
}

/// A completion listener registered per in-flight request id; completions
/// carry the command's decided timestamp (`Action::Reply::ts`).
type DoneMap = HashMap<Rid, Done>;

/// Per-worker observability shared with the [`NodeHandle`].
#[derive(Default)]
struct WorkerStats {
    counters: Counters,
    executed: u64,
    digest: u64,
}

/// Handle to a running node.
pub struct NodeHandle {
    pub id: ProcessId,
    /// One event channel per worker slot.
    events: Vec<Sender<Event>>,
    workers: usize,
    threads: Vec<JoinHandle<()>>,
    /// Stop flag observed by the acceptor and every client event loop;
    /// `shutdown` raises it and fires `wakers` — no self-dial, no
    /// leaked socket on a shutdown race.
    closing: Arc<AtomicBool>,
    /// Wake handles of the acceptor's poller and each client event
    /// loop's poller, fired on shutdown to unblock their `poll`s.
    wakers: Vec<poll::Waker>,
    /// One independently-locked stats slot per worker: each protocol
    /// thread writes only its own slot, so the shared-nothing workers
    /// never contend on observability.
    stats: Vec<Arc<Mutex<WorkerStats>>>,
    /// Byte-level send-path stats, written by the per-peer writers.
    net: Arc<NetStats>,
    /// Heartbeat/suspicion state shared with the peer read paths and
    /// the sweeper thread.
    detector: Arc<FailureDetector>,
}

impl NodeHandle {
    /// Submit a command from an in-process client session; the response
    /// (with its decided timestamp) arrives on the returned receiver once
    /// the command executes at this node (the owning worker's executor
    /// emits `Action::Reply`).
    pub fn submit(&self, cmd: Command) -> Receiver<(Rid, Response, u64)> {
        self.submit_with_floor(cmd, 0)
    }

    /// [`NodeHandle::submit`] with an explicit read-your-writes floor: a
    /// read is released only once the stability frontier covers `floor`
    /// (`Protocol::submit_read`); writes ignore it.
    pub fn submit_with_floor(&self, cmd: Command, floor: u64) -> Receiver<(Rid, Response, u64)> {
        let (tx, rx) = channel();
        let w = worker_of_cmd(&cmd, self.workers)
            .unwrap_or_else(|(a, b)| panic!("command spans worker slots {a} and {b}"));
        let _ = self.events[w].send(Event::Submit { cmd, floor, done: Done::Chan(tx) });
        rx
    }

    /// Merged protocol counters across the node's worker slots, plus the
    /// node's byte-level send-path counters (`bytes_sent`,
    /// `frames_merged`) and the frame pool's hit count (`pooled_hits` —
    /// process-wide, like the pool itself).
    pub fn counters(&self) -> Counters {
        let mut c = Counters::default();
        for slot in &self.stats {
            c.merge(&slot.lock().unwrap().counters);
        }
        c.bytes_sent = self.net.bytes_sent.load(Ordering::Relaxed);
        c.frames_merged = self.net.frames_merged.load(Ordering::Relaxed);
        c.pooled_hits = wire::pool_stats::hits();
        c.client_connections = self.net.client_connections.load(Ordering::Relaxed);
        c.client_wakeups = self.net.client_wakeups.load(Ordering::Relaxed);
        c.client_replies = self.net.client_replies.load(Ordering::Relaxed);
        c.client_flushes = self.net.client_flushes.load(Ordering::Relaxed);
        c.busy_shed = self.net.busy_shed.load(Ordering::Relaxed);
        c.heartbeats_sent = self.net.heartbeats_sent.load(Ordering::Relaxed);
        c.heartbeats_seen = self.detector.heartbeats_seen.load(Ordering::Relaxed);
        c.suspicions = self.detector.suspicions.load(Ordering::Relaxed);
        c
    }

    /// Wire frames this node actually wrote to peers (a merged frame
    /// counts once) — with `counters().frames_merged` this gives the
    /// mean members-per-frame of the outbound merger.
    pub fn wire_frames(&self) -> u64 {
        self.net.wire_frames.load(Ordering::Relaxed)
    }

    /// Commands executed across all worker slots.
    pub fn executed(&self) -> u64 {
        self.stats.iter().map(|s| s.lock().unwrap().executed).sum()
    }

    /// Per-worker-slot KV partition digests — the Merkle leaves, in slot
    /// order. Two replicas that executed the same commands agree
    /// slot-wise; comparing leaf vectors localizes a divergence to the
    /// worker slot that caused it (`store::diverging_slots`).
    pub fn store_digests(&self) -> Vec<u64> {
        self.stats.iter().map(|s| s.lock().unwrap().digest).collect()
    }

    /// Combined store digest: the Merkle-style root over the per-slot
    /// partition digests (`store::merkle_root`). Equal roots ⇔ equal
    /// leaf vectors (unlike the old XOR, which a pair of compensating
    /// slot differences could fool), and an unequal root is localized by
    /// [`NodeHandle::store_digests`].
    pub fn store_digest(&self) -> u64 {
        merkle_root(&self.store_digests())
    }

    /// Stop the node: drain the protocol threads (each flushes its WAL),
    /// close the listener (the port is immediately rebindable, so a
    /// crash-restart can boot the node again on the same address), and
    /// join every thread the node owns. The acceptor and the client
    /// event loops are unblocked through their pollers' wake tokens —
    /// the old listener self-dial (and the socket it could leak on a
    /// shutdown race) is gone. Handlers of still-open peer connections
    /// exit on their next frame — their worker channels are gone —
    /// which severs the sockets and lets surviving peers notice.
    pub fn shutdown(self) {
        self.closing.store(true, Ordering::SeqCst);
        for tx in &self.events {
            let _ = tx.send(Event::Shutdown);
        }
        for waker in &self.wakers {
            waker.wake();
        }
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn write_frame(stream: &mut TcpStream, from: u32, body: &[u8]) -> Result<()> {
    let mut frame = Vec::with_capacity(body.len() + 8);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&from.to_le_bytes());
    frame.extend_from_slice(body);
    stream.write_all(&frame)?;
    Ok(())
}

/// Upper bound on one frame body (`docs/WIRE.md`): a corrupt or hostile
/// length header must not make a node allocate gigabytes before the codec
/// ever sees the bytes. The sender side cooperates: the batching layer
/// flushes a destination queue at `BATCH_SOFT_MAX_BYTES` (4 MiB of
/// estimated encoding, `protocol::common::batch`), keeping legitimate
/// `MBatch` frames far below this cap, and the per-peer frame merger
/// stops adding members before a merged frame would cross it.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Read one raw frame into `buf` — a pooled, per-connection buffer that
/// is **reused across frames** instead of allocated per frame. Returns
/// the sender field; the body is `buf`'s contents. The caller decodes as
/// a routed protocol message (or a merged frame of them) or a client
/// frame depending on the sender ([`CLIENT_FROM`] marks the client
/// plane). A frame that fits in the buffer's existing capacity counts as
/// a pool hit (steady state: every frame after warm-up). Generic over
/// the reader so the equivalence tests can drive it from an in-memory
/// cursor; `wire::FrameDecoder` is the nonblocking twin of this
/// function, and property tests pin the two to identical results.
fn read_frame<R: Read>(stream: &mut R, buf: &mut Vec<u8>) -> Result<u32> {
    let mut hdr = [0u8; 8];
    stream.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})");
    }
    let from = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    if buf.capacity() >= len {
        wire::pool_stats::hit();
    } else {
        wire::pool_stats::miss();
    }
    buf.clear();
    buf.resize(len, 0);
    stream.read_exact(buf)?;
    Ok(from)
}

/// Per-node observability of the byte-level send path, shared between
/// the per-peer writer threads and the [`NodeHandle`].
#[derive(Default)]
struct NetStats {
    /// Bytes written to peer sockets, frame headers included.
    bytes_sent: AtomicU64,
    /// Wire frames actually written (merged frames count once).
    wire_frames: AtomicU64,
    /// Frames coalesced away by merging: a merged frame of `k` members
    /// adds `k - 1`.
    frames_merged: AtomicU64,
    /// Client connections accepted onto the event-loop plane.
    client_connections: AtomicU64,
    /// Event-loop poll returns (readiness, reply batches, or wakes).
    client_wakeups: AtomicU64,
    /// Client-plane frames fully written to sessions (replies + busy).
    client_replies: AtomicU64,
    /// Vectored flushes of per-connection reply queues; replies ÷
    /// flushes > 1 ⇔ the loop batched replies per wakeup.
    client_flushes: AtomicU64,
    /// Submits shed at the edge with an explicit `ClientBusy` reply.
    busy_shed: AtomicU64,
    /// Heartbeat frames written to idle peer links (transport plane —
    /// deliberately excluded from `bytes_sent`/`wire_frames`, so the
    /// protocol byte accounting the benches gate on is unchanged by
    /// the failure detector).
    heartbeats_sent: AtomicU64,
}

/// Heartbeat-driven failure detector state, shared by the peer read
/// paths (any frame from a peer refreshes its last-seen time), the
/// per-peer writers (which keep idle links warm with tag-26 heartbeat
/// frames every `Config::heartbeat_interval_us`), and the sweeper
/// thread (which turns `Config::suspect_delay_us` of silence into
/// `Protocol::suspect` calls at every worker).
///
/// Suspicion is **sticky**: a peer is reported once per node lifetime.
/// That matches the one-way epoch eviction vote it drives — a replica
/// that was evicted and restarts rejoins through state transfer under
/// its recovered identity, it is never "un-suspected".
struct FailureDetector {
    /// Micros since detector start a frame was last seen from each
    /// peer; 0 = never observed (armed at the first sweep, so silence
    /// is measured from detector start, not from the epoch of time).
    last_seen: Vec<AtomicU64>,
    /// Peers already reported as suspected.
    reported: Vec<AtomicBool>,
    start: Instant,
    /// Heartbeat frames consumed off peer links (observability).
    heartbeats_seen: AtomicU64,
    /// Peers reported suspected (observability; == set bits of
    /// `reported`).
    suspicions: AtomicU64,
}

impl FailureDetector {
    fn new(n: usize) -> Self {
        FailureDetector {
            last_seen: (0..n).map(|_| AtomicU64::new(0)).collect(),
            reported: (0..n).map(|_| AtomicBool::new(false)).collect(),
            start: Instant::now(),
            heartbeats_seen: AtomicU64::new(0),
            suspicions: AtomicU64::new(0),
        }
    }

    /// Monotonic micros since detector start, never 0 (0 is the
    /// "never observed" sentinel in `last_seen`).
    fn now_us(&self) -> u64 {
        (self.start.elapsed().as_micros() as u64).max(1)
    }

    /// Record live contact with peer `from` (any frame counts — a peer
    /// pushing protocol traffic needs no separate heartbeats to stay
    /// unsuspected). Out-of-range senders ([`CLIENT_FROM`],
    /// [`TRANSFER_FROM`], hostile values) are ignored.
    fn saw(&self, from: u32) {
        if let Some(slot) = self.last_seen.get(from as usize) {
            slot.store(self.now_us(), Ordering::Relaxed);
        }
    }

    /// One sweep: return the peers silent for at least `delay_us` that
    /// have not been reported yet, marking them reported. Peers never
    /// heard from are armed with the sweep time instead — boot counts
    /// as contact, so a slow-to-dial peer is not insta-suspected.
    fn sweep(&self, me: ProcessId, delay_us: u64) -> Vec<ProcessId> {
        let now = self.now_us();
        let mut out = Vec::new();
        for (j, slot) in self.last_seen.iter().enumerate() {
            let p = ProcessId(j as u32);
            if p == me || self.reported[j].load(Ordering::Relaxed) {
                continue;
            }
            let seen = slot.load(Ordering::Relaxed);
            if seen == 0 {
                let _ = slot.compare_exchange(0, now, Ordering::Relaxed, Ordering::Relaxed);
                continue;
            }
            if now.saturating_sub(seen) >= delay_us {
                self.reported[j].store(true, Ordering::Relaxed);
                self.suspicions.fetch_add(1, Ordering::Relaxed);
                out.push(p);
            }
        }
        out
    }
}

/// Bound on frames queued per peer writer. The channel is *bounded* on
/// purpose: the pre-merger send path blocked on the shared peer socket,
/// so a slow-but-alive peer throttled its senders (TCP backpressure).
/// The queue keeps that property — senders block once a peer falls this
/// far behind — while still giving the merger a window to coalesce.
const PEER_QUEUE_FRAMES: usize = 1024;

/// One encoded frame queued for a peer's writer thread.
enum OutFrame {
    /// Encode-once broadcast body, shared (`Arc`) by every destination
    /// of the fan-out.
    Shared(Arc<[u8]>),
    /// Exclusively-owned pooled body (point-to-point send); the writer
    /// recycles it after the bytes leave the process.
    Owned(wire::FrameBuf),
}

impl OutFrame {
    fn bytes(&self) -> &[u8] {
        match self {
            OutFrame::Shared(b) => b,
            OutFrame::Owned(b) => b.bytes(),
        }
    }
}

/// Lower a typed fan-out to the encode-once byte path: serialize the
/// routed frame a **single time** and emit one [`Action::SendBytes`] per
/// destination, all sharing the same body.
pub fn encode_fanout(worker: u32, to: Vec<ProcessId>, msg: &Msg) -> Vec<Action<Msg>> {
    let body = wire::encode_routed_shared(worker, msg);
    to.into_iter().map(|dest| Action::SendBytes { to: dest, body: body.clone() }).collect()
}

/// Write every part of a logically-contiguous frame with vectored
/// writes, advancing across partial writes (the stable-toolchain spelling
/// of `write_all_vectored`). Retries `ErrorKind::Interrupted` like
/// `write_all` does — a stray signal must not sever the connection.
fn write_all_vectored<W: Write>(w: &mut W, parts: &[&[u8]]) -> Result<()> {
    let mut idx = 0; // first incomplete part
    let mut off = 0; // bytes of parts[idx] already written
    while idx < parts.len() {
        if parts[idx].len() == off {
            idx += 1;
            off = 0;
            continue;
        }
        let mut slices = Vec::with_capacity(parts.len() - idx);
        slices.push(IoSlice::new(&parts[idx][off..]));
        for p in &parts[idx + 1..] {
            slices.push(IoSlice::new(p));
        }
        let mut n = match w.write_vectored(&slices) {
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        if n == 0 {
            bail!("socket closed mid-frame");
        }
        while idx < parts.len() && n > 0 {
            let rem = parts[idx].len() - off;
            if n >= rem {
                n -= rem;
                idx += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

/// Write one merged wire frame — `[len][from][TAG_MERGED][n][len_i,
/// body_i…]` — as a single vectored write: the member-length prefixes
/// live in `scratch` (reused across calls) and the bodies are referenced
/// in place, never copied or re-encoded. Produces exactly the bytes of
/// `wire::encode_merged` behind the transport header (pinned by a unit
/// test below). Returns the total bytes written.
fn write_merged_frame<W: Write>(
    w: &mut W,
    from: u32,
    bodies: &[&[u8]],
    scratch: &mut Vec<u8>,
) -> Result<usize> {
    let body_len = 3 + bodies.iter().map(|b| 4 + b.len()).sum::<usize>();
    scratch.clear();
    scratch.extend_from_slice(&(body_len as u32).to_le_bytes());
    scratch.extend_from_slice(&from.to_le_bytes());
    scratch.push(wire::TAG_MERGED);
    scratch.extend_from_slice(&(bodies.len() as u16).to_le_bytes());
    for b in bodies {
        scratch.extend_from_slice(&(b.len() as u32).to_le_bytes());
    }
    // Scatter list: [hdr + tag + count + len_0], body_0, [len_1],
    // body_1, … — the len_i prefixes are consecutive 4-byte windows of
    // `scratch` starting at offset 11.
    let mut parts: Vec<&[u8]> = Vec::with_capacity(2 * bodies.len());
    parts.push(&scratch[0..11 + 4]);
    for (i, b) in bodies.iter().copied().enumerate() {
        if i > 0 {
            parts.push(&scratch[11 + 4 * i..11 + 4 * (i + 1)]);
        }
        parts.push(b);
    }
    write_all_vectored(w, &parts)?;
    Ok(8 + body_len)
}

/// Gather one flush batch for a peer writer: `first` plus whatever else
/// can join it. With `wait == 0` (`Config::merge_wait_us` default) this
/// is the opportunistic drain — only frames *already* queued are taken,
/// byte-identical to the behaviour before the knob existed (pinned by a
/// unit test below). A positive `wait` lets the writer block up to that
/// long for more frames, raising members per merged frame at a bounded
/// latency cost. Stops at `u16::MAX` members (the merged-frame count
/// field) or when the next frame would push the merged body past
/// `MAX_FRAME_BYTES` — that frame goes to `carry` and leads the next
/// flush.
fn collect_flush(
    rx: &Receiver<OutFrame>,
    first: OutFrame,
    wait: Duration,
    carry: &mut Option<OutFrame>,
) -> Vec<OutFrame> {
    let mut batch = vec![first];
    let mut body_len = 3 + 4 + batch[0].bytes().len();
    let deadline = if wait.is_zero() { None } else { Some(Instant::now() + wait) };
    while batch.len() < u16::MAX as usize {
        let next = match rx.try_recv() {
            Ok(f) => Some(f),
            Err(TryRecvError::Disconnected) => None,
            Err(TryRecvError::Empty) => match deadline {
                None => None,
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        None
                    } else {
                        rx.recv_timeout(d - now).ok()
                    }
                }
            },
        };
        let Some(f) = next else { break };
        let add = 4 + f.bytes().len();
        if body_len + add > MAX_FRAME_BYTES {
            *carry = Some(f); // flush what we have first
            break;
        }
        body_len += add;
        batch.push(f);
    }
    batch
}

/// The per-peer outbound stage: drain encoded frames bound for one peer
/// and put them on the wire, merging everything immediately available
/// (typically the ≤ `workers` per-slot `MBatch` flushes of one tick)
/// into a single merged frame per write. Exits when every sender hung up
/// (node shutdown). A dead peer drops its traffic, but the writer
/// **redials once per flush** — so a killed-and-restarted replica
/// (crash-recovery fault model) rejoins the mesh without the survivors
/// restarting; the frames lost while it was down are covered by the
/// protocol retry timer and client failover. With a nonzero `heartbeat`
/// interval the writer additionally emits a one-byte heartbeat frame
/// (docs/WIRE.md tag 26) whenever the link sits idle that long — the
/// sender half of the failure detector ([`FailureDetector`]).
fn peer_writer(
    stream: TcpStream,
    addr: String,
    rx: Receiver<OutFrame>,
    from: u32,
    merge_wait: Duration,
    heartbeat: Duration,
    stats: Arc<NetStats>,
) {
    let mut scratch: Vec<u8> = Vec::with_capacity(256);
    let mut carry: Option<OutFrame> = None;
    let mut stream: Option<TcpStream> = Some(stream);
    loop {
        let first = match carry.take() {
            Some(f) => f,
            None if heartbeat.is_zero() => match rx.recv() {
                Ok(f) => f,
                Err(_) => return,
            },
            None => match rx.recv_timeout(heartbeat) {
                Ok(f) => f,
                Err(RecvTimeoutError::Disconnected) => return,
                Err(RecvTimeoutError::Timeout) => {
                    // The link has been idle a full heartbeat interval:
                    // keep it warm with a one-byte heartbeat frame
                    // (docs/WIRE.md tag 26) so the peer's failure
                    // detector keeps seeing us, redialing first if the
                    // link is down. Heartbeats are transport-plane
                    // traffic and excluded from the send-path byte
                    // counters.
                    if stream.is_none() {
                        if let Ok(s) = TcpStream::connect(&addr) {
                            let _ = s.set_nodelay(true);
                            stream = Some(s);
                        }
                    }
                    if let Some(s) = stream.as_mut() {
                        match write_frame(s, from, &[wire::TAG_HEARTBEAT]) {
                            Ok(()) => {
                                stats.heartbeats_sent.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => stream = None,
                        }
                    }
                    continue;
                }
            },
        };
        let batch = collect_flush(&rx, first, merge_wait, &mut carry);
        if stream.is_none() {
            // The peer died earlier: one redial attempt per flush (on a
            // LAN a dead peer refuses instantly). Until it answers, its
            // traffic is dropped, exactly as before.
            if let Ok(s) = TcpStream::connect(&addr) {
                let _ = s.set_nodelay(true);
                stream = Some(s);
            }
        }
        let wrote = match stream.as_mut() {
            // 0 is unambiguous for "dropped": a real write is ≥ 9 bytes.
            None => Ok(0),
            Some(s) => {
                if batch.len() == 1 {
                    // A lone frame goes out unmerged: [len][from][body].
                    let body = batch[0].bytes();
                    let mut hdr = [0u8; 8];
                    hdr[0..4].copy_from_slice(&(body.len() as u32).to_le_bytes());
                    hdr[4..8].copy_from_slice(&from.to_le_bytes());
                    write_all_vectored(s, &[&hdr[..], body]).map(|()| 8 + body.len())
                } else {
                    let bodies: Vec<&[u8]> = batch.iter().map(|f| f.bytes()).collect();
                    stats.frames_merged.fetch_add(bodies.len() as u64 - 1, Ordering::Relaxed);
                    write_merged_frame(s, from, &bodies, &mut scratch)
                }
            }
        };
        for f in batch {
            if let OutFrame::Owned(b) = f {
                b.recycle();
            }
        }
        match wrote {
            Ok(0) => {} // peer down, traffic dropped
            Ok(n) => {
                stats.bytes_sent.fetch_add(n as u64, Ordering::Relaxed);
                stats.wire_frames.fetch_add(1, Ordering::Relaxed);
            }
            // A write error severs the connection; redial next flush.
            Err(_) => stream = None,
        }
    }
}

/// Route one decoded routed frame to its worker slot. `Err` drops the
/// connection (hostile/mismatched deployment or shutdown).
fn route_peer_frame(
    txs: &[Sender<Event>],
    from: ProcessId,
    routed: crate::protocol::common::shard::Routed<Msg>,
) -> std::result::Result<(), ()> {
    let w = routed.worker as usize;
    if w >= txs.len() {
        return Err(());
    }
    txs[w].send(Event::Message { from, msg: routed.msg }).map_err(|_| ())
}

/// Handle one frame of a **non-client** connection (peer or transfer
/// plane): routed protocol frames (bare or merged) go to the worker slot
/// named by their envelope; transfer requests round-trip through the
/// slot's worker. Returns `false` to drop the connection (hostile or
/// cross-plane input, a dead worker channel, or a dead socket).
/// `transfer_pages` caches pages per slot so a transfer costs the worker
/// a single `Manifest` event no matter how many pages move.
fn handle_nonclient_frame(
    stream: &mut TcpStream,
    node: ProcessId,
    txs: &[Sender<Event>],
    from: u32,
    body: &[u8],
    transfer_pages: &mut HashMap<u32, HashMap<u64, Vec<u8>>>,
    det: &FailureDetector,
) -> bool {
    let workers = txs.len();
    if from == CLIENT_FROM {
        // Client frames never reach the blocking plane — the event loop
        // keeps client sessions; one arriving here is hostile.
        return false;
    }
    if from == TRANSFER_FROM {
        return match wire::decode_transfer(body) {
            Ok(wire::TransferFrame::ManifestRequest { slot }) => {
                if slot as usize >= workers {
                    return false;
                }
                let (txm, rxm) = channel();
                if txs[slot as usize].send(Event::Manifest { done: txm }).is_err() {
                    return false;
                }
                let (manifest, pages) = match rxm.recv() {
                    Ok(v) => v,
                    Err(_) => return false,
                };
                let reply = wire::TransferFrame::ManifestReply {
                    slot,
                    applied: manifest.applied,
                    chunks: manifest.chunks.clone(),
                    dot_floors: manifest.dot_floors.clone(),
                    dedup: manifest.dedup.clone(),
                };
                transfer_pages
                    .insert(slot, manifest.chunks.iter().copied().zip(pages).collect());
                write_frame(stream, node.0, &wire::encode_transfer(&reply)).is_ok()
            }
            Ok(wire::TransferFrame::Chunk { slot, hash, present: false, .. }) => {
                let data = transfer_pages.get(&slot).and_then(|m| m.get(&hash)).cloned();
                let reply = wire::TransferFrame::Chunk {
                    slot,
                    hash,
                    present: data.is_some(),
                    data: data.unwrap_or_default(),
                };
                write_frame(stream, node.0, &wire::encode_transfer(&reply)).is_ok()
            }
            // A donor never receives replies; malformed input drops the
            // connection.
            Ok(_) | Err(_) => false,
        };
    }
    // Transport-plane liveness: any frame from a peer refreshes its
    // last-seen time, and a bare heartbeat body is consumed right here —
    // it never reaches the protocol codec (which rejects tag 26 on
    // every plane, pinned by the wire tests).
    det.saw(from);
    if body.first() == Some(&wire::TAG_HEARTBEAT) {
        // docs/WIRE.md: a heartbeat body is exactly the tag byte;
        // anything longer is malformed and drops the connection.
        if body.len() != 1 {
            return false;
        }
        det.heartbeats_seen.fetch_add(1, Ordering::Relaxed);
        return true;
    }
    if body.first() == Some(&wire::TAG_MERGED) {
        // The per-peer merger coalesced several routed frames into one
        // wire frame; route the members in wire order (per-slot FIFO is
        // preserved: a slot's frames enter the merge queue in send
        // order).
        let members = match wire::decode_merged(body) {
            Ok(m) => m,
            Err(_) => return false,
        };
        for routed in members {
            if route_peer_frame(txs, ProcessId(from), routed).is_err() {
                return false;
            }
        }
        true
    } else {
        let routed = match wire::decode_routed(body) {
            Ok(r) => r,
            Err(_) => return false,
        };
        route_peer_frame(txs, ProcessId(from), routed).is_ok()
    }
}

/// A peer or transfer connection identified by its first frame leaves
/// the event loop and gets what the peer plane always had: a dedicated
/// blocking thread (right for a full mesh of long-lived firehose links,
/// and for the strictly request/response transfer plane). `dec` arrives
/// holding the complete first frame; `leftover` is whatever the loop
/// read past it. The decoder keeps running here — over blocking reads —
/// so no byte is lost or reordered across the handoff.
fn serve_handoff(
    mut stream: TcpStream,
    node: ProcessId,
    txs: Vec<Sender<Event>>,
    mut dec: wire::FrameDecoder,
    leftover: Vec<u8>,
    det: Arc<FailureDetector>,
) {
    let mut transfer_pages: HashMap<u32, HashMap<u64, Vec<u8>>> = HashMap::new();
    if !handle_nonclient_frame(
        &mut stream,
        node,
        &txs,
        dec.sender(),
        dec.body(),
        &mut transfer_pages,
        &det,
    ) {
        dec.recycle();
        return;
    }
    dec.clear();
    let mut pending = leftover;
    let mut off = 0;
    let mut rbuf = vec![0u8; 16 << 10];
    loop {
        while off < pending.len() {
            let (used, done) = match dec.feed(&pending[off..]) {
                Ok(v) => v,
                Err(_) => {
                    dec.recycle();
                    return;
                }
            };
            off += used;
            if done {
                let keep = handle_nonclient_frame(
                    &mut stream,
                    node,
                    &txs,
                    dec.sender(),
                    dec.body(),
                    &mut transfer_pages,
                    &det,
                );
                dec.clear();
                if !keep {
                    dec.recycle();
                    return;
                }
            }
        }
        pending.clear();
        off = 0;
        match stream.read(&mut rbuf) {
            Ok(0) => {
                dec.recycle();
                return;
            }
            Ok(n) => pending.extend_from_slice(&rbuf[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                dec.recycle();
                return;
            }
        }
    }
}

/// One client session multiplexed on an event loop.
struct ClientConn {
    stream: TcpStream,
    /// Incremental frame decoder (pooled body buffer, reused across
    /// frames — the nonblocking twin of `read_frame`).
    dec: wire::FrameDecoder,
    /// Encoded transport frames awaiting flush; `out_off` is how much of
    /// the front frame already left the socket (partial vectored write).
    out: VecDeque<wire::FrameBuf>,
    out_off: usize,
    /// Submits forwarded to workers and not yet replied — the admission
    /// window (`Config::max_inflight_per_session`) is enforced on this.
    inflight: usize,
    /// Whether the first frame proved this is a client session (a
    /// non-client first frame hands the stream off instead).
    identified: bool,
    /// Current poller interest includes writability (tracked to avoid
    /// redundant `set_interest` calls).
    want_write: bool,
}

/// What servicing a connection's readiness decided.
enum ConnFate {
    Keep,
    /// Drop the connection (EOF, error, hostile input, or shutdown).
    Dead,
    /// First frame was peer/transfer plane: hand the stream (and the
    /// bytes read past the frame) to a blocking thread.
    Handoff(Vec<u8>),
}

/// Encode one client frame as a full transport frame —
/// `[len][from][body]` — into a pooled buffer queued on `conn.out`.
fn enqueue_client_frame(conn: &mut ClientConn, from: u32, frame: &wire::ClientFrame) {
    let mut fb = wire::FrameBuf::take();
    let body_len = wire::client_encoded_len(frame);
    let v = fb.vec();
    v.extend_from_slice(&(body_len as u32).to_le_bytes());
    v.extend_from_slice(&from.to_le_bytes());
    let mut w = wire::Writer::from_vec(std::mem::take(v));
    wire::encode_client_into(&mut w, frame);
    *fb.vec() = w.buf;
    conn.out.push_back(fb);
}

/// Flush `conn`'s outbound queue: every queued reply goes out in as few
/// vectored writes as possible (one, in the common case). Returns
/// `false` if the connection died. On `WouldBlock` the remainder stays
/// queued — the caller raises write interest and retries on the next
/// writable event.
fn flush_conn(conn: &mut ClientConn, stats: &NetStats) -> bool {
    while !conn.out.is_empty() {
        let mut slices: Vec<IoSlice> = Vec::with_capacity(conn.out.len().min(64));
        for (i, fb) in conn.out.iter().take(64).enumerate() {
            let b = fb.bytes();
            slices.push(IoSlice::new(if i == 0 { &b[conn.out_off..] } else { b }));
        }
        match conn.stream.write_vectored(&slices) {
            Ok(0) => return false,
            Ok(mut n) => {
                stats.client_flushes.fetch_add(1, Ordering::Relaxed);
                while n > 0 {
                    let front_rem = conn.out[0].bytes().len() - conn.out_off;
                    if n >= front_rem {
                        n -= front_rem;
                        conn.out_off = 0;
                        let fb = conn.out.pop_front().expect("front frame");
                        fb.recycle();
                        stats.client_replies.fetch_add(1, Ordering::Relaxed);
                    } else {
                        conn.out_off += n;
                        n = 0;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

/// Service one connection's read readiness: drain the socket through the
/// incremental decoder, identify the plane on the first frame, apply
/// admission control, and forward submits to their worker slots.
#[allow(clippy::too_many_arguments)]
fn service_readable(
    conn: &mut ClientConn,
    token: poll::Token,
    node: ProcessId,
    txs: &[Sender<Event>],
    max_inflight: usize,
    cmd_tx: &Sender<LoopCmd>,
    waker: &poll::Waker,
    stats: &NetStats,
    rbuf: &mut [u8],
) -> ConnFate {
    loop {
        let n = match conn.stream.read(rbuf) {
            Ok(0) => return ConnFate::Dead,
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return ConnFate::Keep,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return ConnFate::Dead,
        };
        let mut off = 0;
        while off < n {
            let (used, done) = match conn.dec.feed(&rbuf[off..n]) {
                Ok(v) => v,
                Err(_) => return ConnFate::Dead,
            };
            off += used;
            if !done {
                continue;
            }
            if !conn.identified && conn.dec.sender() != CLIENT_FROM {
                // Peer or transfer plane: hand off with the unconsumed
                // tail of this read (bytes of the *next* frames).
                return ConnFate::Handoff(rbuf[off..n].to_vec());
            }
            conn.identified = true;
            let (cmd, floor) = match wire::decode_client(conn.dec.body()) {
                Ok(wire::ClientFrame::Submit { cmd, floor }) => (cmd, floor),
                // A node only ever receives submits on this plane.
                Ok(_) | Err(_) => return ConnFate::Dead,
            };
            conn.dec.clear();
            // A command must live inside one worker slot (see
            // protocol::common::shard); a spanning key set is malformed
            // for this deployment and drops the connection.
            let w = match worker_of_cmd(&cmd, txs.len()) {
                Ok(w) => w,
                Err(_) => return ConnFate::Dead,
            };
            if max_inflight > 0 && conn.inflight >= max_inflight {
                // Admission control: shed at the edge, before any worker
                // sees the command. The explicit busy reply is the
                // backpressure signal — nothing queues unboundedly.
                stats.busy_shed.fetch_add(1, Ordering::Relaxed);
                enqueue_client_frame(conn, node.0, &wire::ClientFrame::Busy { rid: cmd.rid });
                continue;
            }
            conn.inflight += 1;
            let done = Done::Loop { token, tx: cmd_tx.clone(), waker: waker.clone() };
            if txs[w].send(Event::Submit { cmd, floor, done }).is_err() {
                return ConnFate::Dead;
            }
        }
    }
}

/// One client event loop: multiplexes many sessions over a [`Poller`].
/// Wakeups come from socket readiness, from workers completing requests
/// (`Done::Loop` → [`LoopCmd::Reply`] + wake), from the acceptor handing
/// over fresh connections, and from shutdown. Each wakeup drains the
/// command channel, services ready sockets, then flushes every
/// connection that accumulated replies — one vectored write per
/// connection per wakeup in the common case.
#[allow(clippy::too_many_arguments)]
fn client_loop<P: poll::Poller>(
    mut poller: P,
    cmd_rx: Receiver<LoopCmd>,
    cmd_tx: Sender<LoopCmd>,
    node: ProcessId,
    txs: Vec<Sender<Event>>,
    max_inflight: usize,
    closing: Arc<AtomicBool>,
    stats: Arc<NetStats>,
    det: Arc<FailureDetector>,
) {
    let waker = poller.waker();
    let mut conns: HashMap<poll::Token, ClientConn> = HashMap::new();
    let mut next_token: poll::Token = 0;
    let mut events: Vec<(poll::Token, poll::Readiness)> = Vec::new();
    let mut rbuf = vec![0u8; 64 << 10];
    let mut dirty: Vec<poll::Token> = Vec::new();
    loop {
        if poller.poll(&mut events, None).is_err() {
            break;
        }
        if closing.load(Ordering::SeqCst) {
            break;
        }
        stats.client_wakeups.fetch_add(1, Ordering::Relaxed);
        dirty.clear();
        // Phase 1: commands — adopt fresh connections, absorb completed
        // requests into per-connection reply queues.
        loop {
            match cmd_rx.try_recv() {
                Ok(LoopCmd::Conn(stream)) => {
                    if stream.set_nonblocking(true).is_err() || stream.set_nodelay(true).is_err()
                    {
                        continue;
                    }
                    let token = next_token;
                    next_token += 1;
                    poller.register(token, stream.as_raw_fd(), poll::Interest::READ);
                    conns.insert(
                        token,
                        ClientConn {
                            stream,
                            dec: wire::FrameDecoder::new(),
                            out: VecDeque::new(),
                            out_off: 0,
                            inflight: 0,
                            identified: false,
                            want_write: false,
                        },
                    );
                    stats.client_connections.fetch_add(1, Ordering::Relaxed);
                    // The socket may have become readable before the
                    // registration: service it as if an event fired.
                    dirty.push(token);
                    events.push((token, poll::Readiness { readable: true, ..Default::default() }));
                }
                Ok(LoopCmd::Reply { token, rid, response, ts }) => {
                    // A reply for a connection that died in the meantime
                    // is dropped (the client re-issues via failover).
                    if let Some(conn) = conns.get_mut(&token) {
                        conn.inflight = conn.inflight.saturating_sub(1);
                        enqueue_client_frame(
                            conn,
                            node.0,
                            &wire::ClientFrame::Reply { rid, response, ts },
                        );
                        dirty.push(token);
                    }
                }
                Err(_) => break,
            }
        }
        // Phase 2: socket readiness.
        for i in 0..events.len() {
            let (token, ready) = events[i];
            let fate = match conns.get_mut(&token) {
                None => continue,
                Some(conn) => {
                    if ready.writable {
                        dirty.push(token);
                    }
                    if ready.readable || ready.error {
                        service_readable(
                            conn,
                            token,
                            node,
                            &txs,
                            max_inflight,
                            &cmd_tx,
                            &waker,
                            &stats,
                            &mut rbuf,
                        )
                    } else {
                        ConnFate::Keep
                    }
                }
            };
            match fate {
                ConnFate::Keep => {}
                ConnFate::Dead => {
                    let conn = conns.remove(&token).expect("serviced conn");
                    poller.deregister(token);
                    conn.dec.recycle();
                }
                ConnFate::Handoff(leftover) => {
                    let conn = conns.remove(&token).expect("serviced conn");
                    poller.deregister(token);
                    // Not a client after all: it was never a submit
                    // source, so the connection count stays honest.
                    stats.client_connections.fetch_sub(1, Ordering::Relaxed);
                    if conn.stream.set_nonblocking(false).is_ok() {
                        let txs = txs.to_vec();
                        let det = det.clone();
                        std::thread::spawn(move || {
                            serve_handoff(conn.stream, node, txs, conn.dec, leftover, det)
                        });
                    } else {
                        conn.dec.recycle();
                    }
                }
            }
        }
        // Phase 3: flush every connection that accumulated output, then
        // settle poller interest (write interest only while a queue has
        // a blocked remainder).
        for i in 0..dirty.len() {
            let token = dirty[i];
            let Some(conn) = conns.get_mut(&token) else { continue };
            if !flush_conn(conn, &stats) {
                let conn = conns.remove(&token).expect("flushed conn");
                poller.deregister(token);
                conn.dec.recycle();
                continue;
            }
            let want = !conn.out.is_empty();
            if want != conn.want_write {
                conn.want_write = want;
                let interest =
                    if want { poll::Interest::READ_WRITE } else { poll::Interest::READ };
                poller.set_interest(token, interest);
            }
        }
    }
    for (_, conn) in conns.drain() {
        conn.dec.recycle();
    }
}

/// Dial `addr`'s transfer plane and fetch worker slot `slot`'s state:
/// the donor's manifest, plus every page the local (recovered) store
/// cannot produce itself — the manifest-diff transfer of
/// `store::storage::plan_transfer`. Returns the manifest, a page lookup
/// covering all of its chunks, and how many pages actually crossed the
/// wire. `None` if the donor is unreachable or answers garbage (the
/// caller tries the next peer or continues with local state only).
fn fetch_slot_state(
    addr: &str,
    slot: u32,
    local: &KvStore,
) -> Option<(Manifest, HashMap<u64, Vec<u8>>, u64)> {
    let mut stream = TcpStream::connect(addr).ok()?;
    stream.set_nodelay(true).ok()?;
    let req = wire::encode_transfer(&wire::TransferFrame::ManifestRequest { slot });
    write_frame(&mut stream, TRANSFER_FROM, &req).ok()?;
    let mut buf = Vec::new();
    read_frame(&mut stream, &mut buf).ok()?;
    let manifest = match wire::decode_transfer(&buf).ok()? {
        wire::TransferFrame::ManifestReply { slot: s, applied, chunks, dot_floors, dedup }
            if s == slot =>
        {
            Manifest { applied, chunks, dedup, dot_floors }
        }
        _ => return None,
    };
    let plan = plan_transfer(local, &manifest);
    let mut pages = plan.local;
    let fetched = plan.need.len() as u64;
    for hash in plan.need {
        let req = wire::encode_transfer(&wire::TransferFrame::Chunk {
            slot,
            hash,
            present: false,
            data: vec![],
        });
        write_frame(&mut stream, TRANSFER_FROM, &req).ok()?;
        read_frame(&mut stream, &mut buf).ok()?;
        match wire::decode_transfer(&buf).ok()? {
            wire::TransferFrame::Chunk { hash: h, present: true, data, .. } if h == hash => {
                pages.insert(hash, data);
            }
            // The donor no longer holds the page (it checkpointed past
            // the manifest we hold): abort — the caller retries or keeps
            // local state.
            _ => return None,
        }
    }
    Some((manifest, pages, fetched))
}

/// Start a Tempo node listening on `addrs[id]`, connecting to all peers.
/// `addrs` must be identical across the cluster, and so must
/// `config.workers` — worker slot `w` of this node talks only to slot `w`
/// of its peers. The same listener serves protocol peers,
/// [`TcpClient`]s, and the restart state-transfer plane.
///
/// This variant runs in `StorageMode::Memory` regardless of
/// `config.storage` (no storage root to journal into); use
/// [`start_node_in`] for the crash-recovery fault model.
pub fn start_node(id: ProcessId, config: Config, addrs: Vec<String>) -> Result<NodeHandle> {
    start_node_in(id, config, addrs, None)
}

/// [`start_node`] with a durable storage root. Under `StorageMode::Disk`
/// worker slot `w` journals executions to `<data_dir>/slot<w>/` (WAL +
/// content-addressed snapshot chunks, `store::storage`). When the slot
/// directories already exist the node is **restarting**: each worker
/// first rebuilds snapshot + WAL tail locally, then dials a survivor's
/// transfer plane (docs/WIRE.md tags 22–24) to fetch the pages it is
/// missing, re-seeds its executor's dedup windows from the recovered
/// blob, and advances its dot generator past everything it ever minted
/// before rejoining the mesh.
pub fn start_node_in(
    id: ProcessId,
    config: Config,
    addrs: Vec<String>,
    data_dir: Option<PathBuf>,
) -> Result<NodeHandle> {
    let me = id.0 as usize;
    let workers = config.workers.max(1);
    // The peer-frame envelope names the worker slot in one byte; refuse a
    // config that could not be represented instead of truncating.
    assert!(workers <= 256, "workers must be <= 256 (u8 slot on the wire)");
    let listener =
        TcpListener::bind(&addrs[me]).with_context(|| format!("bind {}", addrs[me]))?;
    let mut event_txs: Vec<Sender<Event>> = Vec::with_capacity(workers);
    let mut event_rxs: Vec<Receiver<Event>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = channel::<Event>();
        event_txs.push(tx);
        event_rxs.push(rx);
    }
    let mut threads = Vec::new();

    // Client event loops: a small fixed pool, each thread multiplexing
    // many sessions over its own poller. Connections land here first —
    // the first frame identifies the plane, and peer/transfer links are
    // handed off to dedicated blocking threads.
    let net_stats = Arc::new(NetStats::default());
    let detector = Arc::new(FailureDetector::new(addrs.len()));
    let closing = Arc::new(AtomicBool::new(false));
    let mut loop_txs: Vec<Sender<LoopCmd>> = Vec::new();
    let mut loop_wakers: Vec<poll::Waker> = Vec::new();
    for _ in 0..config.client_event_threads.max(1) {
        let poller = poll::PollPoller::new().context("create client-loop poller")?;
        loop_wakers.push(poller.waker());
        let (cmd_tx, cmd_rx) = channel::<LoopCmd>();
        loop_txs.push(cmd_tx.clone());
        let txs = event_txs.clone();
        let closing = closing.clone();
        let stats = net_stats.clone();
        let det = detector.clone();
        let max_inflight = config.max_inflight_per_session;
        threads.push(std::thread::spawn(move || {
            client_loop(poller, cmd_rx, cmd_tx, id, txs, max_inflight, closing, stats, det)
        }));
    }

    // Acceptor: protocol peers and clients dial us. Accepted sockets are
    // dealt round-robin to the event loops. The acceptor polls its own
    // nonblocking listener, so `NodeHandle::shutdown` unblocks it with
    // the poller's wake token — no self-dial, no leaked socket; breaking
    // drops the listener and frees the port for an in-process restart.
    let mut wakers: Vec<poll::Waker> = Vec::new();
    {
        listener.set_nonblocking(true)?;
        let mut poller = poll::PollPoller::new().context("create acceptor poller")?;
        wakers.push(poller.waker());
        let closing = closing.clone();
        let loop_txs = loop_txs.clone();
        let loop_wakers = loop_wakers.clone();
        threads.push(std::thread::spawn(move || {
            const LISTENER: poll::Token = 0;
            poller.register(LISTENER, listener.as_raw_fd(), poll::Interest::READ);
            let mut events = Vec::new();
            let mut rr = 0usize;
            loop {
                if poller.poll(&mut events, None).is_err() {
                    return;
                }
                if closing.load(Ordering::SeqCst) {
                    return;
                }
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let i = rr % loop_txs.len();
                            rr = rr.wrapping_add(1);
                            if loop_txs[i].send(LoopCmd::Conn(stream)).is_ok() {
                                loop_wakers[i].wake();
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(_) => return,
                    }
                }
            }
        }));
    }
    wakers.extend(loop_wakers);

    // Dial every peer (retry until the whole cluster is up). Each peer
    // gets its own writer thread — the per-peer outbound stage — fed by
    // a channel the worker threads share; the writer merges whatever is
    // queued into single wire frames (one vectored write per flush;
    // `config.merge_wait_us` optionally lingers for stragglers).
    let merge_wait = Duration::from_micros(config.merge_wait_us);
    let heartbeat = Duration::from_micros(config.heartbeat_interval_us);
    let mut peers: HashMap<ProcessId, SyncSender<OutFrame>> = HashMap::new();
    for (j, addr) in addrs.iter().enumerate() {
        if j == me {
            continue;
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50));
                    let _ = e;
                }
                Err(e) => return Err(e).with_context(|| format!("connect {addr}")),
            }
        };
        stream.set_nodelay(true)?;
        let (tx, rx) = sync_channel::<OutFrame>(PEER_QUEUE_FRAMES);
        let stats = net_stats.clone();
        let from = id.0;
        let peer_addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            peer_writer(stream, peer_addr, rx, from, merge_wait, heartbeat, stats)
        }));
        peers.insert(ProcessId(j as u32), tx);
    }

    // Failure detector sweeper: turns heartbeat silence into
    // `Protocol::suspect` calls at every worker slot, which feed the
    // epoch eviction vote — eviction, GC unfreeze and client failover
    // then happen over real sockets with no harness involvement.
    // Opt-in: `Config::suspect_delay_us` defaults to `u64::MAX` (never).
    if config.suspect_delay_us != u64::MAX && addrs.len() > 1 {
        let txs = event_txs.clone();
        let det = detector.clone();
        let closing = closing.clone();
        let delay = config.suspect_delay_us;
        // Sweep a few times per suspicion window so detection latency
        // stays a fraction of the configured delay, bounded below so a
        // tiny delay cannot spin the sweeper.
        let sweep_every = Duration::from_micros((delay / 4).clamp(1_000, 100_000));
        threads.push(std::thread::spawn(move || loop {
            std::thread::sleep(sweep_every);
            if closing.load(Ordering::SeqCst) {
                return;
            }
            for suspected in det.sweep(id, delay) {
                for tx in &txs {
                    if tx.send(Event::Suspect { suspected }).is_err() {
                        return;
                    }
                }
            }
        }));
    }

    // Tick timer: fan one tick to every worker slot.
    {
        let txs = event_txs.clone();
        let interval = Duration::from_micros(config.tick_interval_us.max(500));
        threads.push(std::thread::spawn(move || loop {
            std::thread::sleep(interval);
            for tx in &txs {
                if tx.send(Event::Tick).is_err() {
                    return;
                }
            }
        }));
    }

    let stats: Vec<Arc<Mutex<WorkerStats>>> =
        (0..workers).map(|_| Arc::new(Mutex::new(WorkerStats::default()))).collect();

    // One protocol thread per worker slot: the slot's state machine, its
    // executor over its KV partition (wrapped in the durability layer),
    // and its rid → reply routing table.
    for (w, events_rx) in event_rxs.into_iter().enumerate() {
        let stats = stats[w].clone();
        let peers = peers.clone();
        let mut cfg = config.clone();
        cfg.workers = workers;
        cfg.worker = w;
        let addrs = addrs.clone();
        let slot_dir = match (&data_dir, config.storage) {
            (Some(dir), StorageMode::Disk) => Some(dir.join(format!("slot{w}"))),
            _ => None,
        };
        threads.push(std::thread::spawn(move || {
            let dedup_window = cfg.dedup_window;
            let fsync_batch = cfg.wal_fsync_batch;
            let snapshot_every = cfg.snapshot_every;
            let mut proto = Tempo::new(id, cfg);
            // Snapshot pages fetched from a donor at startup (0 unless
            // this is a crash-restart that needed state transfer).
            let mut chunks_fetched: u64 = 0;
            let (mut exec, restart_floor) = match slot_dir {
                Some(dir) => {
                    // An existing slot directory means this process is
                    // *restarting* (crash-recovery); a fresh one is the
                    // initial boot and skips state transfer.
                    let restarting = dir.exists();
                    let backend = FileBackend::open(&dir).expect("open slot storage dir");
                    let (mut durable, recovery) = Durable::<KvStore>::recover(
                        Box::new(backend),
                        fsync_batch,
                        snapshot_every,
                    );
                    let mut dedup_blob = recovery.dedup.clone();
                    let mut floor = recovery.dot_floor(id);
                    if restarting {
                        // Catch up from the first survivor that answers:
                        // manifest diff, fetch only the missing pages,
                        // adopt the donor's dedup windows and dot floors.
                        for (j, addr) in addrs.iter().enumerate() {
                            if j == me {
                                continue;
                            }
                            let got = fetch_slot_state(addr, w as u32, durable.store());
                            let (manifest, pages, fetched) = match got {
                                Some(v) => v,
                                None => continue,
                            };
                            // Never regress below locally recovered state
                            // (a donor that lags our WAL tail).
                            if manifest.applied > durable.store().applied() {
                                let store =
                                    assemble::<KvStore>(&manifest, |h| pages.get(&h).cloned());
                                if let Some(store) = store {
                                    durable.install(
                                        store,
                                        &manifest.dedup,
                                        &manifest.dot_floors,
                                    );
                                    dedup_blob = manifest.dedup.clone();
                                    floor = floor.max(
                                        manifest
                                            .dot_floors
                                            .iter()
                                            .find(|(p, _)| *p == id)
                                            .map_or(0, |(_, s)| *s),
                                    );
                                    chunks_fetched = fetched;
                                }
                            }
                            break;
                        }
                    }
                    let exec = Executor::recovered(
                        id,
                        durable,
                        dedup_window,
                        &dedup_blob,
                        &recovery.replayed,
                    );
                    (exec, floor)
                }
                None => (
                    Executor::new(id, Durable::memory(KvStore::new()))
                        .with_dedup_window(dedup_window),
                    0,
                ),
            };
            if restart_floor > 0 {
                // Floors only cover *executed* dots; the slack covers
                // proposals that were in flight when we crashed.
                proto.note_restart(restart_floor + RESTART_DOT_SLACK);
            }
            {
                // Publish the recovered state before the first event, so
                // digests are comparable even if no new traffic arrives.
                let mut slot = stats.lock().unwrap();
                slot.executed = exec.executed();
                slot.digest = exec.state().digest();
            }
            let mut done: DoneMap = HashMap::new();
            let start = Instant::now();
            let now_us = |s: Instant| s.elapsed().as_micros() as u64;
            // Outbound peer bytes attributable to read submissions
            // (`Op::Read`). The ideal local read sends nothing, so this
            // stays ~0 unless reads degrade to the ordering path; the
            // bench gates assert exactly that.
            let mut read_bytes: u64 = 0;
            for event in events_rx {
                let read_submit =
                    matches!(&event, Event::Submit { cmd, .. } if cmd.op == Op::Read);
                let actions = match event {
                    Event::Message { from, msg } => proto.handle(from, msg, now_us(start)),
                    Event::Submit { cmd, floor, done: route } => {
                        done.insert(cmd.rid, route);
                        if read_submit {
                            // The local-read path: served at this replica
                            // with zero protocol messages once covered by
                            // the stability frontier (or parked until it
                            // is); only degraded reads fall back to
                            // `submit` internally. The floor pins the
                            // read no staler than the session's last
                            // acknowledged write.
                            proto.submit_read(cmd, floor, now_us(start))
                        } else {
                            proto.submit(cmd, now_us(start))
                        }
                    }
                    Event::Manifest { done } => {
                        // Serve a recovering peer: snapshot this slot's
                        // store + dedup windows between protocol steps.
                        let blob = exec.dedup_blob();
                        let _ = done.send(exec.state().serve_manifest(blob));
                        Vec::new()
                    }
                    Event::Tick => proto.tick(now_us(start)),
                    Event::Suspect { suspected } => {
                        // Real failure detection: the sweeper found
                        // `suspected` silent. The protocol reacts
                        // exactly as under the simulator's nemesis —
                        // eviction vote, recovery timers — on its
                        // following ticks.
                        proto.suspect(suspected);
                        Vec::new()
                    }
                    Event::Shutdown => {
                        // Clean shutdown syncs the group-commit window
                        // (a kill test bypasses this, by design).
                        exec.state_mut().flush();
                        break;
                    }
                };
                let actions = exec.absorb(actions);
                for action in actions {
                    match action {
                        Action::Send { to, msg } => {
                            if let Some(link) = peers.get(&to) {
                                // Point-to-point: encode into a pooled
                                // buffer; the peer's writer recycles it
                                // after the write. (A dead peer just
                                // drops its traffic.)
                                let body = wire::encode_routed_pooled(w as u32, &msg);
                                if read_submit {
                                    read_bytes += 8 + body.bytes().len() as u64;
                                }
                                let _ = link.send(OutFrame::Owned(body));
                            }
                        }
                        Action::SendShared { to, msg } => {
                            // Encode-once fan-out: one shared body for
                            // every destination — the loop body is
                            // `Action::SendBytes` lowering (see
                            // `encode_fanout`, which pins the sharing)
                            // without the intermediate action vector.
                            let body = wire::encode_routed_shared(w as u32, &msg);
                            for dest in to {
                                if let Some(link) = peers.get(&dest) {
                                    if read_submit {
                                        read_bytes += 8 + body.len() as u64;
                                    }
                                    let _ = link.send(OutFrame::Shared(body.clone()));
                                }
                            }
                        }
                        Action::SendBytes { to, body } => {
                            if let Some(link) = peers.get(&to) {
                                if read_submit {
                                    read_bytes += 8 + body.len() as u64;
                                }
                                let _ = link.send(OutFrame::Shared(body));
                            }
                        }
                        Action::Reply { rid, response, ts } => {
                            if let Some(route) = done.remove(&rid) {
                                route.complete(rid, response, ts);
                            }
                        }
                        _ => {}
                    }
                }
                let mut slot = stats.lock().unwrap();
                if exec.executed() != slot.executed {
                    slot.executed = exec.executed();
                    slot.digest = exec.state().digest();
                }
                slot.counters = proto.counters();
                // Executor-side counters live outside the protocol: fold
                // them in so `NodeHandle::counters()` reports them.
                slot.counters.dedup_hits = exec.dedup_hits();
                slot.counters.read_path_bytes = read_bytes;
                // Durability-layer counters (all 0 in Memory mode).
                let ds = exec.state().stats();
                slot.counters.wal_records = ds.wal_records;
                slot.counters.snapshots_taken = ds.snapshots;
                slot.counters.wal_fsyncs = exec.state().backend_syncs();
                slot.counters.wal_bytes = exec.state().backend_bytes_written();
                slot.counters.chunks_fetched = chunks_fetched;
            }
        }));
    }

    Ok(NodeHandle {
        id,
        events: event_txs,
        workers,
        threads,
        closing,
        wakers,
        stats,
        net: net_stats,
        detector,
    })
}

/// A real request/response client: a [`Session`] speaking `ClientSubmit`
/// / `ClientReply` frames to one node over its own TCP connection.
///
/// Supports **pipelining**: [`TcpClient::submit_async`] puts a request on
/// the wire without waiting, [`TcpClient::recv_reply`] completes the next
/// outstanding request in whatever order the node finishes them — the
/// wire protocol routes replies by request id, so several rids may be in
/// flight per session. [`TcpClient::submit`] remains the closed-loop
/// convenience (submit one, block for that rid, buffering any other
/// pipelined replies that arrive first).
///
/// Supports **failover**: every unacked submission is retained (rid →
/// command) until its reply arrives, so when the contacted node dies the
/// session can dial a survivor and re-issue the lot with
/// [`TcpClient::failover`] — same rids, so the replicas' per-client
/// dedup window (`Config::dedup_window`) absorbs any copy the old
/// coordinator already ordered and replays the cached response instead
/// of executing twice. Exactly-once end to end: a request is lost only
/// if it never reached any surviving quorum, and it is never applied
/// twice no matter how many times it is re-issued.
///
/// Surfaces **admission control**: a node whose per-session in-flight
/// window is full answers a submit with a `ClientBusy` frame (tag 25)
/// instead of queueing it; the client reports it as an error carrying
/// [`BUSY_ERROR_PREFIX`] (classify with `client::is_busy_error`). A
/// busy-shed rid stays outstanding — the command was neither executed
/// nor queued, so [`TcpClient::resubmit`] can safely re-issue it (same
/// rid) after backing off, and failover re-issues it like any other
/// unacked request.
pub struct TcpClient {
    session: Session,
    stream: TcpStream,
    /// Unacked submissions, retained for failover re-issue: every rid
    /// submitted and not yet completed, with the exact command bytes it
    /// carried (re-issuing must not re-allocate a rid — the dedup window
    /// keys on it).
    outstanding: HashMap<Rid, Command>,
    /// Replies (with their decided timestamps) read off the socket while
    /// waiting for a different rid.
    buffered: HashMap<Rid, (Response, u64)>,
    /// Incremental frame decoder (pooled body buffer, reused across
    /// reply frames — the same state machine the node's event loop runs).
    dec: wire::FrameDecoder,
    /// Raw bytes read off the socket and not yet fed to the decoder
    /// (`pending_off` marks the consumed prefix).
    pending: Vec<u8>,
    pending_off: usize,
    /// Busy sheds observed while waiting for a *different* rid, reported
    /// on the next receive call.
    busied: VecDeque<Rid>,
    /// The rid behind the most recent busy error this client returned.
    last_busy: Option<Rid>,
    /// Client-side submit window (0 = unbounded): `submit_async` refuses
    /// (with a busy error) to put more than this many rids in flight,
    /// keeping a well-behaved client under the node's edge window.
    window: usize,
}

/// Deterministically-jittered exponential backoff for client retry
/// loops (busy sheds, failover redials): attempt `n` yields
/// `min(base · 2ⁿ, cap)` plus a jitter in `[0, half that interval]`
/// derived by hashing `(client, attempt)` — so a thundering herd of
/// clients failing over to the same survivor desynchronizes without
/// any shared clock or RNG, and every run of a seeded harness sleeps
/// identically.
pub fn client_backoff(client: ClientId, attempt: u32, base: Duration, cap: Duration) -> Duration {
    let exp = base.saturating_mul(1u32 << attempt.min(16)).min(cap);
    // splitmix64-style avalanche of (client, attempt): cheap, stateless,
    // and two distinct clients land on distinct jitters with high
    // probability.
    let mut h = client.0.wrapping_add((attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    let half = exp.as_micros() as u64 / 2;
    let jitter_us = if half == 0 { 0 } else { h % (half + 1) };
    exp + Duration::from_micros(jitter_us)
}

/// What one decoded client-plane frame from the node means.
enum Incoming {
    Reply(Rid, Response, u64),
    Busy(Rid),
}

/// The error a busy shed surfaces: prefixed so `client::is_busy_error`
/// classifies it as retryable.
fn busy_shed_error(rid: Rid) -> Error {
    Error::msg(format!("{BUSY_ERROR_PREFIX} node shed rid {rid:?}"))
}

impl TcpClient {
    /// Connect to the node at `addr` as `client`. Client ids must be
    /// unique across the deployment (they name the session's requests).
    pub fn connect(addr: &str, client: ClientId) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(TcpClient {
            session: Session::new(client),
            stream,
            outstanding: HashMap::new(),
            buffered: HashMap::new(),
            dec: wire::FrameDecoder::new(),
            pending: Vec::new(),
            pending_off: 0,
            busied: VecDeque::new(),
            last_busy: None,
            window: 0,
        })
    }

    /// Cap the client-side submit window at `n` in-flight rids
    /// (0 = unbounded, the default). With the cap, `submit_async` fails
    /// fast with a busy error instead of letting the node shed.
    pub fn with_window(mut self, n: usize) -> Self {
        self.window = n;
        self
    }

    /// Fail over to the node at `addr`: dial it, then re-issue every
    /// unacked submission **with its original rid** in rid order.
    /// Returns the number of requests re-issued. The replicas' per-client
    /// dedup window makes the re-issue exactly-once: a copy the old
    /// coordinator already pushed through the protocol is absorbed at
    /// execution and its cached response is replayed from the new
    /// coordinator, so the client cannot observe a double execution.
    /// Replies already buffered are kept (their requests completed; only
    /// the delivery to the caller is pending), and the failed stream's
    /// unread bytes are abandoned with it.
    pub fn failover(&mut self, addr: &str) -> Result<usize> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        self.stream = stream;
        // A half-decoded frame from the dead stream is meaningless on
        // the new one; busy sheds from the old node are moot (the rids
        // are still outstanding and re-issued below).
        self.dec.clear();
        self.pending.clear();
        self.pending_off = 0;
        self.busied.clear();
        let mut unacked: Vec<&Command> = self
            .outstanding
            .iter()
            .filter(|(rid, _)| !self.buffered.contains_key(rid))
            .map(|(_, cmd)| cmd)
            .collect();
        unacked.sort_by_key(|cmd| cmd.rid);
        let n = unacked.len();
        let floor = self.session.read_floor();
        for cmd in unacked {
            let body = wire::encode_client(&wire::ClientFrame::Submit {
                cmd: cmd.clone(),
                floor: if cmd.op == Op::Read { floor } else { 0 },
            });
            write_frame(&mut self.stream, CLIENT_FROM, &body)?;
        }
        Ok(n)
    }

    /// The session identity.
    pub fn client(&self) -> ClientId {
        self.session.client()
    }

    /// This session's [`client_backoff`] for retry `attempt`: how long
    /// to sleep before re-dialing a survivor ([`TcpClient::failover`])
    /// or re-issuing a busy-shed rid ([`TcpClient::resubmit`]). Jitter
    /// is seeded by the client id, so concurrent sessions retrying the
    /// same fault spread out instead of stampeding.
    pub fn backoff(&self, attempt: u32, base: Duration, cap: Duration) -> Duration {
        client_backoff(self.session.client(), attempt, base, cap)
    }

    /// The session's read-your-writes floor: the decided timestamp of its
    /// last acknowledged write (`Session::read_floor`). Every read this
    /// client submits is pinned no staler than this.
    pub fn read_floor(&self) -> u64 {
        self.session.read_floor()
    }

    /// Complete `rid`: drop it from the outstanding set and, if it was a
    /// write, raise the session's read-your-writes floor to its decided
    /// timestamp.
    fn finish(&mut self, rid: Rid, ts: u64) {
        if let Some(cmd) = self.outstanding.remove(&rid) {
            if cmd.op != Op::Read {
                self.session.note_write(ts);
            }
        }
    }

    /// Requests currently in flight (pipelined and not yet completed).
    pub fn in_flight(&self) -> usize {
        self.outstanding.len()
    }

    /// Abort a blocked receive after `timeout` (None blocks forever, the
    /// default).
    pub fn set_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Pipeline one command: allocate its rid, put the `ClientSubmit`
    /// frame on the wire and return immediately. Complete it (in any
    /// order) with [`TcpClient::recv_reply`]. A read carries the
    /// session's read-your-writes floor so the node never serves it
    /// staler than this session's last acknowledged write.
    pub fn submit_async(&mut self, keys: Vec<Key>, op: Op, payload_len: u32) -> Result<Rid> {
        if self.window > 0 && self.outstanding.len() >= self.window {
            bail!(
                "{BUSY_ERROR_PREFIX} client window full ({} in flight)",
                self.outstanding.len()
            );
        }
        let cmd = self.session.command(keys, op, payload_len);
        let rid = cmd.rid;
        let floor = if op == Op::Read { self.session.read_floor() } else { 0 };
        let body = wire::encode_client(&wire::ClientFrame::Submit { cmd: cmd.clone(), floor });
        write_frame(&mut self.stream, CLIENT_FROM, &body)?;
        self.outstanding.insert(rid, cmd);
        Ok(rid)
    }

    /// Complete the next outstanding request: returns a buffered reply if
    /// one was already read, otherwise blocks on the socket. Replies may
    /// complete in a different order than their submissions. Replies for
    /// rids that are no longer outstanding (an earlier request whose
    /// `submit` timed out and was abandoned) are skipped, exactly like
    /// the closed-loop path skips them. A busy shed observed for an
    /// outstanding rid is reported as a busy error (the rid stays
    /// outstanding; see [`TcpClient::last_busy`] / [`TcpClient::resubmit`]).
    pub fn recv_reply(&mut self) -> Result<(Rid, Response)> {
        if let Some(rid) = self.busied.pop_front() {
            self.last_busy = Some(rid);
            return Err(busy_shed_error(rid));
        }
        if let Some(&rid) = self.buffered.keys().next() {
            let (response, ts) = self.buffered.remove(&rid).expect("buffered reply");
            self.finish(rid, ts);
            return Ok((rid, response));
        }
        if self.outstanding.is_empty() {
            bail!("no outstanding requests to receive");
        }
        loop {
            match self.read_incoming()? {
                Incoming::Reply(rid, response, ts) => {
                    if self.outstanding.contains_key(&rid) {
                        self.finish(rid, ts);
                        return Ok((rid, response));
                    }
                    // else: stale reply for an abandoned request — skip.
                }
                Incoming::Busy(rid) => {
                    if self.outstanding.contains_key(&rid) {
                        self.last_busy = Some(rid);
                        return Err(busy_shed_error(rid));
                    }
                }
            }
        }
    }

    /// Nonblocking receive: like [`TcpClient::recv_reply`] but returns
    /// `Ok(None)` when nothing is outstanding or no complete frame is
    /// available yet (partial frames stay in the decoder for next time).
    pub fn try_recv_reply(&mut self) -> Result<Option<(Rid, Response)>> {
        if let Some(rid) = self.busied.pop_front() {
            self.last_busy = Some(rid);
            return Err(busy_shed_error(rid));
        }
        if let Some(&rid) = self.buffered.keys().next() {
            let (response, ts) = self.buffered.remove(&rid).expect("buffered reply");
            self.finish(rid, ts);
            return Ok(Some((rid, response)));
        }
        if self.outstanding.is_empty() {
            return Ok(None);
        }
        self.stream.set_nonblocking(true)?;
        let result = loop {
            match self.try_recv_inner() {
                Ok(None) => break Ok(None),
                Ok(Some(Incoming::Reply(rid, response, ts))) => {
                    if self.outstanding.contains_key(&rid) {
                        self.finish(rid, ts);
                        break Ok(Some((rid, response)));
                    }
                }
                Ok(Some(Incoming::Busy(rid))) => {
                    if self.outstanding.contains_key(&rid) {
                        self.last_busy = Some(rid);
                        break Err(busy_shed_error(rid));
                    }
                }
                Err(e) => break Err(e),
            }
        };
        let _ = self.stream.set_nonblocking(false);
        result
    }

    /// The rid behind the most recent busy error this client returned
    /// (the natural `resubmit` target after backing off).
    pub fn last_busy(&self) -> Option<Rid> {
        self.last_busy
    }

    /// Re-issue a busy-shed (or otherwise stalled) outstanding request
    /// **with its original rid** — safe because the dedup window keys on
    /// the rid, so even a racing duplicate executes once.
    pub fn resubmit(&mut self, rid: Rid) -> Result<()> {
        let Some(cmd) = self.outstanding.get(&rid) else {
            bail!("rid {rid:?} is not outstanding");
        };
        let cmd = cmd.clone();
        let floor = if cmd.op == Op::Read { self.session.read_floor() } else { 0 };
        let body = wire::encode_client(&wire::ClientFrame::Submit { cmd, floor });
        write_frame(&mut self.stream, CLIENT_FROM, &body)?;
        Ok(())
    }

    /// Feed buffered socket bytes through the incremental decoder and
    /// return the next complete frame, if any (no I/O here).
    fn poll_incoming(&mut self) -> Result<Option<Incoming>> {
        while self.pending_off < self.pending.len() {
            let (used, done) = self.dec.feed(&self.pending[self.pending_off..])?;
            self.pending_off += used;
            if !done {
                continue;
            }
            let frame = wire::decode_client(self.dec.body())?;
            self.dec.clear();
            return match frame {
                wire::ClientFrame::Reply { rid, response, ts } => {
                    Ok(Some(Incoming::Reply(rid, response, ts)))
                }
                wire::ClientFrame::Busy { rid } => Ok(Some(Incoming::Busy(rid))),
                wire::ClientFrame::Submit { .. } => bail!("unexpected ClientSubmit from node"),
            };
        }
        self.pending.clear();
        self.pending_off = 0;
        Ok(None)
    }

    /// Block until one complete client-plane frame arrives.
    fn read_incoming(&mut self) -> Result<Incoming> {
        loop {
            if let Some(inc) = self.poll_incoming()? {
                return Ok(inc);
            }
            let mut buf = [0u8; 16 << 10];
            match self.stream.read(&mut buf) {
                Ok(0) => bail!("connection closed by node"),
                Ok(n) => self.pending.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("read client stream"),
            }
        }
    }

    /// Nonblocking twin of [`TcpClient::read_incoming`] (stream must be
    /// in nonblocking mode): `Ok(None)` when the socket has no bytes.
    fn try_recv_inner(&mut self) -> Result<Option<Incoming>> {
        loop {
            if let Some(inc) = self.poll_incoming()? {
                return Ok(Some(inc));
            }
            let mut buf = [0u8; 16 << 10];
            match self.stream.read(&mut buf) {
                Ok(0) => bail!("connection closed by node"),
                Ok(n) => self.pending.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(None),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("read client stream"),
            }
        }
    }

    /// Submit one command and block for *its* response (closed loop over
    /// the pipelined plumbing): replies for other in-flight rids that
    /// arrive first are buffered, not dropped; busy sheds for other rids
    /// are queued for their own receive calls. On a busy shed of *this*
    /// rid the call returns a busy error and the rid **stays
    /// outstanding** (nothing executed — `resubmit` re-issues it). On
    /// any other error (e.g. a read timeout) the request is abandoned —
    /// its rid leaves `outstanding`, so a late reply for it is skipped
    /// rather than mistaken for a pipelined completion.
    pub fn submit(&mut self, keys: Vec<Key>, op: Op, payload_len: u32) -> Result<(Rid, Response)> {
        let rid = self.submit_async(keys, op, payload_len)?;
        loop {
            if let Some((response, ts)) = self.buffered.remove(&rid) {
                self.finish(rid, ts);
                return Ok((rid, response));
            }
            match self.read_incoming() {
                Ok(Incoming::Reply(got, response, ts)) => {
                    if got == rid {
                        self.finish(rid, ts);
                        return Ok((rid, response));
                    }
                    if self.outstanding.contains_key(&got) {
                        self.buffered.insert(got, (response, ts));
                    }
                    // else: a reply for an earlier (timed-out) request.
                }
                Ok(Incoming::Busy(got)) => {
                    if got == rid {
                        self.last_busy = Some(rid);
                        return Err(busy_shed_error(rid));
                    }
                    if self.outstanding.contains_key(&got) {
                        self.busied.push_back(got);
                    }
                }
                Err(e) => {
                    self.outstanding.remove(&rid);
                    return Err(e);
                }
            }
        }
    }

    /// Single-key shorthand for [`TcpClient::submit`].
    pub fn submit_single(&mut self, key: Key, op: Op, payload_len: u32) -> Result<(Rid, Response)> {
        self.submit(vec![key], op, payload_len)
    }
}

/// Allocate `n` localhost addresses on free ports.
pub fn local_addrs(n: usize) -> Result<Vec<String>> {
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        // Bind to port 0 to reserve a free port, then release it.
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(format!("127.0.0.1:{}", l.local_addr()?.port()));
    }
    Ok(addrs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Dot;

    #[test]
    fn vectored_merged_frame_matches_the_reference_encoding() {
        // The scatter-gather writer must produce exactly
        // [len][from][wire::encode_merged(bodies)] — the receiver's
        // decode path and the Python mirror are pinned to that layout.
        let dot = Dot::new(ProcessId(1), 5);
        let bodies_owned: Vec<Vec<u8>> = vec![
            wire::encode_routed(&crate::protocol::common::shard::Routed {
                worker: 0,
                msg: Msg::MStable { dot },
            }),
            wire::encode_routed(&crate::protocol::common::shard::Routed {
                worker: 1,
                msg: Msg::MBatch {
                    msgs: vec![Msg::MBump { dot, ts: 3 }, Msg::MStable { dot }],
                },
            }),
            wire::encode_routed(&crate::protocol::common::shard::Routed {
                worker: 2,
                msg: Msg::MRec { dot, bal: 9 },
            }),
        ];
        let bodies: Vec<&[u8]> = bodies_owned.iter().map(|b| b.as_slice()).collect();
        let mut out: Vec<u8> = Vec::new();
        let mut scratch = Vec::new();
        let wrote = write_merged_frame(&mut out, 7, &bodies, &mut scratch).expect("write");
        assert_eq!(wrote, out.len());
        let reference = wire::encode_merged(&bodies);
        assert_eq!(
            u32::from_le_bytes(out[0..4].try_into().unwrap()) as usize,
            reference.len()
        );
        assert_eq!(u32::from_le_bytes(out[4..8].try_into().unwrap()), 7);
        assert_eq!(&out[8..], &reference[..], "vectored layout != reference encoding");
        // And the receiver recovers the members in per-slot send order.
        let members = wire::decode_merged(&out[8..]).expect("decode");
        assert_eq!(members.len(), 3);
        assert_eq!(
            members.iter().map(|m| m.worker).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
    }

    /// Satellite of the merge-wait knob: with `merge_wait_us == 0` (the
    /// default) `collect_flush` must behave exactly like the old
    /// opportunistic drain — take what is already queued, never block —
    /// so default configs keep byte-identical flush batches.
    #[test]
    fn merge_wait_zero_is_the_opportunistic_drain() {
        let (tx, rx) = std::sync::mpsc::channel::<OutFrame>();
        for i in 0..3u8 {
            tx.send(OutFrame::Shared(vec![i; 4].into())).unwrap();
        }
        let mut carry = None;
        let first = OutFrame::Shared(vec![9u8; 4].into());
        let t0 = Instant::now();
        let batch = collect_flush(&rx, first, Duration::ZERO, &mut carry);
        // Everything already queued joins the batch, in order…
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].bytes(), &[9, 9, 9, 9]);
        assert_eq!(batch[3].bytes(), &[2, 2, 2, 2]);
        assert!(carry.is_none());
        // …and an empty queue yields a lone frame with zero waiting.
        let batch = collect_flush(
            &rx,
            OutFrame::Shared(vec![7u8; 2].into()),
            Duration::ZERO,
            &mut carry,
        );
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "wait=0 must never block"
        );
    }

    #[test]
    fn merge_wait_lingers_for_stragglers() {
        let (tx, rx) = std::sync::mpsc::channel::<OutFrame>();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            let _ = tx.send(OutFrame::Shared(vec![1u8; 4].into()));
        });
        let mut carry = None;
        let first = OutFrame::Shared(vec![0u8; 4].into());
        // A generous window: the straggler lands well inside it.
        let batch = collect_flush(&rx, first, Duration::from_millis(500), &mut carry);
        sender.join().unwrap();
        assert_eq!(
            batch.len(),
            2,
            "a positive merge wait must pick up the straggler frame"
        );
    }

    /// The nonblocking decode state machine must agree with the blocking
    /// `read_frame` on every split of the same byte stream — the exact
    /// contract the event loop relies on when frames straddle reads.
    #[test]
    fn frame_decoder_matches_read_frame_on_any_split() {
        let frames: Vec<(u32, Vec<u8>)> = vec![
            (CLIENT_FROM, vec![1, 2, 3]),
            (0, vec![]),
            (7, vec![0xAB; 300]),
            (TRANSFER_FROM, vec![5]),
        ];
        let mut stream_bytes = Vec::new();
        for (from, body) in &frames {
            stream_bytes.extend_from_slice(&(body.len() as u32).to_le_bytes());
            stream_bytes.extend_from_slice(&from.to_le_bytes());
            stream_bytes.extend_from_slice(body);
        }
        // Blocking reference: read_frame over an in-memory cursor.
        let mut cursor = std::io::Cursor::new(stream_bytes.clone());
        let mut reference = Vec::new();
        let mut buf = Vec::new();
        for _ in 0..frames.len() {
            let from = read_frame(&mut cursor, &mut buf).expect("read_frame");
            reference.push((from, buf.clone()));
        }
        // Nonblocking twin, fed in awkward 7-byte chunks.
        let mut dec = wire::FrameDecoder::new();
        let mut decoded = Vec::new();
        let mut off = 0;
        while off < stream_bytes.len() {
            let end = (off + 7).min(stream_bytes.len());
            let mut chunk = &stream_bytes[off..end];
            while !chunk.is_empty() {
                let (used, done) = dec.feed(chunk).expect("feed");
                chunk = &chunk[used..];
                if done {
                    decoded.push((dec.sender(), dec.body().to_vec()));
                    dec.clear();
                }
            }
            off = end;
        }
        dec.recycle();
        assert_eq!(decoded, reference, "decoder != read_frame on the same stream");
    }

    /// Drive a whole client event loop deterministically with the
    /// scripted poller and one real socket pair: a session's submits are
    /// forwarded to the worker within the in-flight window, shed with an
    /// explicit `ClientBusy` beyond it, and the completion path encodes
    /// the reply back onto the socket.
    #[test]
    fn client_loop_forwards_sheds_and_replies_deterministically() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let mut client_side = TcpStream::connect(addr).expect("connect");
        let (node_side, _) = listener.accept().expect("accept");

        // Two submits written BEFORE the loop adopts the socket, so the
        // scripted readable events find both frames buffered.
        let mut session = Session::new(ClientId(42));
        let cmd1 = session.single(5, Op::Put, 8);
        let cmd2 = session.single(5, Op::Put, 8);
        let (rid1, rid2) = (cmd1.rid, cmd2.rid);
        for cmd in [&cmd1, &cmd2] {
            let body =
                wire::encode_client(&wire::ClientFrame::Submit { cmd: cmd.clone(), floor: 0 });
            write_frame(&mut client_side, CLIENT_FROM, &body).expect("write submit");
        }

        // Plenty of scripted readable batches: the loop re-services the
        // socket each poll until the kernel delivered the bytes.
        let script = vec![
            vec![(0usize, poll::Readiness { readable: true, writable: false, error: false })];
            100_000
        ];
        let poller = poll::ScriptedPoller::new(script);
        let waker = poller.waker();
        let (cmd_tx, cmd_rx) = channel::<LoopCmd>();
        let (ev_tx, ev_rx) = channel::<Event>();
        let closing = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(NetStats::default());
        let loop_thread = {
            let closing = closing.clone();
            let stats = stats.clone();
            let cmd_tx = cmd_tx.clone();
            std::thread::spawn(move || {
                client_loop(
                    poller,
                    cmd_rx,
                    cmd_tx,
                    ProcessId(0),
                    vec![ev_tx],
                    1, // max_inflight: the second submit must shed
                    closing,
                    stats,
                    Arc::new(FailureDetector::new(1)),
                )
            })
        };
        cmd_tx.send(LoopCmd::Conn(node_side)).expect("send conn");
        waker.wake();

        // Exactly ONE submit reaches the worker (the window is 1)…
        let (got, done) = loop {
            match ev_rx.recv_timeout(Duration::from_secs(10)).expect("worker event") {
                Event::Submit { cmd, done, .. } => break (cmd, done),
                _ => continue,
            }
        };
        assert_eq!(got.rid, rid1);
        // …and the client first sees the shed of the second one.
        client_side.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut rbuf = Vec::new();
        let from = read_frame(&mut client_side, &mut rbuf).expect("busy frame");
        assert_eq!(from, 0, "replies carry the node id");
        match wire::decode_client(&rbuf).expect("decode busy") {
            wire::ClientFrame::Busy { rid } => assert_eq!(rid, rid2),
            other => panic!("expected Busy for {rid2}, got {other:?}"),
        }
        // Completing the forwarded request routes a Reply back through
        // the loop's command channel and onto the socket.
        let response = Response { versions: vec![(5, 1)] };
        done.complete(rid1, response.clone(), 77);
        read_frame(&mut client_side, &mut rbuf).expect("reply frame");
        match wire::decode_client(&rbuf).expect("decode reply") {
            wire::ClientFrame::Reply { rid, response: got, ts } => {
                assert_eq!(rid, rid1);
                assert_eq!(got, response);
                assert_eq!(ts, 77);
            }
            other => panic!("expected Reply for {rid1}, got {other:?}"),
        }
        // No second Submit ever reached the worker.
        assert!(
            ev_rx.try_recv().is_err(),
            "the shed submit must never reach a worker"
        );
        assert_eq!(stats.busy_shed.load(Ordering::Relaxed), 1);
        assert_eq!(stats.client_connections.load(Ordering::Relaxed), 1);
        assert!(stats.client_replies.load(Ordering::Relaxed) >= 2); // busy + reply
        closing.store(true, Ordering::SeqCst);
        waker.wake();
        loop_thread.join().expect("join loop");
    }

    #[test]
    fn write_all_vectored_handles_empty_and_tiny_parts() {
        let mut out: Vec<u8> = Vec::new();
        let parts: [&[u8]; 5] = [&[], &[1], &[], &[2, 3], &[]];
        write_all_vectored(&mut out, &parts).expect("write");
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn encode_fanout_shares_one_body_across_destinations() {
        let dot = Dot::new(ProcessId(2), 9);
        let msg = Msg::MStable { dot };
        let to: Vec<ProcessId> = (0..4).map(ProcessId).collect();
        let actions = encode_fanout(3, to.clone(), &msg);
        assert_eq!(actions.len(), 4);
        let mut first: Option<Arc<[u8]>> = None;
        for (action, expect) in actions.iter().zip(&to) {
            match action {
                Action::SendBytes { to, body } => {
                    assert_eq!(to, expect);
                    match &first {
                        None => {
                            // The body is the routed encoding, produced once.
                            let legacy = wire::encode_routed(
                                &crate::protocol::common::shard::Routed {
                                    worker: 3,
                                    msg: msg.clone(),
                                },
                            );
                            assert_eq!(&body[..], &legacy[..]);
                            first = Some(body.clone());
                        }
                        Some(f) => assert!(
                            Arc::ptr_eq(f, body),
                            "fan-out destinations must share one encoded body"
                        ),
                    }
                }
                other => panic!("expected SendBytes, got {other:?}"),
            }
        }
    }

    /// Heartbeat frames are transport-plane: they refresh the sender's
    /// last-seen time and are consumed before any codec — no worker
    /// ever sees one. A malformed (overlong) heartbeat body drops the
    /// connection like any hostile frame, and ordinary protocol
    /// traffic counts as liveness too.
    #[test]
    fn heartbeats_refresh_last_seen_and_never_reach_workers() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let _dialer = TcpStream::connect(addr).expect("connect");
        let (mut node_side, _) = listener.accept().expect("accept");
        let det = FailureDetector::new(4);
        let (tx, rx) = channel::<Event>();
        let txs = vec![tx];
        let mut pages = HashMap::new();
        assert!(handle_nonclient_frame(
            &mut node_side,
            ProcessId(0),
            &txs,
            3,
            &[wire::TAG_HEARTBEAT],
            &mut pages,
            &det,
        ));
        assert!(det.last_seen[3].load(Ordering::Relaxed) > 0, "heartbeat refreshes last-seen");
        assert_eq!(det.heartbeats_seen.load(Ordering::Relaxed), 1);
        assert!(rx.try_recv().is_err(), "heartbeat must not reach a worker");
        assert!(
            !handle_nonclient_frame(
                &mut node_side,
                ProcessId(0),
                &txs,
                3,
                &[wire::TAG_HEARTBEAT, 0],
                &mut pages,
                &det,
            ),
            "an overlong heartbeat body is malformed"
        );
        // A protocol frame from a peer is contact too: a peer pushing
        // real traffic needs no separate heartbeats to stay alive.
        let body = wire::encode_routed(&crate::protocol::common::shard::Routed {
            worker: 0,
            msg: Msg::MStable { dot: Dot::new(ProcessId(1), 1) },
        });
        assert!(handle_nonclient_frame(
            &mut node_side,
            ProcessId(0),
            &txs,
            1,
            &body,
            &mut pages,
            &det,
        ));
        assert!(det.last_seen[1].load(Ordering::Relaxed) > 0, "any peer frame is liveness");
    }

    /// Client retry backoff: exponential growth to the cap, bounded
    /// jitter, deterministic per (client, attempt), and distinct
    /// clients desynchronized.
    #[test]
    fn client_backoff_grows_caps_and_jitters_deterministically() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(200);
        let c1 = ClientId(1);
        for attempt in 0..12 {
            let exp = base.saturating_mul(1u32 << attempt.min(16)).min(cap);
            let d = client_backoff(c1, attempt, base, cap);
            assert!(d >= exp, "attempt {attempt}: {d:?} below its interval {exp:?}");
            assert!(
                d <= exp + exp / 2 + Duration::from_micros(1),
                "attempt {attempt}: jitter exceeds half the interval"
            );
            assert_eq!(d, client_backoff(c1, attempt, base, cap), "must be deterministic");
        }
        // Same attempt, different clients → (almost surely) different
        // sleeps; these two specifically differ.
        assert_ne!(
            client_backoff(ClientId(1), 3, base, cap),
            client_backoff(ClientId(2), 3, base, cap),
        );
    }

    /// The sweeper's contract: the first sweep arms never-seen peers
    /// (boot counts as contact) instead of suspecting them; silence
    /// past the delay is then reported exactly once per peer, never
    /// for the local process.
    #[test]
    fn sweeper_arms_then_suspects_silent_peers_once() {
        let det = FailureDetector::new(3);
        assert!(det.sweep(ProcessId(0), 0).is_empty(), "first sweep only arms");
        assert_eq!(det.sweep(ProcessId(0), 0), vec![ProcessId(1), ProcessId(2)]);
        assert!(det.sweep(ProcessId(0), 0).is_empty(), "suspicion is sticky");
        assert_eq!(det.suspicions.load(Ordering::Relaxed), 2);
        // A peer with recent contact is not suspected under a real delay.
        let det = FailureDetector::new(2);
        det.saw(1);
        assert!(det.sweep(ProcessId(0), 60_000_000).is_empty());
    }

    /// The detector end to end over real sockets: three nodes exchange
    /// heartbeats, one is killed, and the survivors suspect it from
    /// heartbeat silence alone — then vote it out of the epoch — with
    /// no harness calling `Protocol::suspect` for them. This is the
    /// test that retires the "no failure detector by design" caveat.
    #[test]
    fn heartbeat_silence_drives_suspicion_and_eviction() {
        let addrs = local_addrs(3).expect("addrs");
        let config = Config::new(3, 1)
            .with_tick_interval_us(2_000)
            .with_heartbeat_interval_us(10_000)
            .with_suspect_delay_us(200_000);
        let mut nodes: Vec<Option<NodeHandle>> = (0..3u32)
            .map(|i| Some(start_node(ProcessId(i), config.clone(), addrs.clone()).expect("start")))
            .collect();
        // Prove the mesh works before the fault.
        let cmd = Command::new(Rid::new(ClientId(7), 1), vec![1], Op::Put, 8);
        let rx = nodes[0].as_ref().expect("node 0").submit(cmd);
        rx.recv_timeout(Duration::from_secs(10)).expect("pre-fault write");
        // Idle long enough that liveness is carried by heartbeats, not
        // protocol traffic.
        std::thread::sleep(Duration::from_millis(100));
        nodes[2].take().expect("node 2").shutdown();
        // The survivors must (a) have heartbeats flowing, (b) suspect
        // the dead node from silence, (c) evict it via the epoch vote.
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            let done = nodes[..2].iter().all(|n| {
                let c = n.as_ref().expect("survivor").counters();
                c.suspicions >= 1 && c.evictions >= 1
            });
            if done {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "survivors never suspected+evicted the killed node: {:?}",
                nodes[..2]
                    .iter()
                    .map(|n| {
                        let c = n.as_ref().unwrap().counters();
                        (c.heartbeats_sent, c.heartbeats_seen, c.suspicions, c.evictions)
                    })
                    .collect::<Vec<_>>()
            );
            std::thread::sleep(Duration::from_millis(25));
        }
        for n in nodes.iter().flatten() {
            let c = n.counters();
            assert!(c.heartbeats_sent >= 1, "idle links must carry heartbeats");
            assert!(c.heartbeats_seen >= 1, "peers' heartbeats must be consumed");
        }
        for n in nodes.into_iter().flatten() {
            n.shutdown();
        }
    }
}
