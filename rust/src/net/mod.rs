//! Real TCP cluster runtime (std::net + threads; Python is never on this
//! path — the Tempo state machine runs exactly as in the simulator, fed by
//! length-prefixed frames from peer sockets).
//!
//! Topology: one [`NodeHandle`] per process, full mesh of TCP connections,
//! plus a *client plane*: real clients ([`TcpClient`]) dial any node,
//! send `ClientSubmit` frames (docs/WIRE.md tag 17) and receive
//! `ClientReply` frames (tag 18) — request/response over the same
//! listener, distinguished by the frame header's sender field
//! ([`CLIENT_FROM`]). Each node runs (a) an acceptor thread per inbound
//! connection that decodes frames into an event channel, (b) the protocol
//! thread owning the Tempo state machine and an [`Executor`] over the KV
//! store (replies are `Action::Reply`, routed back by request id), and
//! (c) a tick timer.
//!
//! With `Config::batch_max_msgs > 0` the protocol layer coalesces the
//! messages bound for one peer into single `MBatch` frames
//! (`protocol::common::batch`), so this send path makes one `write_all`
//! (one syscall, one frame header) per batch instead of one per message —
//! the TCP layer needs no batching logic of its own beyond the codec.
//! Frame layout and limits are documented in `docs/WIRE.md`.

pub mod wire;

use crate::client::Session;
use crate::core::{ClientId, Command, Config, Key, Op, ProcessId, Response, Rid};
use crate::executor::Executor;
use crate::metrics::Counters;
use crate::protocol::tempo::msg::Msg;
use crate::protocol::tempo::Tempo;
use crate::protocol::{Action, Protocol};
use crate::store::KvStore;
use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sender field of frames on the client plane: a connection whose frames
/// carry this value is a client session, not a protocol peer (no real
/// `ProcessId` can collide — process ids are dense and small).
pub const CLIENT_FROM: u32 = u32::MAX;

/// Events fed to the protocol thread.
enum Event {
    Message { from: ProcessId, msg: Msg },
    Submit { cmd: Command, done: Sender<(Rid, Response)> },
    Tick,
    Shutdown,
}

/// A completion listener registered per in-flight request id.
type DoneMap = HashMap<Rid, Sender<(Rid, Response)>>;

/// Handle to a running node.
pub struct NodeHandle {
    pub id: ProcessId,
    events: Sender<Event>,
    threads: Vec<JoinHandle<()>>,
    pub counters: Arc<Mutex<Counters>>,
    pub store_digest: Arc<Mutex<u64>>,
    pub executed: Arc<Mutex<u64>>,
}

impl NodeHandle {
    /// Submit a command from an in-process client session; the response
    /// arrives on the returned receiver once the command executes at this
    /// node (the coordinator's executor emits `Action::Reply`).
    pub fn submit(&self, cmd: Command) -> Receiver<(Rid, Response)> {
        let (tx, rx) = channel();
        let _ = self.events.send(Event::Submit { cmd, done: tx });
        rx
    }

    /// Stop the protocol thread. Acceptor/tick threads are detached (they
    /// block on the listener/timer and exit with the process).
    pub fn shutdown(self) {
        let _ = self.events.send(Event::Shutdown);
        drop(self.threads);
    }
}

fn write_frame(stream: &mut TcpStream, from: u32, body: &[u8]) -> Result<()> {
    let mut frame = Vec::with_capacity(body.len() + 8);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&from.to_le_bytes());
    frame.extend_from_slice(body);
    stream.write_all(&frame)?;
    Ok(())
}

fn write_msg(stream: &mut TcpStream, from: ProcessId, msg: &Msg) -> Result<()> {
    write_frame(stream, from.0, &wire::encode(msg))
}

/// Upper bound on one frame body (`docs/WIRE.md`): a corrupt or hostile
/// length header must not make a node allocate gigabytes before the codec
/// ever sees the bytes. The sender side cooperates: the batching layer
/// flushes a destination queue at `BATCH_SOFT_MAX_BYTES` (4 MiB of
/// estimated encoding, `protocol::common::batch`), keeping legitimate
/// `MBatch` frames far below this cap.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Read one raw frame: the sender field and the undecoded body. The
/// caller decodes as a protocol message or a client frame depending on
/// the sender ([`CLIENT_FROM`] marks the client plane).
fn read_frame(stream: &mut TcpStream) -> Result<(u32, Vec<u8>)> {
    let mut hdr = [0u8; 8];
    stream.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})");
    }
    let from = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok((from, body))
}

/// Serve one inbound connection: protocol frames go straight to the event
/// channel; client submits lazily start a reply-writer thread for the
/// connection and register its sender as the request's completion route.
fn serve_connection(mut stream: TcpStream, node: ProcessId, tx: Sender<Event>) {
    let mut reply_tx: Option<Sender<(Rid, Response)>> = None;
    loop {
        let (from, body) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return,
        };
        if from == CLIENT_FROM {
            let cmd = match wire::decode_client(&body) {
                Ok(wire::ClientFrame::Submit { cmd }) => cmd,
                // A node never receives replies; malformed input drops
                // the connection (the codec promises Err, not panic).
                Ok(wire::ClientFrame::Reply { .. }) | Err(_) => return,
            };
            if reply_tx.is_none() {
                let mut wstream = match stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                let (txr, rxr) = channel::<(Rid, Response)>();
                std::thread::spawn(move || {
                    for (rid, response) in rxr {
                        let body =
                            wire::encode_client(&wire::ClientFrame::Reply { rid, response });
                        if write_frame(&mut wstream, node.0, &body).is_err() {
                            return;
                        }
                    }
                });
                reply_tx = Some(txr);
            }
            let done = reply_tx.as_ref().expect("reply writer started").clone();
            if tx.send(Event::Submit { cmd, done }).is_err() {
                return;
            }
        } else {
            let msg = match wire::decode(&body) {
                Ok(m) => m,
                Err(_) => return,
            };
            if tx.send(Event::Message { from: ProcessId(from), msg }).is_err() {
                return;
            }
        }
    }
}

/// Start a Tempo node listening on `addrs[id]`, connecting to all peers.
/// `addrs` must be identical across the cluster. The same listener serves
/// protocol peers and [`TcpClient`]s.
pub fn start_node(id: ProcessId, config: Config, addrs: Vec<String>) -> Result<NodeHandle> {
    let me = id.0 as usize;
    let listener =
        TcpListener::bind(&addrs[me]).with_context(|| format!("bind {}", addrs[me]))?;
    let (events_tx, events_rx) = channel::<Event>();
    let mut threads = Vec::new();

    // Acceptor: protocol peers and clients dial us.
    {
        let tx = events_tx.clone();
        threads.push(std::thread::spawn(move || {
            for stream in listener.incoming() {
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let tx = tx.clone();
                std::thread::spawn(move || serve_connection(stream, id, tx));
            }
        }));
    }

    // Dial every peer (retry until the whole cluster is up).
    let mut peers: HashMap<ProcessId, TcpStream> = HashMap::new();
    for (j, addr) in addrs.iter().enumerate() {
        if j == me {
            continue;
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50));
                    let _ = e;
                }
                Err(e) => return Err(e).with_context(|| format!("connect {addr}")),
            }
        };
        stream.set_nodelay(true)?;
        peers.insert(ProcessId(j as u32), stream);
    }

    // Tick timer.
    {
        let tx = events_tx.clone();
        let interval = Duration::from_micros(config.tick_interval_us.max(500));
        threads.push(std::thread::spawn(move || loop {
            std::thread::sleep(interval);
            if tx.send(Event::Tick).is_err() {
                break;
            }
        }));
    }

    let counters = Arc::new(Mutex::new(Counters::default()));
    let store_digest = Arc::new(Mutex::new(0u64));
    let executed = Arc::new(Mutex::new(0u64));

    // Protocol thread: the state machine, the executor over the KV store,
    // and the rid → reply-channel routing table.
    {
        let counters = counters.clone();
        let store_digest = store_digest.clone();
        let executed = executed.clone();
        threads.push(std::thread::spawn(move || {
            let mut proto = Tempo::new(id, config);
            let mut exec = Executor::new(id, KvStore::new());
            let mut done: DoneMap = HashMap::new();
            let mut last_executed = 0u64;
            let start = Instant::now();
            let now_us = |s: Instant| s.elapsed().as_micros() as u64;
            for event in events_rx {
                let actions = match event {
                    Event::Message { from, msg } => proto.handle(from, msg, now_us(start)),
                    Event::Submit { cmd, done: tx } => {
                        done.insert(cmd.rid, tx);
                        proto.submit(cmd, now_us(start))
                    }
                    Event::Tick => proto.tick(now_us(start)),
                    Event::Shutdown => break,
                };
                let actions = exec.absorb(actions);
                for action in actions {
                    match action {
                        Action::Send { to, msg } => {
                            if let Some(stream) = peers.get_mut(&to) {
                                // A dead peer just drops its traffic.
                                let _ = write_msg(stream, id, &msg);
                            }
                        }
                        Action::Reply { rid, response } => {
                            if let Some(tx) = done.remove(&rid) {
                                let _ = tx.send((rid, response));
                            }
                        }
                        _ => {}
                    }
                }
                if exec.executed() != last_executed {
                    last_executed = exec.executed();
                    *executed.lock().unwrap() = last_executed;
                    *store_digest.lock().unwrap() = exec.state().digest();
                }
                *counters.lock().unwrap() = proto.counters();
            }
        }));
    }

    Ok(NodeHandle { id, events: events_tx, threads, counters, store_digest, executed })
}

/// A real request/response client: a [`Session`] speaking `ClientSubmit`
/// / `ClientReply` frames to one node over its own TCP connection.
pub struct TcpClient {
    session: Session,
    stream: TcpStream,
}

impl TcpClient {
    /// Connect to the node at `addr` as `client`. Client ids must be
    /// unique across the deployment (they name the session's requests).
    pub fn connect(addr: &str, client: ClientId) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(TcpClient { session: Session::new(client), stream })
    }

    /// The session identity.
    pub fn client(&self) -> ClientId {
        self.session.client()
    }

    /// Abort a blocked [`TcpClient::submit`] after `timeout` (None blocks
    /// forever, the default).
    pub fn set_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Submit one command and block for its response (closed loop): the
    /// session allocates the rid, the frame goes out as `ClientSubmit`,
    /// and the matching `ClientReply` comes back once the command
    /// executed at the node.
    pub fn submit(&mut self, keys: Vec<Key>, op: Op, payload_len: u32) -> Result<(Rid, Response)> {
        let cmd = self.session.command(keys, op, payload_len);
        let rid = cmd.rid;
        let body = wire::encode_client(&wire::ClientFrame::Submit { cmd });
        write_frame(&mut self.stream, CLIENT_FROM, &body)?;
        loop {
            let (_, body) = read_frame(&mut self.stream)?;
            match wire::decode_client(&body)? {
                wire::ClientFrame::Reply { rid: got, response } if got == rid => {
                    return Ok((rid, response));
                }
                // A reply for an earlier (timed-out) request of this
                // closed-loop session: skip it.
                wire::ClientFrame::Reply { .. } => continue,
                wire::ClientFrame::Submit { .. } => bail!("unexpected ClientSubmit from node"),
            }
        }
    }

    /// Single-key shorthand for [`TcpClient::submit`].
    pub fn submit_single(&mut self, key: Key, op: Op, payload_len: u32) -> Result<(Rid, Response)> {
        self.submit(vec![key], op, payload_len)
    }
}

/// Allocate `n` localhost addresses on free ports.
pub fn local_addrs(n: usize) -> Result<Vec<String>> {
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        // Bind to port 0 to reserve a free port, then release it.
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(format!("127.0.0.1:{}", l.local_addr()?.port()));
    }
    Ok(addrs)
}
