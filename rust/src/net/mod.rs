//! Real TCP cluster runtime (std::net + threads; Python is never on this
//! path — the Tempo state machine runs exactly as in the simulator, fed by
//! length-prefixed frames from peer sockets).
//!
//! Topology: one [`NodeHandle`] per process, full mesh of TCP connections,
//! plus a *client plane*: real clients ([`TcpClient`]) dial any node,
//! send `ClientSubmit` frames (docs/WIRE.md tag 17) and receive
//! `ClientReply` frames (tag 18) — request/response over the same
//! listener, distinguished by the frame header's sender field
//! ([`CLIENT_FROM`]). Each node runs (a) an acceptor thread per inbound
//! connection that decodes frames into per-worker event channels, (b)
//! **one protocol thread per worker slot** (`Config::workers`,
//! `protocol::common::shard`): each owns its own Tempo instance over the
//! keys that hash to it, its own [`Executor`]/KV partition and its own
//! rid→reply routing table, and (c) a tick timer fanning ticks to every
//! worker. Peer frames travel inside the worker-routed envelope
//! (docs/WIRE.md tag 19), so the acceptor routes by the envelope tag and
//! client submits route by key hash — the monolithic deployment is simply
//! `workers == 1`.
//!
//! With `Config::batch_max_msgs > 0` each worker's protocol layer
//! coalesces the messages bound for one peer into single `MBatch` frames
//! (`protocol::common::batch`), so this send path makes one `write_all`
//! (one syscall, one frame header) per batch instead of one per message —
//! the TCP layer needs no batching logic of its own beyond the codec.
//! Frame layout and limits are documented in `docs/WIRE.md`.

pub mod wire;

use crate::client::Session;
use crate::core::{ClientId, Command, Config, Key, Op, ProcessId, Response, Rid};
use crate::executor::Executor;
use crate::metrics::Counters;
use crate::protocol::common::shard::{worker_of_cmd, Routed};
use crate::protocol::tempo::msg::Msg;
use crate::protocol::tempo::Tempo;
use crate::protocol::{Action, Protocol};
use crate::store::KvStore;
use crate::util::error::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sender field of frames on the client plane: a connection whose frames
/// carry this value is a client session, not a protocol peer (no real
/// `ProcessId` can collide — process ids are dense and small).
pub const CLIENT_FROM: u32 = u32::MAX;

/// Events fed to one worker's protocol thread.
enum Event {
    Message { from: ProcessId, msg: Msg },
    Submit { cmd: Command, done: Sender<(Rid, Response)> },
    Tick,
    Shutdown,
}

/// A completion listener registered per in-flight request id.
type DoneMap = HashMap<Rid, Sender<(Rid, Response)>>;

/// Per-worker observability shared with the [`NodeHandle`].
#[derive(Default)]
struct WorkerStats {
    counters: Counters,
    executed: u64,
    digest: u64,
}

/// Handle to a running node.
pub struct NodeHandle {
    pub id: ProcessId,
    /// One event channel per worker slot.
    events: Vec<Sender<Event>>,
    workers: usize,
    threads: Vec<JoinHandle<()>>,
    /// One independently-locked stats slot per worker: each protocol
    /// thread writes only its own slot, so the shared-nothing workers
    /// never contend on observability.
    stats: Vec<Arc<Mutex<WorkerStats>>>,
}

impl NodeHandle {
    /// Submit a command from an in-process client session; the response
    /// arrives on the returned receiver once the command executes at this
    /// node (the owning worker's executor emits `Action::Reply`).
    pub fn submit(&self, cmd: Command) -> Receiver<(Rid, Response)> {
        let (tx, rx) = channel();
        let w = worker_of_cmd(&cmd, self.workers)
            .unwrap_or_else(|(a, b)| panic!("command spans worker slots {a} and {b}"));
        let _ = self.events[w].send(Event::Submit { cmd, done: tx });
        rx
    }

    /// Merged protocol counters across the node's worker slots.
    pub fn counters(&self) -> Counters {
        let mut c = Counters::default();
        for slot in &self.stats {
            c.merge(&slot.lock().unwrap().counters);
        }
        c
    }

    /// Commands executed across all worker slots.
    pub fn executed(&self) -> u64 {
        self.stats.iter().map(|s| s.lock().unwrap().executed).sum()
    }

    /// Combined store digest: XOR of the per-worker KV partition digests.
    /// Workers partition the key space, so two replicas that executed the
    /// same commands agree slot-wise — and therefore on the XOR.
    pub fn store_digest(&self) -> u64 {
        self.stats.iter().fold(0, |acc, s| acc ^ s.lock().unwrap().digest)
    }

    /// Stop the protocol threads. Acceptor/tick threads are detached (they
    /// block on the listener/timer and exit with the process).
    pub fn shutdown(self) {
        for tx in &self.events {
            let _ = tx.send(Event::Shutdown);
        }
        drop(self.threads);
    }
}

fn write_frame(stream: &mut TcpStream, from: u32, body: &[u8]) -> Result<()> {
    let mut frame = Vec::with_capacity(body.len() + 8);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&from.to_le_bytes());
    frame.extend_from_slice(body);
    stream.write_all(&frame)?;
    Ok(())
}

/// Write one routed protocol frame to a peer stream shared between the
/// node's worker threads (the mutex keeps frames atomic on the wire).
fn write_routed(stream: &Mutex<TcpStream>, from: ProcessId, routed: &Routed<Msg>) -> Result<()> {
    let body = wire::encode_routed(routed);
    let mut stream = stream.lock().unwrap();
    write_frame(&mut stream, from.0, &body)
}

/// Upper bound on one frame body (`docs/WIRE.md`): a corrupt or hostile
/// length header must not make a node allocate gigabytes before the codec
/// ever sees the bytes. The sender side cooperates: the batching layer
/// flushes a destination queue at `BATCH_SOFT_MAX_BYTES` (4 MiB of
/// estimated encoding, `protocol::common::batch`), keeping legitimate
/// `MBatch` frames far below this cap.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Read one raw frame: the sender field and the undecoded body. The
/// caller decodes as a routed protocol message or a client frame
/// depending on the sender ([`CLIENT_FROM`] marks the client plane).
fn read_frame(stream: &mut TcpStream) -> Result<(u32, Vec<u8>)> {
    let mut hdr = [0u8; 8];
    stream.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})");
    }
    let from = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok((from, body))
}

/// Serve one inbound connection: routed protocol frames go to the worker
/// slot named by their envelope; client submits route by key hash and
/// lazily start a reply-writer thread for the connection, registering its
/// sender as the request's completion route.
fn serve_connection(mut stream: TcpStream, node: ProcessId, txs: Vec<Sender<Event>>) {
    let workers = txs.len();
    let mut reply_tx: Option<Sender<(Rid, Response)>> = None;
    loop {
        let (from, body) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return,
        };
        if from == CLIENT_FROM {
            let cmd = match wire::decode_client(&body) {
                Ok(wire::ClientFrame::Submit { cmd }) => cmd,
                // A node never receives replies; malformed input drops
                // the connection (the codec promises Err, not panic).
                Ok(wire::ClientFrame::Reply { .. }) | Err(_) => return,
            };
            // A command must live inside one worker slot (see
            // protocol::common::shard); a spanning key set is malformed
            // for this deployment and drops the connection.
            let w = match worker_of_cmd(&cmd, workers) {
                Ok(w) => w,
                Err(_) => return,
            };
            if reply_tx.is_none() {
                let mut wstream = match stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                let (txr, rxr) = channel::<(Rid, Response)>();
                std::thread::spawn(move || {
                    for (rid, response) in rxr {
                        let body =
                            wire::encode_client(&wire::ClientFrame::Reply { rid, response });
                        if write_frame(&mut wstream, node.0, &body).is_err() {
                            return;
                        }
                    }
                });
                reply_tx = Some(txr);
            }
            let done = reply_tx.as_ref().expect("reply writer started").clone();
            if txs[w].send(Event::Submit { cmd, done }).is_err() {
                return;
            }
        } else {
            let routed = match wire::decode_routed(&body) {
                Ok(r) => r,
                Err(_) => return,
            };
            let w = routed.worker as usize;
            if w >= workers {
                return; // hostile/mismatched deployment
            }
            if txs[w].send(Event::Message { from: ProcessId(from), msg: routed.msg }).is_err() {
                return;
            }
        }
    }
}

/// Start a Tempo node listening on `addrs[id]`, connecting to all peers.
/// `addrs` must be identical across the cluster, and so must
/// `config.workers` — worker slot `w` of this node talks only to slot `w`
/// of its peers. The same listener serves protocol peers and
/// [`TcpClient`]s.
pub fn start_node(id: ProcessId, config: Config, addrs: Vec<String>) -> Result<NodeHandle> {
    let me = id.0 as usize;
    let workers = config.workers.max(1);
    // The peer-frame envelope names the worker slot in one byte; refuse a
    // config that could not be represented instead of truncating.
    assert!(workers <= 256, "workers must be <= 256 (u8 slot on the wire)");
    let listener =
        TcpListener::bind(&addrs[me]).with_context(|| format!("bind {}", addrs[me]))?;
    let mut event_txs: Vec<Sender<Event>> = Vec::with_capacity(workers);
    let mut event_rxs: Vec<Receiver<Event>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = channel::<Event>();
        event_txs.push(tx);
        event_rxs.push(rx);
    }
    let mut threads = Vec::new();

    // Acceptor: protocol peers and clients dial us.
    {
        let txs = event_txs.clone();
        threads.push(std::thread::spawn(move || {
            for stream in listener.incoming() {
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let txs = txs.clone();
                std::thread::spawn(move || serve_connection(stream, id, txs));
            }
        }));
    }

    // Dial every peer (retry until the whole cluster is up). Streams are
    // shared between the worker threads, mutex-guarded per peer.
    let mut peers: HashMap<ProcessId, Arc<Mutex<TcpStream>>> = HashMap::new();
    for (j, addr) in addrs.iter().enumerate() {
        if j == me {
            continue;
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50));
                    let _ = e;
                }
                Err(e) => return Err(e).with_context(|| format!("connect {addr}")),
            }
        };
        stream.set_nodelay(true)?;
        peers.insert(ProcessId(j as u32), Arc::new(Mutex::new(stream)));
    }

    // Tick timer: fan one tick to every worker slot.
    {
        let txs = event_txs.clone();
        let interval = Duration::from_micros(config.tick_interval_us.max(500));
        threads.push(std::thread::spawn(move || loop {
            std::thread::sleep(interval);
            for tx in &txs {
                if tx.send(Event::Tick).is_err() {
                    return;
                }
            }
        }));
    }

    let stats: Vec<Arc<Mutex<WorkerStats>>> =
        (0..workers).map(|_| Arc::new(Mutex::new(WorkerStats::default()))).collect();

    // One protocol thread per worker slot: the slot's state machine, its
    // executor over its KV partition, and its rid → reply routing table.
    for (w, events_rx) in event_rxs.into_iter().enumerate() {
        let stats = stats[w].clone();
        let peers = peers.clone();
        let mut cfg = config.clone();
        cfg.workers = workers;
        cfg.worker = w;
        threads.push(std::thread::spawn(move || {
            let mut proto = Tempo::new(id, cfg);
            let mut exec = Executor::new(id, KvStore::new());
            let mut done: DoneMap = HashMap::new();
            let start = Instant::now();
            let now_us = |s: Instant| s.elapsed().as_micros() as u64;
            for event in events_rx {
                let actions = match event {
                    Event::Message { from, msg } => proto.handle(from, msg, now_us(start)),
                    Event::Submit { cmd, done: tx } => {
                        done.insert(cmd.rid, tx);
                        proto.submit(cmd, now_us(start))
                    }
                    Event::Tick => proto.tick(now_us(start)),
                    Event::Shutdown => break,
                };
                let actions = exec.absorb(actions);
                for action in actions {
                    match action {
                        Action::Send { to, msg } => {
                            if let Some(stream) = peers.get(&to) {
                                // A dead peer just drops its traffic.
                                let routed = Routed { worker: w as u32, msg };
                                let _ = write_routed(stream, id, &routed);
                            }
                        }
                        Action::Reply { rid, response } => {
                            if let Some(tx) = done.remove(&rid) {
                                let _ = tx.send((rid, response));
                            }
                        }
                        _ => {}
                    }
                }
                let mut slot = stats.lock().unwrap();
                if exec.executed() != slot.executed {
                    slot.executed = exec.executed();
                    slot.digest = exec.state().digest();
                }
                slot.counters = proto.counters();
            }
        }));
    }

    Ok(NodeHandle { id, events: event_txs, workers, threads, stats })
}

/// A real request/response client: a [`Session`] speaking `ClientSubmit`
/// / `ClientReply` frames to one node over its own TCP connection.
///
/// Supports **pipelining**: [`TcpClient::submit_async`] puts a request on
/// the wire without waiting, [`TcpClient::recv_reply`] completes the next
/// outstanding request in whatever order the node finishes them — the
/// wire protocol routes replies by request id, so several rids may be in
/// flight per session. [`TcpClient::submit`] remains the closed-loop
/// convenience (submit one, block for that rid, buffering any other
/// pipelined replies that arrive first).
pub struct TcpClient {
    session: Session,
    stream: TcpStream,
    /// Rids submitted and not yet completed.
    outstanding: HashSet<Rid>,
    /// Replies read off the socket while waiting for a different rid.
    buffered: HashMap<Rid, Response>,
}

impl TcpClient {
    /// Connect to the node at `addr` as `client`. Client ids must be
    /// unique across the deployment (they name the session's requests).
    pub fn connect(addr: &str, client: ClientId) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(TcpClient {
            session: Session::new(client),
            stream,
            outstanding: HashSet::new(),
            buffered: HashMap::new(),
        })
    }

    /// The session identity.
    pub fn client(&self) -> ClientId {
        self.session.client()
    }

    /// Requests currently in flight (pipelined and not yet completed).
    pub fn in_flight(&self) -> usize {
        self.outstanding.len()
    }

    /// Abort a blocked receive after `timeout` (None blocks forever, the
    /// default).
    pub fn set_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Pipeline one command: allocate its rid, put the `ClientSubmit`
    /// frame on the wire and return immediately. Complete it (in any
    /// order) with [`TcpClient::recv_reply`].
    pub fn submit_async(&mut self, keys: Vec<Key>, op: Op, payload_len: u32) -> Result<Rid> {
        let cmd = self.session.command(keys, op, payload_len);
        let rid = cmd.rid;
        let body = wire::encode_client(&wire::ClientFrame::Submit { cmd });
        write_frame(&mut self.stream, CLIENT_FROM, &body)?;
        self.outstanding.insert(rid);
        Ok(rid)
    }

    /// Complete the next outstanding request: returns a buffered reply if
    /// one was already read, otherwise blocks on the socket. Replies may
    /// complete in a different order than their submissions. Replies for
    /// rids that are no longer outstanding (an earlier request whose
    /// `submit` timed out and was abandoned) are skipped, exactly like
    /// the closed-loop path skips them.
    pub fn recv_reply(&mut self) -> Result<(Rid, Response)> {
        if let Some(&rid) = self.buffered.keys().next() {
            let response = self.buffered.remove(&rid).expect("buffered reply");
            self.outstanding.remove(&rid);
            return Ok((rid, response));
        }
        if self.outstanding.is_empty() {
            bail!("no outstanding requests to receive");
        }
        loop {
            let (rid, response) = self.read_reply()?;
            if self.outstanding.remove(&rid) {
                return Ok((rid, response));
            }
            // else: stale reply for an abandoned request — skip it.
        }
    }

    /// Read one `ClientReply` frame off the socket.
    fn read_reply(&mut self) -> Result<(Rid, Response)> {
        let (_, body) = read_frame(&mut self.stream)?;
        match wire::decode_client(&body)? {
            wire::ClientFrame::Reply { rid, response } => Ok((rid, response)),
            wire::ClientFrame::Submit { .. } => bail!("unexpected ClientSubmit from node"),
        }
    }

    /// Submit one command and block for *its* response (closed loop over
    /// the pipelined plumbing): replies for other in-flight rids that
    /// arrive first are buffered, not dropped. On error (e.g. a read
    /// timeout) the request is abandoned — its rid leaves `outstanding`,
    /// so a late reply for it is skipped rather than mistaken for a
    /// pipelined completion.
    pub fn submit(&mut self, keys: Vec<Key>, op: Op, payload_len: u32) -> Result<(Rid, Response)> {
        let rid = self.submit_async(keys, op, payload_len)?;
        loop {
            if let Some(response) = self.buffered.remove(&rid) {
                self.outstanding.remove(&rid);
                return Ok((rid, response));
            }
            let (got, response) = match self.read_reply() {
                Ok(r) => r,
                Err(e) => {
                    self.outstanding.remove(&rid);
                    return Err(e);
                }
            };
            if got == rid {
                self.outstanding.remove(&rid);
                return Ok((rid, response));
            }
            if self.outstanding.contains(&got) {
                self.buffered.insert(got, response);
            }
            // else: a reply for an earlier (timed-out) request — skip it.
        }
    }

    /// Single-key shorthand for [`TcpClient::submit`].
    pub fn submit_single(&mut self, key: Key, op: Op, payload_len: u32) -> Result<(Rid, Response)> {
        self.submit(vec![key], op, payload_len)
    }
}

/// Allocate `n` localhost addresses on free ports.
pub fn local_addrs(n: usize) -> Result<Vec<String>> {
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        // Bind to port 0 to reserve a free port, then release it.
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(format!("127.0.0.1:{}", l.local_addr()?.port()));
    }
    Ok(addrs)
}
