//! Real TCP cluster runtime (std::net + threads; Python is never on this
//! path — the Tempo state machine runs exactly as in the simulator, fed by
//! length-prefixed frames from peer sockets).
//!
//! Topology: one [`NodeHandle`] per process, full mesh of TCP connections.
//! Each node runs (a) an acceptor thread per peer connection that decodes
//! frames into an event channel, (b) the protocol thread owning the Tempo
//! state machine, the KV store, and a tick timer, (c) a client API
//! ([`NodeHandle::submit`]) that enqueues commands and returns completion
//! notifications through a channel.
//!
//! With `Config::batch_max_msgs > 0` the protocol layer coalesces the
//! messages bound for one peer into single `MBatch` frames
//! (`protocol::common::batch`), so this send path makes one `write_all`
//! (one syscall, one frame header) per batch instead of one per message —
//! the TCP layer needs no batching logic of its own beyond the codec.
//! Frame layout and limits are documented in `docs/WIRE.md`.

pub mod wire;

use crate::core::{Command, Config, Dot, DotGen, ProcessId};
use crate::metrics::Counters;
use crate::protocol::tempo::msg::Msg;
use crate::protocol::tempo::Tempo;
use crate::protocol::{Action, Protocol};
use crate::store::{KvStore, Response};
use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Events fed to the protocol thread.
enum Event {
    Message { from: ProcessId, msg: Msg },
    Submit { cmd: Command, done: Sender<(Dot, Response)> },
    Tick,
    Shutdown,
}

/// A completion listener registered per in-flight dot.
type DoneMap = HashMap<Dot, Sender<(Dot, Response)>>;

/// Handle to a running node.
pub struct NodeHandle {
    pub id: ProcessId,
    events: Sender<Event>,
    threads: Vec<JoinHandle<()>>,
    pub counters: Arc<Mutex<Counters>>,
    pub store_digest: Arc<Mutex<u64>>,
    pub executed: Arc<Mutex<u64>>,
}

impl NodeHandle {
    /// Submit a command; the response arrives on the returned receiver once
    /// the command executes locally (origin completion, as in the paper).
    pub fn submit(&self, cmd: Command) -> Receiver<(Dot, Response)> {
        let (tx, rx) = channel();
        let _ = self.events.send(Event::Submit { cmd, done: tx });
        rx
    }

    /// Stop the protocol thread. Acceptor/tick threads are detached (they
    /// block on the listener/timer and exit with the process).
    pub fn shutdown(self) {
        let _ = self.events.send(Event::Shutdown);
        drop(self.threads);
    }
}

fn write_frame(stream: &mut TcpStream, from: ProcessId, msg: &Msg) -> Result<()> {
    let body = wire::encode(msg);
    let mut frame = Vec::with_capacity(body.len() + 8);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&from.0.to_le_bytes());
    frame.extend_from_slice(&body);
    stream.write_all(&frame)?;
    Ok(())
}

/// Upper bound on one frame body (`docs/WIRE.md`): a corrupt or hostile
/// length header must not make a node allocate gigabytes before the codec
/// ever sees the bytes. The sender side cooperates: the batching layer
/// flushes a destination queue at `BATCH_SOFT_MAX_BYTES` (4 MiB of
/// estimated encoding, `protocol::common::batch`), keeping legitimate
/// `MBatch` frames far below this cap.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

fn read_frame(stream: &mut TcpStream) -> Result<(ProcessId, Msg)> {
    let mut hdr = [0u8; 8];
    stream.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})");
    }
    let from = ProcessId(u32::from_le_bytes(hdr[4..8].try_into().unwrap()));
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok((from, wire::decode(&body)?))
}

/// Start a Tempo node listening on `addrs[id]`, connecting to all peers.
/// `addrs` must be identical across the cluster.
pub fn start_node(id: ProcessId, config: Config, addrs: Vec<String>) -> Result<NodeHandle> {
    let me = id.0 as usize;
    let listener =
        TcpListener::bind(&addrs[me]).with_context(|| format!("bind {}", addrs[me]))?;
    let (events_tx, events_rx) = channel::<Event>();
    let mut threads = Vec::new();

    // Acceptor: peers with higher ids dial us.
    {
        let tx = events_tx.clone();
        let expect = addrs.len() - 1 - me; // only higher ids dial in? see below
        let _ = expect;
        threads.push(std::thread::spawn(move || {
            for stream in listener.incoming() {
                let mut stream = match stream {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let tx = tx.clone();
                std::thread::spawn(move || loop {
                    match read_frame(&mut stream) {
                        Ok((from, msg)) => {
                            if tx.send(Event::Message { from, msg }).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                });
            }
        }));
    }

    // Dial every peer (retry until the whole cluster is up).
    let mut peers: HashMap<ProcessId, TcpStream> = HashMap::new();
    for (j, addr) in addrs.iter().enumerate() {
        if j == me {
            continue;
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(50));
                    let _ = e;
                }
                Err(e) => return Err(e).with_context(|| format!("connect {addr}")),
            }
        };
        stream.set_nodelay(true)?;
        peers.insert(ProcessId(j as u32), stream);
    }

    // Tick timer.
    {
        let tx = events_tx.clone();
        let interval = Duration::from_micros(config.tick_interval_us.max(500));
        threads.push(std::thread::spawn(move || loop {
            std::thread::sleep(interval);
            if tx.send(Event::Tick).is_err() {
                break;
            }
        }));
    }

    let counters = Arc::new(Mutex::new(Counters::default()));
    let store_digest = Arc::new(Mutex::new(0u64));
    let executed = Arc::new(Mutex::new(0u64));

    // Protocol thread.
    {
        let counters = counters.clone();
        let store_digest = store_digest.clone();
        let executed = executed.clone();
        threads.push(std::thread::spawn(move || {
            let mut proto = Tempo::new(id, config);
            let mut store = KvStore::new();
            let mut dots = DotGen::new(id);
            let mut done: DoneMap = HashMap::new();
            let start = Instant::now();
            let now_us = |s: Instant| s.elapsed().as_micros() as u64;
            for event in events_rx {
                let actions = match event {
                    Event::Message { from, msg } => proto.handle(from, msg, now_us(start)),
                    Event::Submit { cmd, done: tx } => {
                        let dot = dots.next();
                        done.insert(dot, tx);
                        proto.submit(dot, cmd, now_us(start))
                    }
                    Event::Tick => proto.tick(now_us(start)),
                    Event::Shutdown => break,
                };
                for action in actions {
                    match action {
                        Action::Send { to, msg } => {
                            if let Some(stream) = peers.get_mut(&to) {
                                // A dead peer just drops its traffic.
                                let _ = write_frame(stream, id, &msg);
                            }
                        }
                        Action::Execute { dot, cmd } => {
                            let resp = store.execute(&cmd);
                            *executed.lock().unwrap() += 1;
                            *store_digest.lock().unwrap() = store.digest();
                            if dot.origin == id {
                                if let Some(tx) = done.remove(&dot) {
                                    let _ = tx.send((dot, resp));
                                }
                            }
                        }
                        _ => {}
                    }
                }
                *counters.lock().unwrap() = proto.counters();
            }
        }));
    }

    Ok(NodeHandle { id, events: events_tx, threads, counters, store_digest, executed })
}

/// Allocate `n` localhost addresses on free ports.
pub fn local_addrs(n: usize) -> Result<Vec<String>> {
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        // Bind to port 0 to reserve a free port, then release it.
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(format!("127.0.0.1:{}", l.local_addr()?.port()));
    }
    Ok(addrs)
}
