//! Binary wire codec for Tempo protocol messages (tags 0–16 plus the
//! epoch reconfiguration vote, tag 21), the client service frames
//! (tags 17–18), and the state-transfer frames (tags 22–24). The
//! offline registry has no serde,
//! so framing is hand-rolled: length-prefixed frames, little-endian
//! fixed-width integers, u8 message tags. The complete frame layout —
//! every tag, every compound encoding, and the malformed-input error
//! contract — is documented in `docs/WIRE.md`; keep the two in sync.
//!
//! The tag ranges are *strictly separated streams*: [`decode`]
//! (protocol messages, peer connections) rejects a client or transfer
//! tag, [`decode_client`] (client connections) rejects a protocol or
//! transfer tag, and [`decode_transfer`] (restart state transfer)
//! rejects everything else — a frame can never cross from one plane
//! into another, and an `MBatch` member carrying a client or transfer
//! frame is malformed the same way a nested batch is.
//!
//! **Send path (encode-once, zero-alloc).** Every encoder comes in an
//! append-into form — [`encode_into`], [`encode_routed_into`],
//! [`encode_client_into`] — that writes into a caller-owned [`Writer`]
//! with no intermediate buffers (`MBatch` members are encoded in place
//! behind a backfilled length prefix), plus an exact size function
//! ([`encoded_len`] and friends) so callers reserve once and never
//! reallocate mid-encode. The legacy `encode*` functions are thin
//! wrappers. Buffers themselves come from the [`FrameBuf`] pool (a
//! thread-local free list with a global overflow, shared by the send and
//! receive ends of the TCP runtime), and a broadcast encodes **once**
//! into an `Arc<[u8]>` body shared by every destination
//! ([`encode_routed_shared`]). The merged transport frame
//! ([`TAG_MERGED`]) coalesces several routed envelopes bound for one
//! peer into a single wire frame without re-encoding any of them.

use crate::core::{ClientId, Command, Dot, Op, ProcessId, Response, Rid, ShardId};
use crate::protocol::common::shard::Routed;
use crate::protocol::tempo::msg::{KeyPromises, KeyTs, Msg, Phase, Quorums};
use crate::protocol::tempo::promises::PromiseSet;
use crate::util::error::{bail, Result};
use std::cell::RefCell;
use std::sync::Arc;
use std::sync::Mutex;

/// Tag of the `ClientSubmit` frame (docs/WIRE.md).
pub const TAG_CLIENT_SUBMIT: u8 = 17;
/// Tag of the `ClientReply` frame (docs/WIRE.md).
pub const TAG_CLIENT_REPLY: u8 = 18;
/// Tag of the worker-routed envelope around a protocol message
/// (docs/WIRE.md): `[19][worker u8][inner msg]`. Peer connections under
/// worker sharding carry only routed frames; the inner message may be
/// anything `decode` accepts (including `MBatch`), never another
/// envelope.
pub const TAG_ROUTED: u8 = 19;
/// Tag of the merged transport frame (docs/WIRE.md):
/// `[20][n: u16][n × (len: u32, routed envelope bytes)]`. The per-peer
/// outbound stage of the TCP runtime coalesces the routed frames queued
/// for one peer (typically the ≤ `workers` per-slot `MBatch` flushes of
/// one tick) into a single wire frame. Members are *already-encoded*
/// routed envelopes — merging never re-serializes — and the envelope
/// appears only at the top of a peer frame body, exactly like
/// [`TAG_ROUTED`]: never bare, never inside `MBatch`, never nested.
pub const TAG_MERGED: u8 = 20;
/// Tag of the `MEpoch` reconfiguration vote (docs/WIRE.md):
/// `[21][epoch: u64][n: u16][n × member: u32]`. A protocol-plane
/// message like tags 0–16: legal bare, inside `MBatch`, and under a
/// routed envelope; never on the client plane.
pub const TAG_EPOCH: u8 = 21;
/// Tag of the `ManifestRequest` state-transfer frame (docs/WIRE.md):
/// `[22][slot: u32]`. Transfer-plane only — never a protocol message,
/// never a client frame, never inside `MBatch`.
pub const TAG_MANIFEST_REQUEST: u8 = 22;
/// Tag of the `ManifestReply` state-transfer frame (docs/WIRE.md):
/// `[23][slot: u32][applied: u64][n: u32][n × hash: u64][f: u16]
/// [f × (origin: u32, floor: u64)][dlen: u32][dlen dedup bytes]`.
pub const TAG_MANIFEST_REPLY: u8 = 23;
/// Tag of the `Chunk` state-transfer frame (docs/WIRE.md):
/// `[24][slot: u32][hash: u64][present: u8][len: u32][len page bytes]`.
/// Bidirectional: a fetch request carries `present = 0` and no bytes;
/// the donor's reply carries `present = 1` plus the page (or
/// `present = 0` if the donor no longer holds that hash).
pub const TAG_CHUNK: u8 = 24;
/// Tag of the `ClientBusy` frame (docs/WIRE.md): `[25][rid]`. Node →
/// client load-shed reply from the event-loop edge's admission control:
/// the session's in-flight window (`Config::max_inflight_per_session`)
/// was full, so the submission named by `rid` was **not** accepted —
/// never forwarded to a worker, never ordered, never executed. The
/// client may re-issue the same command with the same rid once its
/// window drains (the per-client dedup window makes that safe even if
/// a copy did slip through elsewhere). Client-plane only, exactly like
/// tags 17–18.
pub const TAG_CLIENT_BUSY: u8 = 25;
/// Tag of the heartbeat frame (docs/WIRE.md): `[26]` — a body of
/// exactly the tag byte, nothing else. **Transport plane only**: a
/// node's per-peer writer emits one whenever
/// `Config::heartbeat_interval_us` elapses with nothing queued for
/// that peer, and the receiving end consumes it while refreshing the
/// sender's last-seen time — *before* any codec runs. Every decoder
/// (protocol, client, transfer) therefore rejects it exactly like a
/// cross-plane tag, and it is never legal inside `MBatch`, a routed
/// envelope, or a merged frame.
pub const TAG_HEARTBEAT: u8 = 26;

/// True iff `tag` belongs to the client plane (tags 17, 18, 25).
pub(crate) fn is_client_tag(tag: u8) -> bool {
    tag == TAG_CLIENT_SUBMIT || tag == TAG_CLIENT_REPLY || tag == TAG_CLIENT_BUSY
}

/// Frames exchanged between a client session and a node over the client
/// plane of the TCP runtime (never between protocol peers).
#[derive(Clone, Debug, PartialEq)]
pub enum ClientFrame {
    /// Client → node: submit `cmd` (which carries its `Rid`) at this
    /// replica. `floor` is the session's read-your-writes floor (the
    /// decided timestamp of its last acknowledged write; 0 when the
    /// session never wrote or the command is a write — only
    /// `Protocol::submit_read` consumes it). Tag 17.
    Submit { cmd: Command, floor: u64 },
    /// Node → client: the response for request `rid`, produced by the
    /// coordinator's executor at execution time. `ts` is the command's
    /// decided timestamp (the covering frontier value for local reads, 0
    /// on timestamp-free protocol families) — the session raises its
    /// read-your-writes floor to the `ts` of each acknowledged write.
    /// Tag 18.
    Reply { rid: Rid, response: Response, ts: u64 },
    /// Node → client: admission control shed the submission named by
    /// `rid` — the session already had `Config::max_inflight_per_session`
    /// requests in flight, so this one was rejected *at the edge*,
    /// before any worker saw it. Retryable: the command was not
    /// executed and re-issuing it with the same rid is safe. Tag 25.
    Busy { rid: Rid },
}

/// Frames of the state-transfer plane (docs/WIRE.md tags 22–24): a
/// recovering replica dials a donor with the [`TRANSFER_FROM`] sender
/// marker, requests the donor's per-slot snapshot manifest, diffs it
/// against its own recovered chunks, and fetches only the pages it
/// cannot produce locally. Strictly separated from the protocol and
/// client planes, exactly like tags 17–20.
///
/// [`TRANSFER_FROM`]: super::TRANSFER_FROM
#[derive(Clone, Debug, PartialEq)]
pub enum TransferFrame {
    /// Recovering replica → donor: send me worker slot `slot`'s current
    /// manifest. Tag 22.
    ManifestRequest { slot: u32 },
    /// Donor → recovering replica: slot `slot`'s content-addressed
    /// manifest — applied count, page hashes in chunk order, per-origin
    /// dot floors, and the executor's serialized dedup windows. Tag 23.
    ManifestReply {
        /// Worker slot the manifest describes.
        slot: u32,
        /// Commands applied by the donor's store at manifest time.
        applied: u64,
        /// Page hashes, in `Snapshottable::to_chunks` order.
        chunks: Vec<u64>,
        /// Highest dot sequence the donor has durably seen per origin.
        dot_floors: Vec<(ProcessId, u64)>,
        /// `Executor::dedup_blob` of the donor at manifest time.
        dedup: Vec<u8>,
    },
    /// Page fetch (both directions, distinguished by role): the
    /// recovering replica sends `present = false` with empty `data` to
    /// request `hash`; the donor replies `present = true` with the page
    /// bytes, or `present = false` if it no longer holds the hash. Tag
    /// 24.
    Chunk { slot: u32, hash: u64, present: bool, data: Vec<u8> },
}

pub struct Writer {
    pub buf: Vec<u8>,
}

impl Default for Writer {
    fn default() -> Self {
        Self::new()
    }
}

impl Writer {
    pub fn new() -> Self {
        Writer { buf: Vec::with_capacity(256) }
    }

    /// A writer whose buffer holds `n` bytes without reallocating — pair
    /// with the exact [`encoded_len`] family for single-allocation
    /// encodes.
    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    /// Wrap an existing (e.g. pooled) buffer; encoding appends to it.
    pub fn from_vec(buf: Vec<u8>) -> Self {
        Writer { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn dot(&mut self, d: Dot) {
        self.u32(d.origin.0);
        self.u64(d.seq);
    }
    fn rid(&mut self, r: Rid) {
        self.u64(r.client().0);
        self.u64(r.seq());
    }
    fn cmd(&mut self, c: &Command) {
        self.rid(c.rid);
        self.u8(match c.op {
            Op::Get => 0,
            Op::Put => 1,
            Op::Rmw => 2,
            // The read flag of the local-read path: op tag 3 marks a
            // `ClientSubmit` as eligible for `Protocol::submit_read`.
            Op::Read => 3,
        });
        self.u32(c.payload_len);
        self.u32(c.batched);
        self.u16(c.keys.len() as u16);
        for &k in c.keys.iter() {
            self.u64(k);
        }
        // Materialize the payload (contents are irrelevant to ordering,
        // so the bytes are zero) — frames carry realistic sizes and
        // `Command::wire_size` equals the encoded length exactly.
        self.buf.resize(self.buf.len() + c.payload_len as usize, 0);
    }
    fn response(&mut self, r: &Response) {
        self.u16(r.versions.len() as u16);
        for &(k, v) in &r.versions {
            self.u64(k);
            self.u64(v);
        }
    }
    fn quorums(&mut self, q: &[(ShardId, Vec<ProcessId>)]) {
        self.u8(q.len() as u8);
        for (s, procs) in q {
            self.u32(s.0);
            self.u8(procs.len() as u8);
            for p in procs {
                self.u32(p.0);
            }
        }
    }
    fn key_ts(&mut self, ts: &[(u64, u64)]) {
        self.u16(ts.len() as u16);
        for &(k, t) in ts {
            self.u64(k);
            self.u64(t);
        }
    }
    fn promise_set(&mut self, p: &PromiseSet) {
        self.u16(p.detached.len() as u16);
        for &(lo, hi) in &p.detached {
            self.u64(lo);
            self.u64(hi);
        }
        self.u16(p.attached.len() as u16);
        for &(d, t) in &p.attached {
            self.dot(d);
            self.u64(t);
        }
    }
    fn key_promises(&mut self, kp: &[(u64, PromiseSet)]) {
        self.u16(kp.len() as u16);
        for (k, p) in kp {
            self.u64(*k);
            self.promise_set(p);
        }
    }
}

/// Observability for the [`FrameBuf`] pool: process-wide monotone
/// counters (like `core::clone_stats`), surfaced through
/// `metrics::Counters::pooled_hits` by the TCP runtime. A *hit* is any
/// frame served without a fresh heap allocation — a recycled buffer
/// taken from the pool, or a read/encode that fit in a kept buffer's
/// existing capacity; a *miss* had to allocate.
pub mod pool_stats {
    use std::sync::atomic::{AtomicU64, Ordering};

    static HITS: AtomicU64 = AtomicU64::new(0);
    static MISSES: AtomicU64 = AtomicU64::new(0);

    pub(crate) fn hit() {
        HITS.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn miss() {
        MISSES.fetch_add(1, Ordering::Relaxed);
    }

    /// Frames served from recycled capacity (no allocation), so far.
    pub fn hits() -> u64 {
        HITS.load(Ordering::Relaxed)
    }

    /// Frames that had to allocate, so far.
    pub fn misses() -> u64 {
        MISSES.load(Ordering::Relaxed)
    }
}

/// Per-thread free list size; beyond it buffers overflow to the global
/// list (bounded too), then are dropped.
const POOL_LOCAL_CAP: usize = 32;
const POOL_GLOBAL_CAP: usize = 128;
/// How many buffers a take pulls from the global list in one lock
/// acquisition when its local list is empty (one to use, the rest cached
/// locally) — amortizes the global lock across refills.
const POOL_REFILL: usize = 8;
/// A recycled buffer keeps at most this much capacity; larger ones are
/// shrunk on recycle so one jumbo frame cannot pin memory forever. With
/// the list caps this bounds pinned pool memory at ~32 MiB global plus
/// 8 MiB per long-lived thread, worst case — typical frames are a few
/// hundred bytes, so the real footprint is kilobytes.
const POOL_MAX_RETAIN: usize = 256 << 10;

thread_local! {
    static LOCAL_POOL: RefCell<Vec<Vec<u8>>> = const { RefCell::new(Vec::new()) };
}

/// Buffers recycled by a different thread than the one that will take
/// them next — the dominant flow: the TCP runtime's send path hands
/// buffers from protocol threads to per-peer writer threads, which only
/// ever recycle. `recycle` therefore returns buffers **here first** (the
/// local list is the overflow), so the protocol threads' takes keep
/// hitting instead of the buffers stranding in a writer's local list.
static GLOBAL_POOL: Mutex<Vec<Vec<u8>>> = Mutex::new(Vec::new());

/// A wire buffer drawn from the frame pool: a thread-local free list
/// with a global overflow shared across threads. Both ends of the TCP
/// runtime use it — `read_frame` refills one per connection instead of
/// allocating per frame, and the send path encodes point-to-point
/// frames into one, recycling it after the write. **A pooled buffer is
/// never observable across frames**: `take` hands out cleared buffers
/// exclusively owned by the caller, and recycling happens only after
/// the frame's bytes left the process (written to a socket) or were
/// fully decoded.
#[derive(Debug, Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    /// Take a cleared buffer: thread-local pool first; on a local miss,
    /// refill a small batch from the shared global list under one lock
    /// ([`POOL_REFILL`] — recycling is global-first, so takes amortize
    /// the lock instead of paying it per frame); else a fresh allocation
    /// (a pool miss).
    pub fn take() -> FrameBuf {
        let recycled = LOCAL_POOL.with(|p| {
            let mut p = p.borrow_mut();
            if let Some(buf) = p.pop() {
                return Some(buf);
            }
            let mut g = GLOBAL_POOL.lock().unwrap();
            let first = g.pop();
            for _ in 1..POOL_REFILL {
                match g.pop() {
                    Some(buf) => p.push(buf),
                    None => break,
                }
            }
            first
        });
        match recycled {
            Some(mut buf) => {
                buf.clear();
                pool_stats::hit();
                FrameBuf { buf }
            }
            None => {
                pool_stats::miss();
                FrameBuf { buf: Vec::new() }
            }
        }
    }

    /// The underlying buffer (cleared on `take`; callers append/resize).
    pub fn vec(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }

    /// Current contents as a slice.
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Return the buffer to the pool: the shared global list first (the
    /// threads that recycle most — per-peer writers — are not the
    /// threads that take, so local-first recycling would strand buffers),
    /// the recycler's local list as overflow, dropped when both are
    /// full. Oversized buffers shrink to [`POOL_MAX_RETAIN`] first.
    pub fn recycle(mut self) {
        if self.buf.capacity() > POOL_MAX_RETAIN {
            self.buf = Vec::with_capacity(POOL_MAX_RETAIN);
        }
        let buf = std::mem::take(&mut self.buf);
        let overflow = {
            let mut g = GLOBAL_POOL.lock().unwrap();
            if g.len() < POOL_GLOBAL_CAP {
                g.push(buf);
                None
            } else {
                Some(buf)
            }
        };
        if let Some(buf) = overflow {
            LOCAL_POOL.with(|p| {
                let mut p = p.borrow_mut();
                if p.len() < POOL_LOCAL_CAP {
                    p.push(buf);
                }
            });
        }
    }
}

/// Incremental frame decoder: the nonblocking twin of the TCP runtime's
/// blocking `read_frame`, consuming a transport frame —
/// `[len: u32][from: u32][body]` — from byte chunks of **any** split
/// (byte-by-byte included) instead of a socket it may block on. One
/// decoder per connection; the body accumulates in a pooled
/// [`FrameBuf`] reused across frames, with the same per-frame
/// hit/miss accounting as the blocking path (a frame whose body fits
/// the kept capacity is a pool hit). The length header is validated
/// against `net::MAX_FRAME_BYTES` the moment it completes — a corrupt
/// or hostile length never allocates.
///
/// Equivalence with the blocking path is pinned by property tests: any
/// chunking of a frame stream yields exactly the frames `read_frame`
/// would return (`rust/tests/properties.rs`, and the Python mirror in
/// `python/bench/wire.py::self_check`).
pub struct FrameDecoder {
    hdr: [u8; 8],
    hdr_have: usize,
    body: FrameBuf,
    body_len: usize,
    complete: bool,
}

impl Default for FrameDecoder {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameDecoder {
    /// A fresh decoder expecting a frame header (pooled body buffer).
    pub fn new() -> FrameDecoder {
        FrameDecoder {
            hdr: [0u8; 8],
            hdr_have: 0,
            body: FrameBuf::take(),
            body_len: 0,
            complete: false,
        }
    }

    /// Consume bytes from `chunk`, stopping at the end of the current
    /// frame. Returns `(consumed, complete)`: how many bytes of `chunk`
    /// were used, and whether a full frame is now buffered — read it
    /// with [`FrameDecoder::sender`]/[`FrameDecoder::body`], then call
    /// [`FrameDecoder::clear`] before feeding further bytes (a feed on
    /// a complete frame consumes nothing). Errors only on a length
    /// header above `net::MAX_FRAME_BYTES` — the connection is then
    /// poisoned and must be dropped, exactly like the blocking path.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<(usize, bool)> {
        if self.complete {
            return Ok((0, true));
        }
        let mut used = 0;
        if self.hdr_have < 8 {
            let n = (8 - self.hdr_have).min(chunk.len());
            self.hdr[self.hdr_have..self.hdr_have + n].copy_from_slice(&chunk[..n]);
            self.hdr_have += n;
            used += n;
            if self.hdr_have < 8 {
                return Ok((used, false));
            }
            let len = u32::from_le_bytes(self.hdr[0..4].try_into().unwrap()) as usize;
            let max = crate::net::MAX_FRAME_BYTES;
            if len > max {
                bail!("frame of {len} bytes exceeds MAX_FRAME_BYTES ({max})");
            }
            // Same per-frame pool accounting as the blocking read path.
            if self.body.vec().capacity() >= len {
                pool_stats::hit();
            } else {
                pool_stats::miss();
            }
            self.body.vec().clear();
            self.body_len = len;
            if len == 0 {
                self.complete = true;
                return Ok((used, true));
            }
        }
        let need = self.body_len - self.body.bytes().len();
        let take = need.min(chunk.len() - used);
        self.body.vec().extend_from_slice(&chunk[used..used + take]);
        used += take;
        self.complete = self.body.bytes().len() == self.body_len;
        Ok((used, self.complete))
    }

    /// Whether a complete frame is buffered.
    pub fn is_complete(&self) -> bool {
        self.complete
    }

    /// The completed (or in-progress, once the header is in) frame's
    /// sender field — `net::CLIENT_FROM` marks the client plane.
    pub fn sender(&self) -> u32 {
        debug_assert!(self.hdr_have == 8, "sender read before the header completed");
        u32::from_le_bytes(self.hdr[4..8].try_into().unwrap())
    }

    /// The completed frame's body.
    pub fn body(&self) -> &[u8] {
        &self.body.bytes()[..self.body_len.min(self.body.bytes().len())]
    }

    /// Discard the completed frame and expect the next header; the body
    /// buffer's capacity is kept (that is the pooled read path).
    pub fn clear(&mut self) {
        self.hdr_have = 0;
        self.body_len = 0;
        self.complete = false;
        self.body.vec().clear();
    }

    /// Return the body buffer to the frame pool (connection teardown).
    pub fn recycle(self) {
        self.body.recycle();
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame at {} + {n} > {}", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn dot(&mut self) -> Result<Dot> {
        Ok(Dot::new(ProcessId(self.u32()?), self.u64()?))
    }
    fn rid(&mut self) -> Result<Rid> {
        Ok(Rid::new(ClientId(self.u64()?), self.u64()?))
    }
    fn cmd(&mut self) -> Result<Command> {
        let rid = self.rid()?;
        let op = match self.u8()? {
            0 => Op::Get,
            1 => Op::Put,
            2 => Op::Rmw,
            3 => Op::Read,
            x => bail!("bad op tag {x}"),
        };
        let payload_len = self.u32()?;
        let batched = self.u32()?;
        let n = self.u16()? as usize;
        let mut keys = Vec::with_capacity(n);
        for _ in 0..n {
            keys.push(self.u64()?);
        }
        // Skip the materialized payload bytes (bounds-checked: a hostile
        // payload_len larger than the frame is a truncation error, and no
        // allocation happens before the check).
        self.take(payload_len as usize)?;
        let mut c = Command::new(rid, keys, op, payload_len);
        c.batched = batched;
        Ok(c)
    }
    fn response(&mut self) -> Result<Response> {
        let n = self.u16()? as usize;
        let mut versions = Vec::with_capacity(n);
        for _ in 0..n {
            versions.push((self.u64()?, self.u64()?));
        }
        Ok(Response { versions })
    }
    fn quorums(&mut self) -> Result<Quorums> {
        let n = self.u8()? as usize;
        let mut q = Vec::with_capacity(n);
        for _ in 0..n {
            let s = ShardId(self.u32()?);
            let m = self.u8()? as usize;
            let mut procs = Vec::with_capacity(m);
            for _ in 0..m {
                procs.push(ProcessId(self.u32()?));
            }
            q.push((s, procs));
        }
        Ok(q.into())
    }
    fn key_ts(&mut self) -> Result<KeyTs> {
        let n = self.u16()? as usize;
        let mut ts = Vec::with_capacity(n);
        for _ in 0..n {
            ts.push((self.u64()?, self.u64()?));
        }
        Ok(ts)
    }
    fn promise_set(&mut self) -> Result<PromiseSet> {
        let nd = self.u16()? as usize;
        let mut detached = Vec::with_capacity(nd);
        for _ in 0..nd {
            detached.push((self.u64()?, self.u64()?));
        }
        let na = self.u16()? as usize;
        let mut attached = Vec::with_capacity(na);
        for _ in 0..na {
            attached.push((self.dot()?, self.u64()?));
        }
        Ok(PromiseSet { detached, attached })
    }
    fn key_promises(&mut self) -> Result<KeyPromises> {
        let n = self.u16()? as usize;
        let mut kp = Vec::with_capacity(n);
        for _ in 0..n {
            kp.push((self.u64()?, self.promise_set()?));
        }
        Ok(kp)
    }
}

const PHASES: [Phase; 7] = [
    Phase::Start,
    Phase::Payload,
    Phase::Propose,
    Phase::RecoverR,
    Phase::RecoverP,
    Phase::Commit,
    Phase::Execute,
];

/// Exact encoded size of a command (`Command::wire_size` is exact by
/// contract, pinned by `command_wire_size_matches_codec`).
fn cmd_len(c: &Command) -> usize {
    c.wire_size() as usize
}

fn quorums_len(q: &[(ShardId, Vec<ProcessId>)]) -> usize {
    1 + q.iter().map(|(_, procs)| 4 + 1 + 4 * procs.len()).sum::<usize>()
}

fn key_ts_len(ts: &[(u64, u64)]) -> usize {
    2 + 16 * ts.len()
}

fn promise_set_len(p: &PromiseSet) -> usize {
    2 + 16 * p.detached.len() + 2 + 20 * p.attached.len()
}

fn key_promises_len(kp: &[(u64, PromiseSet)]) -> usize {
    2 + kp.iter().map(|(_, p)| 8 + promise_set_len(p)).sum::<usize>()
}

fn response_len(r: &Response) -> usize {
    2 + 16 * r.versions.len()
}

/// Exact encoded size of `msg` in bytes — equal to `encode(msg).len()`
/// byte-for-byte (fuzzed in `rust/tests/properties.rs`). Callers use it
/// to reserve a buffer once so [`encode_into`] never reallocates.
pub fn encoded_len(msg: &Msg) -> usize {
    match msg {
        Msg::MSubmit { cmd, quorums, .. } | Msg::MPayload { cmd, quorums, .. } => {
            1 + 12 + cmd_len(cmd) + quorums_len(quorums)
        }
        Msg::MPropose { cmd, quorums, ts, .. } => {
            1 + 12 + cmd_len(cmd) + quorums_len(quorums) + key_ts_len(ts)
        }
        Msg::MProposeAck { ts, promises, .. } => {
            1 + 12 + key_ts_len(ts) + key_promises_len(promises)
        }
        Msg::MCommit { ts, promises, .. } => {
            1 + 12
                + 4
                + key_ts_len(ts)
                + 2
                + promises.iter().map(|(_, kp)| 4 + key_promises_len(kp)).sum::<usize>()
        }
        Msg::MCommitDirect { cmd, quorums, .. } => {
            1 + 12 + cmd_len(cmd) + quorums_len(quorums) + 8
        }
        Msg::MConsensus { ts, .. } => 1 + 12 + key_ts_len(ts) + 8,
        Msg::MConsensusAck { .. } => 1 + 12 + 8,
        Msg::MPromises { promises } => 1 + key_promises_len(promises),
        Msg::MBump { .. } => 1 + 12 + 8,
        Msg::MStable { .. } => 1 + 12,
        Msg::MRec { .. } => 1 + 12 + 8,
        Msg::MRecAck { ts, .. } => 1 + 12 + key_ts_len(ts) + 1 + 8 + 8,
        Msg::MRecNAck { .. } => 1 + 12 + 8,
        Msg::MCommitRequest { .. } => 1 + 12,
        Msg::MGarbageCollect { executed } => 1 + 2 + 12 * executed.len(),
        Msg::MEpoch { evicted, .. } => 1 + 8 + 2 + 4 * evicted.len(),
        Msg::MBatch { msgs } => {
            1 + 2 + msgs.iter().map(|m| 4 + encoded_len(m)).sum::<usize>()
        }
    }
}

/// Encode a message (without the length prefix) into a fresh buffer:
/// a thin wrapper over [`encode_into`] with exact pre-reservation.
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut w = Writer::with_capacity(encoded_len(msg));
    encode_into(&mut w, msg);
    w.buf
}

/// Append the encoding of `msg` to `w` — single pass, no intermediate
/// buffers (`MBatch` members are encoded in place behind a backfilled
/// length prefix). Produces exactly the bytes of [`encode`].
pub fn encode_into(w: &mut Writer, msg: &Msg) {
    match msg {
        Msg::MSubmit { dot, cmd, quorums } => {
            w.u8(0);
            w.dot(*dot);
            w.cmd(cmd);
            w.quorums(quorums);
        }
        Msg::MPropose { dot, cmd, quorums, ts } => {
            w.u8(1);
            w.dot(*dot);
            w.cmd(cmd);
            w.quorums(quorums);
            w.key_ts(ts);
        }
        Msg::MProposeAck { dot, ts, promises } => {
            w.u8(2);
            w.dot(*dot);
            w.key_ts(ts);
            w.key_promises(promises);
        }
        Msg::MPayload { dot, cmd, quorums } => {
            w.u8(3);
            w.dot(*dot);
            w.cmd(cmd);
            w.quorums(quorums);
        }
        Msg::MCommit { dot, group, ts, promises } => {
            w.u8(4);
            w.dot(*dot);
            w.u32(group.0);
            w.key_ts(ts);
            w.u16(promises.len() as u16);
            for (p, kp) in promises.iter() {
                w.u32(p.0);
                w.key_promises(kp);
            }
        }
        Msg::MCommitDirect { dot, cmd, quorums, final_ts } => {
            w.u8(5);
            w.dot(*dot);
            w.cmd(cmd);
            w.quorums(quorums);
            w.u64(*final_ts);
        }
        Msg::MConsensus { dot, ts, bal } => {
            w.u8(6);
            w.dot(*dot);
            w.key_ts(ts);
            w.u64(*bal);
        }
        Msg::MConsensusAck { dot, bal } => {
            w.u8(7);
            w.dot(*dot);
            w.u64(*bal);
        }
        Msg::MPromises { promises } => {
            w.u8(8);
            w.key_promises(promises);
        }
        Msg::MBump { dot, ts } => {
            w.u8(9);
            w.dot(*dot);
            w.u64(*ts);
        }
        Msg::MStable { dot } => {
            w.u8(10);
            w.dot(*dot);
        }
        Msg::MRec { dot, bal } => {
            w.u8(11);
            w.dot(*dot);
            w.u64(*bal);
        }
        Msg::MRecAck { dot, ts, phase, abal, bal } => {
            w.u8(12);
            w.dot(*dot);
            w.key_ts(ts);
            w.u8(PHASES.iter().position(|p| p == phase).unwrap() as u8);
            w.u64(*abal);
            w.u64(*bal);
        }
        Msg::MRecNAck { dot, bal } => {
            w.u8(13);
            w.dot(*dot);
            w.u64(*bal);
        }
        Msg::MCommitRequest { dot } => {
            w.u8(14);
            w.dot(*dot);
        }
        Msg::MGarbageCollect { executed } => {
            w.u8(15);
            w.u16(executed.len() as u16);
            for &(p, wm) in executed {
                w.u32(p.0);
                w.u64(wm);
            }
        }
        Msg::MEpoch { epoch, evicted } => {
            w.u8(TAG_EPOCH);
            w.u64(*epoch);
            w.u16(evicted.len() as u16);
            for p in evicted {
                w.u32(p.0);
            }
        }
        Msg::MBatch { msgs } => {
            w.u8(16);
            w.u16(msgs.len() as u16);
            for m in msgs {
                // Backfilled length prefix: encode the member in place,
                // then write its measured size — no per-member Vec.
                let at = w.buf.len();
                w.u32(0);
                encode_into(w, m);
                let len = (w.buf.len() - at - 4) as u32;
                w.buf[at..at + 4].copy_from_slice(&len.to_le_bytes());
            }
        }
    }
}

/// Exact encoded size of a routed frame: envelope (tag + worker byte)
/// plus the inner message.
pub fn routed_encoded_len(routed: &Routed<Msg>) -> usize {
    2 + encoded_len(&routed.msg)
}

/// Append a worker-routed protocol frame to `w`: the [`TAG_ROUTED`]
/// envelope naming the worker slot, then the inner message.
pub fn encode_routed_into(w: &mut Writer, routed: &Routed<Msg>) {
    w.u8(TAG_ROUTED);
    w.u8(routed.worker as u8);
    encode_into(w, &routed.msg);
}

/// Encode a worker-routed protocol frame (without the length prefix).
/// This is what peer connections carry under worker sharding
/// (`protocol::common::shard`); with one worker the tag is simply 0.
/// Thin wrapper over [`encode_routed_into`].
pub fn encode_routed(routed: &Routed<Msg>) -> Vec<u8> {
    let mut w = Writer::with_capacity(routed_encoded_len(routed));
    encode_routed_into(&mut w, routed);
    w.buf
}

/// Encode-once broadcast body: serialize the routed frame a single time
/// into an exactly-sized shared buffer. The TCP runtime hands one of
/// these to every destination of a fan-out (`Action::SendBytes`), so
/// the serialization cost is paid once, not once per peer.
pub fn encode_routed_shared(worker: u32, msg: &Msg) -> Arc<[u8]> {
    let mut w = Writer::with_capacity(2 + encoded_len(msg));
    w.u8(TAG_ROUTED);
    w.u8(worker as u8);
    encode_into(&mut w, msg);
    w.buf.into()
}

fn decode_routed_at(r: &mut Reader) -> Result<Routed<Msg>> {
    let tag = r.u8()?;
    if tag != TAG_ROUTED {
        bail!("expected routed frame tag {TAG_ROUTED}, got {tag}");
    }
    let worker = r.u8()? as u32;
    let msg = decode_at(r)?;
    Ok(Routed { worker, msg })
}

/// Decode a worker-routed protocol frame. The envelope carries exactly
/// one inner protocol message; a nested envelope or a client tag inside
/// is malformed.
pub fn decode_routed(buf: &[u8]) -> Result<Routed<Msg>> {
    let mut r = Reader::new(buf);
    decode_routed_at(&mut r)
}

/// Encode a routed frame into a pooled buffer (zero heap allocations
/// once the pool is warm): the point-to-point leg of the send path. The
/// caller recycles the buffer after the bytes leave the process.
pub fn encode_routed_pooled(worker: u32, msg: &Msg) -> FrameBuf {
    let mut b = FrameBuf::take();
    b.buf.reserve(2 + encoded_len(msg));
    let mut w = Writer::from_vec(std::mem::take(&mut b.buf));
    w.u8(TAG_ROUTED);
    w.u8(worker as u8);
    encode_into(&mut w, msg);
    b.buf = w.buf;
    b
}

/// Exact merged-frame size for the already-encoded member `bodies`.
pub fn merged_encoded_len(bodies: &[&[u8]]) -> usize {
    1 + 2 + bodies.iter().map(|b| 4 + b.len()).sum::<usize>()
}

/// Reference (contiguous) encoding of the merged transport frame
/// ([`TAG_MERGED`]): the per-peer writer produces exactly these bytes
/// with a vectored write instead of copying the bodies (the unit tests
/// pin the two layouts to each other). Members must be routed envelopes.
pub fn encode_merged(bodies: &[&[u8]]) -> Vec<u8> {
    let mut w = Writer::with_capacity(merged_encoded_len(bodies));
    w.u8(TAG_MERGED);
    w.u16(bodies.len() as u16);
    for b in bodies {
        w.u32(b.len() as u32);
        w.buf.extend_from_slice(b);
    }
    w.buf
}

/// Decode a merged transport frame into its member routed frames, in
/// wire order. Every member must be a well-formed routed envelope that
/// consumes its declared length exactly; anything else — a bare
/// message, a client frame, a nested merged frame — is malformed.
pub fn decode_merged(buf: &[u8]) -> Result<Vec<Routed<Msg>>> {
    let mut r = Reader::new(buf);
    let tag = r.u8()?;
    if tag != TAG_MERGED {
        bail!("expected merged frame tag {TAG_MERGED}, got {tag}");
    }
    let n = r.u16()? as usize;
    let mut out = Vec::with_capacity(n.min(256));
    for _ in 0..n {
        let len = r.u32()? as usize;
        let body = r.take(len)?;
        let mut sub = Reader::new(body);
        let routed = decode_routed_at(&mut sub)?;
        if sub.pos != len {
            bail!("merged member declared {len} bytes, used {}", sub.pos);
        }
        out.push(routed);
    }
    Ok(out)
}

/// Exact encoded size of a client frame.
pub fn client_encoded_len(frame: &ClientFrame) -> usize {
    match frame {
        ClientFrame::Submit { cmd, .. } => 1 + cmd_len(cmd) + 8,
        ClientFrame::Reply { response, .. } => 1 + 16 + response_len(response) + 8,
        ClientFrame::Busy { .. } => 1 + 16,
    }
}

/// Append a client frame to `w`.
pub fn encode_client_into(w: &mut Writer, frame: &ClientFrame) {
    match frame {
        ClientFrame::Submit { cmd, floor } => {
            w.u8(TAG_CLIENT_SUBMIT);
            w.cmd(cmd);
            w.u64(*floor);
        }
        ClientFrame::Reply { rid, response, ts } => {
            w.u8(TAG_CLIENT_REPLY);
            w.rid(*rid);
            w.response(response);
            w.u64(*ts);
        }
        ClientFrame::Busy { rid } => {
            w.u8(TAG_CLIENT_BUSY);
            w.rid(*rid);
        }
    }
}

/// Encode a client frame (without the length prefix): thin wrapper over
/// [`encode_client_into`] with exact pre-reservation.
pub fn encode_client(frame: &ClientFrame) -> Vec<u8> {
    let mut w = Writer::with_capacity(client_encoded_len(frame));
    encode_client_into(&mut w, frame);
    w.buf
}

/// Decode a client frame (tags 17–18 and 25). A protocol or transfer
/// tag here is an error: the client plane never carries either.
pub fn decode_client(buf: &[u8]) -> Result<ClientFrame> {
    let mut r = Reader::new(buf);
    let tag = r.u8()?;
    match tag {
        TAG_CLIENT_SUBMIT => {
            let cmd = r.cmd()?;
            let floor = r.u64()?;
            Ok(ClientFrame::Submit { cmd, floor })
        }
        TAG_CLIENT_REPLY => {
            let rid = r.rid()?;
            let response = r.response()?;
            let ts = r.u64()?;
            Ok(ClientFrame::Reply { rid, response, ts })
        }
        TAG_CLIENT_BUSY => Ok(ClientFrame::Busy { rid: r.rid()? }),
        x if x <= 16 => bail!("protocol frame tag {x} in client stream"),
        x if (TAG_MANIFEST_REQUEST..=TAG_CHUNK).contains(&x) => {
            bail!("transfer frame tag {x} in client stream")
        }
        TAG_HEARTBEAT => bail!("heartbeat frame in client stream (transport plane only)"),
        x => bail!("bad client frame tag {x}"),
    }
}

/// Exact encoded size of a transfer frame.
pub fn transfer_encoded_len(frame: &TransferFrame) -> usize {
    match frame {
        TransferFrame::ManifestRequest { .. } => 1 + 4,
        TransferFrame::ManifestReply { chunks, dot_floors, dedup, .. } => {
            1 + 4 + 8 + 4 + 8 * chunks.len() + 2 + 12 * dot_floors.len() + 4 + dedup.len()
        }
        TransferFrame::Chunk { data, .. } => 1 + 4 + 8 + 1 + 4 + data.len(),
    }
}

/// Encode a state-transfer frame (without the length prefix).
pub fn encode_transfer(frame: &TransferFrame) -> Vec<u8> {
    let mut w = Writer::with_capacity(transfer_encoded_len(frame));
    match frame {
        TransferFrame::ManifestRequest { slot } => {
            w.u8(TAG_MANIFEST_REQUEST);
            w.u32(*slot);
        }
        TransferFrame::ManifestReply { slot, applied, chunks, dot_floors, dedup } => {
            w.u8(TAG_MANIFEST_REPLY);
            w.u32(*slot);
            w.u64(*applied);
            w.u32(chunks.len() as u32);
            for &h in chunks {
                w.u64(h);
            }
            w.u16(dot_floors.len() as u16);
            for &(p, floor) in dot_floors {
                w.u32(p.0);
                w.u64(floor);
            }
            w.u32(dedup.len() as u32);
            w.buf.extend_from_slice(dedup);
        }
        TransferFrame::Chunk { slot, hash, present, data } => {
            w.u8(TAG_CHUNK);
            w.u32(*slot);
            w.u64(*hash);
            w.u8(*present as u8);
            w.u32(data.len() as u32);
            w.buf.extend_from_slice(data);
        }
    }
    w.buf
}

/// Decode a state-transfer frame (tags 22–24). Any other plane's tag —
/// protocol, client, routed, merged — is an error: the transfer plane is
/// as strictly separated as the others.
pub fn decode_transfer(buf: &[u8]) -> Result<TransferFrame> {
    let mut r = Reader::new(buf);
    let tag = r.u8()?;
    match tag {
        TAG_MANIFEST_REQUEST => Ok(TransferFrame::ManifestRequest { slot: r.u32()? }),
        TAG_MANIFEST_REPLY => {
            let slot = r.u32()?;
            let applied = r.u64()?;
            let n = r.u32()? as usize;
            // Bounds-checked up front: a hostile count larger than the
            // frame is a truncation error before any allocation.
            let mut chunks = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                chunks.push(r.u64()?);
            }
            let f = r.u16()? as usize;
            let mut dot_floors = Vec::with_capacity(f);
            for _ in 0..f {
                dot_floors.push((ProcessId(r.u32()?), r.u64()?));
            }
            let dlen = r.u32()? as usize;
            let dedup = r.take(dlen)?.to_vec();
            Ok(TransferFrame::ManifestReply { slot, applied, chunks, dot_floors, dedup })
        }
        TAG_CHUNK => {
            let slot = r.u32()?;
            let hash = r.u64()?;
            let present = match r.u8()? {
                0 => false,
                1 => true,
                x => bail!("bad chunk present byte {x}"),
            };
            let len = r.u32()? as usize;
            let data = r.take(len)?.to_vec();
            Ok(TransferFrame::Chunk { slot, hash, present, data })
        }
        x if x <= TAG_EPOCH => bail!("non-transfer frame tag {x} in transfer stream"),
        TAG_CLIENT_BUSY => {
            bail!("client frame tag {TAG_CLIENT_BUSY} in transfer stream")
        }
        TAG_HEARTBEAT => {
            bail!("heartbeat frame in transfer stream (transport plane only)")
        }
        x => bail!("bad transfer frame tag {x}"),
    }
}

/// Decode a message (frame body). Trailing bytes after a complete
/// top-level message are ignored (forward compatibility); inside an
/// `MBatch` every member must consume its length prefix exactly.
pub fn decode(buf: &[u8]) -> Result<Msg> {
    let mut r = Reader::new(buf);
    decode_at(&mut r)
}

fn decode_at(r: &mut Reader) -> Result<Msg> {
    let tag = r.u8()?;
    let msg = match tag {
        0 => Msg::MSubmit { dot: r.dot()?, cmd: r.cmd()?, quorums: r.quorums()? },
        1 => Msg::MPropose {
            dot: r.dot()?,
            cmd: r.cmd()?,
            quorums: r.quorums()?,
            ts: r.key_ts()?,
        },
        2 => Msg::MProposeAck { dot: r.dot()?, ts: r.key_ts()?, promises: r.key_promises()? },
        3 => Msg::MPayload { dot: r.dot()?, cmd: r.cmd()?, quorums: r.quorums()? },
        4 => {
            let dot = r.dot()?;
            let group = ShardId(r.u32()?);
            let ts = r.key_ts()?;
            let n = r.u16()? as usize;
            let mut promises = Vec::with_capacity(n);
            for _ in 0..n {
                let p = ProcessId(r.u32()?);
                promises.push((p, r.key_promises()?));
            }
            Msg::MCommit { dot, group, ts, promises: promises.into() }
        }
        5 => Msg::MCommitDirect {
            dot: r.dot()?,
            cmd: r.cmd()?,
            quorums: r.quorums()?,
            final_ts: r.u64()?,
        },
        6 => Msg::MConsensus { dot: r.dot()?, ts: r.key_ts()?, bal: r.u64()? },
        7 => Msg::MConsensusAck { dot: r.dot()?, bal: r.u64()? },
        8 => Msg::MPromises { promises: r.key_promises()?.into() },
        9 => Msg::MBump { dot: r.dot()?, ts: r.u64()? },
        10 => Msg::MStable { dot: r.dot()? },
        11 => Msg::MRec { dot: r.dot()?, bal: r.u64()? },
        12 => {
            let dot = r.dot()?;
            let ts = r.key_ts()?;
            let pi = r.u8()? as usize;
            // A malformed phase byte must be an error, not a panic.
            let phase = match PHASES.get(pi) {
                Some(p) => *p,
                None => bail!("bad phase tag {pi}"),
            };
            Msg::MRecAck { dot, ts, phase, abal: r.u64()?, bal: r.u64()? }
        }
        13 => Msg::MRecNAck { dot: r.dot()?, bal: r.u64()? },
        14 => Msg::MCommitRequest { dot: r.dot()? },
        15 => {
            let n = r.u16()? as usize;
            let mut executed = Vec::with_capacity(n);
            for _ in 0..n {
                executed.push((ProcessId(r.u32()?), r.u64()?));
            }
            Msg::MGarbageCollect { executed }
        }
        16 => {
            // Length-prefixed member frames; a batch inside a batch is
            // malformed by construction (the Batcher never nests), and a
            // client frame can never travel between protocol peers — both
            // are rejected *before* recursing, by peeking the member's
            // tag, so a deeply nested hostile frame cannot overflow the
            // stack. Each member must consume its declared length
            // exactly; surplus bytes are corruption.
            let n = r.u16()? as usize;
            let mut msgs = Vec::with_capacity(n.min(256));
            for _ in 0..n {
                let len = r.u32()? as usize;
                let body = r.take(len)?;
                match body.first() {
                    Some(&16) => bail!("nested MBatch frame"),
                    Some(&t) if is_client_tag(t) => {
                        bail!("client frame tag {t} inside MBatch")
                    }
                    Some(&TAG_ROUTED) => bail!("routed envelope inside MBatch"),
                    Some(&TAG_MERGED) => bail!("merged frame inside MBatch"),
                    Some(&t) if (TAG_MANIFEST_REQUEST..=TAG_CHUNK).contains(&t) => {
                        bail!("transfer frame tag {t} inside MBatch")
                    }
                    Some(&TAG_HEARTBEAT) => bail!("heartbeat frame inside MBatch"),
                    _ => {}
                }
                let mut sub = Reader::new(body);
                let inner = decode_at(&mut sub)?;
                if sub.pos != len {
                    bail!("MBatch member declared {len} bytes, used {}", sub.pos);
                }
                msgs.push(inner);
            }
            Msg::MBatch { msgs }
        }
        TAG_EPOCH => {
            let epoch = r.u64()?;
            let n = r.u16()? as usize;
            let mut evicted = Vec::with_capacity(n);
            for _ in 0..n {
                evicted.push(ProcessId(r.u32()?));
            }
            Msg::MEpoch { epoch, evicted }
        }
        x if is_client_tag(x) => {
            bail!("client frame tag {x} in protocol stream")
        }
        TAG_ROUTED => bail!("routed envelope where a bare protocol message was expected"),
        TAG_MERGED => bail!("merged frame where a bare protocol message was expected"),
        x if (TAG_MANIFEST_REQUEST..=TAG_CHUNK).contains(&x) => {
            bail!("transfer frame tag {x} in protocol stream")
        }
        TAG_HEARTBEAT => {
            bail!("heartbeat frame in protocol stream (transport plane only)")
        }
        x => bail!("bad message tag {x}"),
    };
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let bytes = encode(&msg);
        let back = decode(&bytes).expect("decode");
        assert_eq!(format!("{msg:?}"), format!("{back:?}"), "codec round-trip");
    }

    #[test]
    fn all_variants_roundtrip() {
        let dot = Dot::new(ProcessId(3), 42);
        let cmd = Command::new(Rid::new(ClientId(7), 9), vec![1, 99], Op::Rmw, 512);
        let quorums: Quorums = vec![
            (ShardId(0), vec![ProcessId(0), ProcessId(1)]),
            (ShardId(1), vec![ProcessId(3)]),
        ]
        .into();
        let ts: KeyTs = vec![(1, 10), (99, 11)];
        let ps = PromiseSet { detached: vec![(1, 5), (7, 9)], attached: vec![(dot, 10)] };
        let kp: KeyPromises = vec![(1, ps.clone()), (99, PromiseSet::default())];
        roundtrip(Msg::MSubmit { dot, cmd: cmd.clone(), quorums: quorums.clone() });
        roundtrip(Msg::MPropose {
            dot,
            cmd: cmd.clone(),
            quorums: quorums.clone(),
            ts: ts.clone(),
        });
        roundtrip(Msg::MProposeAck { dot, ts: ts.clone(), promises: kp.clone() });
        roundtrip(Msg::MPayload { dot, cmd: cmd.clone(), quorums: quorums.clone() });
        roundtrip(Msg::MCommit {
            dot,
            group: ShardId(1),
            ts: ts.clone(),
            promises: vec![(ProcessId(2), kp.clone())].into(),
        });
        roundtrip(Msg::MCommitDirect { dot, cmd, quorums, final_ts: 17 });
        roundtrip(Msg::MConsensus { dot, ts: ts.clone(), bal: 6 });
        roundtrip(Msg::MConsensusAck { dot, bal: 6 });
        roundtrip(Msg::MPromises { promises: kp.into() });
        roundtrip(Msg::MBump { dot, ts: 12 });
        roundtrip(Msg::MStable { dot });
        roundtrip(Msg::MRec { dot, bal: 8 });
        roundtrip(Msg::MRecAck { dot, ts, phase: Phase::RecoverP, abal: 0, bal: 8 });
        roundtrip(Msg::MRecNAck { dot, bal: 9 });
        roundtrip(Msg::MCommitRequest { dot });
        roundtrip(Msg::MGarbageCollect {
            executed: vec![(ProcessId(0), 41), (ProcessId(4), 7)],
        });
        roundtrip(Msg::MGarbageCollect { executed: vec![] });
        roundtrip(Msg::MEpoch { epoch: 3, evicted: vec![ProcessId(2), ProcessId(4)] });
        roundtrip(Msg::MEpoch { epoch: 0, evicted: vec![] });
        roundtrip(Msg::MBatch {
            msgs: vec![
                Msg::MStable { dot },
                Msg::MPromises { promises: vec![(1, ps)].into() },
                Msg::MGarbageCollect { executed: vec![(ProcessId(2), 3)] },
                Msg::MEpoch { epoch: 1, evicted: vec![ProcessId(2)] },
            ],
        });
        roundtrip(Msg::MBatch { msgs: vec![] });
    }

    #[test]
    fn routed_frames_roundtrip_and_validate() {
        let dot = Dot::new(ProcessId(1), 6); // worker 1 of 4 stride (seq-1 ≡ 1 mod 4)
        let inner = Msg::MStable { dot };
        for worker in [0u32, 1, 3, 255] {
            let bytes = encode_routed(&Routed { worker, msg: inner.clone() });
            assert_eq!(bytes[0], TAG_ROUTED);
            let back = decode_routed(&bytes).expect("decode routed");
            assert_eq!(back.worker, worker);
            assert_eq!(format!("{:?}", back.msg), format!("{inner:?}"));
        }
        // Truncation anywhere must error, not panic.
        let bytes = encode_routed(&Routed { worker: 2, msg: inner.clone() });
        for cut in 0..bytes.len() {
            assert!(decode_routed(&bytes[..cut]).is_err(), "cut at {cut} must fail");
        }
        // A bare message is not a routed frame and vice versa.
        assert!(decode_routed(&encode(&inner)).is_err());
        assert!(decode(&bytes).is_err(), "envelope must not decode as a bare Msg");
        // Envelopes never nest inside MBatch members.
        let mut w = Writer::new();
        w.u8(16);
        w.u16(1);
        let member = encode_routed(&Routed { worker: 0, msg: inner });
        w.u32(member.len() as u32);
        w.buf.extend_from_slice(&member);
        assert!(decode(&w.buf).is_err(), "routed envelope inside MBatch must fail");
    }

    #[test]
    fn batch_frames_fail_cleanly_on_malformed_input() {
        let dot = Dot::new(ProcessId(1), 2);
        let msg = Msg::MBatch {
            msgs: vec![Msg::MStable { dot }, Msg::MBump { dot, ts: 9 }],
        };
        let bytes = encode(&msg);
        // Truncation anywhere inside the frame must error, not panic.
        for cut in 0..bytes.len() {
            assert!(decode(&bytes[..cut]).is_err(), "cut at {cut} must fail");
        }
        // An oversized member length (beyond the buffer) must error.
        let mut oversized = bytes.clone();
        // Layout: tag(1) + count(2) + first member len(4).
        oversized[3..7].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&oversized).is_err(), "oversized member must fail");
        // A member with trailing junk inside its declared length must
        // error too: members consume their length prefix exactly.
        let mut w = Writer::new();
        w.u8(16);
        w.u16(1);
        let body = encode(&Msg::MStable { dot });
        w.u32(body.len() as u32 + 2);
        w.buf.extend_from_slice(&body);
        w.u16(0xBEEF); // 2 junk bytes covered by the member length
        assert!(decode(&w.buf).is_err(), "padded member must fail");
        // A nested batch must be rejected, not recursed into.
        let nested = Msg::MBatch { msgs: vec![] };
        let mut w = Writer::new();
        w.u8(16);
        w.u16(1);
        let body = encode(&nested);
        w.u32(body.len() as u32);
        w.buf.extend_from_slice(&body);
        assert!(decode(&w.buf).is_err(), "nested MBatch must fail");
    }

    #[test]
    fn deeply_nested_batch_errors_without_exhausting_the_stack() {
        // A hostile frame of MBatch-wrapping-MBatch repeated many times
        // must return Err from the tag peek, not recurse per level.
        let mut frame = encode(&Msg::MStable { dot: Dot::new(ProcessId(1), 2) });
        for _ in 0..100_000 {
            let mut w = Writer::new();
            w.u8(16);
            w.u16(1);
            w.u32(frame.len() as u32);
            w.buf.extend_from_slice(&frame);
            frame = w.buf;
        }
        assert!(decode(&frame).is_err(), "deep nesting must fail cleanly");
    }

    #[test]
    fn truncated_frames_fail_cleanly() {
        for msg in [
            Msg::MStable { dot: Dot::new(ProcessId(1), 2) },
            Msg::MGarbageCollect { executed: vec![(ProcessId(3), 9)] },
            Msg::MRecAck {
                dot: Dot::new(ProcessId(1), 2),
                ts: vec![(5, 6)],
                phase: Phase::Commit,
                abal: 1,
                bal: 2,
            },
            Msg::MEpoch { epoch: 2, evicted: vec![ProcessId(4)] },
        ] {
            let bytes = encode(&msg);
            for cut in 0..bytes.len() {
                assert!(decode(&bytes[..cut]).is_err(), "cut at {cut} must fail");
            }
        }
        assert!(decode(&[200]).is_err(), "unknown tag must fail");
    }

    #[test]
    fn command_wire_size_matches_codec() {
        // The sim's NIC model charges Command::wire_size; it must equal
        // the encoded length exactly (op byte, batched count and payload
        // included — the seed undercounted all three).
        let representative = [
            Command::new(Rid::new(ClientId(0), 1), vec![0], Op::Get, 0),
            Command::new(Rid::new(ClientId(7), 9), vec![1, 99], Op::Rmw, 512),
            Command::new(Rid::new(ClientId(u64::MAX), u64::MAX), (0..50).collect(), Op::Put, 4096),
            {
                let rid = Rid::new(ClientId(3), 2);
                let mut batched = Command::new(rid, vec![5, 6, 7], Op::Put, 100);
                batched.batched = 1000;
                batched
            },
        ];
        for cmd in representative {
            let mut w = Writer::new();
            w.cmd(&cmd);
            assert_eq!(
                cmd.wire_size(),
                w.buf.len() as u64,
                "wire_size out of sync with the codec for {cmd:?}"
            );
        }
    }

    #[test]
    fn client_frames_roundtrip() {
        let cmd = Command::new(Rid::new(ClientId(7), 3), vec![1, 99], Op::Put, 256);
        let submit = ClientFrame::Submit { cmd, floor: 41 };
        let bytes = encode_client(&submit);
        assert_eq!(bytes[0], TAG_CLIENT_SUBMIT);
        assert_eq!(decode_client(&bytes).expect("decode submit"), submit);

        let reply = ClientFrame::Reply {
            rid: Rid::new(ClientId(7), 3),
            response: Response { versions: vec![(1, 4), (99, 17)] },
            ts: 77,
        };
        let bytes = encode_client(&reply);
        assert_eq!(bytes[0], TAG_CLIENT_REPLY);
        assert_eq!(decode_client(&bytes).expect("decode reply"), reply);
        let empty = ClientFrame::Reply {
            rid: Rid::new(ClientId(0), 1),
            response: Response { versions: vec![] },
            ts: 0,
        };
        assert_eq!(decode_client(&encode_client(&empty)).unwrap(), empty);

        let busy = ClientFrame::Busy { rid: Rid::new(ClientId(9), 12) };
        let bytes = encode_client(&busy);
        assert_eq!(bytes[0], TAG_CLIENT_BUSY);
        assert_eq!(bytes.len(), 17, "busy is tag + rid, nothing else");
        assert_eq!(decode_client(&bytes).expect("decode busy"), busy);
    }

    #[test]
    fn client_frames_fail_cleanly_on_malformed_input() {
        let cmd = Command::new(Rid::new(ClientId(7), 3), vec![1, 99], Op::Put, 64);
        for frame in [
            ClientFrame::Submit { cmd, floor: 9 },
            ClientFrame::Reply {
                rid: Rid::new(ClientId(2), 9),
                response: Response { versions: vec![(5, 1)] },
                ts: 3,
            },
            ClientFrame::Busy { rid: Rid::new(ClientId(2), 9) },
        ] {
            let bytes = encode_client(&frame);
            for cut in 0..bytes.len() {
                assert!(decode_client(&bytes[..cut]).is_err(), "cut at {cut} must fail");
            }
        }
        assert!(decode_client(&[200]).is_err(), "unknown tag must fail");
    }

    #[test]
    fn client_and_protocol_streams_are_strictly_separated() {
        let dot = Dot::new(ProcessId(1), 2);
        let cmd = Command::new(Rid::new(ClientId(7), 3), vec![1], Op::Put, 8);
        // A client frame in the protocol stream is an error...
        let submit = encode_client(&ClientFrame::Submit { cmd, floor: 0 });
        assert!(decode(&submit).is_err(), "ClientSubmit must not decode as a Msg");
        let reply = encode_client(&ClientFrame::Reply {
            rid: Rid::new(ClientId(1), 1),
            response: Response { versions: vec![] },
            ts: 0,
        });
        assert!(decode(&reply).is_err(), "ClientReply must not decode as a Msg");
        let busy = encode_client(&ClientFrame::Busy { rid: Rid::new(ClientId(1), 1) });
        assert!(decode(&busy).is_err(), "ClientBusy must not decode as a Msg");
        assert!(decode_transfer(&busy).is_err(), "ClientBusy is not a transfer frame");
        // ... and a protocol frame in the client stream is an error.
        let stable = encode(&Msg::MStable { dot });
        assert!(decode_client(&stable).is_err(), "Msg must not decode as a client frame");
    }

    #[test]
    fn batch_rejects_nested_client_frames_like_nested_batches() {
        // An MBatch member whose tag is 17, 18 or 25 must fail from the
        // tag peek, exactly like a nested batch.
        for member in [
            encode_client(&ClientFrame::Submit {
                cmd: Command::new(Rid::new(ClientId(1), 1), vec![3], Op::Put, 4),
                floor: 0,
            }),
            encode_client(&ClientFrame::Reply {
                rid: Rid::new(ClientId(1), 1),
                response: Response { versions: vec![(3, 1)] },
                ts: 5,
            }),
            encode_client(&ClientFrame::Busy { rid: Rid::new(ClientId(1), 1) }),
        ] {
            let mut w = Writer::new();
            w.u8(16);
            w.u16(1);
            w.u32(member.len() as u32);
            w.buf.extend_from_slice(&member);
            assert!(decode(&w.buf).is_err(), "client frame inside MBatch must fail");
        }
    }

    #[test]
    fn hostile_payload_length_is_a_truncation_error() {
        // A cmd whose payload_len claims more bytes than the frame holds
        // must error without allocating.
        let cmd = Command::new(Rid::new(ClientId(1), 1), vec![3], Op::Put, 8);
        let mut bytes = encode_client(&ClientFrame::Submit { cmd, floor: 0 });
        // Layout: tag(1) + rid(16) + op(1) → payload_len at offset 18.
        bytes[18..22].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_client(&bytes).is_err(), "hostile payload_len must fail");
    }

    fn sample_msgs() -> Vec<Msg> {
        let dot = Dot::new(ProcessId(3), 42);
        let cmd = Command::new(Rid::new(ClientId(7), 9), vec![1, 99], Op::Rmw, 512);
        let quorums: Quorums =
            vec![(ShardId(0), vec![ProcessId(0), ProcessId(1)]), (ShardId(1), vec![ProcessId(3)])]
                .into();
        let ts: KeyTs = vec![(1, 10), (99, 11)];
        let ps = PromiseSet { detached: vec![(1, 5), (7, 9)], attached: vec![(dot, 10)] };
        let kp: KeyPromises = vec![(1, ps.clone()), (99, PromiseSet::default())];
        vec![
            Msg::MSubmit { dot, cmd: cmd.clone(), quorums: quorums.clone() },
            Msg::MPropose { dot, cmd: cmd.clone(), quorums: quorums.clone(), ts: ts.clone() },
            Msg::MProposeAck { dot, ts: ts.clone(), promises: kp.clone() },
            Msg::MPayload { dot, cmd: cmd.clone(), quorums: quorums.clone() },
            Msg::MCommit {
                dot,
                group: ShardId(1),
                ts: ts.clone(),
                promises: vec![(ProcessId(2), kp.clone())].into(),
            },
            Msg::MCommitDirect { dot, cmd, quorums, final_ts: 17 },
            Msg::MConsensus { dot, ts: ts.clone(), bal: 6 },
            Msg::MConsensusAck { dot, bal: 6 },
            Msg::MPromises { promises: kp.into() },
            Msg::MBump { dot, ts: 12 },
            Msg::MStable { dot },
            Msg::MRec { dot, bal: 8 },
            Msg::MRecAck { dot, ts, phase: Phase::RecoverP, abal: 0, bal: 8 },
            Msg::MRecNAck { dot, bal: 9 },
            Msg::MCommitRequest { dot },
            Msg::MGarbageCollect { executed: vec![(ProcessId(0), 41), (ProcessId(4), 7)] },
            Msg::MEpoch { epoch: 5, evicted: vec![ProcessId(1), ProcessId(3)] },
            Msg::MEpoch { epoch: 0, evicted: vec![] },
            Msg::MBatch {
                msgs: vec![
                    Msg::MStable { dot },
                    Msg::MPromises { promises: vec![(1, ps)].into() },
                ],
            },
            Msg::MBatch { msgs: vec![] },
        ]
    }

    #[test]
    fn encoded_len_is_exact_for_every_variant() {
        for msg in sample_msgs() {
            let bytes = encode(&msg);
            assert_eq!(
                encoded_len(&msg),
                bytes.len(),
                "encoded_len out of sync with the encoder for {msg:?}"
            );
            // The into-form appends to existing content without
            // disturbing it and produces exactly the wrapper's bytes.
            let mut w = Writer::from_vec(vec![0xAA, 0xBB]);
            encode_into(&mut w, &msg);
            assert_eq!(&w.buf[..2], &[0xAA, 0xBB]);
            assert_eq!(&w.buf[2..], &bytes[..], "encode_into != encode for {msg:?}");
            let routed = Routed { worker: 3, msg };
            assert_eq!(routed_encoded_len(&routed), encode_routed(&routed).len());
        }
    }

    #[test]
    fn encode_routed_shared_matches_the_per_peer_encoding() {
        for msg in sample_msgs() {
            let shared = encode_routed_shared(2, &msg);
            let legacy = encode_routed(&Routed { worker: 2, msg });
            assert_eq!(&shared[..], &legacy[..], "shared body must be byte-identical");
        }
    }

    #[test]
    fn client_encoded_len_is_exact() {
        let cmd = Command::new(Rid::new(ClientId(7), 3), vec![1, 99], Op::Put, 256);
        for frame in [
            ClientFrame::Submit { cmd, floor: 12 },
            ClientFrame::Reply {
                rid: Rid::new(ClientId(7), 3),
                response: Response { versions: vec![(1, 4), (99, 17)] },
                ts: 7,
            },
            ClientFrame::Busy { rid: Rid::new(ClientId(7), 3) },
        ] {
            assert_eq!(client_encoded_len(&frame), encode_client(&frame).len());
        }
    }

    fn sample_transfer_frames() -> Vec<TransferFrame> {
        vec![
            TransferFrame::ManifestRequest { slot: 3 },
            TransferFrame::ManifestReply {
                slot: 1,
                applied: 4096,
                chunks: vec![0xDEAD_BEEF, 0, u64::MAX],
                dot_floors: vec![(ProcessId(0), 17), (ProcessId(4), 99)],
                dedup: vec![1, 2, 3, 4, 5],
            },
            TransferFrame::ManifestReply {
                slot: 0,
                applied: 0,
                chunks: vec![],
                dot_floors: vec![],
                dedup: vec![],
            },
            TransferFrame::Chunk { slot: 2, hash: 0xFACE, present: false, data: vec![] },
            TransferFrame::Chunk { slot: 2, hash: 0xFACE, present: true, data: vec![9; 300] },
        ]
    }

    #[test]
    fn transfer_frames_roundtrip_with_exact_lengths() {
        for frame in sample_transfer_frames() {
            let bytes = encode_transfer(&frame);
            assert_eq!(
                transfer_encoded_len(&frame),
                bytes.len(),
                "transfer_encoded_len out of sync for {frame:?}"
            );
            assert_eq!(decode_transfer(&bytes).expect("decode transfer"), frame);
        }
    }

    #[test]
    fn transfer_frames_fail_cleanly_on_malformed_input() {
        for frame in sample_transfer_frames() {
            let bytes = encode_transfer(&frame);
            for cut in 0..bytes.len() {
                assert!(decode_transfer(&bytes[..cut]).is_err(), "cut at {cut} must fail");
            }
        }
        assert!(decode_transfer(&[200]).is_err(), "unknown tag must fail");
        // A hostile chunk count larger than the frame is a truncation
        // error, not an allocation.
        let mut bytes = encode_transfer(&TransferFrame::ManifestReply {
            slot: 0,
            applied: 1,
            chunks: vec![7],
            dot_floors: vec![],
            dedup: vec![],
        });
        // Layout: tag(1) + slot(4) + applied(8) → chunk count at 13.
        bytes[13..17].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_transfer(&bytes).is_err(), "hostile chunk count must fail");
        // A corrupt present byte must error, not decode as a bool.
        let mut bytes =
            encode_transfer(&TransferFrame::Chunk { slot: 0, hash: 1, present: true, data: vec![] });
        bytes[13] = 9; // tag(1) + slot(4) + hash(8) → present byte at 13
        assert!(decode_transfer(&bytes).is_err(), "bad present byte must fail");
    }

    #[test]
    fn transfer_plane_is_strictly_separated() {
        let dot = Dot::new(ProcessId(1), 2);
        for frame in sample_transfer_frames() {
            let bytes = encode_transfer(&frame);
            // A transfer frame decodes on no other plane...
            assert!(decode(&bytes).is_err(), "transfer frame must not decode as a Msg");
            assert!(decode_client(&bytes).is_err(), "transfer frame is not a client frame");
            assert!(decode_routed(&bytes).is_err(), "transfer frame is not a routed frame");
            assert!(decode_merged(&bytes).is_err(), "transfer frame is not a merged frame");
            // ... and an MBatch member with a transfer tag fails from the
            // tag peek, exactly like nested batches and client frames.
            let mut w = Writer::new();
            w.u8(16);
            w.u16(1);
            w.u32(bytes.len() as u32);
            w.buf.extend_from_slice(&bytes);
            assert!(decode(&w.buf).is_err(), "transfer frame inside MBatch must fail");
        }
        // No other plane's frame decodes as a transfer frame.
        for bytes in [
            encode(&Msg::MStable { dot }),
            encode(&Msg::MEpoch { epoch: 1, evicted: vec![] }),
            encode_client(&ClientFrame::Submit {
                cmd: Command::new(Rid::new(ClientId(1), 1), vec![3], Op::Put, 4),
                floor: 0,
            }),
            encode_routed(&Routed { worker: 0, msg: Msg::MStable { dot } }),
        ] {
            assert!(decode_transfer(&bytes).is_err(), "cross-plane frame must not decode");
        }
    }

    /// The heartbeat frame (tag 26) lives below every codec: the peer
    /// read path consumes it before decoding, so every decoder must
    /// reject it like any cross-plane tag — on its own, routed, merged,
    /// and inside `MBatch`.
    #[test]
    fn heartbeat_tag_is_rejected_on_every_plane() {
        let hb = [TAG_HEARTBEAT];
        assert!(decode(&hb).is_err(), "heartbeat is not a protocol message");
        assert!(decode_client(&hb).is_err(), "heartbeat is not a client frame");
        assert!(decode_transfer(&hb).is_err(), "heartbeat is not a transfer frame");
        assert!(decode_routed(&hb).is_err(), "heartbeat is not a routed frame");
        assert!(decode_merged(&hb).is_err(), "heartbeat is not a merged frame");
        // Inside an MBatch the member-tag peek rejects it up front.
        let mut w = Writer::new();
        w.u8(16);
        w.u16(1);
        w.u32(hb.len() as u32);
        w.buf.extend_from_slice(&hb);
        assert!(decode(&w.buf).is_err(), "heartbeat inside MBatch must fail");
    }

    #[test]
    fn merged_frames_roundtrip_in_order() {
        let dot = Dot::new(ProcessId(1), 2);
        let members: Vec<Routed<Msg>> = vec![
            Routed { worker: 0, msg: Msg::MStable { dot } },
            Routed {
                worker: 1,
                msg: Msg::MBatch {
                    msgs: vec![Msg::MBump { dot, ts: 9 }, Msg::MStable { dot }],
                },
            },
            Routed { worker: 0, msg: Msg::MRec { dot, bal: 3 } },
        ];
        let bodies: Vec<Vec<u8>> = members.iter().map(encode_routed).collect();
        let body_refs: Vec<&[u8]> = bodies.iter().map(|b| b.as_slice()).collect();
        let frame = encode_merged(&body_refs);
        assert_eq!(frame.len(), merged_encoded_len(&body_refs));
        assert_eq!(frame[0], TAG_MERGED);
        let back = decode_merged(&frame).expect("decode merged");
        assert_eq!(back.len(), members.len());
        for (a, b) in members.iter().zip(&back) {
            assert_eq!(a.worker, b.worker, "member slot order must be preserved");
            assert_eq!(format!("{:?}", a.msg), format!("{:?}", b.msg));
        }
    }

    #[test]
    fn merged_frames_fail_cleanly_on_malformed_input() {
        let dot = Dot::new(ProcessId(1), 2);
        let body = encode_routed(&Routed { worker: 0, msg: Msg::MStable { dot } });
        let frame = encode_merged(&[&body]);
        // Truncation anywhere must error, not panic.
        for cut in 0..frame.len() {
            assert!(decode_merged(&frame[..cut]).is_err(), "cut at {cut} must fail");
        }
        // A merged frame never appears in the bare-message position, in
        // the routed position, or inside an MBatch member.
        assert!(decode(&frame).is_err(), "merged frame must not decode as a Msg");
        assert!(decode_routed(&frame).is_err(), "merged frame is not a routed frame");
        let mut w = Writer::new();
        w.u8(16);
        w.u16(1);
        w.u32(frame.len() as u32);
        w.buf.extend_from_slice(&frame);
        assert!(decode(&w.buf).is_err(), "merged frame inside MBatch must fail");
        // Members must be routed envelopes (a bare message is not)...
        let bare = encode(&Msg::MStable { dot });
        assert!(decode_merged(&encode_merged(&[&bare])).is_err());
        // ... never nested merged frames ...
        assert!(decode_merged(&encode_merged(&[&frame])).is_err());
        // ... and must consume their declared length exactly.
        let mut padded = body.clone();
        padded.push(0xEE);
        assert!(decode_merged(&encode_merged(&[&padded])).is_err());
    }

    #[test]
    fn frame_pool_recycles_buffers() {
        let mut b = FrameBuf::take();
        b.vec().extend_from_slice(&[1, 2, 3]);
        let cap = b.vec().capacity();
        b.recycle();
        let hits_before = pool_stats::hits();
        let mut b2 = FrameBuf::take();
        // Either our buffer came back (same thread-local pool) or a
        // concurrent test took it; in the former case it is cleared and
        // keeps its capacity, and the take counted as a hit.
        assert!(b2.bytes().is_empty(), "pooled buffers are handed out cleared");
        if b2.vec().capacity() == cap {
            assert!(pool_stats::hits() >= hits_before + 1, "recycled take must count as a hit");
        }
        b2.recycle();
    }

    /// A transport frame as `write_frame` would put it on the wire:
    /// `[len][from][body]`.
    fn transport_frame(from: u32, body: &[u8]) -> Vec<u8> {
        let mut f = Vec::with_capacity(8 + body.len());
        f.extend_from_slice(&(body.len() as u32).to_le_bytes());
        f.extend_from_slice(&from.to_le_bytes());
        f.extend_from_slice(body);
        f
    }

    #[test]
    fn frame_decoder_consumes_frames_at_any_split() {
        let body = encode_client(&ClientFrame::Busy { rid: Rid::new(ClientId(3), 7) });
        let frame = transport_frame(crate::net::CLIENT_FROM, &body);
        // Whole-buffer feed.
        let mut dec = FrameDecoder::new();
        let (used, done) = dec.feed(&frame).unwrap();
        assert_eq!((used, done), (frame.len(), true));
        assert_eq!(dec.sender(), crate::net::CLIENT_FROM);
        assert_eq!(dec.body(), &body[..]);
        // A feed past a complete frame consumes nothing until clear().
        assert_eq!(dec.feed(&[1, 2, 3]).unwrap(), (0, true));
        // Byte-by-byte: same frame, 1-byte chunks.
        dec.clear();
        for (i, b) in frame.iter().enumerate() {
            let (used, done) = dec.feed(std::slice::from_ref(b)).unwrap();
            assert_eq!(used, 1, "byte {i} must be consumed");
            assert_eq!(done, i == frame.len() - 1, "complete only at the last byte");
        }
        assert_eq!(dec.body(), &body[..]);
        dec.recycle();
    }

    #[test]
    fn frame_decoder_stops_at_frame_boundaries_in_a_shared_chunk() {
        // Two back-to-back frames in one chunk: the decoder must stop at
        // the first boundary so the caller can take the frame, then
        // resume into the second from the leftover bytes.
        let b1 = encode_client(&ClientFrame::Submit {
            cmd: Command::new(Rid::new(ClientId(1), 1), vec![4], Op::Put, 16),
            floor: 2,
        });
        let b2 = encode_client(&ClientFrame::Reply {
            rid: Rid::new(ClientId(1), 1),
            response: Response { versions: vec![(4, 1)] },
            ts: 9,
        });
        let mut stream = transport_frame(crate::net::CLIENT_FROM, &b1);
        stream.extend_from_slice(&transport_frame(crate::net::CLIENT_FROM, &b2));
        let mut dec = FrameDecoder::new();
        let (used, done) = dec.feed(&stream).unwrap();
        assert!(done);
        assert_eq!(used, 8 + b1.len(), "must stop at the first frame boundary");
        assert_eq!(dec.body(), &b1[..]);
        dec.clear();
        let (used2, done2) = dec.feed(&stream[used..]).unwrap();
        assert!(done2);
        assert_eq!(used + used2, stream.len());
        assert_eq!(dec.body(), &b2[..]);
        dec.recycle();
    }

    #[test]
    fn frame_decoder_rejects_hostile_lengths_without_allocating() {
        // A length header above MAX_FRAME_BYTES must error the moment the
        // header completes — before any body byte arrives.
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&(crate::net::MAX_FRAME_BYTES as u32 + 1).to_le_bytes());
        hostile.extend_from_slice(&crate::net::CLIENT_FROM.to_le_bytes());
        let mut dec = FrameDecoder::new();
        assert!(dec.feed(&hostile).is_err(), "oversized length must fail");
        dec.recycle();
        // An empty body completes at the header (used by nothing today,
        // but the state machine must not hang on it).
        let empty = transport_frame(7, &[]);
        let mut dec = FrameDecoder::new();
        let (used, done) = dec.feed(&empty).unwrap();
        assert_eq!((used, done), (empty.len(), true));
        assert_eq!(dec.sender(), 7);
        assert!(dec.body().is_empty());
        dec.recycle();
    }

    #[test]
    fn malformed_phase_byte_is_an_error_not_a_panic() {
        let msg = Msg::MRecAck {
            dot: Dot::new(ProcessId(1), 2),
            ts: vec![],
            phase: Phase::Commit,
            abal: 1,
            bal: 2,
        };
        let mut bytes = encode(&msg);
        // Layout: tag(1) + dot(12) + ts len(2) + phase byte.
        let phase_at = 1 + 12 + 2;
        bytes[phase_at] = 250;
        assert!(decode(&bytes).is_err(), "phase byte 250 must fail cleanly");
    }
}
