//! Shared harness for the figure/table benchmarks (criterion is not
//! available offline; each bench is a `harness = false` binary that prints
//! the rows of the corresponding paper table/figure).
//!
//! Scaling note (see EXPERIMENTS.md): client counts and window lengths are
//! scaled down from the paper's cluster (which ran minutes-long windows
//! with up to 20480 clients/site) so each figure regenerates in minutes on
//! one machine. Shapes — who wins, by what factor, where crossovers fall —
//! are the reproduction target, not absolute numbers.

use crate::core::Config;
use crate::metrics::RunMetrics;
use crate::protocol::Protocol;
use crate::sim::{ResourceModel, SimOpts, Topology};
use crate::workload::Workload;

/// One measured cell of a figure.
#[derive(Clone, Debug)]
pub struct Cell {
    pub protocol: &'static str,
    pub label: String,
    pub metrics: RunMetrics,
}

/// Run a protocol/workload pair under `opts` and collect metrics.
pub fn measure<P: Protocol, W: Workload>(
    protocol: &'static str,
    label: impl Into<String>,
    config: Config,
    opts: SimOpts,
    workload: W,
) -> Cell {
    let result = crate::sim::run::<P, W>(config, opts, workload);
    Cell { protocol, label: label.into(), metrics: result.metrics }
}

/// Simulator-mode options (no CPU/NIC model): latency experiments.
pub fn latency_opts(topology: Topology, clients_per_site: usize, seed: u64) -> SimOpts {
    let mut o = SimOpts::new(topology);
    o.clients_per_site = clients_per_site;
    o.warmup_us = 3_000_000;
    o.duration_us = 20_000_000;
    o.seed = seed;
    o
}

/// Cluster-mode options (CPU/NIC model on): throughput experiments.
pub fn throughput_opts(topology: Topology, clients_per_site: usize, seed: u64) -> SimOpts {
    let mut o = SimOpts::new(topology);
    o.clients_per_site = clients_per_site;
    o.warmup_us = 1_000_000;
    o.duration_us = 3_000_000;
    o.seed = seed;
    o.resources = Some(ResourceModel::cluster());
    o
}

/// Print a fixed-width table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// ms with one decimal from µs.
pub fn ms(us: u64) -> String {
    format!("{:.1}", us as f64 / 1e3)
}

/// Kilo-ops/s with one decimal.
pub fn kops(v: f64) -> String {
    format!("{:.1}", v / 1e3)
}
