//! Core substrate: identifiers, commands, configuration and quorum math.

pub mod command;
pub mod config;
pub mod id;

pub use command::{clone_stats, key_to_shard, Command, Completion, Key, Op, Response};
pub use config::{Config, StorageMode};
pub use id::{ClientId, Dot, DotGen, ProcessId, Rid, ShardId, Stride};
