//! Commands: the unit of replication.
//!
//! A command accesses one or more *partitions*. Following the paper (§6.2,
//! §6.4) a partition is identified by a key: commands conflict iff they
//! share a key. In partial replication each key lives on exactly one shard;
//! in full replication there is a single shard replicated everywhere.
//!
//! A command is named end to end by its [`Rid`] — the rifl-style request
//! id its client's [`crate::client::Session`] allocated. The protocol
//! renames the command internally to a [`Dot`] when it is submitted
//! (`Protocol::submit` allocates the dot; callers never see it), and the
//! reply carries the `Rid` back to the client.

use super::id::{ClientId, Dot, Rid, ShardId};
use std::sync::Arc;

/// A state-machine key (paper: 8-byte keys).
pub type Key = u64;

/// Instrumentation for the zero-clone broadcast invariant: every fresh
/// key-buffer allocation (the only heap storage a [`Command`] owns) bumps
/// a process-wide counter, while `Command::clone` — an `Arc` increment —
/// never does. Tests assert that fanning a command out to `r - 1` peers
/// allocates O(commands), not O(commands × peers).
pub mod clone_stats {
    use std::sync::atomic::{AtomicU64, Ordering};

    static KEY_BUFFER_ALLOCS: AtomicU64 = AtomicU64::new(0);

    pub(super) fn record_alloc() {
        KEY_BUFFER_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }

    /// Total key buffers allocated by `Command` constructors so far
    /// (process-wide, monotone; diff two readings around a workload).
    pub fn key_buffer_allocs() -> u64 {
        KEY_BUFFER_ALLOCS.load(Ordering::Relaxed)
    }
}

/// Operation applied to the in-memory KV store at execution time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Op {
    /// Read the value of the key.
    Get,
    /// Overwrite the value of the key with `payload_len` fresh bytes.
    Put,
    /// Read-modify-write (always conflicting, used by YCSB+T updates).
    Rmw,
    /// Stability-powered read: executes locally at the coordinator with
    /// no broadcast once its timestamp is covered by the stability
    /// frontier (`Protocol::submit_read`). Observes state like [`Op::Get`]
    /// but never enters the ordering protocol on families that support
    /// local reads; the others degrade it to an ordinary command.
    Read,
}

impl Op {
    /// Ops that never mutate state (Get and the local-read class).
    pub fn is_read(&self) -> bool {
        matches!(self, Op::Get | Op::Read)
    }
}

/// An application command submitted by a client.
///
/// `Command` is deliberately *cheap to clone*: the key set (its only heap
/// storage) is `Arc`-backed, and the payload travels as a length (the wire
/// codec materializes the bytes). Protocol broadcast fans a command out to
/// every fast-quorum/group peer by cloning the message that carries it, so
/// a deep copy per peer would put O(peers × keys + peers × payload)
/// allocation on the hot path — with the `Arc` it is a reference-count
/// bump ([`clone_stats`] instruments the invariant).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Command {
    /// Request id allocated by the issuing client's session; routes the
    /// response back to the client (and identifies retries).
    pub rid: Rid,
    /// Keys accessed — one per partition touched. Sorted, deduplicated,
    /// shared: cloning the command shares this buffer.
    pub keys: Arc<[Key]>,
    /// Operation kind (uniform across keys; enough for YCSB+T).
    pub op: Op,
    /// Size of the payload carried by the command, in bytes. Payload
    /// contents are irrelevant to ordering so state carries only the size;
    /// the wire codec materializes `payload_len` zero bytes so frames have
    /// realistic sizes.
    pub payload_len: u32,
    /// Number of single-key commands folded into this one by the batching
    /// layer (1 = unbatched). Throughput counts `batched` operations.
    pub batched: u32,
}

impl Command {
    pub fn new(rid: Rid, mut keys: Vec<Key>, op: Op, payload_len: u32) -> Self {
        keys.sort_unstable();
        keys.dedup();
        clone_stats::record_alloc();
        Self { rid, keys: keys.into(), op, payload_len, batched: 1 }
    }

    /// Single-key shorthand.
    pub fn single(rid: Rid, key: Key, op: Op, payload_len: u32) -> Self {
        clone_stats::record_alloc();
        Self { rid, keys: vec![key].into(), op, payload_len, batched: 1 }
    }

    /// A read-only command over `keys` ([`Op::Read`]): eligible for the
    /// coordination-free local-read path where the protocol supports it.
    /// Reads carry no payload.
    pub fn read(rid: Rid, keys: Vec<Key>) -> Self {
        Self::new(rid, keys, Op::Read, 0)
    }

    /// The issuing client (from the request id).
    pub fn client(&self) -> ClientId {
        self.rid.client()
    }

    /// Does this command conflict with another (shared key)?
    /// Both key vectors are sorted, so this is a linear merge.
    pub fn conflicts_with(&self, other: &Command) -> bool {
        let (mut i, mut j) = (0, 0);
        while i < self.keys.len() && j < other.keys.len() {
            match self.keys[i].cmp(&other.keys[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => return true,
            }
        }
        false
    }

    /// Shards accessed by this command under `key_to_shard` placement.
    pub fn shards(&self, shards: u32) -> Vec<ShardId> {
        let mut out: Vec<ShardId> = self.keys.iter().map(|k| key_to_shard(*k, shards)).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Exact wire size of this command in bytes — equal to the length of
    /// the codec's `cmd` encoding (`net::wire`, docs/WIRE.md): rid
    /// (client u64 + seq u64), op u8, payload_len u32, batched u32, key
    /// count u16, the keys, and `payload_len` payload bytes. The wire
    /// codec tests assert this stays equal to the encoded length so the
    /// simulator's NIC model never under- or over-counts.
    pub fn wire_size(&self) -> u64 {
        8 + 8 + 1 + 4 + 4 + 2 + 8 * self.keys.len() as u64 + self.payload_len as u64
    }
}

/// Static key placement: key → shard.
pub fn key_to_shard(key: Key, shards: u32) -> ShardId {
    debug_assert!(shards > 0);
    // Fibonacci hashing: avoids pathological striding for sequential keys.
    let h = key.wrapping_mul(0x9E3779B97F4A7C15);
    ShardId((h >> 32) as u32 % shards)
}

/// Response returned to the client for one command — computed by the
/// replica's [`crate::executor::Executor`] when the command executes and
/// routed back to the issuing session as `Action::Reply` (and, over TCP,
/// a `ClientReply` frame). Defined here (not in `store`) because it is
/// part of the client-facing API: the PSMR response-validity check is
/// phrased over client-observed `Response`s.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Response {
    /// Per accessed key: version observed (reads) or produced (writes).
    pub versions: Vec<(Key, u64)>,
}

/// A command completion observed by a client: used by the PSMR checker and
/// latency accounting.
#[derive(Clone, Debug)]
pub struct Completion {
    /// Protocol-internal identity the submitting replica assigned.
    pub dot: Dot,
    /// Request id the response was matched against.
    pub rid: Rid,
    /// Observing client. For site-batched commands several clients share
    /// one `rid`/`dot` (and observe the same merged response); `client`
    /// records which member this completion belongs to.
    pub client: ClientId,
    pub submitted_at: u64,
    pub completed_at: u64,
    /// The response this client observed (checked against a sequential
    /// oracle by `check::assert_psmr`).
    pub response: Response,
}

impl Completion {
    pub fn latency(&self) -> u64 {
        self.completed_at.saturating_sub(self.submitted_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rid(c: u64) -> Rid {
        Rid::new(ClientId(c), 1)
    }

    #[test]
    fn conflict_detection_shared_key() {
        let a = Command::new(rid(1), vec![5, 9], Op::Put, 100);
        let b = Command::new(rid(2), vec![9, 12], Op::Put, 100);
        let c = Command::new(rid(3), vec![1, 2], Op::Put, 100);
        assert!(a.conflicts_with(&b));
        assert!(b.conflicts_with(&a));
        assert!(!a.conflicts_with(&c));
        assert!(!c.conflicts_with(&b));
    }

    #[test]
    fn keys_sorted_and_deduped() {
        let a = Command::new(rid(1), vec![9, 5, 9, 5], Op::Get, 0);
        assert_eq!(&a.keys[..], &[5, 9]);
    }

    #[test]
    fn clone_shares_the_key_buffer() {
        let a = Command::new(rid(1), vec![5, 9], Op::Put, 100);
        let before = clone_stats::key_buffer_allocs();
        let clones: Vec<Command> = (0..64).map(|_| a.clone()).collect();
        assert_eq!(
            clone_stats::key_buffer_allocs(),
            before,
            "Command::clone must not allocate a key buffer"
        );
        assert!(clones.iter().all(|c| Arc::ptr_eq(&c.keys, &a.keys)));
    }

    #[test]
    fn command_carries_its_client() {
        let a = Command::single(Rid::new(ClientId(7), 3), 1, Op::Put, 0);
        assert_eq!(a.client(), ClientId(7));
        assert_eq!(a.rid.seq(), 3);
    }

    #[test]
    fn wire_size_counts_every_encoded_field() {
        // Fixed header (rid 16 + op 1 + payload_len 4 + batched 4 + count
        // 2 = 27) plus 8 per key plus the payload bytes. The codec test
        // `command_wire_size_matches_codec` pins this to the encoder.
        let a = Command::new(rid(1), vec![5, 9], Op::Put, 100);
        assert_eq!(a.wire_size(), 27 + 16 + 100);
        let b = Command::single(rid(1), 5, Op::Get, 0);
        assert_eq!(b.wire_size(), 27 + 8);
    }

    #[test]
    fn key_to_shard_is_total_and_stable() {
        for shards in 1..8u32 {
            for key in 0..1000u64 {
                let s = key_to_shard(key, shards);
                assert!(s.0 < shards);
                assert_eq!(s, key_to_shard(key, shards));
            }
        }
    }

    #[test]
    fn key_to_shard_balances_sequential_keys() {
        let shards = 4;
        let mut counts = vec![0u32; shards as usize];
        for key in 0..10_000u64 {
            counts[key_to_shard(key, shards).0 as usize] += 1;
        }
        for &c in &counts {
            // Each shard within 20% of fair share.
            assert!((2000..=3000).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn multi_shard_command_lists_each_shard_once() {
        let cmd = Command::new(rid(1), vec![1, 2, 3, 4, 5, 6, 7, 8], Op::Put, 10);
        let shards = cmd.shards(2);
        assert!(!shards.is_empty() && shards.len() <= 2);
        let mut sorted = shards.clone();
        sorted.dedup();
        assert_eq!(sorted, shards);
    }
}
