//! Commands: the unit of replication.
//!
//! A command accesses one or more *partitions*. Following the paper (§6.2,
//! §6.4) a partition is identified by a key: commands conflict iff they
//! share a key. In partial replication each key lives on exactly one shard;
//! in full replication there is a single shard replicated everywhere.

use super::id::{ClientId, Dot, ShardId};

/// A state-machine key (paper: 8-byte keys).
pub type Key = u64;

/// Operation applied to the in-memory KV store at execution time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Op {
    /// Read the value of the key.
    Get,
    /// Overwrite the value of the key with `payload_len` fresh bytes.
    Put,
    /// Read-modify-write (always conflicting, used by YCSB+T updates).
    Rmw,
}

/// An application command submitted by a client.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Command {
    /// Submitting client (used to route the response).
    pub client: ClientId,
    /// Keys accessed — one per partition touched. Sorted, deduplicated.
    pub keys: Vec<Key>,
    /// Operation kind (uniform across keys; enough for YCSB+T).
    pub op: Op,
    /// Size of the payload carried by the command, in bytes. Payload
    /// contents are irrelevant to ordering so we carry only the size
    /// (the wire codec materializes zero bytes for it).
    pub payload_len: u32,
    /// Number of single-key commands folded into this one by the batching
    /// layer (1 = unbatched). Throughput counts `batched` operations.
    pub batched: u32,
}

impl Command {
    pub fn new(client: ClientId, mut keys: Vec<Key>, op: Op, payload_len: u32) -> Self {
        keys.sort_unstable();
        keys.dedup();
        Self { client, keys, op, payload_len, batched: 1 }
    }

    /// Single-key shorthand.
    pub fn single(client: ClientId, key: Key, op: Op, payload_len: u32) -> Self {
        Self { client, keys: vec![key], op, payload_len, batched: 1 }
    }

    /// Does this command conflict with another (shared key)?
    /// Both key vectors are sorted, so this is a linear merge.
    pub fn conflicts_with(&self, other: &Command) -> bool {
        let (mut i, mut j) = (0, 0);
        while i < self.keys.len() && j < other.keys.len() {
            match self.keys[i].cmp(&other.keys[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => return true,
            }
        }
        false
    }

    /// Shards accessed by this command under `key_to_shard` placement.
    pub fn shards(&self, shards: u32) -> Vec<ShardId> {
        let mut out: Vec<ShardId> = self.keys.iter().map(|k| key_to_shard(*k, shards)).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Approximate wire size of this command in bytes (key bytes + payload).
    pub fn wire_size(&self) -> u64 {
        8 * self.keys.len() as u64 + self.payload_len as u64 + 16
    }
}

/// Static key placement: key → shard.
pub fn key_to_shard(key: Key, shards: u32) -> ShardId {
    debug_assert!(shards > 0);
    // Fibonacci hashing: avoids pathological striding for sequential keys.
    let h = key.wrapping_mul(0x9E3779B97F4A7C15);
    ShardId((h >> 32) as u32 % shards)
}

/// A command completion observed by a client: used by the PSMR checker and
/// latency accounting.
#[derive(Clone, Debug)]
pub struct Completion {
    pub dot: Dot,
    pub client: ClientId,
    pub submitted_at: u64,
    pub completed_at: u64,
}

impl Completion {
    pub fn latency(&self) -> u64 {
        self.completed_at.saturating_sub(self.submitted_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conflict_detection_shared_key() {
        let a = Command::new(ClientId(1), vec![5, 9], Op::Put, 100);
        let b = Command::new(ClientId(2), vec![9, 12], Op::Put, 100);
        let c = Command::new(ClientId(3), vec![1, 2], Op::Put, 100);
        assert!(a.conflicts_with(&b));
        assert!(b.conflicts_with(&a));
        assert!(!a.conflicts_with(&c));
        assert!(!c.conflicts_with(&b));
    }

    #[test]
    fn keys_sorted_and_deduped() {
        let a = Command::new(ClientId(1), vec![9, 5, 9, 5], Op::Get, 0);
        assert_eq!(a.keys, vec![5, 9]);
    }

    #[test]
    fn key_to_shard_is_total_and_stable() {
        for shards in 1..8u32 {
            for key in 0..1000u64 {
                let s = key_to_shard(key, shards);
                assert!(s.0 < shards);
                assert_eq!(s, key_to_shard(key, shards));
            }
        }
    }

    #[test]
    fn key_to_shard_balances_sequential_keys() {
        let shards = 4;
        let mut counts = vec![0u32; shards as usize];
        for key in 0..10_000u64 {
            counts[key_to_shard(key, shards).0 as usize] += 1;
        }
        for &c in &counts {
            // Each shard within 20% of fair share.
            assert!((2000..=3000).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn multi_shard_command_lists_each_shard_once() {
        let cmd = Command::new(ClientId(1), vec![1, 2, 3, 4, 5, 6, 7, 8], Op::Put, 10);
        let shards = cmd.shards(2);
        assert!(!shards.is_empty() && shards.len() <= 2);
        let mut sorted = shards.clone();
        sorted.dedup();
        assert_eq!(sorted, shards);
    }
}
