//! Cluster configuration: replication factor, fault tolerance, sharding,
//! placement, and the quorum arithmetic used throughout the protocols.

use super::id::{ProcessId, ShardId};

/// Which durability backend a replica's executors run on (see
/// `store::storage`). `Memory` — the default — wires the no-op backend
/// in, keeping every pre-existing run byte-identical; `Disk` gives the
/// TCP runtime a real per-worker-slot WAL + snapshot directory, and the
/// simulator its deterministic in-memory equivalent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorageMode {
    Memory,
    Disk,
}

/// Static configuration of a (P)SMR deployment.
///
/// Following Flexible Paxos (and the paper §2), the allowed number of
/// failures `f` is decoupled from the replication factor `r`:
/// `1 <= f <= ⌊(r-1)/2⌋`.
#[derive(Clone, Debug)]
pub struct Config {
    /// Replication factor: processes per partition.
    pub r: usize,
    /// Tolerated failures per partition.
    pub f: usize,
    /// Number of shards (1 = full replication).
    pub shards: u32,
    /// Number of sites (data centers). For full replication `sites == r`.
    pub sites: usize,
    /// Interval between periodic `MPromises` broadcasts / executor runs,
    /// in microseconds of (simulated) time. Paper flushes every 5 ms.
    pub tick_interval_us: u64,
    /// Enable the MBump optimization for faster multi-partition stability
    /// (paper §4 "Faster stability").
    pub bump_enabled: bool,
    /// Timeout after which a pending command triggers recovery, in µs.
    /// `u64::MAX` disables recovery (useful in failure-free benches).
    pub recovery_timeout_us: u64,
    /// Garbage-collection cadence: every `gc_interval_ticks` periodic
    /// ticks a process exchanges its executed-command frontiers with its
    /// group (`MGarbageCollect`) and prunes group-wide-executed command
    /// state. 0 disables GC (memory then grows with the run, as the seed
    /// did unconditionally).
    pub gc_interval_ticks: u64,
    /// Outgoing message batching (`protocol::common::batch::Batcher`):
    /// a per-destination queue is wrapped into one `MBatch` wire frame
    /// once it holds this many messages. 0 disables batching (every
    /// message is its own frame, the seed behaviour).
    pub batch_max_msgs: usize,
    /// Batching flush policy. `true` (the default when batching is on):
    /// queues are held across protocol steps and flushed on the size
    /// threshold or the next periodic tick — maximum amortization, up to
    /// one tick of added latency. `false`: queues are flushed at the end
    /// of every protocol step, so batching only coalesces the messages
    /// one step emits to the same destination and never delays anything
    /// (behaviour- and timing-transparent; see `rust/tests/batching.rs`).
    pub batch_hold: bool,
    /// Number of shared-nothing protocol worker partitions per replica
    /// (`protocol::common::shard::Sharded`): protocol state is
    /// hash-partitioned by key across `workers` inner instances, and
    /// worker `w` of every replica forms one complete protocol instance
    /// over its key subset. 1 (the default) is the monolithic replica.
    pub workers: usize,
    /// Worker slot of *this* protocol instance within a sharded replica,
    /// in `0..workers`. Set by the `Sharded` router when it constructs its
    /// inner instances; leave 0 everywhere else. Drives the instance's
    /// strided dot allocation and stride-aware GC frontiers.
    pub worker: usize,
    /// Age bound for held batch queues, in microseconds. Under
    /// `batch_hold`, a periodic tick flushes only the queues whose oldest
    /// entry has waited at least this long — younger queues keep
    /// accumulating toward `batch_max_msgs` for bigger batches — so a
    /// lone sub-threshold message still departs within one delay bound
    /// (plus one tick of quantization). 0 (the default) flushes every
    /// held queue on every tick.
    pub batch_max_delay_us: u64,
    /// Bounded-staleness slack for the local-read path, in timestamp
    /// units. A local read assigned timestamp `ts` normally waits until
    /// the stability frontier covers `ts`; with slack `s` it is served as
    /// soon as the frontier covers `ts - s` — i.e. it observes state as
    /// of `frontier` and may miss the writes in the last `s` timestamps.
    /// 0 (the default) is the strict stable-read level.
    pub read_slack: u64,
    /// TEST KNOB — artificially inflate the stability frontier the
    /// local-read path consults by this many timestamp units. A non-zero
    /// skew releases reads *before* the writes ordered under them have
    /// stabilized, which is exactly the bug the checker's
    /// read-linearizability oracle exists to catch (the negative test in
    /// `rust/tests/reads.rs` proves the oracle bites). Never set this
    /// outside tests. 0 (the default) is the sound frontier.
    pub read_frontier_skew: u64,
    /// Epoch-based membership reconfiguration: when enabled, survivors
    /// vote a suspected member into an eviction, install a new epoch,
    /// exclude the evicted member from the GC frontier (so executed
    /// frontier GC unfreezes under faults), and fence off messages from
    /// evicted members. On by default; fault-free runs never trigger a
    /// vote so their behaviour is unchanged.
    pub epochs_enabled: bool,
    /// TEST KNOB — accept stale epoch installs (skip the monotonicity
    /// guard when applying a remote epoch vote result). This re-enters
    /// an old epoch after a newer one was installed, which is exactly
    /// the regression the checker's `EpochRegression` oracle exists to
    /// catch (the negative test in `rust/tests/nemesis.rs` proves the
    /// oracle bites). Never set this outside tests.
    pub epoch_fence_off: bool,
    /// Per-client executor dedup window: each replica remembers the
    /// last `dedup_window` request ids it executed per client and
    /// absorbs re-submissions of those rids (exactly-once across
    /// client failover). 0 disables dedup entirely — the negative
    /// knob for the checker's `DuplicateRequest` oracle.
    pub dedup_window: usize,
    /// Retransmission cadence for in-flight coordinator state, in
    /// ticks. Every `retry_interval_ticks` ticks a coordinator
    /// re-broadcasts proposals that have not yet reached quorum and
    /// re-broadcasts commits that peers may have missed, so dropped
    /// links heal once the nemesis window closes. 0 (the default)
    /// disables retransmission and keeps existing seeded runs
    /// bit-identical.
    pub retry_interval_ticks: u64,
    /// Durability backend for the executors' state machines (see
    /// [`StorageMode`]); `Memory` is the default.
    pub storage: StorageMode,
    /// Group-commit window of the write-ahead log: WAL records are
    /// fsynced once this many have accumulated, so a crash loses at most
    /// `wal_fsync_batch - 1` *acked-to-nobody* tail records (recovery
    /// replays everything durable and state transfer refills the rest).
    /// 1 = sync every record.
    pub wal_fsync_batch: usize,
    /// Checkpoint cadence: after this many logged executions the store is
    /// snapshotted (content-addressed chunks + manifest) and the WAL
    /// resets. 0 disables snapshots (recovery then replays the whole
    /// WAL).
    pub snapshot_every: u64,
    /// Number of client-plane event-loop threads in the TCP runtime.
    /// Every client connection is multiplexed onto one of these loops
    /// (round-robin at accept) — connection count no longer costs
    /// threads. Peer and transfer connections are unaffected: they stay
    /// on dedicated blocking threads.
    pub client_event_threads: usize,
    /// Admission-control window: the maximum number of submits a single
    /// client session may have in flight at the node. A submit arriving
    /// over a full window is shed at the edge with an explicit
    /// `ClientBusy` reply (wire tag 25) — it never reaches a worker —
    /// and `TcpClient` surfaces it as a retryable busy error.
    /// 0 = unbounded (no admission control).
    pub max_inflight_per_session: usize,
    /// Bounded wait of the per-peer writer's merge stage, in
    /// microseconds. 0 (the default) keeps the opportunistic behaviour:
    /// the writer merges only frames already queued and flushes
    /// immediately — byte-identical to every run before this knob
    /// existed (pinned by a unit test). A positive value lets the
    /// writer wait up to this long for more frames before flushing,
    /// trading bounded latency for more members per merged frame.
    pub merge_wait_us: u64,
    /// Heartbeat cadence of the TCP runtime's failure detector, in
    /// microseconds: a per-peer writer that has been idle this long
    /// emits a one-byte heartbeat frame (wire tag 26) so the peer's
    /// last-seen table keeps advancing even when the protocol is
    /// quiet. Any frame counts as liveness evidence — heartbeats only
    /// fill the gaps. Also the detector thread's scan cadence.
    pub heartbeat_interval_us: u64,
    /// Failure-detector suspicion timeout, in microseconds: if no frame
    /// (heartbeat or otherwise) has arrived from a peer for this long,
    /// the TCP runtime calls `Protocol::suspect` for it, driving the
    /// `MEpoch` eviction vote over real sockets. `u64::MAX` (the
    /// default) disables the detector — suspicion is then only ever
    /// harness-driven, the pre-detector behaviour. Choose a value
    /// several multiples of `heartbeat_interval_us`: a too-tight
    /// timeout evicts live-but-slow nodes (safe — see the
    /// false-suspicion test — but needlessly shrinks the group).
    pub suspect_delay_us: u64,
    /// Cap of the per-dot exponential retransmission backoff, in ticks.
    /// 0 (the default) keeps the legacy fixed cadence: every in-flight
    /// dot is re-driven on every `retry_interval_ticks`-th tick. A
    /// positive cap makes each dot back off individually — first retry
    /// `retry_interval_ticks` after registration, then doubling up to
    /// the cap — so a long partition heals with a trickle instead of a
    /// retransmit storm. Pinned by `protocol::common::retry` unit tests.
    pub retry_backoff_cap_ticks: u64,
}

impl Config {
    /// Default per-client executor dedup window (see
    /// [`Config::dedup_window`]). Large enough that a re-issued request
    /// lands well inside the window under any realistic client pipeline
    /// depth.
    pub const DEFAULT_DEDUP_WINDOW: usize = 64;

    /// Default client-plane event-loop thread count (see
    /// [`Config::client_event_threads`]). Two loops keep accept latency
    /// and reply batching independent even on small machines; the bench
    /// sweeps hold this fixed while connections scale 1k → 100k.
    pub const DEFAULT_CLIENT_EVENT_THREADS: usize = 2;

    /// Default per-session in-flight window (see
    /// [`Config::max_inflight_per_session`]). Deep enough that a
    /// well-behaved pipelined client never sees a busy reply; shallow
    /// enough that a runaway session cannot queue unboundedly.
    pub const DEFAULT_MAX_INFLIGHT_PER_SESSION: usize = 1024;

    pub fn new(r: usize, f: usize) -> Self {
        assert!(r >= 3, "need at least 3 replicas (r={r})");
        assert!(f >= 1 && f <= (r - 1) / 2, "need 1 <= f <= ⌊(r-1)/2⌋ (r={r}, f={f})");
        Self {
            r,
            f,
            shards: 1,
            sites: r,
            tick_interval_us: 5_000,
            bump_enabled: true,
            recovery_timeout_us: u64::MAX,
            gc_interval_ticks: 16,
            workers: 1,
            worker: 0,
            batch_max_msgs: 0,
            batch_hold: true,
            batch_max_delay_us: 0,
            read_slack: 0,
            read_frontier_skew: 0,
            epochs_enabled: true,
            epoch_fence_off: false,
            dedup_window: Self::DEFAULT_DEDUP_WINDOW,
            retry_interval_ticks: 0,
            storage: StorageMode::Memory,
            wal_fsync_batch: 8,
            snapshot_every: 1024,
            client_event_threads: Self::DEFAULT_CLIENT_EVENT_THREADS,
            max_inflight_per_session: Self::DEFAULT_MAX_INFLIGHT_PER_SESSION,
            merge_wait_us: 0,
            heartbeat_interval_us: 100_000,
            suspect_delay_us: u64::MAX,
            retry_backoff_cap_ticks: 0,
        }
    }

    /// Shard protocol state across `workers` shared-nothing worker
    /// partitions per replica (run the protocol as
    /// `protocol::common::shard::Sharded<P>`; 1 = monolithic). At most
    /// 256: the wire envelope names the worker slot in one byte
    /// (docs/WIRE.md tag 19), and silently truncating would misroute
    /// protocol traffic.
    pub fn with_workers(mut self, workers: usize) -> Self {
        assert!(
            (1..=256).contains(&workers),
            "workers must be in 1..=256 (the Routed envelope carries a u8 slot)"
        );
        self.workers = workers;
        self
    }

    pub fn with_shards(mut self, shards: u32) -> Self {
        assert!(shards >= 1);
        self.shards = shards;
        self
    }

    pub fn with_tick_interval_us(mut self, us: u64) -> Self {
        self.tick_interval_us = us;
        self
    }

    pub fn with_recovery_timeout_us(mut self, us: u64) -> Self {
        self.recovery_timeout_us = us;
        self
    }

    pub fn with_bump(mut self, enabled: bool) -> Self {
        self.bump_enabled = enabled;
        self
    }

    pub fn with_gc_interval_ticks(mut self, ticks: u64) -> Self {
        self.gc_interval_ticks = ticks;
        self
    }

    /// Enable outgoing message batching with the given per-destination
    /// size threshold (0 disables).
    pub fn with_batching(mut self, max_msgs: usize) -> Self {
        self.batch_max_msgs = max_msgs;
        self
    }

    /// Select the batching flush policy (see [`Config::batch_hold`]).
    pub fn with_batch_hold(mut self, hold: bool) -> Self {
        self.batch_hold = hold;
        self
    }

    /// Age bound for held batch queues (see
    /// [`Config::batch_max_delay_us`]; 0 flushes every tick).
    pub fn with_batch_max_delay_us(mut self, us: u64) -> Self {
        self.batch_max_delay_us = us;
        self
    }

    /// Bounded-staleness slack for local reads (see
    /// [`Config::read_slack`]; 0 = strict stable reads).
    pub fn with_read_slack(mut self, slack: u64) -> Self {
        self.read_slack = slack;
        self
    }

    /// TEST KNOB: artificially inflate the local-read stability frontier
    /// (see [`Config::read_frontier_skew`]). Exists so the negative
    /// oracle test can prove unsound early release is caught.
    pub fn with_read_frontier_skew(mut self, skew: u64) -> Self {
        self.read_frontier_skew = skew;
        self
    }

    /// Enable or disable epoch-based membership reconfiguration (see
    /// [`Config::epochs_enabled`]; on by default).
    pub fn with_epochs(mut self, enabled: bool) -> Self {
        self.epochs_enabled = enabled;
        self
    }

    /// TEST KNOB: disable epoch fencing (see
    /// [`Config::epoch_fence_off`]). Exists so the negative oracle test
    /// can prove stale-epoch acceptance is caught.
    pub fn with_epoch_fence_off(mut self, off: bool) -> Self {
        self.epoch_fence_off = off;
        self
    }

    /// Per-client dedup window at the executors (see
    /// [`Config::dedup_window`]; 0 disables — the negative-oracle knob).
    pub fn with_dedup_window(mut self, window: usize) -> Self {
        self.dedup_window = window;
        self
    }

    /// Retransmission cadence in ticks (see
    /// [`Config::retry_interval_ticks`]; 0 disables).
    pub fn with_retry_interval_ticks(mut self, ticks: u64) -> Self {
        self.retry_interval_ticks = ticks;
        self
    }

    /// Durability backend selection (see [`Config::storage`]).
    pub fn with_storage(mut self, mode: StorageMode) -> Self {
        self.storage = mode;
        self
    }

    /// WAL group-commit window (see [`Config::wal_fsync_batch`];
    /// clamped to ≥ 1 by the storage layer).
    pub fn with_wal_fsync_batch(mut self, batch: usize) -> Self {
        self.wal_fsync_batch = batch;
        self
    }

    /// Checkpoint cadence (see [`Config::snapshot_every`]; 0 disables).
    pub fn with_snapshot_every(mut self, every: u64) -> Self {
        self.snapshot_every = every;
        self
    }

    /// Client-plane event-loop thread count (see
    /// [`Config::client_event_threads`]; must be ≥ 1).
    pub fn with_client_event_threads(mut self, threads: usize) -> Self {
        assert!(threads >= 1, "need at least one client event loop");
        self.client_event_threads = threads;
        self
    }

    /// Per-session in-flight admission window (see
    /// [`Config::max_inflight_per_session`]; 0 = unbounded).
    pub fn with_max_inflight_per_session(mut self, window: usize) -> Self {
        self.max_inflight_per_session = window;
        self
    }

    /// Bounded wait for the per-peer writer merge stage (see
    /// [`Config::merge_wait_us`]; 0 = opportunistic, the default).
    pub fn with_merge_wait_us(mut self, us: u64) -> Self {
        self.merge_wait_us = us;
        self
    }

    /// Heartbeat cadence of the TCP failure detector (see
    /// [`Config::heartbeat_interval_us`]; must be ≥ 1 µs).
    pub fn with_heartbeat_interval_us(mut self, us: u64) -> Self {
        assert!(us >= 1, "heartbeat interval must be positive");
        self.heartbeat_interval_us = us;
        self
    }

    /// Failure-detector suspicion timeout (see
    /// [`Config::suspect_delay_us`]; `u64::MAX` disables the detector).
    pub fn with_suspect_delay_us(mut self, us: u64) -> Self {
        self.suspect_delay_us = us;
        self
    }

    /// Cap of the per-dot exponential retransmission backoff (see
    /// [`Config::retry_backoff_cap_ticks`]; 0 = legacy fixed cadence).
    pub fn with_retry_backoff_cap_ticks(mut self, ticks: u64) -> Self {
        self.retry_backoff_cap_ticks = ticks;
        self
    }

    /// Tempo/Atlas fast-quorum size: `⌊r/2⌋ + f`.
    pub fn fast_quorum_size(&self) -> usize {
        self.r / 2 + self.f
    }

    /// Slow (Flexible Paxos phase-2) quorum size: `f + 1`.
    pub fn slow_quorum_size(&self) -> usize {
        self.f + 1
    }

    /// Recovery (Flexible Paxos phase-1) quorum size: `r - f`.
    pub fn recovery_quorum_size(&self) -> usize {
        self.r - self.f
    }

    /// Simple majority: `⌊r/2⌋ + 1`. Stability detection threshold.
    pub fn majority(&self) -> usize {
        self.r / 2 + 1
    }

    /// EPaxos fast-quorum size: `⌊3r/4⌋` (paper §6 intro).
    pub fn epaxos_fast_quorum_size(&self) -> usize {
        3 * self.r / 4
    }

    /// Caesar fast-quorum size: `⌈3r/4⌉` (paper §6 intro).
    pub fn caesar_fast_quorum_size(&self) -> usize {
        (3 * self.r).div_ceil(4)
    }

    /// Total number of processes across all shards.
    pub fn n_processes(&self) -> usize {
        self.r * self.shards as usize
    }

    /// All processes replicating `shard` (the paper's `I_p`).
    pub fn shard_processes(&self, shard: ShardId) -> Vec<ProcessId> {
        let base = shard.0 * self.r as u32;
        (0..self.r as u32).map(|k| ProcessId(base + k)).collect()
    }

    /// Shard replicated by `p`.
    pub fn shard_of(&self, p: ProcessId) -> ShardId {
        ShardId(p.0 / self.r as u32)
    }

    /// Site (data center) where `p` runs. Replica k of every shard is
    /// placed at site k: processes with the same site index are co-located
    /// (paper Fig. 4: "processes with the same color").
    pub fn site_of(&self, p: ProcessId) -> usize {
        (p.0 as usize % self.r) % self.sites
    }

    /// The replica of `shard` co-located with (or closest to) `p`
    /// — used to pick per-partition coordinators (the paper's `I_c^i`).
    pub fn closest_in_shard(&self, p: ProcessId, shard: ShardId) -> ProcessId {
        let k = p.0 % self.r as u32;
        ProcessId(shard.0 * self.r as u32 + k)
    }

    /// Fast quorum for a command coordinated by `coord` at its shard:
    /// the coordinator plus the `fq-1` replicas closest to it
    /// (ring order as a latency proxy; real deployments would sort by RTT).
    pub fn fast_quorum(&self, coord: ProcessId) -> Vec<ProcessId> {
        self.quorum_from(coord, self.fast_quorum_size())
    }

    /// Slow quorum including `coord`.
    pub fn slow_quorum(&self, coord: ProcessId) -> Vec<ProcessId> {
        self.quorum_from(coord, self.slow_quorum_size())
    }

    fn quorum_from(&self, coord: ProcessId, size: usize) -> Vec<ProcessId> {
        let shard = self.shard_of(coord);
        let base = shard.0 * self.r as u32;
        let k0 = coord.0 - base;
        (0..size as u32).map(|d| ProcessId(base + (k0 + d) % self.r as u32)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quorum_sizes_match_paper() {
        // r=5, f=1: fast 3, slow 2, recovery 4, majority 3.
        let c = Config::new(5, 1);
        assert_eq!(c.fast_quorum_size(), 3);
        assert_eq!(c.slow_quorum_size(), 2);
        assert_eq!(c.recovery_quorum_size(), 4);
        assert_eq!(c.majority(), 3);
        // r=5, f=2: fast 4, slow 3, recovery 3.
        let c = Config::new(5, 2);
        assert_eq!(c.fast_quorum_size(), 4);
        assert_eq!(c.slow_quorum_size(), 3);
        assert_eq!(c.recovery_quorum_size(), 3);
        // EPaxos r=5 -> 3; Caesar r=5 -> 4.
        assert_eq!(c.epaxos_fast_quorum_size(), 3);
        assert_eq!(c.caesar_fast_quorum_size(), 4);
    }

    #[test]
    #[should_panic]
    fn rejects_f_too_large() {
        // r=3 admits only f=1.
        let _ = Config::new(3, 2);
    }

    #[test]
    fn recovery_and_fast_quorums_always_intersect_in_majority_minus_coord() {
        // |Q_rec ∩ Q_fast| >= ⌊r/2⌋ (Property 4 prerequisite).
        for r in [3, 5, 7, 9] {
            for f in 1..=(r - 1) / 2 {
                let c = Config::new(r, f);
                assert!(
                    c.recovery_quorum_size() + c.fast_quorum_size() - r >= r / 2,
                    "r={r} f={f}"
                );
            }
        }
    }

    #[test]
    fn shard_process_layout_roundtrips() {
        let c = Config::new(3, 1).with_shards(4);
        assert_eq!(c.n_processes(), 12);
        for s in 0..4 {
            for p in c.shard_processes(ShardId(s)) {
                assert_eq!(c.shard_of(p), ShardId(s));
            }
        }
        // Co-located replicas share sites across shards.
        assert_eq!(c.site_of(ProcessId(0)), c.site_of(ProcessId(3)));
        assert_eq!(c.closest_in_shard(ProcessId(1), ShardId(2)), ProcessId(7));
    }

    #[test]
    fn fast_quorum_contains_coordinator_and_has_right_size() {
        let c = Config::new(5, 2);
        for p in 0..5 {
            let q = c.fast_quorum(ProcessId(p));
            assert_eq!(q.len(), 4);
            assert!(q.contains(&ProcessId(p)));
            let mut u = q.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), q.len(), "duplicates in quorum");
        }
    }
}
