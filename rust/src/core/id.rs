//! Process, shard, client and command identifiers.
//!
//! A [`Dot`] ("identifier dot", following the EPaxos/Atlas lineage) uniquely
//! identifies a command: the process that created it plus a per-process
//! sequence number. The paper's `initial_p(id)` — the initial coordinator of
//! a command at a partition — is recoverable from the dot itself.

use std::fmt;

/// Identifier of a protocol process (replica). Dense, assigned at startup.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProcessId(pub u32);

/// Identifier of a shard (machine-colocated group of partitions).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ShardId(pub u32);

/// Identifier of a closed-loop client.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ClientId(pub u64);

/// Rifl-style request identifier (fantoch's `Rifl` lineage): the issuing
/// client plus a per-client sequence number, allocated by a
/// [`crate::client::Session`]. A `Rid` names a *request* end to end —
/// it travels inside the [`super::Command`], survives the protocol's
/// internal renaming to a [`Dot`], and comes back in the reply — so a
/// client can match responses to requests without ever seeing protocol
/// identifiers.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Rid(pub ClientId, pub u64);

impl Rid {
    /// Build a request id directly (tests; real code uses `Session`).
    pub fn new(client: ClientId, seq: u64) -> Self {
        Self(client, seq)
    }

    /// The issuing client.
    pub fn client(self) -> ClientId {
        self.0
    }

    /// Per-client sequence number (1-based).
    pub fn seq(self) -> u64 {
        self.1
    }
}

impl fmt::Debug for Rid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "C{}.{}", self.0 .0, self.1)
    }
}

impl fmt::Display for Rid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "C{}.{}", self.0 .0, self.1)
    }
}

/// Unique command identifier: (origin process, per-origin sequence number).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Dot {
    pub origin: ProcessId,
    pub seq: u64,
}

impl Dot {
    pub fn new(origin: ProcessId, seq: u64) -> Self {
        Self { origin, seq }
    }

    /// The initial coordinator of this command at its origin partition
    /// (`initial_p(id)` in the paper).
    pub fn initial_coordinator(&self) -> ProcessId {
        self.origin
    }
}

impl fmt::Debug for ProcessId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P{}", self.0)
    }
}

impl fmt::Display for ProcessId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P{}", self.0)
    }
}

impl fmt::Debug for Dot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.origin, self.seq)
    }
}

impl fmt::Display for Dot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.origin, self.seq)
    }
}

/// Interleaved ownership of a 1-based sequence space, shared by every
/// stride-aware structure (dot generation, executed-frontier GC, the
/// worker router): worker slot `w` of `N` owns the sequences
/// `w+1, w+1+N, w+1+2N, …`, i.e. those with `(seq - 1) % N == w`, and
/// folds them into a dense 1-based *index* space so frontiers stay
/// contiguous per slot. The monolithic case is the identity stride
/// (`w = 0, N = 1`), where index space equals sequence space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Stride {
    worker: u64,
    workers: u64,
}

impl Stride {
    /// Stride of worker slot `worker` among `workers` slots (clamped to
    /// a valid slot; `workers = 0` means the identity stride).
    pub fn new(worker: usize, workers: usize) -> Self {
        let workers = workers.max(1) as u64;
        Stride { worker: (worker as u64).min(workers - 1), workers }
    }

    /// The monolithic stride: every sequence, index == sequence.
    pub fn identity() -> Self {
        Stride::new(0, 1)
    }

    /// Is this the identity stride?
    pub fn is_identity(&self) -> bool {
        self.workers == 1
    }

    /// Number of slots the sequence space is interleaved across.
    pub fn workers(&self) -> u64 {
        self.workers
    }

    /// Dense 1-based index of `seq` within this slot's stride, or `None`
    /// if the sequence belongs to another slot (or is 0).
    pub fn index_of(&self, seq: u64) -> Option<u64> {
        if seq == 0 {
            return None;
        }
        let z = seq - 1;
        (z % self.workers == self.worker).then(|| z / self.workers + 1)
    }

    /// The sequence at dense 1-based `index` of this slot — the inverse
    /// of [`Stride::index_of`].
    pub fn seq_at(&self, index: u64) -> u64 {
        debug_assert!(index >= 1);
        (index - 1) * self.workers + self.worker + 1
    }

    /// Which of `workers` slots owns `seq` (1-based).
    pub fn owner_of(seq: u64, workers: usize) -> usize {
        if workers <= 1 {
            return 0;
        }
        debug_assert!(seq >= 1);
        ((seq - 1) % workers as u64) as usize
    }
}

/// Per-process dot generator (`next_id()` in the paper).
///
/// Under worker sharding ([`crate::protocol::common::shard`]) each worker
/// slot of a replica mints its own [`Stride`] of the origin's sequence
/// space, so a dot's owning worker is recoverable from the dot itself
/// ([`Stride::owner_of`]) — acks, commits and recovery messages route
/// back to the right worker without rehashing the command's keys.
#[derive(Debug, Clone)]
pub struct DotGen {
    origin: ProcessId,
    next: u64,
    step: u64,
}

impl DotGen {
    pub fn new(origin: ProcessId) -> Self {
        Self::strided(origin, 0, 1)
    }

    /// Generator for worker slot `worker` of `workers` at `origin`.
    pub fn strided(origin: ProcessId, worker: usize, workers: usize) -> Self {
        let stride = Stride::new(worker, workers);
        Self { origin, next: stride.seq_at(1), step: stride.workers() }
    }

    pub fn next(&mut self) -> Dot {
        let dot = Dot::new(self.origin, self.next);
        self.next += self.step;
        dot
    }

    /// Advance the generator so every dot minted afterwards has
    /// `seq > floor`, staying on this slot's stride. A crash-restarted
    /// replica calls this with the highest own-origin sequence recovered
    /// from its WAL/snapshot (plus slack for in-flight proposals) so it
    /// never re-mints a dot its peers may already hold state for.
    pub fn advance_past(&mut self, floor: u64) {
        if self.next <= floor {
            let gap = floor - self.next;
            self.next += (gap / self.step + 1) * self.step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_gen_is_sequential_and_unique() {
        let mut g = DotGen::new(ProcessId(3));
        let a = g.next();
        let b = g.next();
        assert_eq!(a, Dot::new(ProcessId(3), 1));
        assert_eq!(b, Dot::new(ProcessId(3), 2));
        assert_ne!(a, b);
        assert_eq!(a.initial_coordinator(), ProcessId(3));
    }

    #[test]
    fn dot_ordering_breaks_ties_by_origin_then_seq() {
        // Execution order ties on equal timestamps are broken by dot; the
        // derived lexicographic Ord must therefore be total and stable.
        let a = Dot::new(ProcessId(1), 9);
        let b = Dot::new(ProcessId(2), 1);
        assert!(a < b);
        let c = Dot::new(ProcessId(1), 10);
        assert!(a < c);
    }

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", Dot::new(ProcessId(7), 42)), "P7.42");
        assert_eq!(format!("{}", Rid::new(ClientId(3), 9)), "C3.9");
    }

    #[test]
    fn stride_index_and_seq_are_inverse() {
        for workers in 1..=6usize {
            for worker in 0..workers {
                let s = Stride::new(worker, workers);
                for index in 1..=64u64 {
                    let seq = s.seq_at(index);
                    assert_eq!(s.index_of(seq), Some(index));
                    assert_eq!(Stride::owner_of(seq, workers), worker);
                }
                // Sequences of other slots are not ours; 0 is never valid.
                assert_eq!(s.index_of(0), None);
                if workers > 1 {
                    let other = Stride::new((worker + 1) % workers, workers);
                    assert_eq!(s.index_of(other.seq_at(1)), None);
                }
            }
        }
        assert!(Stride::identity().is_identity());
        assert_eq!(Stride::identity().index_of(7), Some(7));
        assert_eq!(Stride::identity().seq_at(7), 7);
    }

    #[test]
    fn strided_dot_gens_partition_the_sequence_space() {
        let origin = ProcessId(2);
        let workers = 4;
        let mut gens: Vec<DotGen> =
            (0..workers).map(|w| DotGen::strided(origin, w, workers)).collect();
        let mut seen = std::collections::HashSet::new();
        for (w, g) in gens.iter_mut().enumerate() {
            for _ in 0..16 {
                let d = g.next();
                // The owning worker is recoverable from the dot itself.
                assert_eq!(((d.seq - 1) % workers as u64) as usize, w);
                assert!(seen.insert(d.seq), "seq {} minted twice", d.seq);
            }
        }
        // workers=1 stride is the plain generator.
        let mut a = DotGen::new(origin);
        let mut b = DotGen::strided(origin, 0, 1);
        for _ in 0..8 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn advance_past_stays_on_stride_and_never_reuses() {
        for workers in 1..=4usize {
            for worker in 0..workers {
                let mut g = DotGen::strided(ProcessId(1), worker, workers);
                for floor in [0u64, 1, 7, 64, 65, 1000] {
                    g.advance_past(floor);
                    let d = g.next();
                    assert!(d.seq > floor, "seq {} <= floor {}", d.seq, floor);
                    assert_eq!(Stride::owner_of(d.seq, workers), worker);
                }
            }
        }
        // A floor below the current position is a no-op.
        let mut g = DotGen::new(ProcessId(0));
        g.next();
        g.next();
        g.advance_past(1);
        assert_eq!(g.next().seq, 3);
    }

    #[test]
    fn rid_orders_by_client_then_seq() {
        let a = Rid::new(ClientId(1), 9);
        let b = Rid::new(ClientId(2), 1);
        let c = Rid::new(ClientId(1), 10);
        assert!(a < b);
        assert!(a < c);
        assert_eq!(a.client(), ClientId(1));
        assert_eq!(a.seq(), 9);
    }
}
