//! Process, shard, client and command identifiers.
//!
//! A [`Dot`] ("identifier dot", following the EPaxos/Atlas lineage) uniquely
//! identifies a command: the process that created it plus a per-process
//! sequence number. The paper's `initial_p(id)` — the initial coordinator of
//! a command at a partition — is recoverable from the dot itself.

use std::fmt;

/// Identifier of a protocol process (replica). Dense, assigned at startup.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProcessId(pub u32);

/// Identifier of a shard (machine-colocated group of partitions).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ShardId(pub u32);

/// Identifier of a closed-loop client.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ClientId(pub u64);

/// Rifl-style request identifier (fantoch's `Rifl` lineage): the issuing
/// client plus a per-client sequence number, allocated by a
/// [`crate::client::Session`]. A `Rid` names a *request* end to end —
/// it travels inside the [`super::Command`], survives the protocol's
/// internal renaming to a [`Dot`], and comes back in the reply — so a
/// client can match responses to requests without ever seeing protocol
/// identifiers.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Rid(pub ClientId, pub u64);

impl Rid {
    /// Build a request id directly (tests; real code uses `Session`).
    pub fn new(client: ClientId, seq: u64) -> Self {
        Self(client, seq)
    }

    /// The issuing client.
    pub fn client(self) -> ClientId {
        self.0
    }

    /// Per-client sequence number (1-based).
    pub fn seq(self) -> u64 {
        self.1
    }
}

impl fmt::Debug for Rid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "C{}.{}", self.0 .0, self.1)
    }
}

impl fmt::Display for Rid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "C{}.{}", self.0 .0, self.1)
    }
}

/// Unique command identifier: (origin process, per-origin sequence number).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Dot {
    pub origin: ProcessId,
    pub seq: u64,
}

impl Dot {
    pub fn new(origin: ProcessId, seq: u64) -> Self {
        Self { origin, seq }
    }

    /// The initial coordinator of this command at its origin partition
    /// (`initial_p(id)` in the paper).
    pub fn initial_coordinator(&self) -> ProcessId {
        self.origin
    }
}

impl fmt::Debug for ProcessId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P{}", self.0)
    }
}

impl fmt::Display for ProcessId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P{}", self.0)
    }
}

impl fmt::Debug for Dot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.origin, self.seq)
    }
}

impl fmt::Display for Dot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.origin, self.seq)
    }
}

/// Per-process dot generator (`next_id()` in the paper).
#[derive(Debug, Clone)]
pub struct DotGen {
    origin: ProcessId,
    next: u64,
}

impl DotGen {
    pub fn new(origin: ProcessId) -> Self {
        Self { origin, next: 1 }
    }

    pub fn next(&mut self) -> Dot {
        let dot = Dot::new(self.origin, self.next);
        self.next += 1;
        dot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_gen_is_sequential_and_unique() {
        let mut g = DotGen::new(ProcessId(3));
        let a = g.next();
        let b = g.next();
        assert_eq!(a, Dot::new(ProcessId(3), 1));
        assert_eq!(b, Dot::new(ProcessId(3), 2));
        assert_ne!(a, b);
        assert_eq!(a.initial_coordinator(), ProcessId(3));
    }

    #[test]
    fn dot_ordering_breaks_ties_by_origin_then_seq() {
        // Execution order ties on equal timestamps are broken by dot; the
        // derived lexicographic Ord must therefore be total and stable.
        let a = Dot::new(ProcessId(1), 9);
        let b = Dot::new(ProcessId(2), 1);
        assert!(a < b);
        let c = Dot::new(ProcessId(1), 10);
        assert!(a < c);
    }

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", Dot::new(ProcessId(7), 42)), "P7.42");
        assert_eq!(format!("{}", Rid::new(ClientId(3), 9)), "C3.9");
    }

    #[test]
    fn rid_orders_by_client_then_seq() {
        let a = Rid::new(ClientId(1), 9);
        let b = Rid::new(ClientId(2), 1);
        let c = Rid::new(ClientId(1), 10);
        assert!(a < b);
        assert!(a < c);
        assert_eq!(a.client(), ClientId(1));
        assert_eq!(a.seq(), 9);
    }
}
