//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
pub mod stability;
use anyhow::Result;

/// Compiled artifact loaded on the PJRT CPU client.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT client wrapper owning compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact (produced by python/compile/aot.py).
    pub fn load_hlo_text(&self, path: &str) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(Artifact { exe: self.client.compile(&comp)? })
    }
}

impl Artifact {
    /// Execute with literal inputs; returns the elements of the output tuple.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result)
    }
}
