//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The PJRT client needs the external `xla` crate, which the offline
//! registry does not ship — everything touching it is gated behind the
//! `pjrt` feature (see rust/Cargo.toml). The pure-Rust stability
//! reference in [`stability`] is always available and is the default hot
//! path.

pub mod stability;

#[cfg(feature = "pjrt")]
mod pjrt {
    use crate::util::error::{Error, Result};

    /// Compiled artifact loaded on the PJRT CPU client.
    pub struct Artifact {
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT client wrapper owning compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    fn wrap<T, E: std::fmt::Display>(r: std::result::Result<T, E>) -> Result<T> {
        r.map_err(|e| Error::msg(format!("xla: {e}")))
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Ok(Self { client: wrap(xla::PjRtClient::cpu())? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact (produced by python/compile/aot.py).
        pub fn load_hlo_text(&self, path: &str) -> Result<Artifact> {
            let proto = wrap(xla::HloModuleProto::from_text_file(path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(Artifact { exe: wrap(self.client.compile(&comp))? })
        }
    }

    impl Artifact {
        /// Execute with literal inputs; returns the tuple output literal.
        pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
            let result =
                wrap(wrap(self.exe.execute::<xla::Literal>(inputs))?[0][0].to_literal_sync())?;
            Ok(result)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{Artifact, Runtime};
