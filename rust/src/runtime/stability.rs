//! The batched stability kernel: a Rust-side reference (always available)
//! plus, behind the `pjrt` feature, a wrapper over the
//! `artifacts/stability.hlo.txt` artifact produced by
//! `python/compile/aot.py` (L2 executor-tick graph calling the L1 Pallas
//! kernel).
//!
//! The artifact has static shapes: `P` partitions × `r` replicas × `W`
//! promise-window slots, a `Q`-deep queue, and a baked-in majority. The
//! default artifact is (16, 5, 64, 16, majority 3).
//!
//! The per-partition computation — contiguous frontier per replica, then
//! the majority order statistic — is the same kernel the protocol path
//! uses; it lives in [`crate::protocol::common::stability`] so the two
//! never drift.

use crate::protocol::common::stability::majority_watermark;

/// Shape of a compiled stability artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelShape {
    pub partitions: usize,
    pub replicas: usize,
    pub window: usize,
    pub queue: usize,
    pub majority: usize,
}

impl Default for KernelShape {
    fn default() -> Self {
        KernelShape { partitions: 16, replicas: 5, window: 64, queue: 16, majority: 3 }
    }
}

/// Pure-Rust reference of the batched computation, used on the default hot
/// path and cross-checked against the PJRT artifact in tests.
pub fn stable_watermarks_rust(bits: &[u8], shape: &KernelShape) -> Vec<i32> {
    let (p, r, w, m) = (shape.partitions, shape.replicas, shape.window, shape.majority);
    let mut out = Vec::with_capacity(p);
    let mut h: Vec<u64> = vec![0; r];
    for i in 0..p {
        for (j, slot) in h.iter_mut().enumerate() {
            let base = (i * r + j) * w;
            let mut c = 0u64;
            for u in 0..w {
                if bits[base + u] != 0 {
                    c += 1;
                } else {
                    break;
                }
            }
            *slot = c;
        }
        out.push(majority_watermark(&mut h, m) as i32);
    }
    out
}

/// Batched stability detection through PJRT (requires `--features pjrt`).
#[cfg(feature = "pjrt")]
pub struct StabilityKernel {
    artifact: super::Artifact,
    pub shape: KernelShape,
}

#[cfg(feature = "pjrt")]
impl StabilityKernel {
    /// Load `artifacts/stability.hlo.txt` (or a custom path) and compile it
    /// on the runtime's PJRT client.
    pub fn load(
        runtime: &super::Runtime,
        path: &str,
        shape: KernelShape,
    ) -> crate::util::error::Result<Self> {
        let artifact = runtime.load_hlo_text(path)?;
        Ok(StabilityKernel { artifact, shape })
    }

    /// Run one executor tick: `bits` is the row-major `[P, r, W]` promise
    /// bitmap, `queue_ts` the `[P, Q]` committed-queue timestamps.
    /// Returns (per-partition stable watermark, executability mask).
    pub fn tick(
        &self,
        bits: &[u8],
        queue_ts: &[i32],
    ) -> crate::util::error::Result<(Vec<i32>, Vec<i32>)> {
        use crate::util::error::{bail, Error};
        let s = &self.shape;
        if bits.len() != s.partitions * s.replicas * s.window {
            bail!("bits length {} != P*r*W", bits.len());
        }
        if queue_ts.len() != s.partitions * s.queue {
            bail!("queue length {} != P*Q", queue_ts.len());
        }
        let wrap = |e: String| Error::msg(format!("xla: {e}"));
        let bits_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[s.partitions, s.replicas, s.window],
            bits,
        )
        .map_err(|e| wrap(e.to_string()))?;
        let queue_bytes: Vec<u8> = queue_ts.iter().flat_map(|v| v.to_le_bytes()).collect();
        let queue_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[s.partitions, s.queue],
            &queue_bytes,
        )
        .map_err(|e| wrap(e.to_string()))?;
        let result = self.artifact.execute(&[bits_lit, queue_lit])?;
        let (wm_lit, mask_lit) = result.to_tuple2().map_err(|e| wrap(e.to_string()))?;
        Ok((
            wm_lit.to_vec::<i32>().map_err(|e| wrap(e.to_string()))?,
            mask_lit.to_vec::<i32>().map_err(|e| wrap(e.to_string()))?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_reference_figure2() {
        // r=3, watermarks {2, 3, 2} → stable 2 at majority 2.
        let shape = KernelShape { partitions: 1, replicas: 3, window: 4, queue: 1, majority: 2 };
        #[rustfmt::skip]
        let bits = vec![
            1, 1, 0, 0, // A: 1..2
            1, 1, 1, 0, // B: 1..3
            1, 1, 0, 0, // C: 1..2
        ];
        assert_eq!(stable_watermarks_rust(&bits, &shape), vec![2]);
        let unanimity = KernelShape { majority: 3, ..shape };
        assert_eq!(stable_watermarks_rust(&bits, &unanimity), vec![2]);
        let any = KernelShape { majority: 1, ..shape };
        assert_eq!(stable_watermarks_rust(&bits, &any), vec![3]);
    }

    #[test]
    fn rust_reference_gap_blocks() {
        let shape = KernelShape { partitions: 1, replicas: 3, window: 8, queue: 1, majority: 2 };
        let mut bits = vec![1u8; 24];
        bits[0] = 0; // hole at process 0 slot 0
        bits[8] = 0; // hole at process 1 slot 0
        assert_eq!(stable_watermarks_rust(&bits, &shape), vec![0]);
    }
}
