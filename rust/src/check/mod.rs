//! PSMR specification checker (paper §2): Validity, Ordering, Liveness.
//!
//! Consumes the execution logs and client completions recorded by the
//! simulator and verifies:
//!
//! - **Validity** — a process executes a command at most once, and only
//!   commands that were submitted.
//! - **Per-partition agreement** — partitions are *keys* (§2): all
//!   replicas of a key's shard group execute the commands accessing that
//!   key in the same order (up to a prefix; lagging replicas are allowed).
//! - **Ordering** — the union of per-key execution orders and the
//!   real-time order is acyclic (no two partitions order two commands in
//!   contradictory ways, and completed commands precede later ones).
//! - **Liveness** — after a drained run, every submitted command executes
//!   at every live process of every accessed shard group.
//! - **Response validity** — the checker is semantics-aware, not just
//!   order-aware: every client-observed [`crate::core::Response`] must
//!   equal the response produced by replaying the coordinator's execution
//!   log through a sequential `KvStore` oracle. An execution can be
//!   perfectly ordered yet reply garbage; this catches it.

use crate::core::{key_to_shard, Command, Dot, Key, ProcessId, Rid};
use crate::sim::{ReadAudit, SimResult};
use crate::store::KvStore;
use std::collections::{HashMap, HashSet};

/// A violation of the PSMR specification.
#[derive(Clone, Debug)]
pub enum Violation {
    DuplicateExecution { process: ProcessId, dot: Dot },
    ExecutedUnsubmitted { process: ProcessId, dot: Dot },
    DivergentKeyOrder { key: Key, a: ProcessId, b: ProcessId, position: usize },
    OrderingCycle { sample: Vec<Dot> },
    RealTimeViolation { first: Dot, second: Dot, key: Key },
    NotExecuted { process: ProcessId, dot: Dot },
    /// The response the client observed for `rid` differs from what the
    /// sequential oracle computes at `process` (the coordinator) for the
    /// command's position in that replica's execution order.
    ResponseMismatch { process: ProcessId, dot: Dot, rid: Rid },
    /// A locally-served read at `process` missed a write on `key`: the
    /// write's decided timestamp is at or below the timestamp the read's
    /// release claimed was `covered`, yet the write executed only after
    /// the read's audit position — the stale read the stability argument
    /// (Theorem 1) forbids.
    StaleLocalRead { process: ProcessId, key: Key, write: Dot, write_ts: u64, covered: u64 },
    /// The response a local read's client observed differs from the
    /// sequential oracle's replay of the serving replica's log up to the
    /// read's audit position.
    ReadResponseMismatch { process: ProcessId, rid: Rid },
    /// `process` executed two *different* dots carrying the same request
    /// id — a client-failover re-issue applied twice (exactly-once
    /// broken). The executor's per-client dedup window prevents this;
    /// `Config::dedup_window == 0` is the knob that lets it through.
    DuplicateRequest { process: ProcessId, rid: Rid, first: Dot, second: Dot },
    /// `process` installed a non-monotonic epoch history (epoch numbers
    /// must strictly increase and evicted sets must only grow). The
    /// `Config::epoch_fence_off` knob lets a stale epoch install land
    /// after a newer one, which is exactly this regression.
    EpochRegression { process: ProcessId, position: usize },
    /// Two processes installed the *same* epoch number with different
    /// evicted sets — the membership views diverged instead of forming
    /// prefix-compatible histories.
    EpochDivergence { a: ProcessId, b: ProcessId, epoch: u64 },
    /// A restarted process rejoined with a store digest different from
    /// its state-transfer donor's — recovery + manifest-diff transfer
    /// must reproduce the donor's state byte-for-byte. The
    /// `transfer_on_restart = false` knob lets a stale rejoin through,
    /// which is exactly this divergence.
    RecoveryDivergence { process: ProcessId, peer: ProcessId, post: u64, peer_digest: u64 },
    /// Local recovery's arithmetic broke: the applied count after
    /// snapshot + WAL-tail replay must equal the snapshot's applied count
    /// plus the records replayed.
    RecoveryReplayGap {
        process: ProcessId,
        recovered_applied: u64,
        snapshot_applied: u64,
        wal_replayed: u64,
    },
    /// The crash destroyed a WAL record the configuration promised was
    /// durable: with `wal_fsync_batch == 1` every logged record is synced
    /// before the executor moves on, so a lost record means the
    /// group-commit contract is broken.
    RecoveryLostDurableRecord { process: ProcessId, wal_lost: u64 },
}

/// Configuration view the checker needs.
pub struct CheckConfig {
    pub shards: u32,
    pub r: usize,
}

impl CheckConfig {
    fn shard_procs(&self, shard: u32) -> impl Iterator<Item = usize> + '_ {
        let base = shard as usize * self.r;
        base..base + self.r
    }
}

impl From<&crate::core::Config> for CheckConfig {
    fn from(c: &crate::core::Config) -> Self {
        CheckConfig { shards: c.shards, r: c.r }
    }
}

/// Check a drained (or running) simulation result against the PSMR spec.
/// `require_liveness` should be set only for drained runs.
pub fn check_psmr(
    config: &crate::core::Config,
    result: &SimResult,
    require_liveness: bool,
) -> Vec<Violation> {
    let cfg = CheckConfig::from(config);
    let mut violations = Vec::new();
    let submitted: HashMap<Dot, &Command> =
        result.submitted.iter().map(|(d, c)| (*d, c)).collect();

    // --- Validity --------------------------------------------------------
    let mut per_proc: Vec<Vec<Dot>> = Vec::with_capacity(result.execution_logs.len());
    for (p, log) in result.execution_logs.iter().enumerate() {
        let mut seen = HashSet::new();
        let mut order = Vec::with_capacity(log.len());
        for &(dot, _) in log {
            if !seen.insert(dot) {
                violations
                    .push(Violation::DuplicateExecution { process: ProcessId(p as u32), dot });
            }
            if !submitted.contains_key(&dot) {
                violations
                    .push(Violation::ExecutedUnsubmitted { process: ProcessId(p as u32), dot });
            }
            order.push(dot);
        }
        per_proc.push(order);
    }

    // --- Exactly-once per request id ---------------------------------------
    // A client-failover re-issue carries the same rid under a fresh dot;
    // the executors' dedup window must absorb the second delivery. Two
    // *distinct* executed dots with one rid at one process means the
    // request applied twice.
    for (p, order) in per_proc.iter().enumerate() {
        let mut rid_dot: HashMap<Rid, Dot> = HashMap::new();
        for dot in order {
            let Some(cmd) = submitted.get(dot) else { continue };
            match rid_dot.get(&cmd.rid) {
                None => {
                    rid_dot.insert(cmd.rid, *dot);
                }
                Some(&first) if first != *dot => {
                    violations.push(Violation::DuplicateRequest {
                        process: ProcessId(p as u32),
                        rid: cmd.rid,
                        first,
                        second: *dot,
                    });
                }
                Some(_) => {} // same dot twice is DuplicateExecution above
            }
        }
    }

    // --- Per-partition (per-key) agreement --------------------------------
    // Project each process log onto each key; all replicas of the key's
    // shard group must agree on the order of *conflicting* commands:
    // the write sequence must match (up to a prefix), and every read must
    // observe the same preceding write. Read-read reordering is allowed —
    // reads commute (§3.3 "Limitations": only the dependency-based
    // baselines exploit this; Tempo orders everything, which also passes).
    let mut key_order: HashMap<Key, Vec<Dot>> = HashMap::new();
    {
        let is_write = |dot: &Dot| submitted.get(dot).is_none_or(|c| !c.op.is_read());
        // key → per-process projected sequences
        let mut projections: HashMap<Key, Vec<(ProcessId, Vec<Dot>)>> = HashMap::new();
        for (p, order) in per_proc.iter().enumerate() {
            let my_shard = (p / cfg.r) as u32;
            let mut local: HashMap<Key, Vec<Dot>> = HashMap::new();
            for dot in order {
                if let Some(cmd) = submitted.get(dot) {
                    for &k in cmd.keys.iter() {
                        // Only this process's own partitions: a key's order
                        // is agreed among the replicas of its shard group.
                        if key_to_shard(k, cfg.shards).0 == my_shard {
                            local.entry(k).or_default().push(*dot);
                        }
                    }
                }
            }
            for (k, seq) in local {
                projections.entry(k).or_default().push((ProcessId(p as u32), seq));
            }
        }
        for (k, mut seqs) in projections {
            seqs.sort_by_key(|(_, s)| std::cmp::Reverse(s.len()));
            let (ref_p, reference) = seqs[0].clone();
            // Reference write sequence and read→preceding-write mapping.
            let ref_writes: Vec<Dot> =
                reference.iter().filter(|d| is_write(d)).copied().collect();
            let ref_read_ctx: HashMap<Dot, usize> = {
                let mut ctx = HashMap::new();
                let mut w = 0usize;
                for d in &reference {
                    if is_write(d) {
                        w += 1;
                    } else {
                        ctx.insert(*d, w);
                    }
                }
                ctx
            };
            for (p, seq) in &seqs[1..] {
                let mut w = 0usize;
                let mut wseq = 0usize; // index into this replica's writes
                let mut diverged = None;
                for (i, d) in seq.iter().enumerate() {
                    if is_write(d) {
                        if ref_writes.get(wseq) != Some(d) {
                            diverged = Some(i);
                            break;
                        }
                        wseq += 1;
                        w += 1;
                    } else if let Some(&ctx) = ref_read_ctx.get(d) {
                        if ctx != w {
                            diverged = Some(i);
                            break;
                        }
                    }
                }
                if let Some(i) = diverged {
                    violations.push(Violation::DivergentKeyOrder {
                        key: k,
                        a: ref_p,
                        b: *p,
                        position: i,
                    });
                }
            }
            key_order.insert(k, reference);
        }
    }

    // --- Ordering: real-time within shared keys ---------------------------
    // If c completed before d was submitted and they share a key, then c
    // must precede d in that key's execution order.
    let positions: HashMap<Key, HashMap<Dot, usize>> = key_order
        .iter()
        .map(|(k, order)| (*k, order.iter().enumerate().map(|(i, d)| (*d, i)).collect()))
        .collect();
    for c in &result.completions {
        for d in &result.completions {
            if c.completed_at <= d.submitted_at && c.dot != d.dot {
                let (ca, da) = match (submitted.get(&c.dot), submitted.get(&d.dot)) {
                    (Some(a), Some(b)) => (a, b),
                    _ => continue,
                };
                // Only conflicting pairs constrain the order.
                if ca.op.is_read() && da.op.is_read() {
                    continue;
                }
                for &k in ca.keys.iter() {
                    if da.keys.contains(&k) {
                        if let Some(pos) = positions.get(&k) {
                            if let (Some(&pc), Some(&pd)) = (pos.get(&c.dot), pos.get(&d.dot)) {
                                if pd < pc {
                                    violations.push(Violation::RealTimeViolation {
                                        first: c.dot,
                                        second: d.dot,
                                        key: k,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    // --- Ordering: acyclicity of the cross-partition order ----------------
    // Union of per-key execution orders (consecutive edges); a cycle means
    // two partitions ordered two commands in contradictory ways.
    {
        let is_write = |dot: &Dot| submitted.get(dot).is_none_or(|c| !c.op.is_read());
        let mut indeg: HashMap<Dot, usize> = HashMap::new();
        let mut adj: HashMap<Dot, Vec<Dot>> = HashMap::new();
        let mut edge = |a: Dot, b: Dot, adj: &mut HashMap<Dot, Vec<Dot>>,
                        indeg: &mut HashMap<Dot, usize>| {
            adj.entry(a).or_default().push(b);
            *indeg.entry(b).or_insert(0) += 1;
            indeg.entry(a).or_insert(0);
        };
        for order in key_order.values() {
            // Conflicting edges only: last write → read, read → next write,
            // write → next write. Read-read pairs commute.
            let mut last_write: Option<Dot> = None;
            let mut reads_since: Vec<Dot> = Vec::new();
            for &d in order {
                indeg.entry(d).or_insert(0);
                if is_write(&d) {
                    if let Some(w) = last_write {
                        edge(w, d, &mut adj, &mut indeg);
                    }
                    for r in reads_since.drain(..) {
                        edge(r, d, &mut adj, &mut indeg);
                    }
                    last_write = Some(d);
                } else {
                    if let Some(w) = last_write {
                        edge(w, d, &mut adj, &mut indeg);
                    }
                    reads_since.push(d);
                }
            }
        }
        let mut queue: Vec<Dot> =
            indeg.iter().filter(|&(_, &d)| d == 0).map(|(&dot, _)| dot).collect();
        let total = indeg.len();
        let mut visited = 0usize;
        let mut indeg = indeg;
        while let Some(d) = queue.pop() {
            visited += 1;
            if let Some(next) = adj.get(&d) {
                for &n in next {
                    let e = indeg.get_mut(&n).unwrap();
                    *e -= 1;
                    if *e == 0 {
                        queue.push(n);
                    }
                }
            }
        }
        if visited != total {
            let sample: Vec<Dot> =
                indeg.iter().filter(|&(_, &d)| d > 0).take(4).map(|(&dot, _)| dot).collect();
            violations.push(Violation::OrderingCycle { sample });
        }
    }

    // --- Response validity -------------------------------------------------
    // Replay each process's execution log through a sequential KvStore
    // oracle. A client observes its response from the command's
    // coordinator (dot.origin), so for every completion the oracle
    // response computed at that replica's position must match what the
    // client saw. Combined with the order checks above this makes the
    // checker semantics-aware: agreed order AND agreed results.
    {
        // dot → (rid, client-observed response); members of a site-level
        // batch share rid/dot and observed the same merged response.
        let mut observed: HashMap<Dot, (Rid, &crate::core::Response)> = HashMap::new();
        for c in &result.completions {
            observed.entry(c.dot).or_insert((c.rid, &c.response));
        }
        // Completions whose dot never executed anywhere: a failover
        // re-issue the executors absorbed, answered with the cached
        // response of the rid's *original* dot. Their response is checked
        // against the oracle at the rid's executed dot instead (at the
        // re-issue's coordinator — the replica whose cache produced the
        // reply).
        let any_executed: HashSet<Dot> =
            per_proc.iter().flat_map(|v| v.iter().copied()).collect();
        let mut replayed: HashMap<Rid, (Dot, &crate::core::Response)> = HashMap::new();
        for c in &result.completions {
            if !any_executed.contains(&c.dot) && c.dot.seq != 0 {
                replayed.entry(c.rid).or_insert((c.dot, &c.response));
            }
        }
        for (p, log) in result.execution_logs.iter().enumerate() {
            let process = ProcessId(p as u32);
            let mut oracle = KvStore::new();
            for &(dot, _) in log {
                if let Some(cmd) = submitted.get(&dot) {
                    let resp = oracle.execute(cmd);
                    if dot.origin == process {
                        if let Some(&(rid, obs)) = observed.get(&dot) {
                            if *obs != resp {
                                violations.push(Violation::ResponseMismatch {
                                    process,
                                    dot,
                                    rid,
                                });
                            }
                        }
                    }
                    if let Some(&(cdot, obs)) = replayed.get(&cmd.rid) {
                        if cdot.origin == process && *obs != resp {
                            violations.push(Violation::ResponseMismatch {
                                process,
                                dot: cdot,
                                rid: cmd.rid,
                            });
                        }
                    }
                }
            }
        }
    }

    // --- Local-read linearizability (stability-powered reads) -------------
    // A locally-served read observed exactly the writes in the serving
    // replica's log prefix [..pos] (the audit point). The release claimed
    // the frontier covered timestamp `covered`, i.e. "every write stably
    // ordered at or below `covered` on the read's keys has executed":
    // such a write appearing *after* the audit point is a stale read.
    // Additionally, the response the client observed must equal a
    // sequential oracle's replay of that prefix — this also pins the
    // bounded-staleness semantics (a slack read returns the state as of
    // its audit point, never a state that existed at no point).
    {
        let mut ts_of: HashMap<Dot, u64> = HashMap::new();
        for &(dot, ts) in &result.decided_ts {
            ts_of.insert(dot, ts);
        }
        let mut observed: HashMap<Rid, &crate::core::Response> = HashMap::new();
        for c in &result.completions {
            observed.entry(c.rid).or_insert(&c.response);
        }
        for (p, audits) in result.read_audits.iter().enumerate() {
            if audits.is_empty() {
                continue;
            }
            let process = ProcessId(p as u32);
            let log = &result.execution_logs[p];
            for audit in audits {
                for &(dot, _) in &log[audit.pos..] {
                    let cmd = match submitted.get(&dot) {
                        Some(c) if !c.op.is_read() => c,
                        _ => continue,
                    };
                    let wts = match ts_of.get(&dot) {
                        Some(&t) if t > 0 && t <= audit.covered => t,
                        _ => continue,
                    };
                    if let Some(&k) = cmd.keys.iter().find(|k| audit.cmd.keys.contains(k)) {
                        violations.push(Violation::StaleLocalRead {
                            process,
                            key: k,
                            write: dot,
                            write_ts: wts,
                            covered: audit.covered,
                        });
                    }
                }
            }
            // Replay the log, serving each read at its audit position
            // (reads never mutate the oracle, so same-position reads
            // cannot disturb each other).
            let mut by_pos: Vec<&ReadAudit> = audits.iter().collect();
            by_pos.sort_by_key(|a| a.pos);
            let mut next = 0usize;
            let mut oracle = KvStore::new();
            for i in 0..=log.len() {
                while next < by_pos.len() && by_pos[next].pos == i {
                    let audit = by_pos[next];
                    next += 1;
                    let resp = oracle.execute(&audit.cmd);
                    if let Some(&obs) = observed.get(&audit.cmd.rid) {
                        if *obs != resp {
                            violations.push(Violation::ReadResponseMismatch {
                                process,
                                rid: audit.cmd.rid,
                            });
                        }
                    }
                }
                if i < log.len() {
                    if let Some(cmd) = submitted.get(&log[i].0) {
                        oracle.execute(cmd);
                    }
                }
            }
        }
    }

    // --- Liveness ----------------------------------------------------------
    // Grouped by request id: a failover re-issue gives one rid several
    // dots, and exactly-once delivery means each process executes exactly
    // one of them — requiring every dot individually would flag the
    // absorbed duplicate. A process is live for the rid if it executed
    // *any* of the rid's dots; the reported dot is the group's first
    // (the original submission).
    if require_liveness {
        let executed_sets: Vec<HashSet<Dot>> =
            per_proc.iter().map(|v| v.iter().copied().collect()).collect();
        let mut by_rid: HashMap<Rid, Vec<usize>> = HashMap::new();
        for (i, (_, cmd)) in result.submitted.iter().enumerate() {
            by_rid.entry(cmd.rid).or_default().push(i);
        }
        let mut groups: Vec<(Rid, Vec<usize>)> = by_rid.into_iter().collect();
        groups.sort_unstable_by_key(|(rid, _)| *rid);
        for (_, idxs) in groups {
            let (first_dot, cmd) = &result.submitted[idxs[0]];
            for s in cmd.shards(cfg.shards) {
                for p in cfg.shard_procs(s.0) {
                    let any =
                        idxs.iter().any(|&i| executed_sets[p].contains(&result.submitted[i].0));
                    if !any {
                        violations.push(Violation::NotExecuted {
                            process: ProcessId(p as u32),
                            dot: *first_dot,
                        });
                    }
                }
            }
        }
    }

    // --- Epoch histories ----------------------------------------------------
    // Per process: epochs strictly increase and evicted sets only grow
    // (cumulative). Across processes: the same epoch number always names
    // the same evicted set — installed histories are prefix-compatible.
    {
        for (p, view) in result.epoch_views.iter().enumerate() {
            for (i, w) in view.windows(2).enumerate() {
                let ((e0, s0), (e1, s1)) = (&w[0], &w[1]);
                let grows = e1 > e0 && s0.iter().all(|m| s1.contains(m));
                if !grows {
                    violations.push(Violation::EpochRegression {
                        process: ProcessId(p as u32),
                        position: i + 1,
                    });
                }
            }
        }
        let mut canonical: HashMap<u64, (ProcessId, &Vec<ProcessId>)> = HashMap::new();
        for (p, view) in result.epoch_views.iter().enumerate() {
            for (e, set) in view {
                match canonical.get(e) {
                    None => {
                        canonical.insert(*e, (ProcessId(p as u32), set));
                    }
                    Some(&(a, s)) if s != set => {
                        violations.push(Violation::EpochDivergence {
                            a,
                            b: ProcessId(p as u32),
                            epoch: *e,
                        });
                    }
                    Some(_) => {}
                }
            }
        }
    }

    violations
}

/// Check the crash-restart recoveries of a run ([`SimResult::recoveries`])
/// against the durability contract:
///
/// - **No divergent rejoin** — when a restart state-transferred from a
///   donor, the rejoining store digest equals the donor's digest at
///   transfer time (byte-identical state).
/// - **Replay arithmetic** — local recovery applied exactly
///   `snapshot_applied + wal_replayed` commands: the WAL tail was neither
///   partially skipped nor double-applied.
/// - **Group-commit contract** — a crash may only destroy WAL records
///   still inside the fsync batch window; with `wal_fsync_batch == 1`
///   nothing may ever be lost.
pub fn check_recovery(config: &crate::core::Config, result: &SimResult) -> Vec<Violation> {
    let mut violations = Vec::new();
    for rec in &result.recoveries {
        if let Some(peer) = rec.peer {
            if rec.post_digest != rec.peer_digest {
                violations.push(Violation::RecoveryDivergence {
                    process: rec.process,
                    peer,
                    post: rec.post_digest,
                    peer_digest: rec.peer_digest,
                });
            }
        }
        if rec.recovered_applied != rec.snapshot_applied + rec.wal_replayed {
            violations.push(Violation::RecoveryReplayGap {
                process: rec.process,
                recovered_applied: rec.recovered_applied,
                snapshot_applied: rec.snapshot_applied,
                wal_replayed: rec.wal_replayed,
            });
        }
        if rec.wal_lost > 0 && config.wal_fsync_batch <= 1 {
            violations.push(Violation::RecoveryLostDurableRecord {
                process: rec.process,
                wal_lost: rec.wal_lost,
            });
        }
    }
    violations
}

/// Assert no violations, with a readable report.
pub fn assert_psmr(config: &crate::core::Config, result: &SimResult, require_liveness: bool) {
    let violations = check_psmr(config, result, require_liveness);
    if !violations.is_empty() {
        let shown: Vec<_> = violations.iter().take(10).collect();
        panic!("PSMR violated: {} violation(s); first 10: {:#?}", violations.len(), shown);
    }
}

/// Assert the recovery contract holds, with a readable report.
pub fn assert_recovery(config: &crate::core::Config, result: &SimResult) {
    let violations = check_recovery(config, result);
    if !violations.is_empty() {
        let shown: Vec<_> = violations.iter().take(10).collect();
        panic!(
            "recovery contract violated: {} violation(s); first 10: {:#?}",
            violations.len(),
            shown
        );
    }
}
