//! Site-level batching (Fig. 8): single-key commands from co-located
//! clients are aggregated into one multi-key command, flushed after
//! `max_delay_us` or once `max_batch` commands are buffered, whichever is
//! earlier (the paper uses 5 ms / 10⁵ commands).

use super::CommandSpec;
use crate::core::{Key, Op};

/// One buffered entry: (client index, spec, buffered-at time).
#[derive(Clone, Debug)]
pub struct Buffered {
    pub client: usize,
    pub spec: CommandSpec,
    pub at_us: u64,
}

/// A per-site batch accumulator.
#[derive(Clone, Debug)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_delay_us: u64,
    buf: Vec<Buffered>,
    /// Deadline of the oldest buffered entry, if any.
    deadline_us: Option<u64>,
}

/// A flushed batch: the merged command spec plus its member clients with
/// their individual buffering times.
#[derive(Clone, Debug)]
pub struct Batch {
    pub spec: CommandSpec,
    pub members: Vec<(usize, u64)>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_delay_us: u64) -> Self {
        Self { max_batch, max_delay_us, buf: Vec::new(), deadline_us: None }
    }

    /// Buffer a command. Returns `Some(flush_deadline)` if this entry
    /// started a new batch (caller should schedule a flush event), and the
    /// batch itself if the size cap was reached.
    pub fn push(
        &mut self,
        client: usize,
        spec: CommandSpec,
        now_us: u64,
    ) -> (Option<u64>, Option<Batch>) {
        let new_deadline = if self.buf.is_empty() {
            let d = now_us + self.max_delay_us;
            self.deadline_us = Some(d);
            Some(d)
        } else {
            None
        };
        self.buf.push(Buffered { client, spec, at_us: now_us });
        if self.buf.len() >= self.max_batch {
            (new_deadline, Some(self.flush()))
        } else {
            (new_deadline, None)
        }
    }

    /// Flush if the pending deadline is due (timer event handler).
    pub fn flush_if_due(&mut self, now_us: u64) -> Option<Batch> {
        match self.deadline_us {
            Some(d) if d <= now_us && !self.buf.is_empty() => Some(self.flush()),
            _ => None,
        }
    }

    pub fn flush(&mut self) -> Batch {
        debug_assert!(!self.buf.is_empty());
        self.deadline_us = None;
        let buf = std::mem::take(&mut self.buf);
        let mut keys: Vec<Key> = buf.iter().flat_map(|b| b.spec.keys.iter().copied()).collect();
        keys.sort_unstable();
        keys.dedup();
        let payload: u64 = buf.iter().map(|b| b.spec.payload_len as u64).sum();
        let any_write = buf.iter().any(|b| !b.spec.op.is_read());
        let spec = CommandSpec {
            keys,
            op: if any_write { Op::Put } else { Op::Get },
            payload_len: payload.min(u32::MAX as u64) as u32,
        };
        let members = buf.iter().map(|b| (b.client, b.at_us)).collect();
        Batch { spec, members }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(key: Key) -> CommandSpec {
        CommandSpec { keys: vec![key], op: Op::Put, payload_len: 100 }
    }

    #[test]
    fn size_cap_triggers_flush() {
        let mut b = Batcher::new(3, 5_000);
        let (d1, f1) = b.push(0, spec(1), 0);
        assert_eq!(d1, Some(5_000));
        assert!(f1.is_none());
        let (d2, f2) = b.push(1, spec(2), 10);
        assert!(d2.is_none() && f2.is_none());
        let (_, f3) = b.push(2, spec(3), 20);
        let batch = f3.expect("size cap reached");
        assert_eq!(batch.spec.keys, vec![1, 2, 3]);
        assert_eq!(batch.spec.payload_len, 300);
        assert_eq!(batch.members.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn timer_flush() {
        let mut b = Batcher::new(100, 5_000);
        b.push(0, spec(1), 0);
        b.push(1, spec(1), 100); // duplicate key deduped
        assert!(b.flush_if_due(4_999).is_none());
        let batch = b.flush_if_due(5_000).expect("deadline due");
        assert_eq!(batch.spec.keys, vec![1]);
        assert_eq!(batch.members, vec![(0, 0), (1, 100)]);
        // Nothing left: further timers are no-ops.
        assert!(b.flush_if_due(10_000).is_none());
    }

    #[test]
    fn read_only_batch_stays_a_read() {
        let mut b = Batcher::new(10, 1_000);
        let read = CommandSpec { keys: vec![5], op: Op::Get, payload_len: 0 };
        b.push(0, read.clone(), 0);
        b.push(1, read, 1);
        let batch = b.flush();
        assert_eq!(batch.spec.op, Op::Get);
    }
}
