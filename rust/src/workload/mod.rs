//! Workload generators: the paper's conflict-rate microbenchmark (§6.2)
//! and YCSB+T (§6.4), plus the site-level batching layer (Fig. 8).

pub mod batching;

use crate::core::{ClientId, Key, Op};
use crate::util::{Rng, Zipf};

/// What a client wants executed (before a Dot is assigned).
#[derive(Clone, Debug)]
pub struct CommandSpec {
    pub keys: Vec<Key>,
    pub op: Op,
    pub payload_len: u32,
}

/// A stream of command specifications.
pub trait Workload {
    fn next(&mut self, client: ClientId, rng: &mut Rng) -> CommandSpec;
}

/// The paper's microbenchmark: "a client chooses key 0 with probability ρ,
/// and some unique key otherwise" (§6.2). Commands carry `payload` bytes.
#[derive(Clone, Debug)]
pub struct ConflictWorkload {
    /// Conflict rate ρ in [0, 1].
    pub conflict_rate: f64,
    /// Payload size in bytes (paper: 100 B default, 256 B–4 KiB in Figs 7/8).
    pub payload_len: u32,
    /// Next per-client unique-key counters are derived from the client id.
    counter: u64,
}

impl ConflictWorkload {
    pub fn new(conflict_rate: f64, payload_len: u32) -> Self {
        assert!((0.0..=1.0).contains(&conflict_rate));
        Self { conflict_rate, payload_len, counter: 0 }
    }
}

impl Workload for ConflictWorkload {
    fn next(&mut self, client: ClientId, rng: &mut Rng) -> CommandSpec {
        let key = if rng.gen_bool(self.conflict_rate) {
            0
        } else {
            // Unique key: high bits from the client, low bits a counter;
            // bit 63 set so it never collides with key 0 or YCSB keys.
            self.counter += 1;
            (1 << 63) | (client.0 << 24) | (self.counter & 0xFF_FFFF)
        };
        CommandSpec { keys: vec![key], op: Op::Put, payload_len: self.payload_len }
    }
}

/// Single-key zipfian workload: every command writes one key drawn from a
/// zipf(θ) distribution over `n_keys` keys. The worker-scaling benches
/// use it because contention is tunable through θ while every command
/// trivially lives inside one worker slot (`protocol::common::shard`).
#[derive(Clone, Debug)]
pub struct ZipfWorkload {
    zipf: Zipf,
    /// Payload carried by each command, in bytes.
    pub payload_len: u32,
    /// Fraction of commands that are `Op::Read` (the stability-powered
    /// local-read class); 0.0 keeps the classic all-Put shape.
    pub read_ratio: f64,
}

impl ZipfWorkload {
    /// Single-key Put workload over `n_keys` keys at skew `theta`
    /// (0 = uniform / low contention; 0.99 = YCSB-hot / high contention).
    pub fn new(n_keys: u64, theta: f64, payload_len: u32) -> Self {
        Self { zipf: Zipf::new(n_keys, theta), payload_len, read_ratio: 0.0 }
    }

    /// Turn a fraction of commands into `Op::Read` local-read candidates
    /// (e.g. 0.95 for the paper-style 95/5 read-heavy mix). Keys still
    /// come from the same zipf distribution, so reads and writes contend
    /// on the same hot set.
    pub fn with_read_ratio(mut self, read_ratio: f64) -> Self {
        assert!((0.0..=1.0).contains(&read_ratio));
        self.read_ratio = read_ratio;
        self
    }
}

impl Workload for ZipfWorkload {
    fn next(&mut self, _client: ClientId, rng: &mut Rng) -> CommandSpec {
        let key = self.zipf.sample(rng);
        if self.read_ratio > 0.0 && rng.gen_bool(self.read_ratio) {
            return CommandSpec { keys: vec![key], op: Op::Read, payload_len: 0 };
        }
        CommandSpec { keys: vec![key], op: Op::Put, payload_len: self.payload_len }
    }
}

/// YCSB+T (§6.4): every transaction accesses two keys drawn from a
/// scrambled-zipfian distribution; a fraction `write_ratio` of commands are
/// updates (read-modify-write), the rest reads. Workloads A/B/C of YCSB
/// correspond to write ratios 50%/5%/0%.
#[derive(Clone, Debug)]
pub struct YcsbWorkload {
    zipf: Zipf,
    /// Total number of keys (paper: 1M per shard).
    pub n_keys: u64,
    /// Fraction of update (write) commands.
    pub write_ratio: f64,
    /// Keys accessed per transaction (paper: 2).
    pub keys_per_tx: usize,
    pub payload_len: u32,
}

impl YcsbWorkload {
    pub fn new(n_keys: u64, zipf_theta: f64, write_ratio: f64) -> Self {
        Self {
            zipf: Zipf::new(n_keys, zipf_theta),
            n_keys,
            write_ratio,
            keys_per_tx: 2,
            payload_len: 100,
        }
    }

    /// YCSB's "scrambled zipfian": spread hot ranks over the key space so
    /// hot keys land on different shards.
    fn scramble(&self, rank: u64) -> Key {
        // FNV-1a 64-bit over the rank.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in rank.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h % self.n_keys
    }
}

impl Workload for YcsbWorkload {
    fn next(&mut self, _client: ClientId, rng: &mut Rng) -> CommandSpec {
        let mut keys = Vec::with_capacity(self.keys_per_tx);
        while keys.len() < self.keys_per_tx {
            let k = self.scramble(self.zipf.sample(rng));
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let op = if rng.gen_bool(self.write_ratio) { Op::Rmw } else { Op::Get };
        CommandSpec { keys, op, payload_len: self.payload_len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conflict_rate_is_respected() {
        let mut w = ConflictWorkload::new(0.1, 100);
        let mut rng = Rng::new(9);
        let n = 100_000;
        let conflicts = (0..n)
            .filter(|_| w.next(ClientId(7), &mut rng).keys[0] == 0)
            .count();
        let rate = conflicts as f64 / n as f64;
        assert!((0.08..0.12).contains(&rate), "rate={rate}");
    }

    #[test]
    fn nonconflicting_keys_are_unique_per_client() {
        let mut w = ConflictWorkload::new(0.0, 100);
        let mut rng = Rng::new(9);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            let k = w.next(ClientId(3), &mut rng).keys[0];
            assert!(seen.insert(k), "duplicate unique key {k}");
        }
    }

    #[test]
    fn different_clients_never_collide_on_unique_keys() {
        let mut w = ConflictWorkload::new(0.0, 100);
        let mut rng = Rng::new(10);
        let a = w.next(ClientId(1), &mut rng).keys[0];
        let b = w.next(ClientId(2), &mut rng).keys[0];
        assert_ne!(a, b);
    }

    #[test]
    fn zipf_workload_is_single_key_and_in_range() {
        let mut w = ZipfWorkload::new(1_000, 0.99, 64);
        let mut rng = Rng::new(5);
        for _ in 0..1_000 {
            let spec = w.next(ClientId(1), &mut rng);
            assert_eq!(spec.keys.len(), 1);
            assert!(spec.keys[0] < 1_000);
            assert_eq!(spec.op, Op::Put);
        }
    }

    #[test]
    fn ycsb_two_distinct_keys_in_range() {
        let mut w = YcsbWorkload::new(1_000_000, 0.7, 0.05);
        let mut rng = Rng::new(11);
        for _ in 0..10_000 {
            let spec = w.next(ClientId(1), &mut rng);
            assert_eq!(spec.keys.len(), 2);
            assert_ne!(spec.keys[0], spec.keys[1]);
            assert!(spec.keys.iter().all(|&k| k < 1_000_000));
        }
    }

    #[test]
    fn ycsb_write_ratio() {
        let mut w = YcsbWorkload::new(1_000_000, 0.5, 0.5);
        let mut rng = Rng::new(12);
        let writes = (0..10_000)
            .filter(|_| w.next(ClientId(1), &mut rng).op == Op::Rmw)
            .count();
        assert!((4_500..5_500).contains(&writes), "writes={writes}");
    }

    #[test]
    fn ycsb_read_only_workload_c() {
        let mut w = YcsbWorkload::new(1_000, 0.5, 0.0);
        let mut rng = Rng::new(13);
        for _ in 0..100 {
            assert_eq!(w.next(ClientId(1), &mut rng).op, Op::Get);
        }
    }
}
