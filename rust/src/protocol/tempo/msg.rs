//! Tempo wire messages, mirroring the paper's pseudocode
//! (Algorithms 1–6: MSubmit, MPropose, MProposeAck, MPayload, MCommit,
//! MConsensus, MConsensusAck, MPromises, MBump, MStable, MRec, MRecAck,
//! MRecNAck, MCommitRequest).
//!
//! Partitions are *keys* (§2: "arbitrarily fine-grained"). A machine
//! (process) replicates every key of its shard group, so protocol messages
//! between machines batch the per-key payloads of one command into a single
//! wire message: timestamp fields are vectors of `(key, ts)` over the keys
//! the sender's group is responsible for. This is the paper's §4
//! co-location optimization applied to the transport.

use super::promises::PromiseSet;
use crate::core::{Command, Dot, Key, ProcessId, ShardId};
use std::sync::Arc;

/// Fast-quorum mapping `Q`: the fast quorum chosen per accessed shard
/// group. `Arc`-backed: the mapping rides inside `MSubmit`, `MPropose`
/// and `MPayload`, which fan out to every group member — cloning the
/// message per peer must share the mapping, not deep-copy it.
pub type Quorums = Arc<[(ShardId, Vec<ProcessId>)]>;

/// Per-key timestamps for the keys of one group (small: one entry per
/// key the command touches at the group, so messages carry it by value).
pub type KeyTs = Vec<(Key, u64)>;

/// Per-key promise batches (built locally, shipped point-to-point in
/// `MProposeAck`).
pub type KeyPromises = Vec<(Key, PromiseSet)>;

/// Per-key promise batches shared across a fan-out: `MPromises` goes to
/// every group peer and promise histories can be large, so broadcast
/// messages share one buffer instead of deep-copying per peer.
pub type SharedPromises = Arc<[(Key, PromiseSet)]>;

/// The promise batches a coordinator collected from its fast quorum,
/// rebroadcast to every process in `MCommit` (§3.2 piggybacking) —
/// `Arc`-backed for the same zero-clone fan-out reason.
pub type Collected = Arc<[(ProcessId, KeyPromises)]>;

/// Command phase at a process (paper Figure 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Start,
    Payload,
    Propose,
    RecoverR,
    RecoverP,
    Commit,
    Execute,
}

impl Phase {
    /// `pending = payload ∪ propose ∪ recover-p ∪ recover-r`.
    pub fn is_pending(self) -> bool {
        matches!(self, Phase::Payload | Phase::Propose | Phase::RecoverR | Phase::RecoverP)
    }

    pub fn is_committed(self) -> bool {
        matches!(self, Phase::Commit | Phase::Execute)
    }
}

#[derive(Clone, Debug)]
pub enum Msg {
    /// Submitter → per-group coordinator.
    MSubmit { dot: Dot, cmd: Command, quorums: Quorums },
    /// Coordinator → fast quorum of its group: coordinator's per-key
    /// proposals for the keys of this group.
    MPropose { dot: Dot, cmd: Command, quorums: Quorums, ts: KeyTs },
    /// Fast-quorum process → coordinator: per-key proposals plus the
    /// promises generated while computing them (§3.2 piggybacking).
    MProposeAck { dot: Dot, ts: KeyTs, promises: KeyPromises },
    /// Coordinator → remaining group processes (payload dissemination).
    MPayload { dot: Dot, cmd: Command, quorums: Quorums },
    /// Group coordinator → `I_c`: per-key timestamps decided at this group,
    /// with the promise batches collected from the fast quorum.
    MCommit { dot: Dot, group: ShardId, ts: KeyTs, promises: Collected },
    /// Catch-up commit (reply to MCommitRequest): payload + final
    /// timestamp in one step (§B liveness, condensing MPayload+MCommit).
    MCommitDirect { dot: Dot, cmd: Command, quorums: Quorums, final_ts: u64 },
    /// Flexible-Paxos phase 2 (slow path / recovery) on the vector of
    /// per-key timestamps of this group.
    MConsensus { dot: Dot, ts: KeyTs, bal: u64 },
    MConsensusAck { dot: Dot, bal: u64 },
    /// Periodic promise broadcast within the group (per-key deltas),
    /// shared across the group fan-out.
    MPromises { promises: SharedPromises },
    /// Faster multi-partition stability (§4): a fast-quorum process tells
    /// co-located replicas of sibling groups to bump their clocks to its
    /// highest proposal.
    MBump { dot: Dot, ts: u64 },
    /// Multi-group stability announcement (Algorithm 3 line 64).
    MStable { dot: Dot },
    /// Recovery: Flexible-Paxos phase 1 (Algorithm 4).
    MRec { dot: Dot, bal: u64 },
    MRecAck { dot: Dot, ts: KeyTs, phase: Phase, abal: u64, bal: u64 },
    /// Ballot catch-up for the recovery leader (§B).
    MRecNAck { dot: Dot, bal: u64 },
    /// Ask for the payload/commit of a command known only through an
    /// attached promise (§B).
    MCommitRequest { dot: Dot },
    /// Periodic GC exchange (`protocol::common::GCTrack`): the sender's
    /// per-origin contiguous frontier of executed commands.
    MGarbageCollect { executed: Vec<(ProcessId, u64)> },
    /// Epoch reconfiguration vote (`protocol::common::epoch`): the sender
    /// endorses evicting exactly `evicted` (cumulative, sorted) into
    /// `epoch`; a majority of exact-match votes installs the epoch.
    MEpoch { epoch: u64, evicted: Vec<ProcessId> },
    /// Batch frame (`protocol::common::batch`): several messages bound for
    /// the same destination in one frame. Never nested; unbatched inside
    /// `Process::dispatch`, so handlers never see it.
    MBatch { msgs: Vec<Msg> },
}

impl crate::protocol::common::BatchMsg for Msg {
    fn batch(msgs: Vec<Msg>) -> Msg {
        Msg::MBatch { msgs }
    }

    fn is_batch(&self) -> bool {
        matches!(self, Msg::MBatch { .. })
    }

    fn approx_wire_bytes(&self) -> u64 {
        self.wire_size()
    }
}

impl Msg {
    /// Approximate wire size in bytes, used by the simulator's CPU/NIC
    /// resource model (header + payload-bearing fields).
    pub fn wire_size(&self) -> u64 {
        use crate::protocol::common::wire::{key_vals, proc_vals, HDR};
        fn kp_size(kp: &[(Key, PromiseSet)]) -> u64 {
            kp.iter()
                .map(|(_, p)| 8 + 16 * (p.detached.len() + p.attached.len()) as u64)
                .sum()
        }
        match self {
            Msg::MSubmit { cmd, .. } | Msg::MPayload { cmd, .. } => HDR + cmd.wire_size(),
            Msg::MPropose { cmd, ts, .. } => HDR + cmd.wire_size() + key_vals(ts.len()),
            Msg::MCommitDirect { cmd, .. } => HDR + cmd.wire_size() + 8,
            Msg::MProposeAck { ts, promises, .. } => {
                HDR + key_vals(ts.len()) + kp_size(promises)
            }
            Msg::MCommit { ts, promises, .. } => {
                HDR + key_vals(ts.len())
                    + promises.iter().map(|(_, kp)| 8 + kp_size(kp)).sum::<u64>()
            }
            Msg::MPromises { promises } => HDR + kp_size(promises),
            Msg::MConsensus { ts, .. } | Msg::MRecAck { ts, .. } => {
                HDR + 8 + key_vals(ts.len())
            }
            Msg::MGarbageCollect { executed } => HDR + proc_vals(executed.len()),
            Msg::MEpoch { evicted, .. } => HDR + 8 + 4 * evicted.len() as u64,
            // One frame header amortized over the members (each inner size
            // already includes its own HDR; 4 bytes of length prefix each).
            Msg::MBatch { msgs } => {
                HDR + msgs.iter().map(|m| 4 + m.wire_size()).sum::<u64>()
            }
            _ => HDR + 16,
        }
    }
}
