//! Tempo: leaderless (partial) state-machine replication via timestamp
//! stability — the paper's contribution (Algorithms 1–6).
//!
//! Partitions are **keys** (§2: partitions are "arbitrarily fine-grained,
//! e.g., just a single state variable"). Each machine (a [`ProcessId`])
//! replicates all keys of its shard group and runs an independent protocol
//! instance per key: per-key logical clocks, per-key promise stores, and
//! per-key execution queues — this is what makes Tempo's latency and
//! throughput independent of the conflict rate (§6.3) and the protocol
//! "highly parallel" (§4). Messages between machines batch the per-key
//! payloads of one command (the §4 co-location optimization).
//!
//! Commit: per-key timestamps are computed over a fast quorum of
//! `⌊r/2⌋+f` machines — fast path in one round trip when, for every key,
//! the maximal proposal was made by ≥ f quorum members; otherwise a
//! Flexible-Paxos slow path persists the vector of key timestamps.
//! A command's final timestamp is the max over all its keys; it executes
//! in ⟨ts, dot⟩ order per key once *stable* (Theorem 1), with an MStable
//! handshake across shard groups.
//!
//! Structure: broadcast, stalled-message buffering, command info, and the
//! executed-command GC all come from [`crate::protocol::common`]; key
//! stability is the *incremental* majority watermark of
//! [`promises::PromiseStore`] (updated on promise deltas, O(1) to read).

pub mod clock;
pub mod msg;
pub mod promises;

use self::clock::Clock;
use self::msg::{KeyPromises, KeyTs, Msg, Phase, Quorums, SharedPromises};
use self::promises::{PromiseSet, PromiseStore};
use super::common::{
    BaseProcess, CommandsInfo, EpochManager, EpochProcess, GCTrack, GcProcess, Process, ReadStash,
    RetryPacer,
};
use super::{ballot, Action, Footprint, Protocol};
use crate::core::{key_to_shard, Command, Config, Dot, Key, ProcessId, ShardId};
use crate::metrics::Counters;
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

/// Protocol state of one key (= one partition, paper §2).
#[derive(Debug, Default)]
struct KeyState {
    clock: Clock,
    store: PromiseStore,
    /// Everything this process ever promised on this key, for the periodic
    /// full re-broadcast under failures (§B; footnote 2 only optimizes the
    /// failure-free case). GC rewrites attached promises of group-wide
    /// executed commands into detached ranges, keeping this bounded.
    history: PromiseSet,
    /// Committed-not-yet-executed commands on this key, ⟨ts, dot⟩ order.
    queue: BTreeMap<(u64, Dot), ()>,
    /// Cached stable watermark (Theorem 1); refreshed from the store's
    /// incremental majority frontier when the key is dirty.
    stable: u64,
}

impl KeyState {
    fn new(procs: &[ProcessId], majority: usize) -> Self {
        let mut s = KeyState::default();
        s.store.init_quorum(procs, majority);
        s
    }
}

/// Per-command bookkeeping (the paper's cmd/ts/phase/quorums/bal/abal maps,
/// plus coordinator-side collection state). One `Info` per dot per machine;
/// the per-key timestamp values are vectors over the machine's local keys.
#[derive(Clone, Debug)]
struct Info {
    phase: Phase,
    cmd: Option<Command>,
    quorums: Quorums,
    /// Per-key timestamps for OUR group's keys (proposals, then decided).
    ts: KeyTs,
    /// Final (global) timestamp, set at commit.
    final_ts: u64,
    bal: u64,
    abal: u64,
    coordinator: bool,
    /// Coordinator already dispatched MCommit/MConsensus (dedup guard).
    decided: bool,
    /// Coordinator: per-process per-key proposals from MProposeAck.
    proposals: Vec<(ProcessId, KeyTs)>,
    /// Coordinator: promise batches from the fast quorum (rebroadcast in
    /// MCommit, §3.2 piggybacking).
    collected: Vec<(ProcessId, KeyPromises)>,
    consensus_acks: BTreeSet<ProcessId>,
    /// Recovery: (process, per-key ts, phase, abal) from MRecAck.
    rec_acks: Vec<(ProcessId, KeyTs, Phase, u64)>,
    /// Per-group committed key-timestamps (Algorithm 3 line 56).
    group_ts: Vec<(ShardId, KeyTs)>,
    /// Multi-group execution: groups that announced stability.
    stable_acks: BTreeSet<ShardId>,
    announced: bool,
    pending_since: u64,
}

impl Info {
    fn new(time: u64) -> Self {
        Info {
            phase: Phase::Start,
            cmd: None,
            quorums: Vec::new().into(),
            ts: Vec::new(),
            final_ts: 0,
            bal: 0,
            abal: 0,
            coordinator: false,
            decided: false,
            proposals: Vec::new(),
            collected: Vec::new(),
            consensus_acks: BTreeSet::new(),
            rec_acks: Vec::new(),
            group_ts: Vec::new(),
            stable_acks: BTreeSet::new(),
            announced: false,
            pending_since: time,
        }
    }

    fn fast_quorum(&self, group: ShardId) -> Option<&[ProcessId]> {
        self.quorums.iter().find(|(s, _)| *s == group).map(|(_, q)| q.as_slice())
    }
}

/// The Tempo machine state: one protocol instance per local key.
pub struct Tempo {
    /// Identity, group, config, stalled-message buffer (protocol/common).
    bp: BaseProcess<Msg>,
    keys: HashMap<Key, KeyState>,
    /// Keys whose clock outbox has promises to broadcast next tick.
    outbox_keys: BTreeSet<Key>,
    /// Keys whose queues/stability changed since the last execution pass.
    dirty: BTreeSet<Key>,
    info: CommandsInfo<Info>,
    /// Dots seen through gated attached promises: dot → first-seen time.
    missing: HashMap<Dot, u64>,
    /// Dots currently pending (for the recovery timer).
    pending: BTreeSet<Dot>,
    /// Own committed dots not yet group-wide executed — their MCommit is
    /// re-broadcast every `retry_interval_ticks` ticks for peers that
    /// missed it (`handle_commit` is idempotent). Empty when the opt-in
    /// retry timer is off.
    retry_commits: BTreeSet<Dot>,
    /// Per-dot retransmit pacing (`Config::retry_backoff_cap_ticks`);
    /// pass-through when the cap is 0 (legacy fixed cadence).
    retry_pacer: RetryPacer<Dot>,
    suspected: BTreeSet<ProcessId>,
    /// Epoch reconfiguration: eviction votes, installed history, fencing.
    epochs: EpochManager,
    /// Executed-command frontiers + group exchange state (GC).
    gc: GCTrack,
    /// Local reads parked until a key frontier covers their timestamp
    /// (`submit_read`); swept on every execution advance.
    stash: ReadStash,
    ticks: u64,
    pub counters: Counters,
}

impl Tempo {
    /// `leader_p` from the Ω failure detector: lowest non-suspected machine
    /// of our group.
    fn leader(&self) -> ProcessId {
        self.bp
            .group_procs
            .iter()
            .copied()
            .find(|p| !self.suspected.contains(p))
            .unwrap_or(self.bp.id)
    }

    /// Initial coordinator of `dot` at `group` (the paper's `initial_p`).
    fn initial_coordinator(&self, dot: Dot, group: ShardId) -> ProcessId {
        self.bp.config.closest_in_shard(dot.origin, group)
    }

    /// Keys of `cmd` that live in our shard group (our local partitions).
    fn local_keys(&self, cmd: &Command) -> Vec<Key> {
        cmd.keys
            .iter()
            .copied()
            .filter(|&k| key_to_shard(k, self.bp.config.shards) == self.bp.group)
            .collect()
    }

    fn ensure_info(&mut self, dot: Dot, time: u64) -> &mut Info {
        self.info.ensure(dot, || Info::new(time))
    }

    fn phase_of_internal(&self, dot: Dot) -> Phase {
        self.info.get(&dot).map_or(Phase::Start, |i| i.phase)
    }

    /// All machines of every group accessed by `cmd` (the paper's `I_c`).
    fn all_processes_of(&self, cmd: &Command) -> Vec<ProcessId> {
        let mut out = Vec::new();
        for g in cmd.shards(self.bp.config.shards) {
            out.extend(self.bp.config.shard_processes(g));
        }
        out
    }

    /// Incorporate a per-key promise batch from `source`, gating attached
    /// promises on local commits (Algorithm 2 line 47). Promises attached
    /// to group-wide-executed (GC'd) commands count as committed.
    fn add_promises(&mut self, source: ProcessId, batches: &[(Key, PromiseSet)], time: u64) {
        let majority = self.bp.config.majority();
        let shards = self.bp.config.shards;
        let group = self.bp.group;
        for (k, batch) in batches {
            if batch.is_empty() || key_to_shard(*k, shards) != group {
                continue;
            }
            let procs = &self.bp.group_procs;
            let info = &self.info;
            let gc = &self.gc;
            let state = self
                .keys
                .entry(*k)
                .or_insert_with(|| KeyState::new(procs, majority));
            let unknown = state.store.add(source, batch, |dot| {
                info.get(&dot).is_some_and(|i| i.phase.is_committed()) || gc.was_executed(dot)
            });
            self.dirty.insert(*k);
            for dot in unknown {
                self.missing.entry(dot).or_insert(time);
            }
        }
    }

    /// Per-key `proposal(id, m)` over `asks`; returns per-key proposals
    /// and the promise batches generated (for the ack/commit piggyback).
    fn propose_keys(&mut self, dot: Dot, asks: &[(Key, u64)]) -> (KeyTs, KeyPromises) {
        let majority = self.bp.config.majority();
        let mut ts = Vec::with_capacity(asks.len());
        let mut batches = Vec::with_capacity(asks.len());
        for &(k, m) in asks {
            let procs = &self.bp.group_procs;
            let state = self.keys.entry(k).or_insert_with(|| KeyState::new(procs, majority));
            let t = state.clock.proposal(dot, m);
            let batch = state.clock.take_outbox();
            state.history.merge(&batch);
            state.history.coalesce();
            ts.push((k, t));
            batches.push((k, batch));
        }
        ts.sort_unstable_by_key(|&(k, _)| k);
        batches.sort_unstable_by_key(|&(k, _)| k);
        (ts, batches)
    }

    // ------------------------------------------------------------------
    // Commit protocol (Algorithm 1 / Algorithm 5)
    // ------------------------------------------------------------------

    fn handle_submit(
        &mut self,
        dot: Dot,
        cmd: Command,
        quorums: Quorums,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) || self.phase_of_internal(dot) != Phase::Start {
            return; // duplicate MSubmit (or long-executed and GC'd)
        }
        let me = self.bp.id;
        let group = self.bp.group;
        let asks: Vec<(Key, u64)> = self.local_keys(&cmd).iter().map(|&k| (k, 0)).collect();
        let (ts, batches) = self.propose_keys(dot, &asks);
        {
            let info = self.ensure_info(dot, time);
            info.phase = Phase::Propose;
            info.cmd = Some(cmd.clone());
            info.quorums = quorums.clone();
            info.ts = ts.clone();
            info.coordinator = true;
            info.proposals.push((me, ts.clone()));
            info.collected.push((me, batches.clone()));
            info.pending_since = time;
        }
        self.pending.insert(dot);
        self.add_promises(me, &batches, time);

        let fq: Vec<ProcessId> = self.info[&dot]
            .fast_quorum(group)
            .expect("fast quorum for own group")
            .to_vec();
        for &p in &fq {
            if p != me {
                out.push(Action::send(
                    p,
                    Msg::MPropose {
                        dot,
                        cmd: cmd.clone(),
                        quorums: quorums.clone(),
                        ts: ts.clone(),
                    },
                ));
            }
        }
        for p in self.bp.group_procs.clone() {
            if !fq.contains(&p) {
                out.push(Action::send(
                    p,
                    Msg::MPayload { dot, cmd: cmd.clone(), quorums: quorums.clone() },
                ));
            }
        }
        self.drain_stalled(dot, time, out);
        self.try_fast_or_slow(dot, time, out);
    }

    fn handle_payload(
        &mut self,
        dot: Dot,
        cmd: Command,
        quorums: Quorums,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) || self.phase_of_internal(dot) != Phase::Start {
            return;
        }
        let info = self.ensure_info(dot, time);
        info.phase = Phase::Payload;
        info.cmd = Some(cmd);
        info.quorums = quorums;
        info.pending_since = time;
        self.pending.insert(dot);
        self.missing.remove(&dot);
        self.drain_stalled(dot, time, out);
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_propose(
        &mut self,
        from: ProcessId,
        dot: Dot,
        cmd: Command,
        quorums: Quorums,
        coord_ts: KeyTs,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) || self.phase_of_internal(dot) != Phase::Start {
            // Already recovered/committed — the MPropose precondition
            // (line 13) fails; dropping the message prevents the initial
            // coordinator from taking the fast path after recovery started.
            // One exception: a *retransmitted* MPropose while our propose
            // phase still owns the command (our original ack may have been
            // dropped by a lossy link) re-sends the recorded ack verbatim.
            // Conflicts are NOT registered twice — `info.ts` is the
            // proposal we already promised — and `bal > 0` means consensus
            // or recovery overwrote it, so there is nothing to re-ack.
            if let Some(info) = self.info.get(&dot) {
                if info.phase == Phase::Propose && !info.coordinator && info.bal == 0 {
                    let ts = info.ts.clone();
                    self.counters.retransmits += 1;
                    out.push(Action::send(
                        from,
                        Msg::MProposeAck { dot, ts, promises: Vec::new() },
                    ));
                }
            }
            return;
        }
        let me = self.bp.id;
        let (ts, batches) = self.propose_keys(dot, &coord_ts);
        {
            let info = self.ensure_info(dot, time);
            info.phase = Phase::Propose;
            info.cmd = Some(cmd.clone());
            info.quorums = quorums;
            info.ts = ts.clone();
            info.pending_since = time;
        }
        self.pending.insert(dot);
        self.missing.remove(&dot);
        self.add_promises(me, &batches, time);
        let highest = ts.iter().map(|&(_, t)| t).max().unwrap_or(0);
        out.push(Action::send(from, Msg::MProposeAck { dot, ts, promises: batches }));

        // MBump (§4 "Faster stability"): tell co-located replicas of the
        // other groups accessed by the command to bump their clocks.
        if self.bp.config.bump_enabled {
            for g in cmd.shards(self.bp.config.shards) {
                if g != self.bp.group {
                    let peer = self.bp.config.closest_in_shard(me, g);
                    out.push(Action::send(peer, Msg::MBump { dot, ts: highest }));
                }
            }
        }
        self.drain_stalled(dot, time, out);
    }

    fn handle_propose_ack(
        &mut self,
        from: ProcessId,
        dot: Dot,
        ts: KeyTs,
        promises: KeyPromises,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        self.add_promises(from, &promises, time);
        {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.phase != Phase::Propose || !info.coordinator || info.decided {
                return; // stale ack (recovery took over, or duplicate)
            }
            if info.proposals.iter().any(|(p, _)| *p == from) {
                return;
            }
            info.proposals.push((from, ts));
            info.collected.push((from, promises));
        }
        self.try_fast_or_slow(dot, time, out);
    }

    /// MProposeAck quorum check: fast path iff, for every local key, the
    /// maximal proposal was made by at least `f` quorum members
    /// (Algorithm 1 lines 17–21, per partition).
    fn try_fast_or_slow(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        let f = self.bp.config.f;
        let group = self.bp.group;
        let decision = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.phase != Phase::Propose || !info.coordinator || info.decided {
                return;
            }
            let fq_len = match info.fast_quorum(group) {
                Some(q) => q.len(),
                None => return,
            };
            if info.proposals.len() < fq_len {
                return;
            }
            // Per-key max and count over the quorum proposals.
            let keys: Vec<Key> = info.ts.iter().map(|&(k, _)| k).collect();
            let mut decided_ts: KeyTs = Vec::with_capacity(keys.len());
            let mut fast = true;
            for &k in &keys {
                let mut max_t = 0;
                let mut count = 0;
                for (_, kts) in &info.proposals {
                    let t = kts
                        .iter()
                        .find(|&&(k2, _)| k2 == k)
                        .map(|&(_, t)| t)
                        .expect("aligned key proposals");
                    if t > max_t {
                        max_t = t;
                        count = 1;
                    } else if t == max_t {
                        count += 1;
                    }
                }
                decided_ts.push((k, max_t));
                fast &= count >= f;
            }
            info.decided = true;
            info.ts = decided_ts.clone();
            if fast {
                (decided_ts, true, info.cmd.clone().unwrap(), std::mem::take(&mut info.collected))
            } else {
                (decided_ts, false, info.cmd.clone().unwrap(), Vec::new())
            }
        };
        let (ts, fast, cmd, collected) = decision;
        if fast {
            self.counters.fast_path += 1;
            let targets = self.all_processes_of(&cmd);
            self.broadcast(
                &targets,
                Msg::MCommit { dot, group, ts, promises: collected.into() },
                time,
                out,
            );
        } else {
            self.counters.slow_path += 1;
            let b = (self.bp.id.0 - self.bp.group_base()) as u64 + 1; // ballot "i"
            let msg = Msg::MConsensus { dot, ts, bal: b };
            self.broadcast(&self.bp.group_procs.clone(), msg, time, out);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_commit(
        &mut self,
        from: ProcessId,
        dot: Dot,
        group: ShardId,
        ts: KeyTs,
        promises: msg::Collected,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        // Incorporate the piggybacked promise batches (our keys only).
        // `promises` is a shared (Arc) buffer owned by this call frame, so
        // ingesting it borrows rather than deep-copying per source.
        for (src, batches) in promises.iter() {
            self.add_promises(*src, batches, time);
        }
        if self.gc.was_executed(dot) {
            return; // late duplicate for a long-executed, GC'd command
        }
        match self.phase_of_internal(dot) {
            Phase::Start => {
                // Payload not here yet: keep the message (pre: id ∈ pending).
                self.ensure_info(dot, time);
                self.stall(dot, from, Msg::MCommit { dot, group, ts, promises });
                return;
            }
            Phase::Commit | Phase::Execute => return, // duplicate
            _ => {}
        }
        {
            let info = self.info.get_mut(&dot).unwrap();
            if info.group_ts.iter().any(|(g, _)| *g == group) {
                return; // duplicate commit from this group
            }
            info.group_ts.push((group, ts));
        }
        self.try_commit(dot, time, out);
    }

    /// Commit once an MCommit from every accessed group arrived
    /// (Algorithm 3 line 56): final timestamp is the max across all keys.
    fn try_commit(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        let final_ts = {
            let info = match self.info.get(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.phase.is_committed() || info.cmd.is_none() {
                return;
            }
            let groups = info.cmd.as_ref().unwrap().shards(self.bp.config.shards);
            if info.group_ts.len() < groups.len() {
                return;
            }
            info.group_ts
                .iter()
                .flat_map(|(_, kts)| kts.iter().map(|&(_, t)| t))
                .max()
                .expect("non-empty commit vector")
        };
        self.commit(dot, final_ts, time, out);
    }

    fn commit(&mut self, dot: Dot, final_ts: u64, time: u64, out: &mut Vec<Action<Msg>>) {
        let local = {
            let info = self.info.get_mut(&dot).expect("commit without info");
            info.final_ts = final_ts;
            info.phase = Phase::Commit;
            self.pending.remove(&dot);
            self.missing.remove(&dot);
            if info.coordinator && self.bp.config.retry_interval_ticks > 0 {
                // Keep re-broadcasting this commit until the group-wide
                // executed frontier proves every peer has it.
                self.retry_commits.insert(dot);
            }
            info.cmd.clone().expect("commit without payload")
        };
        let majority = self.bp.config.majority();
        let local_keys = self.local_keys(&local);
        for &k in &local_keys {
            let procs = &self.bp.group_procs;
            let state = self.keys.entry(k).or_insert_with(|| KeyState::new(procs, majority));
            // bump(ts[id]): detached promises up to the committed timestamp
            // (Algorithm 1 line 25 / Algorithm 3 line 59).
            state.clock.bump(final_ts);
            if !state.clock.outbox_is_empty() {
                self.outbox_keys.insert(k);
            }
            // Release attached promises gated on this command (line 47).
            state.store.on_commit(dot);
            state.queue.insert((final_ts, dot), ());
            self.dirty.insert(k);
        }
        out.push(Action::Committed { dot, fast: true });
        self.drain_stalled(dot, time, out);
        self.advance_execution(out);
    }

    // ------------------------------------------------------------------
    // Slow path: single-decree Flexible Paxos (Algorithm 5 lines 30–37)
    // ------------------------------------------------------------------

    fn handle_consensus(
        &mut self,
        from: ProcessId,
        dot: Dot,
        ts: KeyTs,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return;
        }
        let info = self.ensure_info(dot, time);
        if info.bal > bal {
            // §B liveness: help the recovery leader pick a higher ballot.
            let cur = info.bal;
            out.push(Action::send(from, Msg::MRecNAck { dot, bal: cur }));
            return;
        }
        info.ts = ts;
        info.bal = bal;
        info.abal = bal;
        out.push(Action::send(from, Msg::MConsensusAck { dot, bal }));
    }

    fn handle_consensus_ack(
        &mut self,
        from: ProcessId,
        dot: Dot,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        let slow_quorum = self.bp.config.slow_quorum_size();
        let ready = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.bal != bal || info.phase.is_committed() {
                return;
            }
            info.consensus_acks.insert(from);
            // Fires exactly once, when the (f+1)-th distinct ack arrives.
            info.consensus_acks.len() == slow_quorum
        };
        if !ready {
            return;
        }
        let (ts, cmd, collected) = {
            let info = self.info.get_mut(&dot).unwrap();
            (info.ts.clone(), info.cmd.clone(), std::mem::take(&mut info.collected))
        };
        let cmd = match cmd {
            Some(c) => c,
            None => return,
        };
        let group = self.bp.group;
        let targets = self.all_processes_of(&cmd);
        self.broadcast(
            &targets,
            Msg::MCommit { dot, group, ts, promises: collected.into() },
            time,
            out,
        );
    }

    // ------------------------------------------------------------------
    // Execution protocol (Algorithm 2 / Algorithm 6 lines 97–103)
    // ------------------------------------------------------------------

    /// Drain the dirty-key set, executing every stable queue head in
    /// ⟨ts, dot⟩ order. A command executes once it is the stable head of
    /// every local key it accesses and (if multi-group) every accessed
    /// group has announced stability via MStable.
    fn advance_execution(&mut self, out: &mut Vec<Action<Msg>>) {
        while let Some(k) = self.dirty.pop_first() {
            // Refresh this key's stable watermark (Theorem 1) from the
            // store's incrementally maintained majority frontier — an O(1)
            // read; the seed re-scanned every source tracker here.
            {
                if let Some(state) = self.keys.get_mut(&k) {
                    let w = state.store.watermark();
                    if w > state.stable {
                        state.stable = w;
                        self.counters.wm_advances += 1;
                    }
                } else {
                    continue;
                }
            }
            loop {
                let (ts, dot) = {
                    let state = &self.keys[&k];
                    match state.queue.keys().next() {
                        Some(&(ts, dot)) if ts <= state.stable => (ts, dot),
                        _ => break,
                    }
                };
                if !self.try_execute(dot, ts, out) {
                    break;
                }
            }
        }
        // Frontiers may have advanced: sweep the parked local reads.
        self.release_reads(out);
    }

    /// Is the stability frontier of every key of `cmd` provably at or
    /// beyond `target`? Exact, not conservative: at watermark `w` every
    /// committed command with timestamp <= `w` sits in the key's queue,
    /// and no uncommitted command can still acquire a timestamp <= `w`
    /// (Theorem 1) — so "watermark covers `target` and no queue entry at
    /// or below it" means every such write already executed locally.
    ///
    /// `Config::read_frontier_skew` inflates the observed watermark; it
    /// breaks exactly this argument (proposed-not-yet-committed writes
    /// are invisible to the queue check) and exists only so the
    /// read-linearizability oracle's negative test has a fault to catch.
    fn read_covered(&mut self, cmd: &Command, target: u64) -> bool {
        let skew = self.bp.config.read_frontier_skew;
        for &k in &cmd.keys {
            match self.keys.get_mut(&k) {
                Some(state) => {
                    let w = state.store.watermark();
                    if w > state.stable {
                        state.stable = w;
                        self.counters.wm_advances += 1;
                    }
                    if state.stable + skew < target {
                        return false;
                    }
                    // Committed-but-unexecuted writes at or below the
                    // target must apply before the read can observe them.
                    let max_dot = Dot::new(ProcessId(u32::MAX), u64::MAX);
                    if state.queue.range(..=(target, max_dot)).next().is_some() {
                        return false;
                    }
                }
                // No state: this key was never written here, but a fresh
                // write could still acquire any timestamp >= 1 — only
                // target 0 (nothing to observe) is covered.
                None => {
                    if target > skew {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Emit `Action::ExecuteRead` for every parked read whose release
    /// target the frontier now covers.
    fn release_reads(&mut self, out: &mut Vec<Action<Msg>>) {
        if self.stash.is_empty() {
            return;
        }
        let mut stash = std::mem::take(&mut self.stash);
        let released = stash.release(|cmd, target| self.read_covered(cmd, target));
        self.stash = stash;
        for p in released {
            // `slack` is decided at release: the slackened target let the
            // read go while the strict frontier still lagged its timestamp.
            let slack = p.slackened() && !self.read_covered(&p.cmd, p.ts);
            if slack {
                self.counters.read_slack_served += 1;
            }
            self.counters.local_reads += 1;
            out.push(Action::ExecuteRead { cmd: p.cmd, covered: p.target, slack });
        }
    }

    /// Try to execute `dot` (committed with final timestamp `ts`). Returns
    /// true if it executed (and queues advanced).
    fn try_execute(&mut self, dot: Dot, ts: u64, out: &mut Vec<Action<Msg>>) -> bool {
        let cmd = match self.info.get(&dot) {
            Some(i) if i.phase == Phase::Commit => i.cmd.clone().unwrap(),
            _ => return false,
        };
        let local = self.local_keys(&cmd);
        // Stable head of every local key?
        for &k2 in &local {
            let state = match self.keys.get(&k2) {
                Some(s) => s,
                None => return false,
            };
            if state.stable < ts || state.queue.keys().next() != Some(&(ts, dot)) {
                return false;
            }
        }
        let groups = cmd.shards(self.bp.config.shards);
        if groups.len() > 1 {
            // Announce our stability once (Algorithm 6 line 101), then wait
            // for every accessed group (Algorithm 6 line 102).
            let me = self.bp.id;
            let own = self.bp.group;
            let announce = {
                let info = self.info.get_mut(&dot).unwrap();
                if info.announced {
                    false
                } else {
                    info.announced = true;
                    info.stable_acks.insert(own);
                    true
                }
            };
            if announce {
                for p in self.all_processes_of(&cmd) {
                    if p != me && self.bp.config.shard_of(p) != own {
                        out.push(Action::send(p, Msg::MStable { dot }));
                    }
                }
            }
            let ready = {
                let info = &self.info[&dot];
                groups.iter().all(|g| info.stable_acks.contains(g))
            };
            if !ready {
                return false;
            }
        }
        // Execute: remove from all local queues and emit the upcall.
        for &k2 in &local {
            let state = self.keys.get_mut(&k2).unwrap();
            state.queue.remove(&(ts, dot));
            self.dirty.insert(k2);
        }
        self.info.get_mut(&dot).unwrap().phase = Phase::Execute;
        self.gc.record_executed(dot);
        self.counters.executed += 1;
        out.push(Action::Execute { dot, cmd, ts });
        true
    }

    fn handle_stable(&mut self, from: ProcessId, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        if self.gc.was_executed(dot) {
            return;
        }
        let group = self.bp.config.shard_of(from);
        match self.phase_of_internal(dot) {
            Phase::Execute => {}
            Phase::Commit => {
                let (ts, local) = {
                    let info = self.info.get_mut(&dot).unwrap();
                    info.stable_acks.insert(group);
                    (info.final_ts, info.cmd.clone().unwrap())
                };
                let _ = ts;
                for k in self.local_keys(&local) {
                    self.dirty.insert(k);
                }
                self.advance_execution(out);
            }
            _ => {
                self.ensure_info(dot, time);
                // Record the ack even before commit; no need to re-handle.
                self.info.get_mut(&dot).unwrap().stable_acks.insert(group);
            }
        }
    }

    fn handle_promises(
        &mut self,
        from: ProcessId,
        promises: SharedPromises,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        self.add_promises(from, &promises, time);
        self.advance_execution(out);
    }

    fn handle_bump(&mut self, from: ProcessId, dot: Dot, ts: u64, time: u64) {
        if self.gc.was_executed(dot) {
            return;
        }
        match self.phase_of_internal(dot) {
            Phase::Start | Phase::Payload => {
                // Precondition `id ∈ propose` not met yet; retry when the
                // command advances (dropped once committed, where the commit
                // bump subsumes this one).
                self.ensure_info(dot, time);
                self.stall(dot, from, Msg::MBump { dot, ts });
            }
            Phase::Propose => {
                let majority = self.bp.config.majority();
                let cmd = self.info[&dot].cmd.clone().unwrap();
                for k in self.local_keys(&cmd) {
                    let procs = &self.bp.group_procs;
                    let state =
                        self.keys.entry(k).or_insert_with(|| KeyState::new(procs, majority));
                    state.clock.bump(ts);
                    if !state.clock.outbox_is_empty() {
                        self.outbox_keys.insert(k);
                    }
                }
            }
            _ => {}
        }
    }
}

impl GcProcess for Tempo {
    fn gc_track(&mut self) -> &mut GCTrack {
        &mut self.gc
    }

    /// Prune all per-command state for dots every group member executed,
    /// and rewrite promise histories so they stop referencing those dots.
    fn prune_executed(&mut self) {
        let ranges = self.gc.safe_to_prune();
        if ranges.is_empty() {
            return;
        }
        let mut pruned: HashSet<Dot> = HashSet::new();
        for (origin, lo, hi) in ranges {
            for idx in lo..=hi {
                let dot = self.gc.dot_at(origin, idx);
                if self.info.prune(&dot) {
                    self.counters.gc_pruned += 1;
                }
                self.bp.drop_stalled(dot);
                self.missing.remove(&dot);
                self.pending.remove(&dot);
                pruned.insert(dot);
            }
        }
        // Attached promises of pruned commands become detached ranges in
        // the re-broadcast history: receivers treat them gate-free (their
        // command executed group-wide), and `history` stays bounded.
        for state in self.keys.values_mut() {
            state.history.detach_executed(&pruned);
        }
    }
}

impl EpochProcess for Tempo {
    fn epoch_mgr(&mut self) -> &mut EpochManager {
        &mut self.epochs
    }

    fn on_evicted(&mut self, member: ProcessId) {
        // The GC frontier stops waiting for the evicted member — this is
        // what unfreezes pruning after a crash (bounded memory, tested by
        // the nemesis sweep's footprint oracle).
        self.gc.evict(member);
        self.suspected.insert(member);
        self.counters.evictions += 1;
    }
}

impl Process for Tempo {
    type Msg = Msg;

    fn base(&self) -> &BaseProcess<Msg> {
        &self.bp
    }

    fn base_mut(&mut self) -> &mut BaseProcess<Msg> {
        &mut self.bp
    }

    fn dispatch(&mut self, from: ProcessId, msg: Msg, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        // Epoch fencing: messages from members the installed epoch evicted
        // are late by definition — reject them wholesale.
        if self.epochs.rejects(from) {
            return out;
        }
        match msg {
            Msg::MSubmit { dot, cmd, quorums } => {
                self.handle_submit(dot, cmd, quorums, time, &mut out)
            }
            Msg::MPropose { dot, cmd, quorums, ts } => {
                self.handle_propose(from, dot, cmd, quorums, ts, time, &mut out)
            }
            Msg::MProposeAck { dot, ts, promises } => {
                self.handle_propose_ack(from, dot, ts, promises, time, &mut out)
            }
            Msg::MPayload { dot, cmd, quorums } => {
                self.handle_payload(dot, cmd, quorums, time, &mut out)
            }
            Msg::MCommit { dot, group, ts, promises } => {
                self.handle_commit(from, dot, group, ts, promises, time, &mut out)
            }
            Msg::MCommitDirect { dot, cmd, quorums, final_ts } => {
                self.handle_commit_direct(dot, cmd, quorums, final_ts, time, &mut out)
            }
            Msg::MConsensus { dot, ts, bal } => {
                self.handle_consensus(from, dot, ts, bal, time, &mut out)
            }
            Msg::MConsensusAck { dot, bal } => {
                self.handle_consensus_ack(from, dot, bal, time, &mut out)
            }
            Msg::MPromises { promises } => self.handle_promises(from, promises, time, &mut out),
            Msg::MBump { dot, ts } => self.handle_bump(from, dot, ts, time),
            Msg::MStable { dot } => self.handle_stable(from, dot, time, &mut out),
            Msg::MRec { dot, bal } => self.handle_rec(from, dot, bal, time, &mut out),
            Msg::MRecAck { dot, ts, phase, abal, bal } => {
                self.handle_rec_ack(from, dot, ts, phase, abal, bal, time, &mut out)
            }
            Msg::MRecNAck { dot, bal } => self.handle_rec_nack(dot, bal, time, &mut out),
            Msg::MCommitRequest { dot } => self.handle_commit_request(from, dot, &mut out),
            Msg::MGarbageCollect { executed } => self.handle_garbage_collect(from, &executed),
            Msg::MEpoch { epoch, evicted } => self.handle_epoch(
                from,
                epoch,
                evicted,
                |epoch, evicted| Msg::MEpoch { epoch, evicted },
                &mut out,
            ),
            // Unbatching lives here, not in the handlers: a batch frame
            // re-dispatches its members in order (protocol::common::batch).
            Msg::MBatch { msgs } => {
                for m in msgs {
                    let actions = self.dispatch(from, m, time);
                    out.extend(actions);
                }
            }
        }
        out
    }
}

impl Tempo {
    // ------------------------------------------------------------------
    // Recovery (Algorithm 4 / Algorithm 5 lines 38–62) and §B liveness
    // ------------------------------------------------------------------

    /// Take over coordination of `dot` (paper `recover(id)`).
    fn recover(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        let bal = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if !info.phase.is_pending() {
                return;
            }
            info.rec_acks.clear();
            info.consensus_acks.clear();
            info.bal
        };
        let b =
            ballot::next_owned(bal, self.bp.id, self.bp.config.r as u64, self.bp.group_base());
        self.counters.recoveries += 1;
        out.push(Action::RecoveryStarted { dot });
        self.broadcast(&self.bp.group_procs.clone(), Msg::MRec { dot, bal: b }, time, out);
    }

    fn handle_rec(
        &mut self,
        from: ProcessId,
        dot: Dot,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return; // GC'd: everyone executed; MCommitRequest serves laggards
        }
        let phase = self.phase_of_internal(dot);
        if phase == Phase::Start {
            self.ensure_info(dot, time);
            self.stall(dot, from, Msg::MRec { dot, bal });
            return;
        }
        if !phase.is_pending() {
            return; // already committed; MCommitRequest liveness helps `from`
        }
        let cur_bal = self.info[&dot].bal;
        if cur_bal >= bal {
            out.push(Action::send(from, Msg::MRecNAck { dot, bal: cur_bal }));
            return;
        }
        if cur_bal == 0 {
            match phase {
                Phase::Payload => {
                    // Compute per-key proposals now; RECOVER-R records that
                    // they happened in the MRec handler, which invalidates
                    // the fast path (Algorithm 4, case 1).
                    let cmd = self.info[&dot].cmd.clone().unwrap();
                    let asks: Vec<(Key, u64)> =
                        self.local_keys(&cmd).iter().map(|&k| (k, 0)).collect();
                    let (ts, batches) = self.propose_keys(dot, &asks);
                    let me = self.bp.id;
                    self.add_promises(me, &batches, time);
                    for (k, _) in &batches {
                        self.outbox_keys.insert(*k);
                    }
                    let info = self.info.get_mut(&dot).unwrap();
                    info.ts = ts;
                    info.phase = Phase::RecoverR;
                }
                Phase::Propose => {
                    self.info.get_mut(&dot).unwrap().phase = Phase::RecoverP;
                }
                _ => {}
            }
        }
        let info = self.info.get_mut(&dot).unwrap();
        info.bal = bal;
        let (ts, ph, abal) = (info.ts.clone(), info.phase, info.abal);
        out.push(Action::send(from, Msg::MRecAck { dot, ts, phase: ph, abal, bal }));
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_rec_ack(
        &mut self,
        from: ProcessId,
        dot: Dot,
        ts: KeyTs,
        phase: Phase,
        abal: u64,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        let rec_quorum = self.bp.config.recovery_quorum_size();
        let group = self.bp.group;
        let initial = self.initial_coordinator(dot, group);
        let decided: KeyTs = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.bal != bal || info.phase.is_committed() {
                return;
            }
            if info.rec_acks.iter().any(|&(p, ..)| p == from) {
                return;
            }
            info.rec_acks.push((from, ts, phase, abal));
            if info.rec_acks.len() != rec_quorum {
                return;
            }
            if let Some((_, kts, _, _)) = info
                .rec_acks
                .iter()
                .filter(|&&(_, _, _, ab)| ab != 0)
                .max_by_key(|&&(_, _, _, ab)| ab)
            {
                // Some process accepted a consensus proposal: classic Paxos
                // rule — adopt the value accepted at the highest ballot.
                kts.clone()
            } else {
                // Nobody accepted: reconstruct per-key timestamps that
                // preserve Properties 3 and 4.
                let fq: Vec<ProcessId> =
                    info.fast_quorum(group).map(|q| q.to_vec()).unwrap_or_default();
                let in_i: Vec<&(ProcessId, KeyTs, Phase, u64)> =
                    info.rec_acks.iter().filter(|&&(p, ..)| fq.contains(&p)).collect();
                let s = in_i.iter().any(|&&(p, ..)| p == initial)
                    || in_i.iter().any(|&&(_, _, ph, _)| ph == Phase::RecoverR);
                // Candidate set Q': whole recovery quorum if the initial
                // coordinator cannot have taken the fast path; otherwise
                // I = Q_rec ∩ Q_fast (>= ⌊r/2⌋ members, Property 4).
                let candidates: Vec<&(ProcessId, KeyTs, Phase, u64)> = if s {
                    info.rec_acks.iter().collect()
                } else {
                    in_i
                };
                let keys: Vec<Key> = info.ts.iter().map(|&(k, _)| k).collect();
                // When `info.ts` is empty (we never proposed — possible for
                // a RECOVER-R that raced), derive the key set from an ack.
                let keys = if keys.is_empty() {
                    candidates
                        .first()
                        .map(|(_, kts, _, _)| kts.iter().map(|&(k, _)| k).collect())
                        .unwrap_or_default()
                } else {
                    keys
                };
                keys.iter()
                    .map(|&k| {
                        let max_t = candidates
                            .iter()
                            .filter_map(|(_, kts, _, _)| {
                                kts.iter().find(|&&(k2, _)| k2 == k).map(|&(_, t)| t)
                            })
                            .max()
                            .unwrap_or(0);
                        (k, max_t)
                    })
                    .collect()
            }
        };
        {
            let info = self.info.get_mut(&dot).unwrap();
            info.ts = decided.clone();
            info.coordinator = true; // we are this command's coordinator now
            info.consensus_acks.clear();
        }
        let msg = Msg::MConsensus { dot, ts: decided, bal };
        self.broadcast(&self.bp.group_procs.clone(), msg, time, out);
    }

    fn handle_rec_nack(&mut self, dot: Dot, bal: u64, time: u64, out: &mut Vec<Action<Msg>>) {
        // §B: join the higher ballot and retry recovery (only the leader).
        if self.leader() != self.bp.id {
            return;
        }
        {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.bal >= bal || !info.phase.is_pending() {
                return;
            }
            info.bal = bal;
        }
        self.recover(dot, time, out);
    }

    fn handle_commit_request(&mut self, from: ProcessId, dot: Dot, out: &mut Vec<Action<Msg>>) {
        if let Some(info) = self.info.get(&dot) {
            if info.phase.is_committed() {
                if let Some(cmd) = &info.cmd {
                    out.push(Action::send(
                        from,
                        Msg::MCommitDirect {
                            dot,
                            cmd: cmd.clone(),
                            quorums: info.quorums.clone(),
                            final_ts: info.final_ts,
                        },
                    ));
                }
            }
        }
    }

    fn handle_commit_direct(
        &mut self,
        dot: Dot,
        cmd: Command,
        quorums: Quorums,
        final_ts: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return;
        }
        {
            let info = self.ensure_info(dot, time);
            if info.phase.is_committed() {
                return;
            }
            if info.cmd.is_none() {
                info.cmd = Some(cmd.clone());
                info.quorums = quorums;
            }
        }
        self.commit(dot, final_ts, time, out);
    }

    /// Opt-in retransmission (`Config::retry_interval_ticks`): re-drive own
    /// in-flight proposals and re-broadcast own commits over lossy links.
    ///
    /// Recovery timers (§B) only cover dots the Ω leader has in its local
    /// `pending` set, and `MCommitRequest` only serves *committed* dots —
    /// so a single dropped MPropose to the leader itself, or a dropped
    /// MCommit to a payload-less replica with promise gossip off, stalls a
    /// command with no timer left to save it. The coordinator still knows
    /// everything needed to finish, so it periodically re-sends. Every
    /// retransmit is idempotent at the receiver: a duplicate MPropose
    /// re-acks the recorded proposal without re-registering conflicts,
    /// MPayload/MCommit dedup on phase, and MConsensus acks re-collect
    /// into a voter set.
    fn retry_tick(&mut self, time: u64, out: &mut Vec<Action<Msg>>) {
        let every = self.bp.config.retry_interval_ticks;
        if every == 0 {
            return;
        }
        // Legacy fixed cadence fires everything on every N-th tick; with
        // backoff the per-dot pacer owns the schedule and we must look at
        // every tick (each dot has its own due point).
        if !self.retry_pacer.backoff_enabled() && self.ticks % every != 0 {
            return;
        }
        let me = self.bp.id;
        let group = self.bp.group;
        let own_bal = (me.0 - self.bp.group_base()) as u64 + 1;
        for dot in self.pending.clone() {
            if !self.retry_pacer.due(dot, self.ticks) {
                continue;
            }
            let plan = {
                let Some(info) = self.info.get(&dot) else { continue };
                if !info.coordinator || info.phase != Phase::Propose {
                    continue;
                }
                let Some(cmd) = info.cmd.clone() else { continue };
                let Some(fq) = info.fast_quorum(group) else { continue };
                let fq = fq.to_vec();
                let acked: Vec<ProcessId> =
                    info.proposals.iter().map(|&(p, _)| p).collect();
                (cmd, info.quorums.clone(), info.ts.clone(), fq, acked, info.decided, info.bal)
            };
            let (cmd, quorums, ts, fq, acked, decided, bal) = plan;
            if !decided {
                // Fast round still collecting: `info.ts` is our original
                // proposal until the decision overwrites it, so the
                // retransmit is bit-identical to the first MPropose.
                for &p in &fq {
                    if p != me && !acked.contains(&p) {
                        self.counters.retransmits += 1;
                        out.push(Action::send(
                            p,
                            Msg::MPropose {
                                dot,
                                cmd: cmd.clone(),
                                quorums: quorums.clone(),
                                ts: ts.clone(),
                            },
                        ));
                    }
                }
                for p in self.bp.group_procs.clone() {
                    if p != me && !fq.contains(&p) {
                        self.counters.retransmits += 1;
                        out.push(Action::send(
                            p,
                            Msg::MPayload { dot, cmd: cmd.clone(), quorums: quorums.clone() },
                        ));
                    }
                }
            } else if bal == own_bal {
                // Slow round in flight and still ours (recovery would have
                // claimed a higher ballot): re-run our consensus round.
                // Receivers with `bal >= info.bal` re-ack; the coordinator's
                // ack set fires once at f+1 distinct voters.
                self.counters.retransmits += 1;
                let msg = Msg::MConsensus { dot, ts, bal: own_bal };
                self.broadcast(&self.bp.group_procs.clone(), msg, time, out);
            }
        }
        // Own committed dots: re-broadcast MCommit until the group-wide
        // executed frontier proves everyone has it. The promise batches
        // piggybacked on the original commit flow separately (periodic
        // MPromises); the retransmit carries none.
        for dot in self.retry_commits.clone() {
            if self.gc.was_executed(dot) {
                self.retry_commits.remove(&dot);
                continue;
            }
            if !self.retry_pacer.due(dot, self.ticks) {
                continue;
            }
            let redo = {
                let Some(info) = self.info.get(&dot) else {
                    self.retry_commits.remove(&dot);
                    continue;
                };
                let Some(cmd) = info.cmd.clone() else { continue };
                (cmd, info.ts.clone())
            };
            let (cmd, ts) = redo;
            let targets = self.all_processes_of(&cmd);
            self.counters.retransmits += 1;
            let none: Vec<(ProcessId, KeyPromises)> = Vec::new();
            self.broadcast(
                &targets,
                Msg::MCommit { dot, group, ts, promises: none.into() },
                time,
                out,
            );
        }
        // Completed dots leave both retry sets; drop their schedules so
        // the pacer stays bounded by the in-flight state it paces.
        let (pending, commits) = (&self.pending, &self.retry_commits);
        self.retry_pacer.retain(|d| pending.contains(d) || commits.contains(d));
    }
}

impl Protocol for Tempo {
    type Message = Msg;

    fn new(id: ProcessId, config: Config) -> Self {
        let bp = BaseProcess::new(id, config);
        // Stride-aware executed frontier: a worker slot sees only the dots
        // of its own sequence stride (identity stride when unsharded).
        let gc = GCTrack::strided(
            id,
            bp.group_procs.clone(),
            bp.config.worker,
            bp.config.workers,
        );
        let epochs =
            EpochManager::new(id, bp.group_procs.clone(), bp.config.epoch_fence_off);
        let retry_pacer = RetryPacer::new(
            bp.config.retry_interval_ticks,
            bp.config.retry_backoff_cap_ticks,
        );
        Tempo {
            bp,
            keys: HashMap::new(),
            outbox_keys: BTreeSet::new(),
            dirty: BTreeSet::new(),
            info: CommandsInfo::default(),
            missing: HashMap::new(),
            pending: BTreeSet::new(),
            retry_commits: BTreeSet::new(),
            retry_pacer,
            suspected: BTreeSet::new(),
            epochs,
            gc,
            stash: ReadStash::default(),
            ticks: 0,
            counters: Counters::default(),
        }
    }

    fn name() -> &'static str {
        "tempo"
    }

    /// Submit a command (paper line 1): rename it to a freshly allocated
    /// dot, pick a fast quorum per accessed group and hand the command to
    /// the co-located coordinator of each.
    fn submit(&mut self, cmd: Command, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        let dot = self.bp.next_dot();
        out.push(Action::Submitted { dot });
        let groups = cmd.shards(self.bp.config.shards);
        debug_assert!(
            groups.contains(&self.bp.group),
            "submitter must replicate one accessed partition"
        );
        let quorums: Quorums = groups
            .iter()
            .map(|&g| {
                let coord = self.bp.config.closest_in_shard(self.bp.id, g);
                (g, self.bp.config.fast_quorum(coord))
            })
            .collect::<Vec<_>>()
            .into();
        let coords: Vec<ProcessId> = groups
            .iter()
            .map(|&g| self.bp.config.closest_in_shard(self.bp.id, g))
            .collect();
        self.broadcast(&coords, Msg::MSubmit { dot, cmd, quorums }, time, &mut out);
        self.outbound(out, false, time)
    }

    /// Stability-powered local read (the tentpole of the read path): the
    /// read is assigned the *current* clock value of its key — no bump,
    /// no proposal, no quorum, no dot — and executes locally the moment
    /// the key's stability frontier covers that timestamp. Zero protocol
    /// messages in both the instant and the parked case.
    ///
    /// Degradations (counted in `Counters::slow_reads`):
    /// - multi-group key sets: stability is per group; a coordination-free
    ///   snapshot across groups would need the MStable handshake anyway;
    /// - multi-key reads that cannot be served instantly: a quiet key's
    ///   frontier only advances with write traffic, so parking on the max
    ///   timestamp across keys could stall forever — the ordering path
    ///   guarantees liveness instead.
    ///
    /// Single-key parked reads are live: a clock value `v` was reached by
    /// proposals/bumps of writes that eventually commit with final
    /// timestamp >= `v`, and their commit bumps push every group member's
    /// promises — and hence the majority watermark — to `v`.
    fn submit_read(&mut self, cmd: Command, floor: u64, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        debug_assert!(cmd.op.is_read(), "submit_read takes read-only commands");
        let groups = cmd.shards(self.bp.config.shards);
        if groups.len() > 1 || !groups.contains(&self.bp.group) {
            self.counters.slow_reads += 1;
            return self.submit(cmd, time);
        }
        // Read-your-writes: the session's last acked write decided at
        // `floor`, so the read's timestamp — and, below, its release
        // target — must not sit under it, whatever the local clock or the
        // staleness slack would otherwise allow.
        let ts = cmd
            .keys
            .iter()
            .map(|&k| self.keys.get(&k).map_or(0, |s| s.clock.value()))
            .max()
            .unwrap_or(0)
            .max(floor);
        let target = ts.saturating_sub(self.bp.config.read_slack).max(floor);
        if self.read_covered(&cmd, target) {
            let slack = target < ts && !self.read_covered(&cmd, ts);
            if slack {
                self.counters.read_slack_served += 1;
            }
            self.counters.local_reads += 1;
            out.push(Action::ExecuteRead { cmd, covered: target, slack });
            return out;
        }
        if cmd.keys.len() > 1 {
            self.counters.slow_reads += 1;
            return self.submit(cmd, time);
        }
        self.stash.park(cmd, target, ts);
        out
    }

    fn handle(&mut self, from: ProcessId, msg: Msg, time: u64) -> Vec<Action<Msg>> {
        let out = self.dispatch(from, msg, time);
        self.outbound(out, false, time)
    }

    /// Periodic handler: broadcast freshly generated promises, advance
    /// execution, run the GC exchange, and run the §B liveness mechanisms
    /// (recovery timers and MCommitRequest for commands known only through
    /// attached promises).
    fn tick(&mut self, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        // 1. Promise broadcast (Algorithm 2 line 45; deltas only, per the
        //    paper's footnote 2), batched across keys into one message.
        if !self.outbox_keys.is_empty() {
            let keys: Vec<Key> = std::mem::take(&mut self.outbox_keys).into_iter().collect();
            let mut batches: KeyPromises = Vec::with_capacity(keys.len());
            for k in keys {
                if let Some(state) = self.keys.get_mut(&k) {
                    let batch = state.clock.take_outbox();
                    if !batch.is_empty() {
                        state.history.merge(&batch);
                        state.history.coalesce();
                        batches.push((k, batch));
                    }
                }
            }
            if !batches.is_empty() {
                let me = self.bp.id;
                self.add_promises(me, &batches, time);
                // Share one buffer across the group fan-out: per-peer
                // clones bump a refcount instead of copying the batches.
                let shared: SharedPromises = batches.into();
                for p in self.bp.group_procs.clone() {
                    if p != me {
                        out.push(Action::send(p, Msg::MPromises { promises: shared.clone() }));
                    }
                }
            }
        }
        // 1b. Periodic *full* promise re-broadcast (§B): under failures,
        //     promises piggybacked to a dead coordinator would otherwise be
        //     lost forever and stability would stall. Only needed when
        //     recovery is enabled; throttled to every 32nd tick.
        self.ticks += 1;
        if self.bp.config.recovery_timeout_us != u64::MAX && self.ticks % 32 == 0 {
            let mut full: KeyPromises = Vec::new();
            for (&k, state) in &self.keys {
                if !state.history.is_empty() {
                    full.push((k, state.history.clone()));
                }
            }
            if !full.is_empty() {
                full.sort_unstable_by_key(|&(k, _)| k);
                let shared: SharedPromises = full.into();
                for p in self.bp.group_procs.clone() {
                    if p != self.bp.id {
                        out.push(Action::send(p, Msg::MPromises { promises: shared.clone() }));
                    }
                }
            }
        }
        // 2. Execution.
        self.advance_execution(&mut out);
        // 2b. GC exchange: share our executed frontiers with the group and
        //     prune everything the whole group executed (common::GcProcess).
        let ticks = self.ticks;
        self.gc_tick(ticks, |executed| Msg::MGarbageCollect { executed }, &mut out);
        // 2c. Epoch reconfiguration: while an eviction proposal is pending,
        //     vote and re-broadcast it until a majority installs the epoch.
        self.epoch_tick(|epoch, evicted| Msg::MEpoch { epoch, evicted }, &mut out);
        // 2d. Opt-in retransmission of own proposals/commits (lossy links).
        self.retry_tick(time, &mut out);
        // 3. Recovery timers (only the Ω leader calls recover()).
        if self.bp.config.recovery_timeout_us != u64::MAX && self.leader() == self.bp.id {
            let timeout = self.bp.config.recovery_timeout_us;
            let r = self.bp.config.r as u64;
            let base = self.bp.group_base();
            let me = self.bp.id;
            let due: Vec<Dot> = self
                .pending
                .iter()
                .copied()
                .filter(|d| {
                    self.info.get(d).is_some_and(|i| {
                        i.phase.is_pending()
                            && time.saturating_sub(i.pending_since) >= timeout
                            && (i.bal == 0 || ballot::leader(i.bal, r, base) != me)
                    })
                })
                .collect();
            for dot in due {
                // Restart the timer so we do not spam MRec every tick.
                if let Some(i) = self.info.get_mut(&dot) {
                    i.pending_since = time;
                }
                self.recover(dot, time, &mut out);
            }
        }
        // 4. MCommitRequest for dots known only via gated attached promises.
        if self.bp.config.recovery_timeout_us != u64::MAX {
            let timeout = self.bp.config.recovery_timeout_us;
            let due: Vec<Dot> = self
                .missing
                .iter()
                .filter(|&(_, &since)| time.saturating_sub(since) >= timeout)
                .map(|(&d, _)| d)
                .collect();
            for dot in due {
                *self.missing.get_mut(&dot).unwrap() = time;
                // We may not know I_c yet: ask the origin's group and ours.
                let mut targets =
                    self.bp.config.shard_processes(self.bp.config.shard_of(dot.origin));
                targets.extend(self.bp.group_procs.iter().copied());
                targets.sort_unstable();
                targets.dedup();
                for p in targets {
                    if p != self.bp.id {
                        out.push(Action::send(p, Msg::MCommitRequest { dot }));
                    }
                }
            }
        }
        self.outbound(out, true, time)
    }

    fn crash(&mut self) {
        self.bp.crashed = true;
    }

    fn note_restart(&mut self, dot_floor: u64) {
        // Never re-mint a dot the pre-crash incarnation may have proposed:
        // peers hold per-dot commands/promises keyed by (origin, seq), and
        // a recycled seq would attach a *different* command to an existing
        // identity. The floor comes from the recovered WAL/snapshot plus
        // peer manifests and so covers every *executed* dot; proposals
        // still in flight at the crash are covered by the runtime's slack
        // (`crate::protocol::RESTART_DOT_SLACK`).
        self.bp.advance_dots_past(dot_floor);
    }

    fn suspect(&mut self, p: ProcessId) {
        self.suspected.insert(p);
        self.epochs.suspect(p);
    }

    fn counters(&self) -> Counters {
        let mut c = self.counters;
        self.bp.batcher.record_stats(&mut c);
        c
    }

    fn epoch_view(&self) -> Vec<(u64, Vec<ProcessId>)> {
        self.epochs.history().to_vec()
    }

    fn msg_size(msg: &Msg) -> u64 {
        msg.wire_size()
    }

    fn footprint(&self) -> Footprint {
        Footprint {
            infos: self.info.len(),
            keys: self.keys.len(),
            stalled: self.bp.stalled_len() + self.missing.len() + self.stash.len(),
            queued: self.bp.batcher.queued(),
            fragments: 0,
        }
    }
}

impl Tempo {
    /// Logical clock of `key` (diagnostics/tests).
    pub fn clock_value(&self, key: Key) -> u64 {
        self.keys.get(&key).map_or(0, |s| s.clock.value())
    }

    /// Stable watermark of `key` (diagnostics/tests): the scan-based
    /// reference path, which must agree with the incremental cache.
    pub fn stable_watermark(&self, key: Key) -> u64 {
        self.keys.get(&key).map_or(0, |s| {
            s.store.stable_watermark(&self.bp.group_procs, self.bp.config.majority())
        })
    }

    /// Phase of `dot` (tests).
    pub fn phase_of(&self, dot: Dot) -> Option<Phase> {
        self.info.get(&dot).map(|i| i.phase)
    }

    /// Committed (final) timestamp of `dot`, if committed (Property 1).
    pub fn committed_ts(&self, dot: Dot) -> Option<u64> {
        self.info.get(&dot).filter(|i| i.phase.is_committed()).map(|i| i.final_ts)
    }

    /// Committed per-key timestamps at this group (tests).
    pub fn committed_key_ts(&self, dot: Dot) -> Option<KeyTs> {
        self.info.get(&dot).filter(|i| i.phase.is_committed()).map(|i| i.ts.clone())
    }
}
