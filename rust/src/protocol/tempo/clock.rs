//! The per-process logical clock and promise generation
//! (paper Algorithm 1, functions `proposal` and `bump`).

use super::promises::PromiseSet;
use crate::core::Dot;

/// Logical clock that mints timestamp proposals and records the promises
/// it gives up along the way. Generated promises accumulate in an outbox
/// ([`Clock::take_outbox`]) which the protocol drains into `MPromises` /
/// `MProposeAck` / `MCommit` messages.
#[derive(Clone, Debug, Default)]
pub struct Clock {
    value: u64,
    outbox: PromiseSet,
}

impl Clock {
    pub fn value(&self) -> u64 {
        self.value
    }

    /// `proposal(id, m)`: propose `t = max(m, Clock+1)` for command `id`,
    /// generating detached promises for the skipped range
    /// `Clock+1 ..= t-1` and the attached promise `⟨i, t⟩`.
    pub fn proposal(&mut self, id: Dot, m: u64) -> u64 {
        let t = m.max(self.value + 1);
        if self.value + 1 <= t - 1 {
            self.outbox.detached.push((self.value + 1, t - 1));
        }
        self.outbox.attached.push((id, t));
        self.value = t;
        t
    }

    /// `bump(t)`: advance the clock to `max(t, Clock)`, generating
    /// detached promises for the entire skipped range `Clock+1 ..= t`.
    pub fn bump(&mut self, t: u64) {
        let t = t.max(self.value);
        if self.value + 1 <= t {
            self.outbox.detached.push((self.value + 1, t));
        }
        self.value = t;
    }

    /// Drain promises generated since the last call.
    pub fn take_outbox(&mut self) -> PromiseSet {
        std::mem::take(&mut self.outbox)
    }

    pub fn outbox_is_empty(&self) -> bool {
        self.outbox.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ProcessId;

    fn dot(n: u64) -> Dot {
        Dot::new(ProcessId(0), n)
    }

    #[test]
    fn proposal_takes_max_of_clock_and_coordinator() {
        let mut c = Clock::default();
        // Table 1 d), process C: clock 1, coordinator proposal 6 → 6.
        c.bump(1);
        c.take_outbox();
        let t = c.proposal(dot(1), 6);
        assert_eq!(t, 6);
        let out = c.take_outbox();
        // Detached 2..=5 (four promises), attached ⟨C,6⟩.
        assert_eq!(out.detached, vec![(2, 5)]);
        assert_eq!(out.attached, vec![(dot(1), 6)]);
    }

    #[test]
    fn proposal_no_detached_when_bump_by_one() {
        let mut c = Clock::default();
        // Table 1 d), process B: clock 5, proposal m=6 → 6, no detached.
        c.bump(5);
        c.take_outbox();
        let t = c.proposal(dot(1), 6);
        assert_eq!(t, 6);
        let out = c.take_outbox();
        assert!(out.detached.is_empty());
        assert_eq!(out.attached, vec![(dot(1), 6)]);
    }

    #[test]
    fn proposal_above_coordinator_when_clock_ahead() {
        let mut c = Clock::default();
        // Table 1 a), process C: clock 10, coordinator 6 → proposes 11.
        c.bump(10);
        c.take_outbox();
        let t = c.proposal(dot(1), 6);
        assert_eq!(t, 11);
        assert_eq!(c.value(), 11);
    }

    #[test]
    fn bump_generates_detached_range_inclusive() {
        let mut c = Clock::default();
        c.bump(4);
        let out = c.take_outbox();
        assert_eq!(out.detached, vec![(1, 4)]);
        // bump below the clock is a no-op
        c.bump(2);
        assert!(c.outbox_is_empty());
        assert_eq!(c.value(), 4);
    }

    #[test]
    fn sequence_of_proposals_is_strictly_increasing() {
        let mut c = Clock::default();
        let mut last = 0;
        for i in 1..100 {
            let t = c.proposal(dot(i), if i % 3 == 0 { last + 5 } else { 0 });
            assert!(t > last);
            last = t;
        }
        // Every timestamp 1..=last is promised exactly once (attached or
        // detached): union of outbox ranges must be 1..=last w/o overlap.
        let out = c.take_outbox();
        let mut covered: Vec<u64> = Vec::new();
        for (lo, hi) in out.detached {
            covered.extend(lo..=hi);
        }
        covered.extend(out.attached.iter().map(|&(_, t)| t));
        covered.sort_unstable();
        let expect: Vec<u64> = (1..=last).collect();
        assert_eq!(covered, expect, "promise ranges must tile 1..=Clock");
    }
}
