//! Promise tracking and stability detection (paper §3.2).
//!
//! A *promise* `⟨j, u⟩` says process `j` will never (again) propose
//! timestamp `u`. Promises *attached* to a command additionally carry the
//! command's identifier and are only incorporated once that command is
//! committed locally (Algorithm 2, line 47) — that gating is what makes
//! Theorem 1 sound. Timestamp `t` is *stable* once a majority of processes
//! have all their promises up to `t` known (Theorem 1).
//!
//! Promises from one process are dense ranges in practice (clocks only move
//! forward), so we track a contiguous watermark plus a sparse set of
//! out-of-order values — `highest_contiguous_promise` is then O(1).

use crate::core::{Dot, ProcessId};
use std::collections::{BTreeSet, HashMap};

/// Set of known promises from a single source process.
#[derive(Clone, Debug, Default)]
pub struct SourceTracker {
    /// All promises `1..=watermark` are present.
    watermark: u64,
    /// Promises above the watermark, not yet contiguous.
    above: BTreeSet<u64>,
}

impl SourceTracker {
    /// `highest_contiguous_promise(j)` of Algorithm 2.
    #[inline]
    pub fn highest_contiguous(&self) -> u64 {
        self.watermark
    }

    /// Add a single promise.
    pub fn add(&mut self, u: u64) {
        if u <= self.watermark {
            return;
        }
        if u == self.watermark + 1 {
            self.watermark = u;
            self.drain_contiguous();
        } else {
            self.above.insert(u);
        }
    }

    /// Add the inclusive promise range `lo..=hi` (no-op if `lo > hi`).
    pub fn add_range(&mut self, lo: u64, hi: u64) {
        if lo > hi {
            return;
        }
        if lo <= self.watermark + 1 {
            if hi > self.watermark {
                self.watermark = hi;
                self.drain_contiguous();
            }
        } else {
            self.above.extend(lo..=hi);
        }
    }

    fn drain_contiguous(&mut self) {
        while self.above.remove(&(self.watermark + 1)) {
            self.watermark += 1;
        }
        // Values at or below the watermark are redundant; drop them.
        if let Some(&min) = self.above.iter().next() {
            if min <= self.watermark {
                self.above = self.above.split_off(&(self.watermark + 1));
            }
        }
    }

    /// Number of promises buffered out of order (diagnostics).
    pub fn pending(&self) -> usize {
        self.above.len()
    }
}

/// A batch of promises from one process, as shipped in `MPromises`,
/// `MProposeAck` and `MCommit` messages.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PromiseSet {
    /// Detached promise ranges (inclusive).
    pub detached: Vec<(u64, u64)>,
    /// Attached promises: (command, timestamp).
    pub attached: Vec<(Dot, u64)>,
}

impl PromiseSet {
    pub fn is_empty(&self) -> bool {
        self.detached.is_empty() && self.attached.is_empty()
    }

    pub fn merge(&mut self, other: &PromiseSet) {
        self.detached.extend_from_slice(&other.detached);
        self.attached.extend_from_slice(&other.attached);
    }

    /// Coalesce overlapping/adjacent detached ranges and dedup attached
    /// promises (keeps long-lived promise histories compact).
    pub fn coalesce(&mut self) {
        if self.detached.len() > 1 {
            self.detached.sort_unstable();
            let mut merged: Vec<(u64, u64)> = Vec::with_capacity(self.detached.len());
            for &(lo, hi) in &self.detached {
                if lo > hi {
                    continue;
                }
                match merged.last_mut() {
                    Some((_, mhi)) if lo <= mhi.saturating_add(1) => {
                        *mhi = (*mhi).max(hi);
                    }
                    _ => merged.push((lo, hi)),
                }
            }
            self.detached = merged;
        }
        self.attached.sort_unstable();
        self.attached.dedup();
    }
}

/// All promises known at one process for its partition, with the
/// commit-gating required by Algorithm 2 line 47.
#[derive(Clone, Debug, Default)]
pub struct PromiseStore {
    trackers: HashMap<ProcessId, SourceTracker>,
    /// Attached promises whose command is not yet committed locally:
    /// dot → (source, timestamp) pairs.
    gated: HashMap<Dot, Vec<(ProcessId, u64)>>,
}

impl PromiseStore {
    /// Incorporate a batch from `source`. `is_committed` reports whether a
    /// dot is locally committed or executed; non-committed attached
    /// promises are gated until [`Self::on_commit`].
    /// Returns the dots of gated attached promises (candidates for
    /// MCommitRequest, §B liveness).
    pub fn add(
        &mut self,
        source: ProcessId,
        batch: &PromiseSet,
        mut is_committed: impl FnMut(Dot) -> bool,
    ) -> Vec<Dot> {
        let tracker = self.trackers.entry(source).or_default();
        for &(lo, hi) in &batch.detached {
            tracker.add_range(lo, hi);
        }
        let mut unknown = Vec::new();
        for &(dot, u) in &batch.attached {
            if is_committed(dot) {
                self.trackers.entry(source).or_default().add(u);
            } else {
                self.gated.entry(dot).or_default().push((source, u));
                unknown.push(dot);
            }
        }
        unknown
    }

    /// Release promises gated on `dot` (call when `dot` commits locally).
    pub fn on_commit(&mut self, dot: Dot) {
        if let Some(pairs) = self.gated.remove(&dot) {
            for (source, u) in pairs {
                self.trackers.entry(source).or_default().add(u);
            }
        }
    }

    /// Highest contiguous promise of `source`.
    pub fn highest_contiguous(&self, source: ProcessId) -> u64 {
        self.trackers.get(&source).map_or(0, |t| t.highest_contiguous())
    }

    /// The stable watermark over `processes`: the largest `s` such that
    /// all promises up to `s` are known from at least `majority` of them —
    /// i.e. the `⌊r/2⌋`-indexed order statistic of Algorithm 2 line 50,
    /// generalized to an arbitrary majority size.
    pub fn stable_watermark(&self, processes: &[ProcessId], majority: usize) -> u64 {
        debug_assert!(majority >= 1 && majority <= processes.len());
        let mut h: Vec<u64> = processes.iter().map(|p| self.highest_contiguous(*p)).collect();
        h.sort_unstable();
        // `majority` processes have watermark >= h[len - majority].
        h[h.len() - majority]
    }

    /// Dots with gated (attached) promises — commands other processes have
    /// proposed for but we have not committed (used by §B liveness).
    pub fn gated_dots(&self) -> impl Iterator<Item = Dot> + '_ {
        self.gated.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const P: [ProcessId; 3] = [ProcessId(0), ProcessId(1), ProcessId(2)];

    #[test]
    fn source_tracker_contiguity() {
        let mut t = SourceTracker::default();
        t.add(1);
        t.add(2);
        assert_eq!(t.highest_contiguous(), 2);
        t.add(5); // gap at 3,4
        assert_eq!(t.highest_contiguous(), 2);
        assert_eq!(t.pending(), 1);
        t.add_range(3, 4);
        assert_eq!(t.highest_contiguous(), 5);
        assert_eq!(t.pending(), 0);
    }

    #[test]
    fn source_tracker_overlapping_ranges_and_duplicates() {
        let mut t = SourceTracker::default();
        t.add_range(1, 10);
        t.add_range(5, 8); // fully contained
        t.add(3); // duplicate
        assert_eq!(t.highest_contiguous(), 10);
        t.add_range(15, 20);
        t.add_range(8, 14); // bridges the gap, overlapping both sides
        assert_eq!(t.highest_contiguous(), 20);
        t.add_range(7, 3); // inverted range is a no-op
        assert_eq!(t.highest_contiguous(), 20);
    }

    #[test]
    fn source_tracker_random_insertion_order_converges() {
        let mut r = Rng::new(42);
        for _ in 0..50 {
            let mut vals: Vec<u64> = (1..=200).collect();
            r.shuffle(&mut vals);
            let mut t = SourceTracker::default();
            for v in vals {
                t.add(v);
            }
            assert_eq!(t.highest_contiguous(), 200);
            assert_eq!(t.pending(), 0);
        }
    }

    #[test]
    fn attached_promises_gated_until_commit() {
        // Figure 2 / Theorem 1 mechanics: an attached promise must not
        // contribute to stability before its command commits locally.
        let mut s = PromiseStore::default();
        let dot = Dot::new(ProcessId(1), 1);
        let batch = PromiseSet { detached: vec![(1, 1)], attached: vec![(dot, 2)] };
        let unknown = s.add(ProcessId(1), &batch, |_| false);
        assert_eq!(unknown, vec![dot]);
        assert_eq!(s.highest_contiguous(ProcessId(1)), 1); // only the detached one
        s.on_commit(dot);
        assert_eq!(s.highest_contiguous(ProcessId(1)), 2);
    }

    #[test]
    fn stable_watermark_is_majority_order_statistic() {
        // Figure 2 of the paper: r=3, watermarks {A:2, B:3, C:2} → stable 2.
        let mut s = PromiseStore::default();
        s.add(P[0], &PromiseSet { detached: vec![(1, 2)], attached: vec![] }, |_| true);
        s.add(P[1], &PromiseSet { detached: vec![(1, 3)], attached: vec![] }, |_| true);
        s.add(P[2], &PromiseSet { detached: vec![(1, 2)], attached: vec![] }, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 3 - 1); // majority of 2 → 2... see below
        // majority=2 → second-highest watermark = 2
        assert_eq!(s.stable_watermark(&P, 2), 2);
        // unanimity (majority=3) → min = 2
        assert_eq!(s.stable_watermark(&P, 3), 2);
        // single process (majority=1) → max = 3
        assert_eq!(s.stable_watermark(&P, 1), 3);
    }

    #[test]
    fn stable_watermark_missing_source_counts_as_zero() {
        let mut s = PromiseStore::default();
        s.add(P[0], &PromiseSet { detached: vec![(1, 5)], attached: vec![] }, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 0);
    }

    #[test]
    fn figure2_example_from_paper() {
        // Promises: X = {A:1..2}, Y = {B:1..3, A:2? ...}. We reproduce the
        // table on the right of Figure 2 with the three listed sets:
        //   X = all promises up to 2 by A
        //   Y = promise 2 by A missing 1; all up to 3 by B  (we model Y as
        //       B:1..3 plus A:2 out-of-order)
        //   Z = all promises up to 2 by C
        let xs = PromiseSet { detached: vec![(1, 2)], attached: vec![] }; // A
        let ys_b = PromiseSet { detached: vec![(1, 3)], attached: vec![] }; // B
        let ys_a = PromiseSet { detached: vec![(2, 2)], attached: vec![] }; // A (sparse)
        let zs = PromiseSet { detached: vec![(1, 2)], attached: vec![] }; // C

        // Y ∪ Z → stable 2 (majority {B, C}).
        let mut s = PromiseStore::default();
        s.add(P[1], &ys_b, |_| true);
        s.add(P[0], &ys_a, |_| true);
        s.add(P[2], &zs, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 2);

        // Y alone → stable 0 (no majority has contiguous promises).
        let mut s = PromiseStore::default();
        s.add(P[1], &ys_b, |_| true);
        s.add(P[0], &ys_a, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 0);

        // X ∪ Y → A becomes contiguous to 2, B to 3 → stable 2.
        let mut s = PromiseStore::default();
        s.add(P[0], &xs, |_| true);
        s.add(P[0], &ys_a, |_| true);
        s.add(P[1], &ys_b, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 2);

        // X ∪ Y ∪ Z → stable 2 (not 3: only B reaches 3).
        let mut s = PromiseStore::default();
        s.add(P[0], &xs, |_| true);
        s.add(P[0], &ys_a, |_| true);
        s.add(P[1], &ys_b, |_| true);
        s.add(P[2], &zs, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 2);
    }

    #[test]
    fn gated_dots_visible_for_liveness() {
        let mut s = PromiseStore::default();
        let dot = Dot::new(ProcessId(2), 7);
        s.add(P[1], &PromiseSet { detached: vec![], attached: vec![(dot, 4)] }, |_| false);
        assert_eq!(s.gated_dots().collect::<Vec<_>>(), vec![dot]);
        s.on_commit(dot);
        assert_eq!(s.gated_dots().count(), 0);
    }
}
