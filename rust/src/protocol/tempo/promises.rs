//! Promise tracking and stability detection (paper §3.2).
//!
//! A *promise* `⟨j, u⟩` says process `j` will never (again) propose
//! timestamp `u`. Promises *attached* to a command additionally carry the
//! command's identifier and are only incorporated once that command is
//! committed locally (Algorithm 2, line 47) — that gating is what makes
//! Theorem 1 sound. Timestamp `t` is *stable* once a majority of processes
//! have all their promises up to `t` known (Theorem 1).
//!
//! The frontier/order-statistic kernel lives in
//! [`crate::protocol::common::stability`], shared with the GC tracker and
//! the batched runtime kernel; this module adds the commit gating and the
//! *incremental* majority watermark: [`PromiseStore::watermark`] is an
//! O(1) read updated on add/commit deltas, replacing the seed's
//! collect-and-sort scan on every dirty pass.

use crate::core::{Dot, ProcessId};
use crate::protocol::common::stability::{majority_watermark, QuorumFrontier};
use std::collections::{HashMap, HashSet};

pub use crate::protocol::common::stability::SourceTracker;

/// A batch of promises from one process, as shipped in `MPromises`,
/// `MProposeAck` and `MCommit` messages.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PromiseSet {
    /// Detached promise ranges (inclusive).
    pub detached: Vec<(u64, u64)>,
    /// Attached promises: (command, timestamp).
    pub attached: Vec<(Dot, u64)>,
}

/// Merge auto-compaction granularity: [`PromiseSet::merge`] coalesces the
/// set whenever the detached range list crosses a multiple of this many
/// fragments. Long-lived promise histories (the §B full re-broadcast set)
/// therefore stay compact even when callers never invoke
/// [`PromiseSet::coalesce`] — without it a history merged once per tick
/// grew by one fragment per delta forever.
const AUTO_COALESCE_FRAGMENTS: usize = 32;

impl PromiseSet {
    pub fn is_empty(&self) -> bool {
        self.detached.is_empty() && self.attached.is_empty()
    }

    /// Fold `other` into this set. Self-compacting: a merge that crosses
    /// a multiple of [`AUTO_COALESCE_FRAGMENTS`] triggers
    /// [`PromiseSet::coalesce`], so merge-heavy call sites stay O(live
    /// ranges) without calling it themselves. Firing on boundary
    /// *crossings* (not on size alone) keeps the cost amortized: a set
    /// whose ranges are genuinely disjoint (incompressible) pays the
    /// O(n log n) sort once per 32 merges, not on every merge, while the
    /// list stays within one granule of its live size.
    pub fn merge(&mut self, other: &PromiseSet) {
        let before = self.detached.len();
        self.detached.extend_from_slice(&other.detached);
        self.attached.extend_from_slice(&other.attached);
        let after = self.detached.len();
        if after >= AUTO_COALESCE_FRAGMENTS
            && after / AUTO_COALESCE_FRAGMENTS > before / AUTO_COALESCE_FRAGMENTS
        {
            self.coalesce();
        }
    }

    /// Coalesce overlapping/adjacent detached ranges and dedup attached
    /// promises (keeps long-lived promise histories compact).
    pub fn coalesce(&mut self) {
        if self.detached.len() > 1 {
            self.detached.sort_unstable();
            let mut merged: Vec<(u64, u64)> = Vec::with_capacity(self.detached.len());
            for &(lo, hi) in &self.detached {
                if lo > hi {
                    continue;
                }
                match merged.last_mut() {
                    Some((_, mhi)) if lo <= mhi.saturating_add(1) => {
                        *mhi = (*mhi).max(hi);
                    }
                    _ => merged.push((lo, hi)),
                }
            }
            self.detached = merged;
        }
        self.attached.sort_unstable();
        self.attached.dedup();
    }

    /// Convert the attached promises of group-wide-executed commands into
    /// detached ranges (GC): once everyone executed a command, receivers
    /// no longer need its commit gating, and the promise history stops
    /// referencing the pruned dot.
    pub fn detach_executed(&mut self, executed: &HashSet<Dot>) {
        if self.attached.is_empty() {
            return;
        }
        let before = self.attached.len();
        let mut detached = std::mem::take(&mut self.detached);
        self.attached.retain(|&(d, t)| {
            if executed.contains(&d) {
                detached.push((t, t));
                false
            } else {
                true
            }
        });
        self.detached = detached;
        // Most keys hold none of the pruned dots: skip the sort then.
        if self.attached.len() != before {
            self.coalesce();
        }
    }
}

/// All promises known at one process for its partition, with the
/// commit-gating required by Algorithm 2 line 47.
#[derive(Clone, Debug, Default)]
pub struct PromiseStore {
    trackers: HashMap<ProcessId, SourceTracker>,
    /// Attached promises whose command is not yet committed locally:
    /// dot → (source, timestamp) pairs.
    gated: HashMap<Dot, Vec<(ProcessId, u64)>>,
    /// Incrementally maintained majority watermark (configure through
    /// [`Self::init_quorum`]); [`Self::stable_watermark`] remains as the
    /// scan-based reference/diagnostic path.
    quorum: QuorumFrontier,
}

impl PromiseStore {
    /// Configure the incremental watermark over `processes`/`majority`.
    /// Existing tracker state (if any) seeds the frontier.
    pub fn init_quorum(&mut self, processes: &[ProcessId], majority: usize) {
        let mut q = QuorumFrontier::new(processes, majority);
        for (&p, t) in &self.trackers {
            q.update(p, t.highest_contiguous());
        }
        self.quorum = q;
    }

    /// Incorporate a batch from `source`. `is_committed` reports whether a
    /// dot is locally committed or executed; non-committed attached
    /// promises are gated until [`Self::on_commit`].
    /// Returns the dots of gated attached promises (candidates for
    /// MCommitRequest, §B liveness).
    pub fn add(
        &mut self,
        source: ProcessId,
        batch: &PromiseSet,
        mut is_committed: impl FnMut(Dot) -> bool,
    ) -> Vec<Dot> {
        let tracker = self.trackers.entry(source).or_default();
        for &(lo, hi) in &batch.detached {
            tracker.add_range(lo, hi);
        }
        let mut unknown = Vec::new();
        for &(dot, u) in &batch.attached {
            if is_committed(dot) {
                self.trackers.entry(source).or_default().add(u);
            } else {
                self.gated.entry(dot).or_default().push((source, u));
                unknown.push(dot);
            }
        }
        let frontier = self.highest_contiguous(source);
        self.quorum.update(source, frontier);
        unknown
    }

    /// Release promises gated on `dot` (call when `dot` commits locally).
    pub fn on_commit(&mut self, dot: Dot) {
        if let Some(pairs) = self.gated.remove(&dot) {
            for (source, u) in pairs {
                let tracker = self.trackers.entry(source).or_default();
                tracker.add(u);
                let frontier = tracker.highest_contiguous();
                self.quorum.update(source, frontier);
            }
        }
    }

    /// Highest contiguous promise of `source`.
    pub fn highest_contiguous(&self, source: ProcessId) -> u64 {
        self.trackers.get(&source).map_or(0, |t| t.highest_contiguous())
    }

    /// The incrementally maintained majority watermark: O(1). Returns 0
    /// until [`Self::init_quorum`] configured the source set.
    #[inline]
    pub fn watermark(&self) -> u64 {
        self.quorum.watermark()
    }

    /// The stable watermark over `processes`, computed by scan: the largest
    /// `s` such that all promises up to `s` are known from at least
    /// `majority` of them — Algorithm 2 line 50. Reference/diagnostic path;
    /// the hot path reads [`Self::watermark`].
    pub fn stable_watermark(&self, processes: &[ProcessId], majority: usize) -> u64 {
        debug_assert!(majority >= 1 && majority <= processes.len());
        let mut h: Vec<u64> = processes.iter().map(|p| self.highest_contiguous(*p)).collect();
        majority_watermark(&mut h, majority)
    }

    /// Dots with gated (attached) promises — commands other processes have
    /// proposed for but we have not committed (used by §B liveness).
    pub fn gated_dots(&self) -> impl Iterator<Item = Dot> + '_ {
        self.gated.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const P: [ProcessId; 3] = [ProcessId(0), ProcessId(1), ProcessId(2)];

    #[test]
    fn attached_promises_gated_until_commit() {
        // Figure 2 / Theorem 1 mechanics: an attached promise must not
        // contribute to stability before its command commits locally.
        let mut s = PromiseStore::default();
        let dot = Dot::new(ProcessId(1), 1);
        let batch = PromiseSet { detached: vec![(1, 1)], attached: vec![(dot, 2)] };
        let unknown = s.add(ProcessId(1), &batch, |_| false);
        assert_eq!(unknown, vec![dot]);
        assert_eq!(s.highest_contiguous(ProcessId(1)), 1); // only the detached one
        s.on_commit(dot);
        assert_eq!(s.highest_contiguous(ProcessId(1)), 2);
    }

    #[test]
    fn stable_watermark_is_majority_order_statistic() {
        // Figure 2 of the paper: r=3, watermarks {A:2, B:3, C:2} → stable 2.
        let mut s = PromiseStore::default();
        s.add(P[0], &PromiseSet { detached: vec![(1, 2)], attached: vec![] }, |_| true);
        s.add(P[1], &PromiseSet { detached: vec![(1, 3)], attached: vec![] }, |_| true);
        s.add(P[2], &PromiseSet { detached: vec![(1, 2)], attached: vec![] }, |_| true);
        // majority=2 → second-highest watermark = 2
        assert_eq!(s.stable_watermark(&P, 2), 2);
        // unanimity (majority=3) → min = 2
        assert_eq!(s.stable_watermark(&P, 3), 2);
        // single process (majority=1) → max = 3
        assert_eq!(s.stable_watermark(&P, 1), 3);
    }

    #[test]
    fn stable_watermark_missing_source_counts_as_zero() {
        let mut s = PromiseStore::default();
        s.add(P[0], &PromiseSet { detached: vec![(1, 5)], attached: vec![] }, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 0);
    }

    #[test]
    fn incremental_watermark_matches_scan_through_gating() {
        let mut s = PromiseStore::default();
        s.init_quorum(&P, 2);
        let dot = Dot::new(ProcessId(2), 9);
        s.add(P[0], &PromiseSet { detached: vec![(1, 3)], attached: vec![] }, |_| true);
        s.add(P[1], &PromiseSet { detached: vec![(1, 1)], attached: vec![(dot, 2)] }, |_| false);
        // Gated attached promise must not advance the cached watermark.
        assert_eq!(s.watermark(), 1);
        assert_eq!(s.watermark(), s.stable_watermark(&P, 2));
        s.on_commit(dot);
        assert_eq!(s.watermark(), 2);
        assert_eq!(s.watermark(), s.stable_watermark(&P, 2));
    }

    #[test]
    fn init_quorum_seeds_from_existing_trackers() {
        let mut s = PromiseStore::default();
        s.add(P[0], &PromiseSet { detached: vec![(1, 4)], attached: vec![] }, |_| true);
        s.add(P[1], &PromiseSet { detached: vec![(1, 6)], attached: vec![] }, |_| true);
        assert_eq!(s.watermark(), 0, "unconfigured store reports 0");
        s.init_quorum(&P, 2);
        assert_eq!(s.watermark(), 4);
    }

    #[test]
    fn figure2_example_from_paper() {
        // Promises: X = {A:1..2}, Y = {B:1..3, A:2? ...}. We reproduce the
        // table on the right of Figure 2 with the three listed sets:
        //   X = all promises up to 2 by A
        //   Y = promise 2 by A missing 1; all up to 3 by B  (we model Y as
        //       B:1..3 plus A:2 out-of-order)
        //   Z = all promises up to 2 by C
        let xs = PromiseSet { detached: vec![(1, 2)], attached: vec![] }; // A
        let ys_b = PromiseSet { detached: vec![(1, 3)], attached: vec![] }; // B
        let ys_a = PromiseSet { detached: vec![(2, 2)], attached: vec![] }; // A (sparse)
        let zs = PromiseSet { detached: vec![(1, 2)], attached: vec![] }; // C

        // Y ∪ Z → stable 2 (majority {B, C}).
        let mut s = PromiseStore::default();
        s.add(P[1], &ys_b, |_| true);
        s.add(P[0], &ys_a, |_| true);
        s.add(P[2], &zs, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 2);

        // Y alone → stable 0 (no majority has contiguous promises).
        let mut s = PromiseStore::default();
        s.add(P[1], &ys_b, |_| true);
        s.add(P[0], &ys_a, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 0);

        // X ∪ Y → A becomes contiguous to 2, B to 3 → stable 2.
        let mut s = PromiseStore::default();
        s.add(P[0], &xs, |_| true);
        s.add(P[0], &ys_a, |_| true);
        s.add(P[1], &ys_b, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 2);

        // X ∪ Y ∪ Z → stable 2 (not 3: only B reaches 3).
        let mut s = PromiseStore::default();
        s.add(P[0], &xs, |_| true);
        s.add(P[0], &ys_a, |_| true);
        s.add(P[1], &ys_b, |_| true);
        s.add(P[2], &zs, |_| true);
        assert_eq!(s.stable_watermark(&P, 2), 2);
    }

    #[test]
    fn gated_dots_visible_for_liveness() {
        let mut s = PromiseStore::default();
        let dot = Dot::new(ProcessId(2), 7);
        s.add(P[1], &PromiseSet { detached: vec![], attached: vec![(dot, 4)] }, |_| false);
        assert_eq!(s.gated_dots().collect::<Vec<_>>(), vec![dot]);
        s.on_commit(dot);
        assert_eq!(s.gated_dots().count(), 0);
    }

    #[test]
    fn merge_auto_coalesces_growing_histories() {
        // Simulates the §B history path without any explicit coalesce():
        // one adjacent delta merged per tick. The fragment list must stay
        // below the auto-coalesce threshold instead of growing linearly.
        let mut history = PromiseSet::default();
        for i in 1..=10_000u64 {
            let delta = PromiseSet { detached: vec![(i, i)], attached: vec![] };
            history.merge(&delta);
            assert!(
                history.detached.len() <= AUTO_COALESCE_FRAGMENTS,
                "history fragmented: {} ranges after {i} merges",
                history.detached.len()
            );
        }
        // All 10k adjacent singletons collapse to one range in the end.
        history.coalesce();
        assert_eq!(history.detached, vec![(1, 10_000)]);
    }

    #[test]
    fn merge_auto_coalesce_preserves_disjoint_ranges() {
        // Genuinely disjoint ranges must survive auto-coalescing intact.
        let mut s = PromiseSet::default();
        for i in 0..100u64 {
            let lo = i * 10 + 1; // 1..=5, 11..=15, ... (real gaps in between)
            let delta = PromiseSet { detached: vec![(lo, lo + 4)], attached: vec![] };
            s.merge(&delta);
        }
        s.coalesce();
        assert_eq!(s.detached.len(), 100, "disjoint ranges must not be merged away");
        assert!(s.detached.iter().all(|&(lo, hi)| hi - lo == 4));
    }

    #[test]
    fn detach_executed_rewrites_history() {
        let d1 = Dot::new(ProcessId(0), 1);
        let d2 = Dot::new(ProcessId(0), 2);
        let mut ps = PromiseSet { detached: vec![(1, 2)], attached: vec![(d1, 3), (d2, 5)] };
        let executed: HashSet<Dot> = [d1].into_iter().collect();
        ps.detach_executed(&executed);
        // ⟨d1, 3⟩ became the detached range (3,3), coalesced into (1,3).
        assert_eq!(ps.detached, vec![(1, 3)]);
        assert_eq!(ps.attached, vec![(d2, 5)]);
    }

    #[test]
    fn random_interleavings_keep_cache_and_scan_agreeing() {
        let mut rng = Rng::new(0xD07);
        for _ in 0..20 {
            let mut s = PromiseStore::default();
            s.init_quorum(&P, 2);
            let mut pending: Vec<Dot> = Vec::new();
            for i in 0..200u64 {
                let src = P[rng.gen_range(3) as usize];
                if rng.gen_bool(0.7) {
                    let lo = rng.gen_range(40) + 1;
                    let batch = PromiseSet {
                        detached: vec![(lo, lo + rng.gen_range(6))],
                        attached: vec![],
                    };
                    s.add(src, &batch, |_| true);
                } else {
                    let dot = Dot::new(src, i + 1);
                    let batch = PromiseSet {
                        detached: vec![],
                        attached: vec![(dot, rng.gen_range(50) + 1)],
                    };
                    s.add(src, &batch, |_| false);
                    pending.push(dot);
                }
                if !pending.is_empty() && rng.gen_bool(0.5) {
                    let dot = pending.swap_remove(rng.gen_range(pending.len() as u64) as usize);
                    s.on_commit(dot);
                }
                assert_eq!(s.watermark(), s.stable_watermark(&P, 2));
            }
        }
    }
}
