//! Caesar [Arun et al., DSN'17]: leaderless SMR combining timestamps with
//! explicit dependencies — the paper's closest timestamp-based baseline
//! (§3.3 "Dependency-based stability", §6).
//!
//! A coordinator proposes a (unique) logical timestamp for its command to a
//! fast quorum of `⌈3r/4⌉` processes. A quorum member *blocks* its reply
//! while a conflicting command with a higher proposed timestamp is pending
//! (Caesar's wait condition — the source of the delays and of the §D
//! livelock); once unblocked it either ACKs with the conflicting
//! lower-timestamp commands as dependencies, or NACKs if a conflicting
//! command already committed with a higher timestamp, forcing a retry at a
//! higher timestamp (the slow path). Commands execute in timestamp order
//! once all their smaller-timestamp dependencies have executed.
//!
//! Broadcast, buffering (the wait condition reuses the shared stall buffer
//! keyed by the *blocking* command), command info and executed-command GC
//! come from [`crate::protocol::common`].
//!
//! Reproduction notes (DESIGN.md): ballots/recovery are not implemented
//! (the paper never crashes baseline processes), and the retry round
//! accepts unconditionally — both simplifications favour Caesar.

use super::common::{
    wire, BaseProcess, CommandsInfo, EpochManager, EpochProcess, GCTrack, GcProcess, Process,
};
use super::{Action, Footprint, Protocol};
use crate::core::{Command, Config, Dot, Key, ProcessId};
use crate::metrics::Counters;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::Arc;

/// Timestamps are made unique by pairing with the command identifier.
type Ts = (u64, Dot);

/// Dependency set as carried by the commit broadcast — `Arc`-backed so
/// the per-peer message clones of `MCommit` (sent to *every* process)
/// share one buffer instead of deep-copying an unbounded dep list.
pub type Deps = Arc<[Dot]>;

#[derive(Clone, Debug)]
pub enum Msg {
    MPropose { dot: Dot, cmd: Command, ts: u64 },
    MProposeAck { dot: Dot, ts: u64, deps: Vec<Dot> },
    MProposeNack { dot: Dot, higher_ts: u64 },
    MRetry { dot: Dot, cmd: Command, ts: u64 },
    MRetryAck { dot: Dot, ts: u64, deps: Vec<Dot> },
    MCommit { dot: Dot, cmd: Command, ts: u64, deps: Deps },
    /// Periodic GC exchange (`protocol::common::GCTrack`).
    MGarbageCollect { executed: Vec<(ProcessId, u64)> },
    /// Epoch reconfiguration vote (`protocol::common::epoch`).
    MEpoch { epoch: u64, evicted: Vec<ProcessId> },
    /// Batch frame (`protocol::common::batch`): several messages bound for
    /// the same destination; unbatched inside `Process::dispatch`.
    MBatch { msgs: Vec<Msg> },
}

impl super::common::BatchMsg for Msg {
    fn batch(msgs: Vec<Msg>) -> Msg {
        Msg::MBatch { msgs }
    }

    fn is_batch(&self) -> bool {
        matches!(self, Msg::MBatch { .. })
    }

    fn approx_wire_bytes(&self) -> u64 {
        self.wire_size()
    }
}

impl Msg {
    pub fn wire_size(&self) -> u64 {
        use wire::{dots, proc_vals, HDR};
        match self {
            Msg::MPropose { cmd, .. } | Msg::MRetry { cmd, .. } => HDR + cmd.wire_size() + 8,
            Msg::MCommit { cmd, deps, .. } => HDR + cmd.wire_size() + 8 + dots(deps.len()),
            Msg::MProposeAck { deps, .. } | Msg::MRetryAck { deps, .. } => {
                HDR + 8 + dots(deps.len())
            }
            Msg::MProposeNack { .. } => HDR + 16,
            Msg::MGarbageCollect { executed } => HDR + proc_vals(executed.len()),
            Msg::MEpoch { evicted, .. } => HDR + 8 + 4 * evicted.len() as u64,
            Msg::MBatch { msgs } => {
                HDR + msgs.iter().map(|m| 4 + m.wire_size()).sum::<u64>()
            }
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Pending,
    Committed,
    Executed,
}

#[derive(Clone, Debug)]
struct Info {
    phase: Phase,
    cmd: Command,
    ts: u64,
    deps: Vec<Dot>,
    /// Coordinator bookkeeping. Acks are a *voter set*, not a counter:
    /// nemesis-duplicated (or retransmitted) replies must not complete a
    /// quorum twice over.
    coordinator: bool,
    ack_from: BTreeSet<ProcessId>,
    ack_deps: BTreeSet<Dot>,
    nack_ts: u64,
    nacked: bool,
    retrying: bool,
    decided: bool,
}

/// One known proposal on a key (for the wait condition and dependencies).
#[derive(Clone, Copy, Debug)]
struct KeyEntry {
    ts: u64,
    committed: bool,
}

pub struct Caesar {
    bp: BaseProcess<Msg>,
    clock: u64,
    info: CommandsInfo<Info>,
    /// Per-key: commands seen (proposals and commits) with their latest ts.
    /// GC removes group-wide-executed commands from these tables.
    seen: HashMap<Key, BTreeMap<Dot, KeyEntry>>,
    /// Committed-unexecuted commands ordered by ⟨ts, dot⟩.
    exec_queue: BTreeMap<Ts, ()>,
    /// Executor retry index: dependency → committed commands waiting on it
    /// (§Perf: avoids rescanning the whole queue per event).
    exec_blocked: HashMap<Dot, Vec<Dot>>,
    gc: GCTrack,
    /// Epoch reconfiguration: eviction votes, installed history, fencing.
    epochs: EpochManager,
    /// Coordinator dots awaiting quorum — re-proposed every
    /// `retry_interval_ticks` ticks so dropped links heal.
    retry_pending: BTreeSet<Dot>,
    /// Coordinator dots committed but not yet group-wide pruned — their
    /// MCommit is re-broadcast on the same cadence.
    retry_commits: BTreeSet<Dot>,
    ticks: u64,
    pub counters: Counters,
}

impl Caesar {
    fn fast_quorum(&self) -> Vec<ProcessId> {
        let size = self.bp.config.caesar_fast_quorum_size();
        let k0 = self.bp.id.0;
        (0..size as u32)
            .map(|d| ProcessId((k0 + d) % self.bp.config.r as u32))
            .collect()
    }

    fn all(&self) -> Vec<ProcessId> {
        (0..self.bp.config.r as u32).map(ProcessId).collect()
    }

    /// Conflicting commands seen on the keys of `cmd`.
    fn conflicts(&self, cmd: &Command) -> Vec<(Dot, KeyEntry)> {
        let mut out = Vec::new();
        for k in cmd.keys.iter() {
            if let Some(m) = self.seen.get(k) {
                out.extend(m.iter().map(|(d, e)| (*d, *e)));
            }
        }
        out.sort_unstable_by_key(|&(d, _)| d);
        out.dedup_by_key(|&mut (d, _)| d);
        out
    }

    fn register(&mut self, dot: Dot, cmd: &Command, ts: u64, committed: bool) {
        for &k in cmd.keys.iter() {
            self.seen.entry(k).or_default().insert(dot, KeyEntry { ts, committed });
        }
    }

    fn handle_propose(
        &mut self,
        from: ProcessId,
        dot: Dot,
        cmd: Command,
        ts: u64,
        _time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return;
        }
        // A retransmitted/duplicated MPropose must never downgrade the
        // conflict-table entry of an already-committed command.
        if self.info.get(&dot).is_some_and(|i| i.phase != Phase::Pending) {
            return;
        }
        self.clock = self.clock.max(ts);
        let conflicts = self.conflicts(&cmd);
        // Wait condition: a conflicting command with a *higher* proposed
        // timestamp is still pending → block the reply until it commits
        // (§3.3; unbounded in §D). The reply is parked in the shared stall
        // buffer keyed by the blocking command.
        if let Some(&(blocking, _)) = conflicts
            .iter()
            .find(|(d, e)| !e.committed && (e.ts, *d) > (ts, dot) && *d != dot)
        {
            self.stall(blocking, from, Msg::MPropose { dot, cmd, ts });
            return;
        }
        // NACK if a conflicting command *committed* with a higher timestamp:
        // `ts` can no longer be honored.
        let committed_higher = conflicts
            .iter()
            .filter(|(d, e)| e.committed && (e.ts, *d) > (ts, dot) && *d != dot)
            .map(|(_, e)| e.ts)
            .max();
        if let Some(h) = committed_higher {
            self.register(dot, &cmd, ts, false);
            out.push(Action::send(from, Msg::MProposeNack { dot, higher_ts: h }));
            return;
        }
        // ACK with the smaller-timestamp conflicts as dependencies.
        let deps: Vec<Dot> = conflicts
            .iter()
            .filter(|(d, e)| (e.ts, *d) < (ts, dot) && *d != dot)
            .map(|(d, _)| *d)
            .collect();
        self.register(dot, &cmd, ts, false);
        out.push(Action::send(from, Msg::MProposeAck { dot, ts, deps }));
    }

    fn try_decide(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        let quorum = self.bp.config.caesar_fast_quorum_size();
        let decision = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if !info.coordinator || info.decided || info.phase != Phase::Pending {
                return;
            }
            if info.ack_from.is_empty() && !info.nacked {
                return;
            }
            if info.nacked {
                // Slow path: retry at a timestamp above every conflict.
                if info.retrying {
                    return;
                }
                info.retrying = true;
                Some((false, info.cmd.clone(), info.nack_ts))
            } else if info.ack_from.len() >= quorum {
                info.decided = true;
                Some((true, info.cmd.clone(), info.ts))
            } else {
                None
            }
        };
        match decision {
            Some((true, cmd, ts)) => {
                self.counters.fast_path += 1;
                let deps: Deps =
                    self.info[&dot].ack_deps.iter().copied().collect::<Vec<_>>().into();
                let targets = self.all();
                self.broadcast(&targets, Msg::MCommit { dot, cmd, ts, deps }, time, out);
            }
            Some((false, cmd, nack_ts)) => {
                self.counters.slow_path += 1;
                self.clock = self.clock.max(nack_ts) + 1;
                let ts = self.clock;
                {
                    let info = self.info.get_mut(&dot).unwrap();
                    info.ts = ts;
                    info.ack_from.clear();
                    info.ack_deps.clear();
                    info.nacked = false;
                }
                let q = self.fast_quorum();
                self.broadcast(&q, Msg::MRetry { dot, cmd, ts }, time, out);
            }
            None => {}
        }
    }

    fn handle_commit(
        &mut self,
        dot: Dot,
        cmd: Command,
        ts: u64,
        deps: Deps,
        out: &mut Vec<Action<Msg>>,
        time: u64,
    ) {
        if self.gc.was_executed(dot) {
            return;
        }
        let already = self.info.get(&dot).is_some_and(|i| i.phase != Phase::Pending);
        if already {
            return;
        }
        self.clock = self.clock.max(ts);
        self.register(dot, &cmd, ts, true);
        let info = self.info.ensure(dot, || Info {
            phase: Phase::Pending,
            cmd: cmd.clone(),
            ts,
            deps: Vec::new(),
            coordinator: false,
            ack_from: BTreeSet::new(),
            ack_deps: BTreeSet::new(),
            nack_ts: 0,
            nacked: false,
            retrying: false,
            decided: true,
        });
        info.phase = Phase::Committed;
        info.cmd = cmd;
        info.ts = ts;
        info.deps = deps.to_vec(); // one receipt-side copy, not one per peer
        if self.retry_pending.remove(&dot) {
            self.retry_commits.insert(dot);
        }
        self.exec_queue.insert((ts, dot), ());
        out.push(Action::Committed { dot, fast: true });
        // Unblock replies waiting on this command (wait condition).
        self.drain_stalled(dot, time, out);
        let mut queue = vec![dot];
        if let Some(waiters) = self.exec_blocked.remove(&dot) {
            queue.extend(waiters);
        }
        self.advance(queue, out);
    }

    /// Retransmission (opt-in via `config.retry_interval_ticks`): re-send
    /// the current round's proposal to quorum members that have not voted,
    /// and re-broadcast commits until group-wide pruning confirms receipt.
    /// Receivers are idempotent (duplicate proposals re-ack, duplicate
    /// commits are dropped) and the coordinator counts voter *sets*, so
    /// retransmission under nemesis duplication stays safe.
    fn retry_tick(&mut self, time: u64, out: &mut Vec<Action<Msg>>) {
        let every = self.bp.config.retry_interval_ticks;
        if every == 0 || self.ticks % every != 0 {
            return;
        }
        for dot in self.retry_pending.clone() {
            let (cmd, ts, retrying, acked) = match self.info.get(&dot) {
                Some(i) if i.coordinator && i.phase == Phase::Pending && !i.decided => {
                    (i.cmd.clone(), i.ts, i.retrying, i.ack_from.clone())
                }
                _ => {
                    self.retry_pending.remove(&dot);
                    continue;
                }
            };
            let targets: Vec<ProcessId> = self
                .fast_quorum()
                .into_iter()
                .filter(|p| *p != self.bp.id && !acked.contains(p))
                .collect();
            if targets.is_empty() {
                continue;
            }
            let msg = if retrying {
                Msg::MRetry { dot, cmd, ts }
            } else {
                Msg::MPropose { dot, cmd, ts }
            };
            self.counters.retransmits += 1;
            self.broadcast(&targets, msg, time, out);
        }
        for dot in self.retry_commits.clone() {
            let (cmd, ts, deps) = match self.info.get(&dot) {
                Some(i) if i.phase == Phase::Committed || i.phase == Phase::Executed => {
                    (i.cmd.clone(), i.ts, i.deps.clone())
                }
                _ => {
                    self.retry_commits.remove(&dot);
                    continue;
                }
            };
            let targets: Vec<ProcessId> =
                self.all().into_iter().filter(|p| *p != self.bp.id).collect();
            self.counters.retransmits += 1;
            self.broadcast(
                &targets,
                Msg::MCommit { dot, cmd, ts, deps: deps.into() },
                time,
                out,
            );
        }
    }

    /// Execute committed commands in ⟨ts, dot⟩ order; a command waits for
    /// its smaller-timestamp dependencies (timestamp stability through
    /// explicit dependencies — the delayed-execution mechanism of §3.3).
    /// Retries are indexed by the blocking dependency.
    fn advance(&mut self, mut queue: Vec<Dot>, out: &mut Vec<Action<Msg>>) {
        while let Some(dot) = queue.pop() {
            let (ts, executable, blocker) = {
                let info = match self.info.get(&dot) {
                    Some(i) if i.phase == Phase::Committed => i,
                    _ => continue,
                };
                let ts = info.ts;
                let mut blocker = None;
                for d in &info.deps {
                    // GC'd dependencies executed everywhere long ago.
                    if self.gc.was_executed(*d) {
                        continue;
                    }
                    match self.info.get(d) {
                        Some(di) if di.phase == Phase::Executed => {}
                        // A dependency committed with a *higher* timestamp
                        // does not precede us.
                        Some(di) if di.phase == Phase::Committed && (di.ts, *d) > (ts, dot) => {}
                        // Unknown/pending/smaller-ts dependency: wait on it.
                        _ => {
                            blocker = Some(*d);
                            break;
                        }
                    }
                }
                (ts, blocker.is_none(), blocker)
            };
            if let Some(b) = blocker {
                self.exec_blocked.entry(b).or_default().push(dot);
                continue;
            }
            if !executable {
                continue;
            }
            self.exec_queue.remove(&(ts, dot));
            let info = self.info.get_mut(&dot).unwrap();
            info.phase = Phase::Executed;
            self.gc.record_executed(dot);
            self.counters.executed += 1;
            let cmd = info.cmd.clone();
            out.push(Action::Execute { dot, cmd, ts });
            // Wake commands blocked on this one.
            if let Some(waiters) = self.exec_blocked.remove(&dot) {
                queue.extend(waiters);
            }
        }
    }
}

impl GcProcess for Caesar {
    fn gc_track(&mut self) -> &mut GCTrack {
        &mut self.gc
    }

    /// Prune info and conflict-table (`seen`) entries of commands every
    /// replica executed: they executed everywhere before any future
    /// conflicting proposal is acked, so they can never be needed as a
    /// dependency or wait-condition blocker again.
    fn prune_executed(&mut self) {
        for (origin, lo, hi) in self.gc.safe_to_prune() {
            for idx in lo..=hi {
                let dot = self.gc.dot_at(origin, idx);
                let keys: Vec<Key> =
                    self.info.get(&dot).map(|i| i.cmd.keys.to_vec()).unwrap_or_default();
                for k in keys {
                    let empty = if let Some(m) = self.seen.get_mut(&k) {
                        m.remove(&dot);
                        m.is_empty()
                    } else {
                        false
                    };
                    if empty {
                        self.seen.remove(&k);
                    }
                }
                if self.info.prune(&dot) {
                    self.counters.gc_pruned += 1;
                }
                self.exec_blocked.remove(&dot);
                self.retry_commits.remove(&dot);
                self.bp.drop_stalled(dot);
            }
        }
    }
}

impl Process for Caesar {
    type Msg = Msg;

    fn base(&self) -> &BaseProcess<Msg> {
        &self.bp
    }

    fn base_mut(&mut self) -> &mut BaseProcess<Msg> {
        &mut self.bp
    }

    fn dispatch(&mut self, from: ProcessId, msg: Msg, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        // Epoch fencing: drop messages from members the installed epoch
        // evicted (late by definition).
        if self.epochs.rejects(from) {
            return out;
        }
        match msg {
            Msg::MPropose { dot, cmd, ts } => {
                self.handle_propose(from, dot, cmd, ts, time, &mut out)
            }
            Msg::MProposeAck { dot, ts, deps } | Msg::MRetryAck { dot, ts, deps } => {
                let run = {
                    match self.info.get_mut(&dot) {
                        Some(info)
                            if info.coordinator
                                && info.phase == Phase::Pending
                                && info.ts == ts =>
                        {
                            info.ack_from.insert(from);
                            info.ack_deps.extend(deps);
                            true
                        }
                        _ => false,
                    }
                };
                if run {
                    self.try_decide(dot, time, &mut out);
                }
            }
            Msg::MProposeNack { dot, higher_ts } => {
                let run = {
                    match self.info.get_mut(&dot) {
                        // Late NACKs from the original round are ignored
                        // once the retry started (the retry round always
                        // accepts, so no further NACK can be pending).
                        Some(info)
                            if info.coordinator
                                && info.phase == Phase::Pending
                                && !info.retrying =>
                        {
                            info.nacked = true;
                            info.nack_ts = info.nack_ts.max(higher_ts);
                            true
                        }
                        _ => false,
                    }
                };
                if run {
                    self.try_decide(dot, time, &mut out);
                }
            }
            Msg::MRetry { dot, cmd, ts } => {
                if self.gc.was_executed(dot)
                    || self.info.get(&dot).is_some_and(|i| i.phase != Phase::Pending)
                {
                    return out;
                }
                // Retry round: accept unconditionally (simplification, see
                // module docs), reporting smaller-timestamp conflicts.
                self.clock = self.clock.max(ts);
                let deps: Vec<Dot> = self
                    .conflicts(&cmd)
                    .iter()
                    .filter(|(d, e)| (e.ts, *d) < (ts, dot) && *d != dot)
                    .map(|(d, _)| *d)
                    .collect();
                self.register(dot, &cmd, ts, false);
                out.push(Action::send(from, Msg::MRetryAck { dot, ts, deps }));
            }
            Msg::MCommit { dot, cmd, ts, deps } => {
                self.handle_commit(dot, cmd, ts, deps, &mut out, time)
            }
            Msg::MGarbageCollect { executed } => self.handle_garbage_collect(from, &executed),
            Msg::MEpoch { epoch, evicted } => self.handle_epoch(
                from,
                epoch,
                evicted,
                |epoch, evicted| Msg::MEpoch { epoch, evicted },
                &mut out,
            ),
            Msg::MBatch { msgs } => {
                for m in msgs {
                    let actions = self.dispatch(from, m, time);
                    out.extend(actions);
                }
            }
        }
        out
    }
}

impl EpochProcess for Caesar {
    fn epoch_mgr(&mut self) -> &mut EpochManager {
        &mut self.epochs
    }

    fn on_evicted(&mut self, member: ProcessId) {
        self.gc.evict(member);
        self.counters.evictions += 1;
    }
}

impl Protocol for Caesar {
    type Message = Msg;

    fn new(id: ProcessId, config: Config) -> Self {
        assert_eq!(config.shards, 1, "Caesar baseline is full-replication only");
        let bp = BaseProcess::new(id, config);
        let gc = GCTrack::strided(
            id,
            bp.group_procs.clone(),
            bp.config.worker,
            bp.config.workers,
        );
        let epochs =
            EpochManager::new(id, bp.group_procs.clone(), bp.config.epoch_fence_off);
        Caesar {
            bp,
            clock: 0,
            info: CommandsInfo::default(),
            seen: HashMap::new(),
            exec_queue: BTreeMap::new(),
            exec_blocked: HashMap::new(),
            gc,
            epochs,
            retry_pending: BTreeSet::new(),
            retry_commits: BTreeSet::new(),
            ticks: 0,
            counters: Counters::default(),
        }
    }

    fn name() -> &'static str {
        "caesar"
    }

    fn submit(&mut self, cmd: Command, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        let dot = self.bp.next_dot();
        out.push(Action::Submitted { dot });
        self.clock += 1;
        let ts = self.clock;
        self.info.insert(
            dot,
            Info {
                phase: Phase::Pending,
                cmd: cmd.clone(),
                ts,
                deps: Vec::new(),
                coordinator: true,
                ack_from: BTreeSet::new(),
                ack_deps: BTreeSet::new(),
                nack_ts: 0,
                nacked: false,
                retrying: false,
                decided: false,
            },
        );
        if self.bp.config.retry_interval_ticks > 0 {
            self.retry_pending.insert(dot);
        }
        let q = self.fast_quorum();
        self.broadcast(&q, Msg::MPropose { dot, cmd, ts }, time, &mut out);
        self.outbound(out, false, time)
    }

    fn handle(&mut self, from: ProcessId, msg: Msg, time: u64) -> Vec<Action<Msg>> {
        let out = self.dispatch(from, msg, time);
        self.outbound(out, false, time)
    }

    fn tick(&mut self, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        self.ticks += 1;
        let ticks = self.ticks;
        self.gc_tick(ticks, |executed| Msg::MGarbageCollect { executed }, &mut out);
        self.epoch_tick(|epoch, evicted| Msg::MEpoch { epoch, evicted }, &mut out);
        self.retry_tick(time, &mut out);
        self.outbound(out, true, time)
    }

    /// Caesar's whitelist watermark is not a read frontier: reads run
    /// through the full timestamp-consensus path (counted as slow reads),
    /// which serializes them after the session's writes — floor moot.
    fn submit_read(&mut self, cmd: Command, _floor: u64, time: u64) -> Vec<Action<Msg>> {
        self.counters.slow_reads += 1;
        self.submit(cmd, time)
    }

    fn crash(&mut self) {
        self.bp.crashed = true;
    }

    fn suspect(&mut self, p: ProcessId) {
        self.epochs.suspect(p);
    }

    fn epoch_view(&self) -> Vec<(u64, Vec<ProcessId>)> {
        self.epochs.history().to_vec()
    }

    fn counters(&self) -> Counters {
        let mut c = self.counters;
        self.bp.batcher.record_stats(&mut c);
        c
    }

    fn msg_size(msg: &Msg) -> u64 {
        msg.wire_size()
    }

    fn footprint(&self) -> Footprint {
        Footprint {
            infos: self.info.len(),
            keys: self.seen.len(),
            stalled: self.bp.stalled_len() + self.exec_blocked.len(),
            queued: self.bp.batcher.queued(),
            fragments: 0,
        }
    }
}
