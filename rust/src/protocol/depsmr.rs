//! Unified dependency-based leaderless SMR core: EPaxos [Moraru et al.,
//! SOSP'13], Atlas [Enes et al., EuroSys'20] and Janus* (§6: Atlas
//! generalized to partial replication, the paper's improved version of
//! Janus [Mu et al., OSDI'16]).
//!
//! The three protocols share the same structure and differ in
//! (a) fast-quorum size — EPaxos `⌊3r/4⌋`, Atlas/Janus* `⌊r/2⌋+f` — and
//! (b) fast-path condition — EPaxos: all dependency reports identical;
//! Atlas/Janus*: every dependency in the union reported by ≥ f quorum
//! members. Commands commit with explicit per-group dependency sets and
//! execute through the SCC graph executor (§3.3), which is precisely the
//! mechanism whose unbounded chains produce the tail latencies the paper
//! measures.
//!
//! Broadcast, stalled-message buffering, command info and executed-command
//! GC come from [`crate::protocol::common`] (shared with Tempo, Caesar and
//! FPaxos).
//!
//! Reproduction notes (see DESIGN.md): the slow path uses the Flexible
//! Paxos `f+1` quorum for all variants (favourable to EPaxos); Janus*
//! execution uses per-group dependency graphs plus a cross-group
//! readiness barrier in place of the full union-graph inquiry protocol —
//! faithful for transactions whose conflicts are per-key, which YCSB+T's
//! are.
//!
//! Recovery: one ballot-based prepare phase covers all three variants
//! (the Atlas recovery of arXiv 2003.11789 §4, structurally identical to
//! the Tempo §B port in [`crate::protocol::tempo`]). On a recovery
//! timeout the Ω leader claims the dot at a ballot it owns
//! (`protocol::ballot`), reads recorded dependency reports from a
//! recovery quorum of `r - f` (`MRecDep`/`MRecDepAck`, NAck-helped like
//! Tempo's `handle_rec_nack`), picks the highest accepted consensus
//! value if one exists — else reconstructs the committed union from
//! `I = Q_rec ∩ Q_fast` — and re-drives the dot through the ordinary
//! `MConsensus` slow path to commit. Safety of the union rule: every
//! fast-quorum report is extended with the initial coordinator's
//! dependencies (`handle_propose`), so a dependency committed on the
//! fast path that is missing from every `I` report would have to be
//! reported only by `FQ \ Q_rec` — at most `f` processes including the
//! initial coordinator — and anything the initial coordinator reported
//! is in *every* report, a contradiction.

use super::common::{
    wire, BaseProcess, CommandsInfo, EpochManager, EpochProcess, GCTrack, GcProcess, Process,
};
use super::{ballot, Action, Footprint, Protocol};
use crate::core::{key_to_shard, Command, Config, Dot, Key, Op, ProcessId, ShardId};
use crate::executor::DepGraph;
use crate::metrics::Counters;
use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

/// Which protocol this core instance implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    EPaxos,
    Atlas,
    Janus,
}

impl Variant {
    fn fast_quorum_size(self, config: &Config) -> usize {
        match self {
            // EPaxos fast quorums have ⌊3r/4⌋ processes (§6); never below a
            // majority so recovery intersections stay non-empty.
            Variant::EPaxos => config.epaxos_fast_quorum_size().max(config.majority()),
            Variant::Atlas | Variant::Janus => config.fast_quorum_size(),
        }
    }
}

/// Fast quorum mapping per accessed group. `Arc`-backed: it rides in the
/// payload fan-out (`MPropose`/`MPayload` to every group member), so
/// per-peer message clones share it instead of deep-copying.
pub type Quorums = Arc<[(ShardId, Vec<ProcessId>)]>;

/// A dependency set as carried by messages. Dependency sets are the bulk
/// of every `MCommit`/`MConsensus` broadcast (unbounded under contention,
/// §D), so messages share one `Arc` buffer across the fan-out; handlers
/// that mutate copy once on receipt, never once per peer.
pub type Deps = Arc<[Dot]>;

/// Per-command lifecycle (public because [`Msg::MRecDepAck`] carries it
/// as the recovery leader's fast-path-validity evidence).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Start,
    Payload,
    Propose,
    /// Recovery touched this replica before it saw the fast round: its
    /// dependency report was computed in the `MRecDep` handler, which
    /// invalidates the fast path (it never acked and, at a nonzero
    /// ballot, never will).
    RecoverR,
    /// Recovery touched this replica after it acked the fast round: its
    /// report is the one the initial coordinator may have committed on.
    RecoverP,
    Commit,
    Execute,
}

#[derive(Clone, Debug)]
pub enum Msg {
    MSubmit { dot: Dot, cmd: Command, quorums: Quorums },
    MPropose { dot: Dot, cmd: Command, quorums: Quorums, deps: Deps },
    MProposeAck { dot: Dot, deps: Deps },
    MPayload { dot: Dot, cmd: Command, quorums: Quorums },
    MCommit { dot: Dot, group: ShardId, deps: Deps },
    MConsensus { dot: Dot, deps: Deps, bal: u64 },
    MConsensusAck { dot: Dot, bal: u64 },
    /// Recovery prepare (Atlas §4 / Tempo MRec analogue): a recovery
    /// leader claims `dot` at ballot `bal` and asks the group for its
    /// recorded dependency reports.
    MRecDep { dot: Dot, bal: u64 },
    /// Prepare reply: the replier's recorded report, its phase (the
    /// leader's fast-path-validity evidence) and the ballot `abal` at
    /// which it last *accepted* a consensus value (0 = never).
    MRecDepAck { dot: Dot, deps: Deps, phase: Phase, abal: u64, bal: u64 },
    /// Prepare rejection carrying the replier's (higher) promised
    /// ballot, so the leader can help by retrying above it.
    MRecDepNAck { dot: Dot, bal: u64 },
    /// Janus* cross-group execution barrier: this group is ready to
    /// execute `dot` (its local dependency closure is committed).
    MReady { dot: Dot },
    /// Periodic GC exchange (`protocol::common::GCTrack`).
    MGarbageCollect { executed: Vec<(ProcessId, u64)> },
    /// Epoch reconfiguration vote (`protocol::common::epoch`).
    MEpoch { epoch: u64, evicted: Vec<ProcessId> },
    /// Batch frame (`protocol::common::batch`): several messages bound for
    /// the same destination; unbatched inside `Process::dispatch`.
    MBatch { msgs: Vec<Msg> },
}

impl super::common::BatchMsg for Msg {
    fn batch(msgs: Vec<Msg>) -> Msg {
        Msg::MBatch { msgs }
    }

    fn is_batch(&self) -> bool {
        matches!(self, Msg::MBatch { .. })
    }

    fn approx_wire_bytes(&self) -> u64 {
        self.wire_size()
    }
}

impl Msg {
    pub fn wire_size(&self) -> u64 {
        use wire::{dots, proc_vals, HDR};
        match self {
            Msg::MSubmit { cmd, .. } | Msg::MPayload { cmd, .. } => HDR + cmd.wire_size(),
            Msg::MPropose { cmd, deps, .. } => HDR + cmd.wire_size() + dots(deps.len()),
            Msg::MProposeAck { deps, .. }
            | Msg::MCommit { deps, .. }
            | Msg::MConsensus { deps, .. } => HDR + dots(deps.len()),
            // phase byte + two ballots on top of the dep set.
            Msg::MRecDepAck { deps, .. } => HDR + dots(deps.len()) + 17,
            Msg::MGarbageCollect { executed } => HDR + proc_vals(executed.len()),
            Msg::MEpoch { evicted, .. } => HDR + 8 + 4 * evicted.len() as u64,
            Msg::MBatch { msgs } => {
                HDR + msgs.iter().map(|m| 4 + m.wire_size()).sum::<u64>()
            }
            _ => HDR + 16,
        }
    }
}

/// A set of [`Dot`]s stored as per-origin coalesced, inclusive sequence
/// ranges. Built for `reads_since_write`: on a write-free hot key every
/// read between two GC rounds used to append one `Dot` to a `Vec`, so the
/// conflict table grew linearly with read throughput (ROADMAP PR 1 item).
/// Reads from one origin arrive with (near-)monotone sequence numbers, so
/// contiguous bursts collapse into single `(lo, hi)` fragments: memory is
/// O(origins × fragments), bounded by the interleaving rather than by the
/// read count. Exact membership is preserved — dependency enumeration
/// expands ranges back into dots.
#[derive(Clone, Debug, Default)]
pub struct DotRanges {
    /// Per origin: disjoint, sorted, inclusive `(lo, hi)` seq ranges.
    per_origin: Vec<(ProcessId, Vec<(u64, u64)>)>,
}

impl DotRanges {
    /// Insert `dot`, coalescing with adjacent fragments.
    pub fn add(&mut self, dot: Dot) {
        let ranges = match self.per_origin.iter_mut().find(|(o, _)| *o == dot.origin) {
            Some((_, r)) => r,
            None => {
                self.per_origin.push((dot.origin, Vec::new()));
                &mut self.per_origin.last_mut().expect("just pushed").1
            }
        };
        let s = dot.seq;
        // First fragment starting after `s`; the one that could contain or
        // left-extend to `s` is at `i - 1`.
        let i = ranges.partition_point(|&(lo, _)| lo <= s);
        if i > 0 {
            let (_, hi) = ranges[i - 1];
            if s <= hi {
                return; // already present
            }
            if s == hi + 1 {
                ranges[i - 1].1 = s;
                if i < ranges.len() && ranges[i].0 == s + 1 {
                    let (_, rhi) = ranges.remove(i);
                    ranges[i - 1].1 = rhi;
                }
                return;
            }
        }
        if i < ranges.len() && ranges[i].0 == s + 1 {
            ranges[i].0 = s;
            return;
        }
        ranges.insert(i, (s, s));
    }

    /// Remove `dot` if present (GC scrub), splitting its fragment.
    pub fn remove(&mut self, dot: Dot) {
        let Some(slot) = self.per_origin.iter_mut().position(|(o, _)| *o == dot.origin) else {
            return;
        };
        let ranges = &mut self.per_origin[slot].1;
        let s = dot.seq;
        let i = ranges.partition_point(|&(lo, _)| lo <= s);
        if i == 0 {
            return;
        }
        let (lo, hi) = ranges[i - 1];
        if s > hi {
            return;
        }
        match (s == lo, s == hi) {
            (true, true) => {
                ranges.remove(i - 1);
            }
            (true, false) => ranges[i - 1].0 = s + 1,
            (false, true) => ranges[i - 1].1 = s - 1,
            (false, false) => {
                ranges[i - 1].1 = s - 1;
                ranges.insert(i, (s + 1, hi));
            }
        }
        if self.per_origin[slot].1.is_empty() {
            self.per_origin.remove(slot);
        }
    }

    /// No dots stored?
    pub fn is_empty(&self) -> bool {
        self.per_origin.is_empty()
    }

    /// Number of dots stored (expanded).
    pub fn len(&self) -> usize {
        self.per_origin
            .iter()
            .flat_map(|(_, rs)| rs.iter())
            .map(|&(lo, hi)| (hi - lo + 1) as usize)
            .sum()
    }

    /// Number of `(lo, hi)` fragments held — the actual memory footprint
    /// (the boundedness tests assert on this, not on [`Self::len`]).
    pub fn fragments(&self) -> usize {
        self.per_origin.iter().map(|(_, rs)| rs.len()).sum()
    }

    /// Iterate the stored dots (dependency enumeration).
    pub fn iter(&self) -> impl Iterator<Item = Dot> + '_ {
        self.per_origin.iter().flat_map(|&(o, ref rs)| {
            rs.iter().flat_map(move |&(lo, hi)| (lo..=hi).map(move |s| Dot::new(o, s)))
        })
    }

    /// Drop everything (a write supersedes the reads before it).
    pub fn clear(&mut self) {
        self.per_origin.clear();
    }
}

/// Per-key conflict bookkeeping: dependencies are the most recent write and
/// the reads since it (reads don't conflict with reads — the feature that
/// gives EPaxos/Janus an edge on read-heavy workloads, §3.3 "Limitations").
/// Reads are held as coalesced ranges ([`DotRanges`]) so write-free keys
/// stay compact between GC rounds.
#[derive(Clone, Debug, Default)]
struct KeyDeps {
    last_write: Option<Dot>,
    reads_since_write: DotRanges,
}

#[derive(Clone, Debug)]
struct Info {
    phase: Phase,
    cmd: Option<Command>,
    quorums: Quorums,
    /// Current local dependency value (proposal → decided for our group).
    deps: Vec<Dot>,
    bal: u64,
    /// Ballot at which a consensus value was last *accepted* (0 = never)
    /// — the classic Paxos highest-accepted rule during recovery.
    abal: u64,
    coordinator: bool,
    decided: bool,
    /// Quorum replies, holding the shared wire buffers directly.
    acks: Vec<(ProcessId, Deps)>,
    consensus_acks: BTreeSet<ProcessId>,
    /// Recovery prepare replies: (process, report, phase, abal).
    rec_acks: Vec<(ProcessId, Deps, Phase, u64)>,
    /// When this dot entered a pending phase (recovery timer base).
    pending_since: u64,
    /// Committed dependency sets per accessed group.
    group_deps: Vec<(ShardId, Deps)>,
    /// Cross-group execution barrier.
    ready_acks: BTreeSet<ShardId>,
    announced: bool,
}

impl Info {
    fn new() -> Self {
        Info {
            phase: Phase::Start,
            cmd: None,
            quorums: Vec::new().into(),
            deps: Vec::new(),
            bal: 0,
            abal: 0,
            coordinator: false,
            decided: false,
            acks: Vec::new(),
            consensus_acks: BTreeSet::new(),
            rec_acks: Vec::new(),
            pending_since: 0,
            group_deps: Vec::new(),
            ready_acks: BTreeSet::new(),
            announced: false,
        }
    }
}

/// Shared state machine for the dependency-based protocols.
pub struct DepCore {
    bp: BaseProcess<Msg>,
    variant: Variant,
    conflicts: HashMap<Key, KeyDeps>,
    info: CommandsInfo<Info>,
    graph: DepGraph,
    /// Committed-unexecuted commands (roots for the executor scan).
    pending_roots: BTreeSet<Dot>,
    /// Executor retry index: uncommitted/unexecuted dependency → roots
    /// whose closure is blocked on it.
    blocked_on: HashMap<Dot, Vec<Dot>>,
    gc: GCTrack,
    /// Epoch reconfiguration: eviction votes, installed history, fencing.
    epochs: EpochManager,
    /// Coordinator dots not yet locally committed — re-proposed every
    /// `retry_interval_ticks` ticks so dropped links heal.
    retry_pending: BTreeSet<Dot>,
    /// Coordinator dots committed but not yet group-wide pruned — their
    /// MCommit is re-broadcast on the same cadence for peers that missed
    /// it (handle_commit is idempotent).
    retry_commits: BTreeSet<Dot>,
    /// Per-dot retransmit pacing (`Config::retry_backoff_cap_ticks`);
    /// pass-through when the cap is 0 (legacy fixed cadence).
    retry_pacer: super::common::RetryPacer<Dot>,
    /// Every locally known, not-yet-committed dot — any replica may
    /// become the recovery leader, so all of them arm the timer.
    pending: BTreeSet<Dot>,
    /// Processes this replica suspects (Ω input for leader election).
    suspected: BTreeSet<ProcessId>,
    ticks: u64,
    pub counters: Counters,
}

impl DepCore {
    pub fn new(id: ProcessId, config: Config, variant: Variant) -> Self {
        if variant != Variant::Janus {
            assert_eq!(config.shards, 1, "EPaxos/Atlas are full-replication baselines");
        }
        let bp = BaseProcess::new(id, config);
        // Stride-aware frontiers: a worker slot only ever sees dots of its
        // own sequence stride (identity stride when unsharded).
        let gc = GCTrack::strided(
            id,
            bp.group_procs.clone(),
            bp.config.worker,
            bp.config.workers,
        );
        let graph = DepGraph::strided(bp.config.worker, bp.config.workers);
        let epochs =
            EpochManager::new(id, bp.group_procs.clone(), bp.config.epoch_fence_off);
        let retry_pacer = super::common::RetryPacer::new(
            bp.config.retry_interval_ticks,
            bp.config.retry_backoff_cap_ticks,
        );
        DepCore {
            bp,
            variant,
            conflicts: HashMap::new(),
            info: CommandsInfo::default(),
            graph,
            pending_roots: BTreeSet::new(),
            blocked_on: HashMap::new(),
            gc,
            epochs,
            retry_pending: BTreeSet::new(),
            retry_commits: BTreeSet::new(),
            retry_pacer,
            pending: BTreeSet::new(),
            suspected: BTreeSet::new(),
            ticks: 0,
            counters: Counters::default(),
        }
    }

    /// `leader_p` from the Ω failure detector: lowest non-suspected
    /// machine of our group (same election as Tempo's).
    fn leader(&self) -> ProcessId {
        self.bp
            .group_procs
            .iter()
            .copied()
            .find(|p| !self.suspected.contains(p))
            .unwrap_or(self.bp.id)
    }

    /// Initial coordinator of `dot` at our group (the paper's
    /// `initial_p`): the origin's co-located replica.
    fn initial_coordinator(&self, dot: Dot) -> ProcessId {
        self.bp.config.closest_in_shard(dot.origin, self.bp.group)
    }

    fn local_keys<'a>(&'a self, cmd: &'a Command) -> impl Iterator<Item = Key> + 'a {
        cmd.keys
            .iter()
            .copied()
            .filter(move |&k| key_to_shard(k, self.bp.config.shards) == self.bp.group)
    }

    fn is_write(cmd: &Command) -> bool {
        // `Op::Read` reads travel this slow path too (no stability
        // frontier here) and must commute like `Op::Get`.
        !cmd.op.is_read()
    }

    /// Dependencies of `cmd` on our local keys, then register `dot` in the
    /// conflict tables (each process reports what it has seen, §3.3).
    fn conflicts_and_register(&mut self, dot: Dot, cmd: &Command) -> Vec<Dot> {
        let write = Self::is_write(cmd);
        let keys: Vec<Key> = self.local_keys(cmd).collect();
        let mut deps = Vec::new();
        for k in keys {
            let slot = self.conflicts.entry(k).or_default();
            // Reads depend on the last write; writes depend on the last
            // write and all reads since it.
            if let Some(w) = slot.last_write {
                deps.push(w);
            }
            if write {
                deps.extend(slot.reads_since_write.iter());
                slot.last_write = Some(dot);
                slot.reads_since_write.clear();
            } else {
                slot.reads_since_write.add(dot);
            }
        }
        deps.sort_unstable();
        deps.dedup();
        deps.retain(|&d| d != dot);
        deps
    }

    fn fast_quorum_of(&self, info: &Info) -> Option<Vec<ProcessId>> {
        info.quorums
            .iter()
            .find(|(g, _)| *g == self.bp.group)
            .map(|(_, q)| q.clone())
    }

    fn all_processes_of(&self, cmd: &Command) -> Vec<ProcessId> {
        let mut out = Vec::new();
        for g in cmd.shards(self.bp.config.shards) {
            out.extend(self.bp.config.shard_processes(g));
        }
        out
    }

    // -- commit protocol ---------------------------------------------------

    pub fn submit(&mut self, cmd: Command, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        let dot = self.bp.next_dot();
        out.push(Action::Submitted { dot });
        let groups = cmd.shards(self.bp.config.shards);
        let quorums: Quorums = groups
            .iter()
            .map(|&g| {
                let coord = self.bp.config.closest_in_shard(self.bp.id, g);
                let base = g.0 * self.bp.config.r as u32;
                let k0 = coord.0 - base;
                let size = self.variant.fast_quorum_size(&self.bp.config) as u32;
                let q = (0..size)
                    .map(|d| ProcessId(base + (k0 + d) % self.bp.config.r as u32))
                    .collect();
                (g, q)
            })
            .collect::<Vec<_>>()
            .into();
        let coords: Vec<ProcessId> = groups
            .iter()
            .map(|&g| self.bp.config.closest_in_shard(self.bp.id, g))
            .collect();
        self.broadcast(&coords, Msg::MSubmit { dot, cmd, quorums }, time, &mut out);
        out
    }

    fn handle_submit(
        &mut self,
        dot: Dot,
        cmd: Command,
        quorums: Quorums,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot)
            || self.info.get(&dot).is_some_and(|i| i.phase != Phase::Start)
        {
            return;
        }
        let deps = self.conflicts_and_register(dot, &cmd);
        let me = self.bp.id;
        // One shared buffer for the whole fast-quorum fan-out.
        let shared: Deps = deps.clone().into();
        {
            let info = self.info.ensure(dot, Info::new);
            info.phase = Phase::Propose;
            info.cmd = Some(cmd.clone());
            info.quorums = quorums.clone();
            info.deps = deps;
            info.coordinator = true;
            info.pending_since = time;
            info.acks.push((me, shared.clone()));
        }
        self.pending.insert(dot);
        if self.bp.config.retry_interval_ticks > 0 {
            self.retry_pending.insert(dot);
        }
        let fq = self.fast_quorum_of(&self.info[&dot]).expect("own quorum");
        for &p in &fq {
            if p != me {
                out.push(Action::send(
                    p,
                    Msg::MPropose {
                        dot,
                        cmd: cmd.clone(),
                        quorums: quorums.clone(),
                        deps: shared.clone(),
                    },
                ));
            }
        }
        for p in self.bp.group_procs.clone() {
            if !fq.contains(&p) {
                out.push(Action::send(
                    p,
                    Msg::MPayload { dot, cmd: cmd.clone(), quorums: quorums.clone() },
                ));
            }
        }
        self.drain_stalled(dot, time, out);
        self.try_decide(dot, time, out);
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_propose(
        &mut self,
        from: ProcessId,
        dot: Dot,
        cmd: Command,
        quorums: Quorums,
        coord_deps: Deps,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return;
        }
        if let Some(i) = self.info.get(&dot) {
            if i.phase != Phase::Start {
                // Duplicate/re-transmitted MPropose: if we are still in the
                // propose phase (our original ack may have been dropped),
                // re-send the recorded reply; conflicts are NOT registered
                // twice. `bal > 0` means consensus overwrote `deps` — the
                // slow path is in charge, nothing to re-ack.
                if i.phase == Phase::Propose && !i.coordinator && i.bal == 0 {
                    let shared: Deps = i.deps.clone().into();
                    out.push(Action::send(from, Msg::MProposeAck { dot, deps: shared }));
                }
                return;
            }
        }
        let mut deps = self.conflicts_and_register(dot, &cmd);
        deps.extend(coord_deps.iter().copied());
        deps.sort_unstable();
        deps.dedup();
        deps.retain(|&d| d != dot);
        let shared: Deps = deps.clone().into();
        {
            let info = self.info.ensure(dot, Info::new);
            info.phase = Phase::Propose;
            info.cmd = Some(cmd);
            info.quorums = quorums;
            info.deps = deps;
            info.pending_since = time;
        }
        self.pending.insert(dot);
        out.push(Action::send(from, Msg::MProposeAck { dot, deps: shared }));
        self.drain_stalled(dot, time, out);
    }

    fn handle_propose_ack(
        &mut self,
        from: ProcessId,
        dot: Dot,
        deps: Deps,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.phase != Phase::Propose || !info.coordinator || info.decided {
                return;
            }
            if info.acks.iter().any(|(p, _)| *p == from) {
                return;
            }
            info.acks.push((from, deps));
        }
        self.try_decide(dot, time, out);
    }

    /// Fast-path check once the whole fast quorum answered.
    fn try_decide(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        let f = self.bp.config.f;
        let variant = self.variant;
        let group = self.bp.group;
        let decision = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.phase != Phase::Propose || !info.coordinator || info.decided {
                return;
            }
            let fq_len = info
                .quorums
                .iter()
                .find(|(g, _)| *g == group)
                .map(|(_, q)| q.len())
                .unwrap_or(usize::MAX);
            if info.acks.len() < fq_len {
                return;
            }
            let mut union: Vec<Dot> =
                info.acks.iter().flat_map(|(_, d)| d.iter().copied()).collect();
            union.sort_unstable();
            union.dedup();
            let fast = match variant {
                // EPaxos: every reply reported the same dependencies.
                Variant::EPaxos => info.acks.iter().all(|(_, d)| {
                    let mut d = d.to_vec();
                    d.sort_unstable();
                    d == union
                }),
                // Atlas/Janus*: every dependency in the union was reported
                // by at least f quorum members (so it survives f failures).
                Variant::Atlas | Variant::Janus => union.iter().all(|dep| {
                    info.acks.iter().filter(|(_, d)| d.contains(dep)).count() >= f
                }),
            };
            info.decided = true;
            info.deps = union.clone();
            (union, fast, info.cmd.clone().unwrap())
        };
        let (deps, fast, cmd) = decision;
        let deps: Deps = deps.into(); // one buffer for the whole fan-out
        if fast {
            self.counters.fast_path += 1;
            let targets = self.all_processes_of(&cmd);
            self.broadcast(&targets, Msg::MCommit { dot, group, deps }, time, out);
        } else {
            self.counters.slow_path += 1;
            let b = (self.bp.id.0 - self.bp.group_base()) as u64 + 1;
            let msg = Msg::MConsensus { dot, deps, bal: b };
            self.broadcast(&self.bp.group_procs.clone(), msg, time, out);
        }
    }

    fn handle_commit(
        &mut self,
        from: ProcessId,
        dot: Dot,
        group: ShardId,
        deps: Deps,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return;
        }
        match self.info.get(&dot).map_or(Phase::Start, |i| i.phase) {
            Phase::Start => {
                self.info.ensure(dot, Info::new);
                self.stall(dot, from, Msg::MCommit { dot, group, deps });
                return;
            }
            Phase::Commit | Phase::Execute => return,
            _ => {}
        }
        {
            let info = self.info.get_mut(&dot).unwrap();
            if info.group_deps.iter().any(|(g, _)| *g == group) {
                return;
            }
            info.group_deps.push((group, deps));
        }
        self.try_commit(dot, time, out);
    }

    fn try_commit(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        let local_deps = {
            let info = match self.info.get(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.phase.is_committed_like() || info.cmd.is_none() {
                return;
            }
            let groups = info.cmd.as_ref().unwrap().shards(self.bp.config.shards);
            if info.group_deps.len() < groups.len() {
                return;
            }
            // Execution at our group follows our group's dependencies: they
            // all share a local key, so their commits reach us (genuine
            // dependency delivery); cross-group ordering goes through the
            // MReady barrier.
            info.group_deps
                .iter()
                .find(|(g, _)| *g == self.bp.group)
                .map(|(_, d)| d.to_vec())
                .unwrap_or_default()
        };
        {
            let info = self.info.get_mut(&dot).unwrap();
            info.phase = Phase::Commit;
            if self.retry_pending.remove(&dot) && info.coordinator {
                self.retry_commits.insert(dot);
            }
        }
        self.pending.remove(&dot);
        self.graph.commit(dot, local_deps);
        self.pending_roots.insert(dot);
        out.push(Action::Committed { dot, fast: true });
        self.drain_stalled(dot, time, out);
        // Retry this command plus everything blocked on its commit.
        let mut queue = vec![dot];
        if let Some(waiters) = self.blocked_on.remove(&dot) {
            queue.extend(waiters);
        }
        self.try_execute_roots(queue, out);
    }

    // -- slow path (Flexible Paxos phase 2) --------------------------------

    fn handle_consensus(
        &mut self,
        from: ProcessId,
        dot: Dot,
        deps: Deps,
        bal: u64,
        _time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return;
        }
        let info = self.info.ensure(dot, Info::new);
        if info.bal > bal {
            // Help a stale proposer (a recovery leader working from an
            // old ballot) instead of silently dropping: the NAck carries
            // our promise so it can retry above it.
            let cur = info.bal;
            out.push(Action::send(from, Msg::MRecDepNAck { dot, bal: cur }));
            return;
        }
        info.deps = deps.to_vec();
        info.bal = bal;
        info.abal = bal;
        out.push(Action::send(from, Msg::MConsensusAck { dot, bal }));
    }

    fn handle_consensus_ack(
        &mut self,
        from: ProcessId,
        dot: Dot,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        let slow_quorum = self.bp.config.slow_quorum_size();
        let ready = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.bal != bal || info.phase.is_committed_like() {
                return;
            }
            info.consensus_acks.insert(from);
            info.consensus_acks.len() == slow_quorum
        };
        if !ready {
            return;
        }
        let (deps, cmd) = {
            let info = self.info.get(&dot).unwrap();
            (info.deps.clone(), info.cmd.clone())
        };
        let cmd = match cmd {
            Some(c) => c,
            None => return,
        };
        let group = self.bp.group;
        let targets = self.all_processes_of(&cmd);
        self.broadcast(&targets, Msg::MCommit { dot, group, deps: deps.into() }, time, out);
    }

    // -- recovery (Atlas §4 prepare phase; Tempo §B port) -------------------

    /// Take over coordination of `dot` at a ballot we own.
    fn recover(&mut self, dot: Dot, time: u64, out: &mut Vec<Action<Msg>>) {
        let bal = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if !info.phase.is_pending() {
                return;
            }
            info.rec_acks.clear();
            info.consensus_acks.clear();
            info.bal
        };
        let b =
            ballot::next_owned(bal, self.bp.id, self.bp.config.r as u64, self.bp.group_base());
        self.counters.recoveries += 1;
        out.push(Action::RecoveryStarted { dot });
        self.broadcast(
            &self.bp.group_procs.clone(),
            Msg::MRecDep { dot, bal: b },
            time,
            out,
        );
    }

    fn handle_rec_dep(
        &mut self,
        from: ProcessId,
        dot: Dot,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        if self.gc.was_executed(dot) {
            return; // GC'd: the whole group executed it already
        }
        let phase = self.info.get(&dot).map_or(Phase::Start, |i| i.phase);
        if phase == Phase::Start {
            // No payload yet: park the prepare until it arrives (the
            // in-flight MPropose/MPayload of the crashed coordinator, or
            // the recovery leader's own re-drive, will drain it).
            self.info.ensure(dot, Info::new);
            self.stall(dot, from, Msg::MRecDep { dot, bal });
            return;
        }
        if !phase.is_pending() {
            // Committed here: no vote needed — the recorded decision
            // helps `from` directly (the MCommitRequest analogue).
            let group_deps = self.info[&dot].group_deps.clone();
            for (g, d) in group_deps {
                out.push(Action::send(from, Msg::MCommit { dot, group: g, deps: d }));
            }
            return;
        }
        let cur_bal = self.info[&dot].bal;
        if cur_bal >= bal {
            out.push(Action::send(from, Msg::MRecDepNAck { dot, bal: cur_bal }));
            return;
        }
        if cur_bal == 0 {
            match phase {
                Phase::Payload => {
                    // Never acked the fast round: compute and register
                    // our report now. RECOVER-R records that it happened
                    // here — the fast path is invalidated (we will never
                    // ack the original proposal at a nonzero ballot).
                    let cmd = self.info[&dot].cmd.clone().unwrap();
                    let deps = self.conflicts_and_register(dot, &cmd);
                    let info = self.info.get_mut(&dot).unwrap();
                    info.deps = deps;
                    info.phase = Phase::RecoverR;
                }
                Phase::Propose => {
                    self.info.get_mut(&dot).unwrap().phase = Phase::RecoverP;
                }
                _ => {}
            }
        }
        let info = self.info.get_mut(&dot).unwrap();
        info.bal = bal;
        let (deps, ph, abal) = (info.deps.clone(), info.phase, info.abal);
        out.push(Action::send(
            from,
            Msg::MRecDepAck { dot, deps: deps.into(), phase: ph, abal, bal },
        ));
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_rec_dep_ack(
        &mut self,
        from: ProcessId,
        dot: Dot,
        deps: Deps,
        phase: Phase,
        abal: u64,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        let rec_quorum = self.bp.config.recovery_quorum_size();
        let group = self.bp.group;
        let initial = self.initial_coordinator(dot);
        let decided: Vec<Dot> = {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.bal != bal || info.phase.is_committed_like() {
                return;
            }
            if info.rec_acks.iter().any(|&(p, ..)| p == from) {
                return;
            }
            info.rec_acks.push((from, deps, phase, abal));
            if info.rec_acks.len() != rec_quorum {
                return;
            }
            if let Some((_, d, _, _)) = info
                .rec_acks
                .iter()
                .filter(|&&(_, _, _, ab)| ab != 0)
                .max_by_key(|&&(_, _, _, ab)| ab)
            {
                // Some process accepted a consensus value: classic Paxos
                // rule — adopt the value accepted at the highest ballot.
                d.to_vec()
            } else {
                // Nobody accepted: reconstruct a dependency set that
                // equals any fast-path commit. I = Q_rec ∩ Q_fast; the
                // fast path is impossible if the initial coordinator
                // answered the prepare (it would have committed itself
                // first) or any I member never saw the proposal
                // (RECOVER-R: its fast ack is missing forever) — then
                // any report union is safe, so take all of them.
                // Otherwise the union over I's extended reports equals
                // the committed union (see the module header).
                let fq: Vec<ProcessId> = info
                    .quorums
                    .iter()
                    .find(|(g, _)| *g == group)
                    .map(|(_, q)| q.clone())
                    .unwrap_or_default();
                let in_i: Vec<&(ProcessId, Deps, Phase, u64)> =
                    info.rec_acks.iter().filter(|&&(p, ..)| fq.contains(&p)).collect();
                let s = in_i.iter().any(|&&(p, ..)| p == initial)
                    || in_i.iter().any(|&&(_, _, ph, _)| ph == Phase::RecoverR);
                let candidates: Vec<&(ProcessId, Deps, Phase, u64)> =
                    if s { info.rec_acks.iter().collect() } else { in_i };
                let mut union: Vec<Dot> = candidates
                    .iter()
                    .flat_map(|(_, d, _, _)| d.iter().copied())
                    .collect();
                union.sort_unstable();
                union.dedup();
                union.retain(|&d| d != dot);
                union
            }
        };
        {
            let info = self.info.get_mut(&dot).unwrap();
            info.deps = decided.clone();
            info.coordinator = true; // we own this command's completion now
            info.decided = true; // fence our own fast-path decision
            info.consensus_acks.clear();
        }
        let msg = Msg::MConsensus { dot, deps: decided.into(), bal };
        self.broadcast(&self.bp.group_procs.clone(), msg, time, out);
    }

    fn handle_rec_dep_nack(
        &mut self,
        dot: Dot,
        bal: u64,
        time: u64,
        out: &mut Vec<Action<Msg>>,
    ) {
        // Join the higher ballot and retry recovery (only the Ω leader,
        // so competing takeovers converge instead of dueling).
        if self.leader() != self.bp.id {
            return;
        }
        {
            let info = match self.info.get_mut(&dot) {
                Some(i) => i,
                None => return,
            };
            if info.bal >= bal || !info.phase.is_pending() {
                return;
            }
            info.bal = bal;
        }
        self.recover(dot, time, out);
    }

    // -- execution ----------------------------------------------------------

    /// Execute every SCC (reachable from `queue` roots) whose closure is
    /// committed and whose multi-group members passed the MReady barrier.
    /// Blocked roots are indexed by the dependency that blocks them and
    /// retried only when it commits/executes (§Perf iteration 6: the naive
    /// rescan of all pending commands was 94% of the Fig. 7 wall time).
    fn try_execute_roots(&mut self, mut queue: Vec<Dot>, out: &mut Vec<Action<Msg>>) {
        while let Some(root) = queue.pop() {
            if !self.pending_roots.contains(&root) {
                continue; // already executed (or not locally committed yet)
            }
            let sccs = match self.graph.ready_or_missing(root) {
                Ok(s) => s,
                Err(missing) => {
                    self.blocked_on.entry(missing).or_default().push(root);
                    continue;
                }
            };
            'scc: for scc in sccs {
                // Barrier: multi-group members need every group ready;
                // handle_ready re-queues the member when acks arrive.
                for &m in &scc {
                    if !self.barrier_passed(m, out) {
                        break 'scc;
                    }
                }
                for m in scc {
                    if !self.pending_roots.remove(&m) {
                        continue;
                    }
                    self.graph.mark_executed(m);
                    self.gc.record_executed(m);
                    let info = self.info.get_mut(&m).unwrap();
                    info.phase = Phase::Execute;
                    let cmd = info.cmd.clone().unwrap();
                    self.counters.executed += 1;
                    // Dependency-ordered families have no timestamp order.
                    out.push(Action::Execute { dot: m, cmd, ts: 0 });
                    // Wake commands that were blocked on `m`.
                    if let Some(waiters) = self.blocked_on.remove(&m) {
                        queue.extend(waiters);
                    }
                }
            }
        }
    }

    /// For multi-group commands: announce our readiness once and check all
    /// accessed groups announced theirs (Janus* cross-shard ordering —
    /// the non-genuine messaging the paper calls out in §4).
    fn barrier_passed(&mut self, dot: Dot, out: &mut Vec<Action<Msg>>) -> bool {
        let (cmd, announced) = {
            let info = &self.info[&dot];
            (info.cmd.clone().unwrap(), info.announced)
        };
        let groups = cmd.shards(self.bp.config.shards);
        if groups.len() <= 1 {
            return true;
        }
        let me = self.bp.id;
        let own = self.bp.group;
        if !announced {
            let info = self.info.get_mut(&dot).unwrap();
            info.announced = true;
            info.ready_acks.insert(own);
            for p in self.all_processes_of(&cmd) {
                if p != me && self.bp.config.shard_of(p) != own {
                    out.push(Action::send(p, Msg::MReady { dot }));
                }
            }
        }
        let info = &self.info[&dot];
        groups.iter().all(|g| info.ready_acks.contains(g))
    }

    fn handle_ready(&mut self, from: ProcessId, dot: Dot, out: &mut Vec<Action<Msg>>) {
        if self.gc.was_executed(dot) {
            return;
        }
        let group = self.bp.config.shard_of(from);
        self.info.ensure(dot, Info::new).ready_acks.insert(group);
        self.try_execute_roots(vec![dot], out);
    }

    /// Retransmission (`Config::retry_interval_ticks`): re-propose the
    /// coordinator's uncommitted dots and re-broadcast its committed,
    /// not-yet-pruned MCommits. Every receiver path is idempotent
    /// (duplicate MPropose re-acks, duplicate MConsensus re-acks,
    /// duplicate MCommit is dropped), so dropped links heal once the
    /// nemesis window closes without double-counting anything.
    fn retry_tick(&mut self, out: &mut Vec<Action<Msg>>) {
        let every = self.bp.config.retry_interval_ticks;
        if every == 0 {
            return;
        }
        // Legacy fixed cadence fires everything on every N-th tick; with
        // backoff the per-dot pacer owns the schedule and must be
        // consulted on every tick (each dot has its own due point).
        if !self.retry_pacer.backoff_enabled() && self.ticks % every != 0 {
            return;
        }
        let me = self.bp.id;
        let group = self.bp.group;
        for dot in self.retry_pending.clone() {
            if !self.retry_pacer.due(dot, self.ticks) {
                continue;
            }
            let Some(info) = self.info.get(&dot) else { continue };
            let Some(cmd) = info.cmd.clone() else { continue };
            if info.decided {
                // Slow path in flight: re-broadcast the consensus round.
                let msg = Msg::MConsensus {
                    dot,
                    deps: info.deps.clone().into(),
                    bal: info.bal.max(1),
                };
                self.counters.retransmits += 1;
                for p in self.bp.group_procs.clone() {
                    if p != me {
                        out.push(Action::send(p, msg.clone()));
                    }
                }
                continue;
            }
            // Fast path in flight: re-send MPropose to quorum members that
            // have not acked yet (they re-ack if the original reply was
            // the casualty).
            let own_deps: Deps = info
                .acks
                .iter()
                .find(|(p, _)| *p == me)
                .map(|(_, d)| d.clone())
                .unwrap_or_else(|| Vec::new().into());
            let acked: Vec<ProcessId> = info.acks.iter().map(|&(p, _)| p).collect();
            let quorums = info.quorums.clone();
            let Some(fq) = self.fast_quorum_of(&self.info[&dot]) else { continue };
            self.counters.retransmits += 1;
            for p in fq {
                if p != me && !acked.contains(&p) {
                    out.push(Action::send(
                        p,
                        Msg::MPropose {
                            dot,
                            cmd: cmd.clone(),
                            quorums: quorums.clone(),
                            deps: own_deps.clone(),
                        },
                    ));
                }
            }
        }
        for dot in self.retry_commits.clone() {
            if !self.retry_pacer.due(dot, self.ticks) {
                continue;
            }
            let Some(info) = self.info.get(&dot) else {
                self.retry_commits.remove(&dot);
                continue;
            };
            let Some(cmd) = info.cmd.clone() else { continue };
            let Some(deps) =
                info.group_deps.iter().find(|(g, _)| *g == group).map(|(_, d)| d.clone())
            else {
                continue;
            };
            let targets = self.all_processes_of(&cmd);
            self.counters.retransmits += 1;
            for p in targets {
                if p != me {
                    out.push(Action::send(
                        p,
                        Msg::MCommit { dot, group, deps: deps.clone() },
                    ));
                }
            }
        }
        // Completed dots leave both retry sets; drop their schedules so
        // the pacer stays bounded by the in-flight state it paces.
        let (pending, commits) = (&self.retry_pending, &self.retry_commits);
        self.retry_pacer.retain(|d| pending.contains(d) || commits.contains(d));
    }

    /// Periodic handler: the GC frontier exchange (common::GcProcess),
    /// the epoch reconfiguration vote, retransmission, and the recovery
    /// timers.
    pub fn tick(&mut self, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        self.ticks += 1;
        let ticks = self.ticks;
        self.gc_tick(ticks, |executed| Msg::MGarbageCollect { executed }, &mut out);
        self.epoch_tick(|epoch, evicted| Msg::MEpoch { epoch, evicted }, &mut out);
        self.retry_tick(&mut out);
        // Recovery timers (only the Ω leader calls recover()): a pending
        // dot whose progress stalled past the timeout — and whose current
        // ballot we do not already own — gets the prepare phase.
        if self.bp.config.recovery_timeout_us != u64::MAX && self.leader() == self.bp.id {
            let timeout = self.bp.config.recovery_timeout_us;
            let r = self.bp.config.r as u64;
            let base = self.bp.group_base();
            let me = self.bp.id;
            let due: Vec<Dot> = self
                .pending
                .iter()
                .copied()
                .filter(|d| {
                    self.info.get(d).is_some_and(|i| {
                        i.phase.is_pending()
                            && time.saturating_sub(i.pending_since) >= timeout
                            && (i.bal == 0 || ballot::leader(i.bal, r, base) != me)
                    })
                })
                .collect();
            for dot in due {
                // Restart the timer so we do not spam MRecDep every tick.
                if let Some(i) = self.info.get_mut(&dot) {
                    i.pending_since = time;
                }
                self.recover(dot, time, &mut out);
            }
        }
        out
    }

    pub fn suspect(&mut self, p: ProcessId) {
        self.suspected.insert(p);
        self.epochs.suspect(p);
    }

    pub fn crash(&mut self) {
        self.bp.crashed = true;
    }

    pub fn footprint(&self) -> Footprint {
        Footprint {
            infos: self.info.len(),
            keys: self.conflicts.len(),
            stalled: self.bp.stalled_len() + self.blocked_on.len(),
            queued: self.bp.batcher.queued(),
            fragments: self
                .conflicts
                .values()
                .map(|kd| kd.reads_since_write.fragments())
                .sum(),
        }
    }
}

impl GcProcess for DepCore {
    fn gc_track(&mut self) -> &mut GCTrack {
        &mut self.gc
    }

    fn prune_executed(&mut self) {
        for (origin, lo, hi) in self.gc.safe_to_prune() {
            for idx in lo..=hi {
                let dot = self.gc.dot_at(origin, idx);
                // Scrub the conflict tables: a group-wide-executed command
                // executed everywhere before any future conflicting command
                // commits, so it need not appear as a dependency again (the
                // graph remembers it as executed in bounded space).
                let keys: Vec<Key> = self
                    .info
                    .get(&dot)
                    .and_then(|i| i.cmd.as_ref())
                    .map(|c| self.local_keys(c).collect())
                    .unwrap_or_default();
                for k in keys {
                    let remove = if let Some(slot) = self.conflicts.get_mut(&k) {
                        if slot.last_write == Some(dot) {
                            slot.last_write = None;
                        }
                        slot.reads_since_write.remove(dot);
                        slot.last_write.is_none() && slot.reads_since_write.is_empty()
                    } else {
                        false
                    };
                    if remove {
                        self.conflicts.remove(&k);
                    }
                }
                if self.info.prune(&dot) {
                    self.counters.gc_pruned += 1;
                }
                self.blocked_on.remove(&dot);
                self.retry_commits.remove(&dot);
                self.pending.remove(&dot);
                self.bp.drop_stalled(dot);
            }
        }
    }
}

impl EpochProcess for DepCore {
    fn epoch_mgr(&mut self) -> &mut EpochManager {
        &mut self.epochs
    }

    fn on_evicted(&mut self, member: ProcessId) {
        self.gc.evict(member);
        self.counters.evictions += 1;
    }
}

impl Process for DepCore {
    type Msg = Msg;

    fn base(&self) -> &BaseProcess<Msg> {
        &self.bp
    }

    fn base_mut(&mut self) -> &mut BaseProcess<Msg> {
        &mut self.bp
    }

    fn dispatch(&mut self, from: ProcessId, msg: Msg, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        // Epoch fencing: drop messages from members the installed epoch
        // evicted (late by definition).
        if self.epochs.rejects(from) {
            return out;
        }
        match msg {
            Msg::MSubmit { dot, cmd, quorums } => {
                self.handle_submit(dot, cmd, quorums, time, &mut out)
            }
            Msg::MPropose { dot, cmd, quorums, deps } => {
                self.handle_propose(from, dot, cmd, quorums, deps, time, &mut out)
            }
            Msg::MProposeAck { dot, deps } => {
                self.handle_propose_ack(from, dot, deps, time, &mut out)
            }
            Msg::MPayload { dot, cmd, quorums } => {
                if self.gc.was_executed(dot) {
                    return out;
                }
                if self.info.get(&dot).is_none_or(|i| i.phase == Phase::Start) {
                    let info = self.info.ensure(dot, Info::new);
                    info.phase = Phase::Payload;
                    info.cmd = Some(cmd);
                    info.quorums = quorums;
                    info.pending_since = time;
                    self.pending.insert(dot);
                    self.drain_stalled(dot, time, &mut out);
                }
            }
            Msg::MCommit { dot, group, deps } => {
                self.handle_commit(from, dot, group, deps, time, &mut out)
            }
            Msg::MConsensus { dot, deps, bal } => {
                self.handle_consensus(from, dot, deps, bal, time, &mut out)
            }
            Msg::MConsensusAck { dot, bal } => {
                self.handle_consensus_ack(from, dot, bal, time, &mut out)
            }
            Msg::MRecDep { dot, bal } => self.handle_rec_dep(from, dot, bal, time, &mut out),
            Msg::MRecDepAck { dot, deps, phase, abal, bal } => {
                self.handle_rec_dep_ack(from, dot, deps, phase, abal, bal, time, &mut out)
            }
            Msg::MRecDepNAck { dot, bal } => {
                self.handle_rec_dep_nack(dot, bal, time, &mut out)
            }
            Msg::MReady { dot } => self.handle_ready(from, dot, &mut out),
            Msg::MGarbageCollect { executed } => self.handle_garbage_collect(from, &executed),
            Msg::MEpoch { epoch, evicted } => self.handle_epoch(
                from,
                epoch,
                evicted,
                |epoch, evicted| Msg::MEpoch { epoch, evicted },
                &mut out,
            ),
            Msg::MBatch { msgs } => {
                for m in msgs {
                    let actions = self.dispatch(from, m, time);
                    out.extend(actions);
                }
            }
        }
        out
    }
}

impl Phase {
    fn is_committed_like(self) -> bool {
        matches!(self, Phase::Commit | Phase::Execute)
    }

    /// In flight: known (payload or proposal seen) but not yet committed
    /// — the phases the recovery timer and prepare phase operate on.
    fn is_pending(self) -> bool {
        matches!(
            self,
            Phase::Payload | Phase::Propose | Phase::RecoverR | Phase::RecoverP
        )
    }
}

/// Declare a `Protocol` wrapper around [`DepCore`] for one [`Variant`].
macro_rules! dep_protocol {
    ($name:ident, $variant:expr, $proto_name:literal) => {
        pub struct $name(pub DepCore);

        impl Protocol for $name {
            type Message = Msg;

            fn new(id: ProcessId, config: Config) -> Self {
                $name(DepCore::new(id, config, $variant))
            }

            fn name() -> &'static str {
                $proto_name
            }

            fn submit(&mut self, cmd: Command, time: u64) -> Vec<Action<Msg>> {
                let out = self.0.submit(cmd, time);
                self.0.outbound(out, false, time)
            }

            /// No stability frontier: reads run through the full
            /// dependency-ordering path (counted as slow reads), which
            /// serializes them after the session's writes — floor moot.
            fn submit_read(&mut self, cmd: Command, _floor: u64, time: u64) -> Vec<Action<Msg>> {
                self.0.counters.slow_reads += 1;
                self.submit(cmd, time)
            }

            fn handle(&mut self, from: ProcessId, msg: Msg, time: u64) -> Vec<Action<Msg>> {
                let out = self.0.dispatch(from, msg, time);
                self.0.outbound(out, false, time)
            }

            fn tick(&mut self, time: u64) -> Vec<Action<Msg>> {
                let out = self.0.tick(time);
                self.0.outbound(out, true, time)
            }

            fn crash(&mut self) {
                self.0.crash();
            }

            fn suspect(&mut self, p: ProcessId) {
                self.0.suspect(p);
            }

            fn counters(&self) -> Counters {
                let mut c = self.0.counters;
                self.0.bp.batcher.record_stats(&mut c);
                c
            }

            fn epoch_view(&self) -> Vec<(u64, Vec<ProcessId>)> {
                self.0.epochs.history().to_vec()
            }

            fn msg_size(msg: &Msg) -> u64 {
                msg.wire_size()
            }

            fn footprint(&self) -> Footprint {
                self.0.footprint()
            }
        }
    };
}

dep_protocol!(EPaxos, Variant::EPaxos, "epaxos");
dep_protocol!(Atlas, Variant::Atlas, "atlas");
dep_protocol!(Janus, Variant::Janus, "janus*");

#[cfg(test)]
mod tests {
    use super::*;

    fn dot(p: u32, s: u64) -> Dot {
        Dot::new(ProcessId(p), s)
    }

    #[test]
    fn dot_ranges_coalesce_contiguous_reads() {
        let mut r = DotRanges::default();
        for s in 1..=1000u64 {
            r.add(dot(0, s));
        }
        assert_eq!(r.len(), 1000);
        assert_eq!(r.fragments(), 1, "a contiguous burst is one fragment");
        // Out-of-order arrival still coalesces.
        let mut r = DotRanges::default();
        for s in [5u64, 3, 1, 4, 2] {
            r.add(dot(0, s));
        }
        assert_eq!((r.len(), r.fragments()), (5, 1));
    }

    #[test]
    fn dot_ranges_membership_is_exact() {
        let mut r = DotRanges::default();
        for s in [1u64, 2, 3, 7, 8, 20] {
            r.add(dot(4, s));
        }
        r.add(dot(9, 2));
        let mut got: Vec<Dot> = r.iter().collect();
        got.sort_unstable();
        let mut want: Vec<Dot> = [1u64, 2, 3, 7, 8, 20]
            .iter()
            .map(|&s| dot(4, s))
            .chain(std::iter::once(dot(9, 2)))
            .collect();
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(r.fragments(), 4);
        // Duplicates are no-ops.
        r.add(dot(4, 7));
        assert_eq!(r.len(), 7);
    }

    #[test]
    fn dot_ranges_remove_splits_and_drains() {
        let mut r = DotRanges::default();
        for s in 1..=5u64 {
            r.add(dot(0, s));
        }
        r.remove(dot(0, 3)); // split 1..=5 → 1..=2, 4..=5
        assert_eq!((r.len(), r.fragments()), (4, 2));
        assert!(!r.iter().any(|d| d == dot(0, 3)));
        r.remove(dot(0, 1)); // shrink left edge
        r.remove(dot(0, 5)); // shrink right edge
        assert_eq!((r.len(), r.fragments()), (2, 2));
        r.remove(dot(0, 2));
        r.remove(dot(0, 4));
        assert!(r.is_empty(), "fully drained set must be empty");
        // Removing absent dots is a no-op.
        r.remove(dot(0, 9));
        r.remove(dot(7, 1));
        assert!(r.is_empty());
    }

    #[test]
    fn write_free_key_state_is_bounded_by_fragments_not_reads() {
        // The ROADMAP pathology: thousands of reads on a write-free key
        // between GC rounds. Three origins issue contiguous read bursts;
        // the per-key state must stay O(origins), not O(reads).
        let mut slot = KeyDeps::default();
        for origin in 0..3u32 {
            for s in 1..=10_000u64 {
                slot.reads_since_write.add(dot(origin, s));
            }
        }
        assert_eq!(slot.reads_since_write.len(), 30_000);
        assert!(
            slot.reads_since_write.fragments() <= 3,
            "write-free key fragmented: {} fragments for 30k reads",
            slot.reads_since_write.fragments()
        );
    }
}
