//! EPaxos baseline — re-export of the unified dependency-based core.
pub use super::depsmr::{EPaxos, Msg};
