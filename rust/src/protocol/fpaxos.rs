//! FPaxos: leader-based Multi-Paxos with Flexible quorums [Howard et al.,
//! OPODIS'16], the paper's leader-based baseline (§6).
//!
//! A fixed leader orders all commands into a log; phase-2 quorums have size
//! `f+1` (instead of a majority), so the leader commits after `f` acks from
//! followers. Replicas execute the log in slot order. Like the paper's
//! deployment we keep the leader at process 0 (Ireland — the placement the
//! paper found fairest) and do not exercise leader change during benches:
//! the leader is the single point of contention being measured.

use super::{Action, Protocol};
use crate::core::{Command, Config, Dot, ProcessId};
use crate::metrics::Counters;
use std::collections::{BTreeMap, HashMap};

#[derive(Clone, Debug)]
pub enum Msg {
    /// Any process → leader: order this command.
    MForward { dot: Dot, cmd: Command },
    /// Leader → all: phase-2 accept for a log slot.
    MAccept { slot: u64, dot: Dot, cmd: Command },
    /// Follower → leader.
    MAccepted { slot: u64 },
    /// Leader → all: slot is chosen.
    MCommit { slot: u64 },
}

impl Msg {
    pub fn wire_size(&self) -> u64 {
        const HDR: u64 = 24;
        match self {
            Msg::MForward { cmd, .. } | Msg::MAccept { cmd, .. } => HDR + cmd.wire_size(),
            _ => HDR + 8,
        }
    }
}

struct Slot {
    dot: Dot,
    cmd: Command,
    committed: bool,
}

/// FPaxos process state.
pub struct FPaxos {
    id: ProcessId,
    config: Config,
    /// Log: slot → entry.
    log: BTreeMap<u64, Slot>,
    /// Leader only: next slot to assign.
    next_slot: u64,
    /// Leader only: ack counts per slot.
    acks: HashMap<u64, usize>,
    /// Next slot to execute (all below are executed).
    exec_from: u64,
    crashed: bool,
    counters: Counters,
}

impl FPaxos {
    fn leader(&self) -> ProcessId {
        ProcessId(0)
    }

    fn is_leader(&self) -> bool {
        self.id == self.leader()
    }

    /// Execute every committed slot in order from `exec_from`.
    fn advance(&mut self, out: &mut Vec<Action<Msg>>) {
        while let Some(entry) = self.log.get(&self.exec_from) {
            if !entry.committed {
                break;
            }
            self.counters.executed += 1;
            out.push(Action::Execute { dot: entry.dot, cmd: entry.cmd.clone() });
            self.exec_from += 1;
        }
    }

    fn leader_order(&mut self, dot: Dot, cmd: Command, out: &mut Vec<Action<Msg>>) {
        let slot = self.next_slot;
        self.next_slot += 1;
        self.log.insert(slot, Slot { dot, cmd: cmd.clone(), committed: false });
        self.acks.insert(slot, 1); // the leader accepts its own proposal
        self.counters.fast_path += 1; // every command takes the same path
        for p in 0..self.config.r as u32 {
            if p != self.id.0 {
                out.push(Action::send(ProcessId(p), Msg::MAccept { slot, dot, cmd: cmd.clone() }));
            }
        }
    }

    fn commit_slot(&mut self, slot: u64, out: &mut Vec<Action<Msg>>) {
        if let Some(e) = self.log.get_mut(&slot) {
            if !e.committed {
                e.committed = true;
                out.push(Action::Committed { dot: e.dot, fast: true });
            }
        }
        self.advance(out);
    }
}

impl Protocol for FPaxos {
    type Message = Msg;

    fn new(id: ProcessId, config: Config) -> Self {
        assert_eq!(config.shards, 1, "FPaxos baseline is full-replication only");
        FPaxos {
            id,
            config,
            log: BTreeMap::new(),
            next_slot: 0,
            acks: HashMap::new(),
            exec_from: 0,
            crashed: false,
            counters: Counters::default(),
        }
    }

    fn name() -> &'static str {
        "fpaxos"
    }

    fn submit(&mut self, dot: Dot, cmd: Command, _time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.crashed {
            return out;
        }
        if self.is_leader() {
            self.leader_order(dot, cmd, &mut out);
        } else {
            out.push(Action::send(self.leader(), Msg::MForward { dot, cmd }));
        }
        out
    }

    fn handle(&mut self, from: ProcessId, msg: Msg, _time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.crashed {
            return out;
        }
        match msg {
            Msg::MForward { dot, cmd } => {
                if self.is_leader() {
                    self.leader_order(dot, cmd, &mut out);
                }
            }
            Msg::MAccept { slot, dot, cmd } => {
                self.log.insert(slot, Slot { dot, cmd, committed: false });
                out.push(Action::send(from, Msg::MAccepted { slot }));
            }
            Msg::MAccepted { slot } => {
                if !self.is_leader() {
                    return out;
                }
                let acks = self.acks.entry(slot).or_insert(0);
                *acks += 1;
                // Flexible Paxos phase-2 quorum: f+1 (leader included).
                if *acks == self.config.slow_quorum_size() {
                    self.commit_slot(slot, &mut out);
                    for p in 0..self.config.r as u32 {
                        if p != self.id.0 {
                            out.push(Action::send(ProcessId(p), Msg::MCommit { slot }));
                        }
                    }
                }
            }
            Msg::MCommit { slot } => {
                self.commit_slot(slot, &mut out);
            }
        }
        out
    }

    fn tick(&mut self, _time: u64) -> Vec<Action<Msg>> {
        Vec::new()
    }

    fn crash(&mut self) {
        self.crashed = true;
    }

    fn counters(&self) -> Counters {
        self.counters
    }

    fn msg_size(msg: &Msg) -> u64 {
        msg.wire_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::assert_psmr;
    use crate::sim::{run, SimOpts, Topology};
    use crate::workload::ConflictWorkload;

    fn opts(seed: u64) -> SimOpts {
        let mut o = SimOpts::new(Topology::ec2());
        o.clients_per_site = 4;
        o.warmup_us = 0;
        o.duration_us = 3_000_000;
        o.drain_us = 2_000_000;
        o.seed = seed;
        o.record_execution = true;
        o
    }

    #[test]
    fn fpaxos_satisfies_psmr() {
        let config = Config::new(5, 1);
        let result = run::<FPaxos, _>(config.clone(), opts(21), ConflictWorkload::new(0.02, 100));
        assert!(result.metrics.ops > 50);
        assert_psmr(&config, &result, true);
    }

    #[test]
    fn fpaxos_f2_satisfies_psmr() {
        let config = Config::new(5, 2);
        let result = run::<FPaxos, _>(config.clone(), opts(22), ConflictWorkload::new(1.0, 100));
        assert!(result.metrics.ops > 50);
        assert_psmr(&config, &result, true);
    }

    #[test]
    fn fpaxos_unfair_to_remote_sites() {
        // The leaderless fairness argument (Fig. 5): non-leader sites pay
        // the round trip to Ireland.
        let config = Config::new(5, 1);
        let result = run::<FPaxos, _>(config.clone(), opts(23), ConflictWorkload::new(0.02, 100));
        let leader_site = result.metrics.site_latency[&0].quantile(0.5);
        // Singapore (site 2) is 186 ms RTT from the leader.
        let remote_site = result.metrics.site_latency[&2].quantile(0.5);
        assert!(
            remote_site > 2 * leader_site,
            "leader {leader_site}µs vs remote {remote_site}µs"
        );
    }
}
