//! FPaxos: leader-based Multi-Paxos with Flexible quorums [Howard et al.,
//! OPODIS'16], the paper's leader-based baseline (§6).
//!
//! A fixed leader orders all commands into a log; phase-2 quorums have size
//! `f+1` (instead of a majority), so the leader commits after `f` acks from
//! followers. Replicas execute the log in slot order. Like the paper's
//! deployment we keep the leader at process 0 (Ireland — the placement the
//! paper found fairest) and do not exercise leader change during benches:
//! the leader is the single point of contention being measured.
//!
//! Built on [`crate::protocol::common`]: `BaseProcess` carries the
//! identity/config state and `GCTrack` drives log truncation — slots are
//! mapped onto the leader's dot space (slot `s` ↔ sequence `s + 1`) so the
//! shared frontier exchange prunes every log prefix the whole group
//! executed.

use super::common::{
    wire, BaseProcess, EpochManager, EpochProcess, GCTrack, GcProcess, Process,
};
use super::{Action, Footprint, Protocol};
use crate::core::{Command, Config, Dot, ProcessId};
use crate::metrics::Counters;
use std::collections::{BTreeMap, BTreeSet, HashMap};

#[derive(Clone, Debug)]
pub enum Msg {
    /// Any process → leader: order this command.
    MForward { dot: Dot, cmd: Command },
    /// Leader → all: phase-2 accept for a log slot.
    MAccept { slot: u64, dot: Dot, cmd: Command },
    /// Follower → leader.
    MAccepted { slot: u64 },
    /// Leader → all: slot is chosen.
    MCommit { slot: u64 },
    /// Periodic GC exchange (`protocol::common::GCTrack`).
    MGarbageCollect { executed: Vec<(ProcessId, u64)> },
    /// Epoch reconfiguration vote (`protocol::common::epoch`).
    MEpoch { epoch: u64, evicted: Vec<ProcessId> },
    /// Batch frame (`protocol::common::batch`): several messages bound for
    /// the same destination; unbatched inside `Process::dispatch`.
    MBatch { msgs: Vec<Msg> },
}

impl super::common::BatchMsg for Msg {
    fn batch(msgs: Vec<Msg>) -> Msg {
        Msg::MBatch { msgs }
    }

    fn is_batch(&self) -> bool {
        matches!(self, Msg::MBatch { .. })
    }

    fn approx_wire_bytes(&self) -> u64 {
        self.wire_size()
    }
}

impl Msg {
    pub fn wire_size(&self) -> u64 {
        use wire::{proc_vals, HDR};
        match self {
            Msg::MForward { cmd, .. } | Msg::MAccept { cmd, .. } => HDR + cmd.wire_size(),
            Msg::MGarbageCollect { executed } => HDR + proc_vals(executed.len()),
            Msg::MEpoch { evicted, .. } => HDR + 8 + 4 * evicted.len() as u64,
            Msg::MBatch { msgs } => {
                HDR + msgs.iter().map(|m| 4 + m.wire_size()).sum::<u64>()
            }
            _ => HDR + 8,
        }
    }
}

struct Slot {
    dot: Dot,
    cmd: Command,
    committed: bool,
}

/// FPaxos process state.
pub struct FPaxos {
    bp: BaseProcess<Msg>,
    /// Log: slot → entry. GC truncates the group-wide-executed prefix.
    log: BTreeMap<u64, Slot>,
    /// Leader only: next slot to assign.
    next_slot: u64,
    /// Leader only: per-slot acceptor *voter sets* (dropped once the slot
    /// commits). Sets, not counters: nemesis-duplicated or retransmitted
    /// `MAccepted` replies must not complete a quorum twice over.
    acks: HashMap<u64, BTreeSet<ProcessId>>,
    /// Leader only: dedup of forwarded commands — a retransmitted or
    /// nemesis-duplicated `MForward` must not be ordered into a second
    /// slot. Entries are pruned with their slot; a post-prune duplicate
    /// (possible only through extreme delay) is absorbed by the
    /// executor's per-client dedup window.
    ordered: HashMap<Dot, u64>,
    /// Submitter side: own commands forwarded to the leader but not yet
    /// executed locally — re-forwarded every `retry_interval_ticks` so a
    /// dropped `MForward` (the single point of loss for remote
    /// submissions) heals.
    forwarded: HashMap<Dot, Command>,
    /// Leader only: committed slots not yet group-wide pruned — their
    /// `MAccept`+`MCommit` pair is re-broadcast on the retry cadence so
    /// followers that missed either message catch up.
    retry_commits: BTreeSet<u64>,
    /// Next slot to execute (all below are executed).
    exec_from: u64,
    gc: GCTrack,
    /// Epoch reconfiguration: eviction votes, installed history, fencing.
    epochs: EpochManager,
    ticks: u64,
    counters: Counters,
}

impl FPaxos {
    fn leader(&self) -> ProcessId {
        ProcessId(0)
    }

    fn is_leader(&self) -> bool {
        self.bp.id == self.leader()
    }

    /// Slot `s` in the GC dot space: origin = leader, seq = s + 1
    /// (sequence numbers are 1-based).
    fn slot_dot(&self, slot: u64) -> Dot {
        Dot::new(self.leader(), slot + 1)
    }

    /// Execute every committed slot in order from `exec_from`.
    fn advance(&mut self, out: &mut Vec<Action<Msg>>) {
        while let Some(entry) = self.log.get(&self.exec_from) {
            if !entry.committed {
                break;
            }
            self.counters.executed += 1;
            if !self.forwarded.is_empty() {
                // Own forwarded command made it into the log and executed:
                // stop re-forwarding it.
                self.forwarded.remove(&entry.dot);
            }
            // Slot order, not a timestamp order.
            out.push(Action::Execute { dot: entry.dot, cmd: entry.cmd.clone(), ts: 0 });
            let slot = self.exec_from;
            self.gc.record_executed(self.slot_dot(slot));
            self.exec_from += 1;
        }
    }

    fn leader_order(&mut self, dot: Dot, cmd: Command, out: &mut Vec<Action<Msg>>) {
        // Retransmitted/duplicated forwards must not claim a second slot.
        if self.ordered.contains_key(&dot) {
            return;
        }
        let slot = self.next_slot;
        self.next_slot += 1;
        self.ordered.insert(dot, slot);
        self.log.insert(slot, Slot { dot, cmd: cmd.clone(), committed: false });
        // The leader accepts its own proposal.
        self.acks.insert(slot, BTreeSet::from([self.bp.id]));
        self.counters.fast_path += 1; // every command takes the same path
        for p in 0..self.bp.config.r as u32 {
            if p != self.bp.id.0 {
                out.push(Action::send(ProcessId(p), Msg::MAccept { slot, dot, cmd: cmd.clone() }));
            }
        }
    }

    fn commit_slot(&mut self, slot: u64, out: &mut Vec<Action<Msg>>) {
        if let Some(e) = self.log.get_mut(&slot) {
            if !e.committed {
                e.committed = true;
                out.push(Action::Committed { dot: e.dot, fast: true });
            }
        }
        self.acks.remove(&slot);
        if self.is_leader() && self.bp.config.retry_interval_ticks > 0 {
            self.retry_commits.insert(slot);
        }
        self.advance(out);
    }

    /// Retransmission (opt-in via `config.retry_interval_ticks`): the
    /// leader re-runs phase 2 for uncommitted slots towards silent
    /// acceptors and re-broadcasts `MAccept`+`MCommit` for committed,
    /// not-yet-pruned slots (payload first, so a follower that missed
    /// the original accept can still commit); submitters re-forward own
    /// commands until they execute locally. Every receiver path is
    /// idempotent (accepts never downgrade a committed entry, ack voter
    /// sets dedup, `ordered` dedups forwards), so retransmission under
    /// nemesis duplication stays safe.
    fn retry_tick(&mut self, out: &mut Vec<Action<Msg>>) {
        let every = self.bp.config.retry_interval_ticks;
        if every == 0 || self.ticks % every != 0 {
            return;
        }
        let me = self.bp.id;
        if !self.is_leader() {
            for (dot, cmd) in &self.forwarded {
                self.counters.retransmits += 1;
                out.push(Action::send(
                    self.leader(),
                    Msg::MForward { dot: *dot, cmd: cmd.clone() },
                ));
            }
            return;
        }
        // Uncommitted slots: re-accept towards acceptors that have not
        // voted yet.
        let pending: Vec<(u64, BTreeSet<ProcessId>)> =
            self.acks.iter().map(|(s, v)| (*s, v.clone())).collect();
        for (slot, voted) in pending {
            let Some(e) = self.log.get(&slot) else { continue };
            let (dot, cmd) = (e.dot, e.cmd.clone());
            self.counters.retransmits += 1;
            for p in 0..self.bp.config.r as u32 {
                let p = ProcessId(p);
                if p != me && !voted.contains(&p) {
                    out.push(Action::send(
                        p,
                        Msg::MAccept { slot, dot, cmd: cmd.clone() },
                    ));
                }
            }
        }
        // Committed slots: re-broadcast payload + commit until group-wide
        // pruning confirms everyone executed.
        for slot in self.retry_commits.clone() {
            let Some(e) = self.log.get(&slot) else {
                self.retry_commits.remove(&slot);
                continue;
            };
            let (dot, cmd) = (e.dot, e.cmd.clone());
            self.counters.retransmits += 1;
            for p in 0..self.bp.config.r as u32 {
                let p = ProcessId(p);
                if p != me {
                    out.push(Action::send(
                        p,
                        Msg::MAccept { slot, dot, cmd: cmd.clone() },
                    ));
                    out.push(Action::send(p, Msg::MCommit { slot }));
                }
            }
        }
    }
}

impl GcProcess for FPaxos {
    fn gc_track(&mut self) -> &mut GCTrack {
        &mut self.gc
    }

    /// Truncate the log prefix every replica executed.
    fn prune_executed(&mut self) {
        for (_origin, lo, hi) in self.gc.safe_to_prune() {
            for seq in lo..=hi {
                let slot = seq - 1;
                if let Some(e) = self.log.remove(&slot) {
                    self.counters.gc_pruned += 1;
                    self.ordered.remove(&e.dot);
                }
                self.acks.remove(&slot);
                self.retry_commits.remove(&slot);
            }
        }
    }
}

impl Process for FPaxos {
    type Msg = Msg;

    fn base(&self) -> &BaseProcess<Msg> {
        &self.bp
    }

    fn base_mut(&mut self) -> &mut BaseProcess<Msg> {
        &mut self.bp
    }

    fn dispatch(&mut self, from: ProcessId, msg: Msg, _time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        // Epoch fencing: drop messages from members the installed epoch
        // evicted (late by definition).
        if self.epochs.rejects(from) {
            return out;
        }
        match msg {
            Msg::MForward { dot, cmd } => {
                if self.is_leader() {
                    self.leader_order(dot, cmd, &mut out);
                }
            }
            Msg::MAccept { slot, dot, cmd } => {
                // Insert-if-absent: a retransmitted/duplicated accept must
                // never downgrade an already-committed entry. The ack is
                // re-sent either way (the original may have been lost).
                if slot >= self.exec_from && !self.log.contains_key(&slot) {
                    self.log.insert(slot, Slot { dot, cmd, committed: false });
                }
                out.push(Action::send(from, Msg::MAccepted { slot }));
            }
            Msg::MAccepted { slot } => {
                if !self.is_leader() {
                    return out;
                }
                let acks = match self.acks.get_mut(&slot) {
                    Some(a) => a,
                    None => return out, // already committed (acks dropped)
                };
                acks.insert(from);
                // Flexible Paxos phase-2 quorum: f+1 (leader included).
                if acks.len() == self.bp.config.slow_quorum_size() {
                    self.commit_slot(slot, &mut out);
                    for p in 0..self.bp.config.r as u32 {
                        if p != self.bp.id.0 {
                            out.push(Action::send(ProcessId(p), Msg::MCommit { slot }));
                        }
                    }
                }
            }
            Msg::MCommit { slot } => {
                self.commit_slot(slot, &mut out);
            }
            Msg::MGarbageCollect { executed } => self.handle_garbage_collect(from, &executed),
            Msg::MEpoch { epoch, evicted } => self.handle_epoch(
                from,
                epoch,
                evicted,
                |epoch, evicted| Msg::MEpoch { epoch, evicted },
                &mut out,
            ),
            Msg::MBatch { msgs } => {
                for m in msgs {
                    let actions = self.dispatch(from, m, _time);
                    out.extend(actions);
                }
            }
        }
        out
    }
}

impl EpochProcess for FPaxos {
    fn epoch_mgr(&mut self) -> &mut EpochManager {
        &mut self.epochs
    }

    fn on_evicted(&mut self, member: ProcessId) {
        self.gc.evict(member);
        self.counters.evictions += 1;
    }
}

impl Protocol for FPaxos {
    type Message = Msg;

    fn new(id: ProcessId, config: Config) -> Self {
        assert_eq!(config.shards, 1, "FPaxos baseline is full-replication only");
        let bp = BaseProcess::new(id, config);
        let gc = GCTrack::new(id, bp.group_procs.clone());
        let epochs =
            EpochManager::new(id, bp.group_procs.clone(), bp.config.epoch_fence_off);
        FPaxos {
            bp,
            log: BTreeMap::new(),
            next_slot: 0,
            acks: HashMap::new(),
            ordered: HashMap::new(),
            forwarded: HashMap::new(),
            retry_commits: BTreeSet::new(),
            exec_from: 0,
            gc,
            epochs,
            ticks: 0,
            counters: Counters::default(),
        }
    }

    fn name() -> &'static str {
        "fpaxos"
    }

    fn submit(&mut self, cmd: Command, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        let dot = self.bp.next_dot();
        out.push(Action::Submitted { dot });
        if self.is_leader() {
            self.leader_order(dot, cmd, &mut out);
        } else {
            if self.bp.config.retry_interval_ticks > 0 {
                self.forwarded.insert(dot, cmd.clone());
            }
            out.push(Action::send(self.leader(), Msg::MForward { dot, cmd }));
        }
        self.outbound(out, false, time)
    }

    fn handle(&mut self, from: ProcessId, msg: Msg, time: u64) -> Vec<Action<Msg>> {
        let out = self.dispatch(from, msg, time);
        self.outbound(out, false, time)
    }

    fn tick(&mut self, time: u64) -> Vec<Action<Msg>> {
        let mut out = Vec::new();
        if self.bp.crashed {
            return out;
        }
        self.ticks += 1;
        let ticks = self.ticks;
        self.gc_tick(ticks, |executed| Msg::MGarbageCollect { executed }, &mut out);
        self.epoch_tick(|epoch, evicted| Msg::MEpoch { epoch, evicted }, &mut out);
        self.retry_tick(&mut out);
        self.outbound(out, true, time)
    }

    /// No stability frontier: reads run through the leader's log like any
    /// other command (counted as slow reads). The ordering path serializes
    /// the read after the session's own writes, so the floor is moot.
    fn submit_read(&mut self, cmd: Command, _floor: u64, time: u64) -> Vec<Action<Msg>> {
        self.counters.slow_reads += 1;
        self.submit(cmd, time)
    }

    fn crash(&mut self) {
        self.bp.crashed = true;
    }

    /// Note: the fixed leader (process 0) is outside the eviction vote's
    /// reach — leader election is out of scope for this baseline, so
    /// nemesis scenarios crash followers only.
    fn suspect(&mut self, p: ProcessId) {
        self.epochs.suspect(p);
    }

    fn epoch_view(&self) -> Vec<(u64, Vec<ProcessId>)> {
        self.epochs.history().to_vec()
    }

    fn counters(&self) -> Counters {
        let mut c = self.counters;
        self.bp.batcher.record_stats(&mut c);
        c
    }

    fn msg_size(msg: &Msg) -> u64 {
        msg.wire_size()
    }

    fn footprint(&self) -> Footprint {
        Footprint {
            infos: self.log.len(),
            keys: 0,
            stalled: self.bp.stalled_len() + self.acks.len(),
            queued: self.bp.batcher.queued(),
            fragments: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::assert_psmr;
    use crate::sim::{run, SimOpts, Topology};
    use crate::workload::ConflictWorkload;

    fn opts(seed: u64) -> SimOpts {
        let mut o = SimOpts::new(Topology::ec2());
        o.clients_per_site = 4;
        o.warmup_us = 0;
        o.duration_us = 3_000_000;
        o.drain_us = 2_000_000;
        o.seed = seed;
        o.record_execution = true;
        o
    }

    #[test]
    fn fpaxos_satisfies_psmr() {
        let config = Config::new(5, 1);
        let result = run::<FPaxos, _>(config.clone(), opts(21), ConflictWorkload::new(0.02, 100));
        assert!(result.metrics.ops > 50);
        assert_psmr(&config, &result, true);
    }

    #[test]
    fn fpaxos_f2_satisfies_psmr() {
        let config = Config::new(5, 2);
        let result = run::<FPaxos, _>(config.clone(), opts(22), ConflictWorkload::new(1.0, 100));
        assert!(result.metrics.ops > 50);
        assert_psmr(&config, &result, true);
    }

    #[test]
    fn fpaxos_unfair_to_remote_sites() {
        // The leaderless fairness argument (Fig. 5): non-leader sites pay
        // the round trip to Ireland.
        let config = Config::new(5, 1);
        let result = run::<FPaxos, _>(config.clone(), opts(23), ConflictWorkload::new(0.02, 100));
        let leader_site = result.metrics.site_latency[&0].quantile(0.5);
        // Singapore (site 2) is 186 ms RTT from the leader.
        let remote_site = result.metrics.site_latency[&2].quantile(0.5);
        assert!(
            remote_site > 2 * leader_site,
            "leader {leader_site}µs vs remote {remote_site}µs"
        );
    }

    #[test]
    fn fpaxos_log_is_truncated_by_gc() {
        let config = Config::new(5, 1); // default gc_interval_ticks
        let mut o = opts(24);
        o.duration_us = 4_000_000;
        o.drain_us = 3_000_000;
        let result = run::<FPaxos, _>(config.clone(), o, ConflictWorkload::new(0.1, 100));
        assert!(result.metrics.ops > 100);
        assert!(result.metrics.counters.gc_pruned > 0, "log never truncated");
        for fp in &result.footprints {
            assert!(
                fp.infos < result.metrics.ops as usize / 2,
                "log retained {} slots after {} ops",
                fp.infos,
                result.metrics.ops
            );
        }
        assert_psmr(&config, &result, true);
    }
}
